//! Concurrent apps under contention: the paper's Workload 2 (KWS +
//! SimpleNet + WideNet) on four wearables, comparing Synergy's holistic
//! planning against independent state-of-the-art partitioning — including
//! the out-of-resource failure IndModel hits when each app plans alone.
//!
//! Run: `cargo run --release --example concurrent_apps`

use synergy::baselines::{IndModel, JointModel};
use synergy::estimator::{estimate_plan, LatencyModel};
use synergy::orchestrator::{Planner, Synergy};
use synergy::scheduler::{simulate, GroundTruth, SimConfig};
use synergy::workload::{fleet4, workload};

fn main() {
    let w = workload(2);
    let fleet = fleet4();
    let gt = GroundTruth::with_seed(7);

    for planner in [
        &Synergy::planner() as &dyn Planner,
        &IndModel::default(),
        &JointModel::default(),
    ] {
        print!("{:<12}", planner.name());
        match planner.plan(&w.pipelines, &fleet) {
            Ok(plan) => {
                let lm = LatencyModel::new(&fleet);
                let est = estimate_plan(&plan, &w.pipelines, &fleet, &lm);
                let rep = simulate(
                    &plan,
                    &w.pipelines,
                    &fleet,
                    &gt,
                    SimConfig { policy: planner.exec_policy(), ..Default::default() },
                );
                println!(
                    "estimated {:.2} inf/s → measured {:.2} inf/s, {:.0} ms latency, {:.2} W",
                    est.throughput,
                    rep.throughput,
                    rep.avg_latency * 1e3,
                    rep.power_w
                );
                for ep in &plan.plans {
                    println!("             {ep}");
                }
            }
            Err(e) => println!("{e}"),
        }
    }
}
