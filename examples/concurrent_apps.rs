//! Concurrent apps under contention: the paper's Workload 2 (KWS +
//! SimpleNet + WideNet) on four wearables, comparing Synergy's holistic
//! planning against independent state-of-the-art partitioning — including
//! the out-of-resource failure IndModel hits when each app plans alone.
//!
//! Every method runs behind the same `SynergyRuntime` facade: only the
//! planner plugged into the builder changes.
//!
//! Run: `cargo run --release --example concurrent_apps`

use synergy::api::{RunConfig, SynergyRuntime};
use synergy::baselines::{IndModel, JointModel};
use synergy::orchestrator::{Planner, Synergy};
use synergy::workload::{fleet4, workload};

fn main() {
    let planners: Vec<(&str, Box<dyn Planner + Send>)> = vec![
        ("Synergy", Box::new(Synergy::planner())),
        ("IndModel", Box::new(IndModel::default())),
        ("JointModel", Box::new(JointModel::default())),
    ];

    for (label, planner) in planners {
        print!("{label:<12}");
        let runtime = SynergyRuntime::builder()
            .fleet(fleet4())
            .planner_boxed(planner)
            .build();

        // Register the workload; a planner that cannot fit all three apps
        // errors on the registration that breaks the camel's back.
        let mut failed = false;
        for spec in workload(2).unwrap().pipelines {
            if let Err(e) = runtime.register(spec) {
                println!("{e}");
                failed = true;
                break;
            }
        }
        if failed {
            continue;
        }

        let dep = runtime.deployment().expect("workload registered");
        let rep = runtime
            .run(&RunConfig { seed: 7, ..RunConfig::default() })
            .expect("simulation runs");
        println!(
            "estimated {:.2} inf/s → measured {:.2} inf/s, {:.0} ms latency, {:.2} W",
            dep.estimate.throughput,
            rep.throughput,
            rep.avg_latency_s * 1e3,
            rep.power_w.unwrap_or(0.0),
        );
        for ep in &dep.plan.plans {
            println!("             {ep}");
        }
    }
}
