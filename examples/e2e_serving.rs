//! End-to-end serving driver (EXPERIMENTS.md §E2E): the full system on a
//! real workload — three concurrent apps planned by Synergy, deployed over
//! per-device worker threads, executing *real* AOT-compiled HLO chunks
//! through PJRT, with split-vs-full numerical verification and measured
//! wall-clock throughput/latency. The only difference from a simulated
//! session is the backend plugged into the `SynergyRuntime` builder.
//!
//! Requires `make artifacts` (Python runs once at build time; this binary
//! never touches Python) and the `pjrt` cargo feature.
//!
//! Run: `cargo run --release --features pjrt --example e2e_serving [-- --runs 16]`

use synergy::api::{PjrtBackend, RunConfig, SynergyRuntime};
use synergy::model::zoo::ModelName;
use synergy::orchestrator::Synergy;
use synergy::plan::EnumerateCfg;
use synergy::util::cli::Args;
use synergy::workload::{fleet4, pipeline};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["runs", "artifacts"]);
    let backend = PjrtBackend::load(args.opt("artifacts").unwrap_or("artifacts"))?;
    // Cross-check the Python-emitted manifest against the rust zoo.
    for m in ["ConvNet5", "KWS", "SimpleNet"] {
        backend.manifest().check_against_zoo(m)?;
    }

    // Restrict to 2-way splits: aot.py emits chunk artifacts for every
    // 2-way split of the demo models.
    let mut planner = Synergy::planner();
    planner.cfg.enumerate = EnumerateCfg { max_split_devices: 2 };
    let runtime = SynergyRuntime::builder()
        .fleet(fleet4())
        .planner(planner)
        .backend(backend)
        .build();

    for (i, m) in [ModelName::ConvNet5, ModelName::KWS, ModelName::SimpleNet]
        .iter()
        .enumerate()
    {
        runtime.register(pipeline(i, *m, i % 4, (i + 1) % 4))?;
    }
    let dep = runtime.deployment().expect("three apps registered");
    println!("deployment (holistic collaboration plan):");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }

    // Simulated on-body metrics (the MAX78000-class timing).
    let sim = runtime.simulate(24, 7).unwrap();
    println!(
        "simulated on-body: {:.2} inf/s, mean latency {:.0} ms, {:.2} W",
        sim.throughput,
        sim.avg_latency * 1e3,
        sim.power_w
    );

    // Real inference through PJRT: batched continuous runs across the
    // device worker threads.
    let report = runtime.run(&RunConfig {
        runs: args.opt_parse("runs", 8),
        ..RunConfig::default()
    })?;
    println!(
        "real serving: {} inferences in {:.2} s — {:.1} inf/s wall-clock (CPU testbed)",
        report.completions,
        report.wall_s.unwrap_or(0.0),
        report.throughput
    );
    for p in &report.per_app {
        println!(
            "  {:<10} {} runs, mean latency {:.1} ms, max |split − full| = {:.2e}",
            p.name,
            p.completions,
            p.mean_latency_s * 1e3,
            p.max_split_err.unwrap_or(0.0)
        );
    }
    anyhow::ensure!(
        report.verified == Some(true),
        "split execution diverged from full model"
    );
    println!("VERIFIED: chunked execution matches whole-model execution");
    Ok(())
}
