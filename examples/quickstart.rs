//! Quickstart: register two on-body AI apps through the `SynergyRuntime`
//! session API, watch the runtime orchestrate (events, not polling),
//! simulate the selected holistic collaboration plan, and shed a device.
//!
//! Run: `cargo run --release --example quickstart`

use synergy::api::{Interaction, Qos, RunConfig, Sensor, SynergyRuntime};
use synergy::device::DeviceId;
use synergy::model::zoo::ModelName;
use synergy::workload::fleet4;

fn main() -> anyhow::Result<()> {
    // Four wearables: earbud (d0), glasses (d1), watch (d2), ring (d3).
    let runtime = SynergyRuntime::new(fleet4());
    let events = runtime.subscribe();

    // App 1 — keyword spotting: any microphone → KWS → haptic alert.
    // No devices named: the runtime decides placement (§IV-B), and the
    // QoS hint tells it what "good enough" means.
    let kws = runtime
        .app("keyword-spotting")
        .source(Sensor::Microphone)
        .model(ModelName::KWS)
        .target(Interaction::Haptic)
        .qos(Qos { min_rate_hz: 2.0, ..Qos::default() })
        .register()?;

    // App 2 — attention alert: the glasses camera → SimpleNet → display.
    // The source pins a designated device instead of a capability.
    let alert = runtime
        .app("attention-alert")
        .source(DeviceId(1))
        .model(ModelName::SimpleNet)
        .target(Interaction::Display)
        .register()?;

    let dep = runtime.deployment().expect("two apps registered");
    println!("holistic collaboration plan:");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }
    println!(
        "planner estimate: {:.2} inf/s, round latency {:.0} ms, {:.2} W",
        dep.estimate.throughput,
        dep.estimate.round_latency * 1e3,
        dep.estimate.power_w,
    );

    // Execute on the simulated hardware (cycle-accurate device models) —
    // `run` is the same call that drives real PJRT inference when the
    // runtime is built with a PjrtBackend.
    let report = runtime.run(&RunConfig { runs: 32, seed: 7, ..RunConfig::default() })?;
    println!(
        "simulated 32 rounds: {:.2} inf/s, mean latency {:.0} ms, {:.2} W",
        report.throughput,
        report.avg_latency_s * 1e3,
        report.power_w.unwrap_or(0.0),
    );

    // The attention app goes to background: one replan covers KWS alone.
    alert.pause()?;
    println!(
        "paused attention-alert: active plan now has {} pipeline(s)",
        runtime.deployment().unwrap().plan.plans.len()
    );
    alert.resume()?;

    // The ring leaves the body — the runtime replans *incrementally*
    // (cached plan enumerations survive a suffix departure; the watch
    // still offers a haptic interface for KWS).
    runtime.device_left(DeviceId(3))?;
    let dep = runtime.deployment().unwrap();
    println!("after the ring left:");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }

    // The app-side view: placement, estimated rate/latency, QoS standing.
    let stats = kws.stats()?;
    println!(
        "kws stats: est {:.2} Hz, est latency {:.0} ms, qos_ok={}",
        stats.est_rate_hz.unwrap_or(0.0),
        stats.est_latency_s.unwrap_or(0.0) * 1e3,
        stats.qos_violation.is_none(),
    );

    // Everything above was also pushed on the event channel, stamped
    // with a sequence number (and, inside a live `Session`, the simulated
    // time — see the `live_session` example for scenario-driven runs).
    println!("events observed:");
    for stamped in events.try_iter() {
        println!("  #{:<3} {:?}", stamped.seq, stamped.event);
    }
    Ok(())
}
