//! Quickstart: register two on-body AI apps through the device-agnostic
//! interface, let the moderator orchestrate, and inspect/simulate the
//! selected holistic collaboration plan.
//!
//! Run: `cargo run --release --example quickstart`

use synergy::coordinator::Moderator;
use synergy::device::{DeviceId, InteractionKind, SensorKind};
use synergy::model::zoo::{model_by_name, ModelName};
use synergy::orchestrator::Synergy;
use synergy::pipeline::{PipelineSpec, SourceReq, TargetReq};
use synergy::workload::fleet4;

fn main() -> anyhow::Result<()> {
    // Four wearables: earbud (d0), glasses (d1), watch (d2), ring (d3).
    let fleet = fleet4();
    let mut moderator = Moderator::new(fleet, Synergy::planner());

    // App 1 — keyword spotting: any microphone → KWS → haptic alert.
    // No devices named: the runtime decides placement (§IV-B).
    moderator.register_app(PipelineSpec::new(
        0,
        "keyword-spotting",
        SourceReq::Sensor(SensorKind::Microphone),
        model_by_name(ModelName::KWS).clone(),
        TargetReq::Interaction(InteractionKind::Haptic),
    ))?;

    // App 2 — attention alert: the glasses camera → SimpleNet → display.
    // The source pins a designated device instead of a capability.
    moderator.register_app(PipelineSpec::new(
        1,
        "attention-alert",
        SourceReq::Device(DeviceId(1)),
        model_by_name(ModelName::SimpleNet).clone(),
        TargetReq::Interaction(InteractionKind::Display),
    ))?;

    let dep = moderator.deployment().unwrap();
    println!("holistic collaboration plan:");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }
    println!(
        "planner estimate: {:.2} inf/s, round latency {:.0} ms, {:.2} W",
        dep.estimate.throughput,
        dep.estimate.round_latency * 1e3,
        dep.estimate.power_w,
    );

    // Execute on the simulated hardware (cycle-accurate device models).
    let report = moderator.simulate(32, 7).unwrap();
    println!(
        "simulated 32 rounds: {:.2} inf/s, mean latency {:.0} ms, {:.2} W",
        report.throughput,
        report.avg_latency * 1e3,
        report.power_w,
    );

    // The ring leaves the body — the moderator re-orchestrates (the watch
    // still offers a haptic interface).
    moderator.set_fleet(synergy::workload::fleet_n(3))?;
    let dep = moderator.deployment().unwrap();
    println!("after shrinking to 3 devices:");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }
    Ok(())
}
