//! Streaming serving: the canned "jog" scenario executed on the live
//! `ServeEngine` — real worker threads (one per device computation unit),
//! a sensor-rate ticker per app, and plan switches that rebind the
//! workers *mid-stream* while in-flight rounds drain gracefully.
//!
//! The engine runs the deterministic virtual-time executor (the device
//! model doubling as a cost executor), so this works on a stock toolchain
//! with no artifacts, and the same scenario on the discrete-event
//! simulator (`cargo run --release --example live_session`) lands within
//! a few percent — the two execution paths are directly comparable.
//!
//! Run: `cargo run --release --example streaming_serve`

use synergy::api::{SessionCfg, SynergyRuntime};
use synergy::serving::ServeCfg;
use synergy::workload::scenario_jog4;

fn main() -> anyhow::Result<()> {
    let canned = scenario_jog4();
    println!(
        "serving scenario {:?}: {} devices, {} timed events over {:.1} s\n",
        canned.name,
        canned.fleet.len(),
        canned.scenario.events().len(),
        canned.scenario.duration(),
    );

    let runtime = SynergyRuntime::new(canned.fleet);
    let session = runtime
        .session_with(canned.scenario, SessionCfg { seed: 7, ..SessionCfg::default() })?
        .serve(ServeCfg::default())?;
    let report = session.finish()?;

    println!("plan-switch timeline (live worker rebinds):");
    for sw in &report.switches {
        println!(
            "  t={:5.2}s  {:<24} apps={}  {}  replan {:.2} ms  rebind {:.2} ms",
            sw.t,
            sw.cause,
            sw.apps,
            if sw.incremental {
                "incremental".to_string()
            } else {
                format!("enumerated {}", sw.enumerated_apps)
            },
            sw.replan_wall_s * 1e3,
            sw.rebind_wall_s * 1e3,
        );
    }

    println!("\ntime series:");
    for iv in &report.intervals {
        println!(
            "  [{:5.2}–{:5.2}s]  {:3} rounds  {:5.2} inf/s  {:5.1} ms latency",
            iv.start,
            iv.end,
            iv.completions,
            iv.throughput,
            iv.avg_latency_s * 1e3,
        );
        for app in &iv.per_app {
            println!(
                "      {:<20} {:3} rounds  {:5.2} inf/s  {:5.1} ms",
                app.name,
                app.completions,
                app.throughput,
                app.mean_latency_s * 1e3,
            );
        }
    }

    let served = report.served.expect("served session carries a summary");
    println!(
        "\nstreaming engine ({}): {} rounds admitted, {} completed, \
         {} rebinds over {} workers",
        served.executor,
        served.admitted_rounds,
        served.completed_rounds,
        served.rebinds,
        served.workers,
    );
    anyhow::ensure!(
        served.admitted_rounds == served.completed_rounds,
        "conservation violated: a plan switch dropped an in-flight round"
    );
    anyhow::ensure!(
        report.completions > 0,
        "served session completed no rounds"
    );
    println!(
        "session total: {} rounds in {:.1} s of engine time — {:.2} inf/s",
        report.completions, report.duration, report.throughput
    );
    Ok(())
}
