//! Live session: replay the canned "jog" scenario — apps arriving and
//! leaving, the watch dropping off mid-run and rejoining — through the
//! scenario-driven `Session` API, and print the time-series report.
//!
//! This is Synergy's dynamism story end to end: every churn event replans
//! *inside* the discrete-event timeline (incrementally, off the warm
//! enumeration cache) while the clock, in-flight work, and energy
//! accounting carry across the plan switches.
//!
//! Run: `cargo run --release --example live_session`

use synergy::api::{SessionCfg, SynergyRuntime};
use synergy::workload::scenario_jog4;

fn main() -> anyhow::Result<()> {
    let canned = scenario_jog4();
    println!(
        "scenario {:?}: {} devices, {} timed events over {:.1} s\n",
        canned.name,
        canned.fleet.len(),
        canned.scenario.events().len(),
        canned.scenario.duration(),
    );

    let runtime = SynergyRuntime::new(canned.fleet);
    let events = runtime.subscribe();
    let session = runtime.session_with(
        canned.scenario,
        SessionCfg { seed: 7, ..SessionCfg::default() },
    )?;
    let report = session.finish()?;

    println!("plan-switch timeline:");
    for sw in &report.switches {
        println!(
            "  t={:5.2}s  {:<24} apps={}  {}  replan {:.2} ms  est {:.2} inf/s",
            sw.t,
            sw.cause,
            sw.apps,
            if sw.incremental {
                "incremental".to_string()
            } else {
                format!("enumerated {}", sw.enumerated_apps)
            },
            sw.replan_wall_s * 1e3,
            sw.est_throughput,
        );
    }

    println!("\ntime series:");
    for iv in &report.intervals {
        println!(
            "  [{:5.2}–{:5.2}s]  {:3} rounds  {:5.2} inf/s  {:5.1} ms latency  {:.2} W",
            iv.start,
            iv.end,
            iv.completions,
            iv.throughput,
            iv.avg_latency_s * 1e3,
            iv.power_w,
        );
        for app in &iv.per_app {
            println!(
                "      {:<20} {:3} rounds  {:5.2} inf/s  {:5.1} ms",
                app.name,
                app.completions,
                app.throughput,
                app.mean_latency_s * 1e3,
            );
        }
    }

    if report.qos_spans.is_empty() {
        println!("\nno QoS violations");
    } else {
        println!("\nQoS-violation spans:");
        for span in &report.qos_spans {
            println!(
                "  {:<20} [{:.2}–{:.2}s]  {}",
                span.name, span.start, span.end, span.violation
            );
        }
    }

    println!(
        "\nsession total: {} rounds in {:.1} s — {:.2} inf/s, {:.1} J ({:.2} W)",
        report.completions, report.duration, report.throughput, report.energy_j, report.power_w
    );

    // Every switch was also pushed on the event channel, stamped with its
    // simulated time and a sequence number.
    let stamped = events.try_iter().count();
    println!("observed {stamped} stamped runtime events");
    Ok(())
}
