//! Large-model splitting: MobileNetV2 (821 KB) exceeds any single
//! MAX78000's 442 KB weight memory — Workload 4 in the paper. Synergy
//! splits it across the fleet; this example shows how the split adapts as
//! devices join, and what a heterogeneous upgrade (MAX78002) changes.
//!
//! Run: `cargo run --release --example large_model_split`

use synergy::estimator::{estimate_plan, LatencyModel};
use synergy::model::zoo::{model_by_name, ModelName};
use synergy::orchestrator::{PlanError, Planner, Synergy};
use synergy::workload::{fleet4_hetero, fleet_n, workload};

fn main() {
    let w = workload(4); // MobileNetV2, glasses → ring
    let model = model_by_name(ModelName::MobileNetV2);
    println!(
        "MobileNetV2: {} layers, {} weights — single MAX78000 holds 442 KB\n",
        model.num_layers(),
        synergy::util::fmt_bytes(model.weight_bytes(model.full())),
    );

    for n in 1..=5 {
        let fleet = fleet_n(n);
        // Keep the endpoints on devices that exist in the shrunken fleet.
        let pipelines = vec![synergy::workload::pipeline(
            0,
            ModelName::MobileNetV2,
            1 % n,
            3 % n.max(1),
        )];
        print!("{n} × MAX78000: ");
        match Synergy::planner().plan(&pipelines, &fleet) {
            Ok(plan) => {
                let lm = LatencyModel::new(&fleet);
                let est = estimate_plan(&plan, &pipelines, &fleet, &lm);
                println!("{} — {:.2} inf/s", plan.plans[0], est.throughput);
            }
            Err(PlanError::Oor { .. }) => println!("OOR (cannot hold the model)"),
            Err(e) => println!("{e}"),
        }
    }

    let fleet = fleet4_hetero();
    let plan = Synergy::planner()
        .plan(&w.pipelines, &fleet)
        .expect("hetero fleet must fit");
    let lm = LatencyModel::new(&fleet);
    let est = estimate_plan(&plan, &w.pipelines, &fleet, &lm);
    println!(
        "\nwith a MAX78002 in the fleet: {} — {:.2} inf/s",
        plan.plans[0], est.throughput
    );
}
