//! Large-model splitting: MobileNetV2 (821 KB) exceeds any single
//! MAX78000's 442 KB weight memory — Workload 4 in the paper. Synergy
//! splits it across the fleet; this example shows how the split adapts as
//! devices join, and what a heterogeneous upgrade (MAX78002) changes. OOR
//! is a typed planning error surfaced through `RuntimeError::Plan`.
//!
//! Run: `cargo run --release --example large_model_split`

use synergy::api::{RuntimeError, SynergyRuntime};
use synergy::device::DeviceId;
use synergy::model::zoo::{model_by_name, ModelName};
use synergy::orchestrator::PlanError;
use synergy::workload::{fleet4_hetero, fleet_n};

fn main() {
    let model = model_by_name(ModelName::MobileNetV2);
    println!(
        "MobileNetV2: {} layers, {} weights — single MAX78000 holds 442 KB\n",
        model.num_layers(),
        synergy::util::fmt_bytes(model.weight_bytes(model.full())),
    );

    for n in 1..=5 {
        let runtime = SynergyRuntime::new(fleet_n(n));
        print!("{n} × MAX78000: ");
        // Keep the endpoints on devices that exist in the shrunken fleet.
        let registered = runtime
            .app("mobilenet")
            .source(DeviceId(1 % n))
            .model(ModelName::MobileNetV2)
            .target(DeviceId(3 % n))
            .register();
        match registered {
            Ok(_) => {
                let dep = runtime.deployment().unwrap();
                println!("{} — {:.2} inf/s", dep.plan.plans[0], dep.estimate.throughput);
            }
            Err(RuntimeError::Plan(PlanError::Oor { .. })) => {
                println!("OOR (cannot hold the model)")
            }
            Err(e) => println!("{e}"),
        }
    }

    // Heterogeneous upgrade: the watch becomes a MAX78002 (Fig. 17).
    let runtime = SynergyRuntime::new(fleet4_hetero());
    runtime
        .app("mobilenet")
        .source(DeviceId(1))
        .model(ModelName::MobileNetV2)
        .target(DeviceId(3))
        .register()
        .expect("hetero fleet must fit");
    let dep = runtime.deployment().unwrap();
    println!(
        "\nwith a MAX78002 in the fleet: {} — {:.2} inf/s",
        dep.plan.plans[0], dep.estimate.throughput
    );
}
