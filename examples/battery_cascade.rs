//! Battery cascade: replay the canned `cascade8` scenario — four apps on
//! the first body band, batteries armed on the whole second band — and
//! watch event-driven depletion drive a departure cascade: each wearable
//! drains dry at an *exact* timeline instant (no poll quantization), its
//! departure replans the survivors, and the shifted load accelerates the
//! next depletion.
//!
//! The same scenario then runs on the streaming serve path: the drain
//! model is engine-independent, so the depletion instants match the
//! simulator bit-for-bit, and the served session reports real
//! power/energy from its workers' busy spans.
//!
//! Run: `cargo run --release --example battery_cascade`

use synergy::api::{SessionCfg, SessionReport, SynergyRuntime};
use synergy::orchestrator::Synergy;
use synergy::serving::ServeCfg;
use synergy::workload::scenario_cascade8;

fn session_report(serve: bool) -> anyhow::Result<SessionReport> {
    let canned = scenario_cascade8();
    let runtime = SynergyRuntime::builder()
        .fleet(canned.fleet)
        .planner(Synergy::planner_bounded(8))
        .build();
    let session = runtime.session_with(
        canned.scenario,
        SessionCfg { seed: 7, ..SessionCfg::default() },
    )?;
    let session = if serve {
        session.serve(ServeCfg::default())?
    } else {
        session
    };
    Ok(session.finish()?)
}

fn main() -> anyhow::Result<()> {
    let canned = scenario_cascade8();
    println!(
        "scenario {:?}: {} devices, {} batteries armed, {:.1} s horizon\n",
        canned.name,
        canned.fleet.len(),
        canned.scenario.batteries().len(),
        canned.scenario.duration(),
    );
    for &(d, cap, _) in canned.scenario.batteries() {
        println!("  {} starts with {cap:.1} J", canned.fleet.get(d).name);
    }

    let sim = session_report(false)?;
    println!("\nsimulated timeline ({} rounds, {:.2} J total):", sim.completions, sim.energy_j);
    for sw in &sim.switches {
        println!(
            "  t={:5.2}s  {:<24} apps={}  est {:.2} inf/s",
            sw.t, sw.cause, sw.apps, sw.est_throughput
        );
    }
    println!("\nper-interval power (load concentrating as the band drains):");
    for iv in &sim.intervals {
        println!(
            "  [{:5.2}–{:5.2}s]  {:3} rounds  {:5.2} inf/s  {:.2} W",
            iv.start, iv.end, iv.completions, iv.throughput, iv.power_w
        );
    }

    let served = session_report(true)?;
    println!("\nserved replay (streaming engine, live rebinds):");
    let depletions = |r: &SessionReport| -> Vec<(String, f64)> {
        r.switches
            .iter()
            .filter(|s| s.cause.starts_with("battery-depleted"))
            .map(|s| (s.cause.clone(), s.t))
            .collect()
    };
    let (ds, dv) = (depletions(&sim), depletions(&served));
    anyhow::ensure!(ds.len() == 4, "expected 4 depletions, got {ds:?}");
    anyhow::ensure!(ds == dv, "sim {ds:?} and serve {dv:?} depletion instants must match");
    for (cause, t) in &dv {
        println!("  t={t:5.2}s  {cause}  (matches the simulator exactly)");
    }
    let summary = served.served.expect("served summary");
    println!(
        "\nserved {} rounds (admitted {}, conserved: {}), {:.2} J vs {:.2} J simulated",
        summary.completed_rounds,
        summary.admitted_rounds,
        summary.admitted_rounds == summary.completed_rounds,
        served.energy_j,
        sim.energy_j,
    );
    anyhow::ensure!(
        summary.admitted_rounds == summary.completed_rounds,
        "battery-driven rebinds must not drop rounds"
    );
    anyhow::ensure!(
        served.energy_j > 0.0 && sim.energy_j > 0.0,
        "both paths must integrate energy"
    );
    println!("\nOK: event-driven battery cascade holds on both engines");
    Ok(())
}
