//! # Synergy — on-body AI via tiny AI accelerator collaboration
//!
//! A full-system reproduction of *Synergy: Towards On-Body AI via Tiny AI
//! Accelerator Collaboration on Wearables* (Gong et al., Nokia Bell Labs).
//!
//! Synergy is a runtime orchestration system for concurrent on-body AI apps
//! running across wearables equipped with tiny AI accelerators (MAX78000 /
//! MAX78002 class). Apps are written against a device-agnostic pipeline
//! interface (sensing → model → interaction); the runtime enumerates
//! execution plans (including layer-wise model splits across accelerators),
//! selects a *holistic collaboration plan* for all concurrent apps under
//! memory constraints, and executes it with an adaptive task parallelization
//! scheduler over per-computation-unit queues.
//!
//! ## Quickstart
//!
//! Everything goes through the [`api::SynergyRuntime`] session facade —
//! apps say *what* they need; the runtime decides *where* it runs:
//!
//! ```no_run
//! use synergy::api::{Interaction, Qos, RunConfig, Sensor, SynergyRuntime};
//! use synergy::model::zoo::ModelName;
//!
//! # fn main() -> Result<(), synergy::api::RuntimeError> {
//! let runtime = SynergyRuntime::new(synergy::workload::fleet4());
//! let events = runtime.subscribe();
//!
//! let kws = runtime
//!     .app("keyword-spotting")
//!     .source(Sensor::Microphone)
//!     .model(ModelName::KWS)
//!     .target(Interaction::Haptic)
//!     .qos(Qos { min_rate_hz: 5.0, ..Qos::default() })
//!     .register()?;
//!
//! let report = runtime.run(&RunConfig::default())?; // simulator backend
//! println!("{:.2} inf/s", report.throughput);
//!
//! runtime.device_left(synergy::device::DeviceId(3))?; // incremental replan
//! for event in events.try_iter() {
//!     println!("{event:?}"); // DeviceLeft, Replanned { incremental: true, .. }
//! }
//! kws.unregister()?;
//! # Ok(())
//! # }
//! ```
//!
//! Swap the backend to run the same deployment for real:
//! `SynergyRuntime::builder().fleet(...).backend(PjrtBackend::load("artifacts")?).build()`
//! (needs the `pjrt` cargo feature, which pulls the vendored `xla`
//! dependency tree).
//!
//! ## Module map
//!
//! In rough dependency order:
//!
//! - [`util`], [`testkit`] — in-repo substrates (JSON, PRNG, CLI, stats,
//!   property testing); only the `xla` crate's dependency tree is available.
//! - [`model`] — layer algebra and the paper's 8-model zoo (Table I).
//! - [`device`] — the hardware substrate: MAX78000/78002 specs, memory
//!   accounting, radio and power models.
//! - [`power`] — the unified energy & battery subsystem: per-device
//!   energy integration with presence banking ([`power::Accountant`],
//!   shared by the DES and the streaming engine), modeled per-device plan
//!   draws, and event-driven battery depletion
//!   ([`power::BatteryManager`]) with recharge and Peukert derating.
//! - [`pipeline`] — §IV-B device-agnostic pipeline specs (requirements,
//!   not device bindings).
//! - [`plan`] — §IV-C execution plans, split-skeleton/plan enumeration,
//!   holistic collaboration plans.
//! - [`estimator`] — §IV-E clock-cycle latency model and throughput
//!   estimation.
//! - [`scheduler`] — §IV-F adaptive task parallelization on a
//!   discrete-event simulator (also the experiments' hardware ground truth).
//! - [`orchestrator`] — §IV-D progressive search-space reduction,
//!   prioritization strategies, objectives, and the Oracle complete search.
//! - [`baselines`] — the paper's 7 comparison methods + phone offloading.
//! - [`runtime`] — PJRT bridge: load AOT-compiled HLO chunks and run real
//!   split inference (Python never on the request path).
//! - [`coordinator`] — the moderator compatibility shim.
//! - [`serving`] — the live streaming engine: worker threads per
//!   (device, unit), a [`serving::ChunkExecutor`] abstraction (virtual
//!   time on stock toolchains, PJRT behind the feature), and mid-stream
//!   plan rebinding with graceful drain.
//! - [`analysis`] — static verification: plan/scenario invariant checking
//!   ([`analysis::verify_deployment`] / [`analysis::verify_scenario`],
//!   wired into every plan-commit point and the `synergy check`
//!   subcommand) and seeded same-time race exploration
//!   ([`analysis::SameTimePolicy`]).
//! - [`api`] — **the public surface**: the [`api::SynergyRuntime`] session
//!   facade — fluent app registration with QoS hints, typed
//!   [`api::RuntimeError`]s, stamped [`api::RuntimeEvent`] subscriptions,
//!   incremental re-orchestration with per-app plan-enumeration caching,
//!   the [`api::ExecutionBackend`] abstraction unifying simulated and
//!   real inference, and scenario-driven live sessions
//!   ([`api::Scenario`] / [`api::Session`]) that replan mid-timeline and
//!   report time series.
//! - [`obs`] — observability: the flight recorder ([`obs::TraceSink`] /
//!   [`obs::FlightRecording`] stamped in simulated time), the
//!   [`obs::MetricsRegistry`] of deterministic counters/gauges/histograms,
//!   and Chrome/Perfetto trace + flat JSON exporters (`synergy trace`,
//!   [`api::Session::finish_traced`]).
//! - [`workload`] — Table I workloads and synthetic sensor sources, plus
//!   seeded whole-user sampling ([`workload::sample_user`]) for
//!   population runs.
//! - [`population`] — many bodies, one runtime: N sampled user sessions
//!   driven through one shared planning service
//!   ([`api::GlobalPlanCache`]) on a bounded worker pool, with
//!   deterministic aggregate distributions ([`population::PopulationReport`]).
//! - [`experiments`] — one harness per paper table/figure.

pub mod util;
pub mod testkit;
pub mod model;
pub mod device;
pub mod power;
pub mod pipeline;
pub mod plan;
pub mod estimator;
pub mod scheduler;
pub mod orchestrator;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod serving;
pub mod analysis;
pub mod api;
pub mod obs;
pub mod workload;
pub mod population;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
