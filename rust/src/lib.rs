//! # Synergy — on-body AI via tiny AI accelerator collaboration
//!
//! A full-system reproduction of *Synergy: Towards On-Body AI via Tiny AI
//! Accelerator Collaboration on Wearables* (Gong et al., Nokia Bell Labs).
//!
//! Synergy is a runtime orchestration system for concurrent on-body AI apps
//! running across wearables equipped with tiny AI accelerators (MAX78000 /
//! MAX78002 class). Apps are written against a device-agnostic pipeline
//! interface (sensing → model → interaction); the runtime enumerates
//! execution plans (including layer-wise model splits across accelerators),
//! selects a *holistic collaboration plan* for all concurrent apps under
//! memory constraints, and executes it with an adaptive task parallelization
//! scheduler over per-computation-unit queues.
//!
//! The crate is organized in rough dependency order:
//!
//! - [`util`], [`testkit`] — in-repo substrates (JSON, PRNG, CLI, stats,
//!   property testing); only the `xla` crate's dependency tree is available.
//! - [`model`] — layer algebra and the paper's 8-model zoo (Table I).
//! - [`device`] — the hardware substrate: MAX78000/78002 specs, memory
//!   accounting, radio and power models.
//! - [`pipeline`] — §IV-B device-agnostic programming interface.
//! - [`plan`] — §IV-C execution plans + holistic collaboration plans.
//! - [`estimator`] — §IV-E clock-cycle latency model and throughput
//!   estimation.
//! - [`scheduler`] — §IV-F adaptive task parallelization on a
//!   discrete-event simulator (also the experiments' hardware ground truth).
//! - [`orchestrator`] — §IV-D progressive search-space reduction,
//!   prioritization strategies, objectives, and the Oracle complete search.
//! - [`baselines`] — the paper's 7 comparison methods + phone offloading.
//! - [`runtime`] — PJRT bridge: load AOT-compiled HLO chunks and run real
//!   split inference (Python never on the request path).
//! - [`coordinator`] — the moderator: registration, orchestration,
//!   deployment, and the threaded serving loop.
//! - [`workload`] — Table I workloads and synthetic sensor sources.
//! - [`experiments`] — one harness per paper table/figure.

pub mod util;
pub mod testkit;
pub mod model;
pub mod device;
pub mod pipeline;
pub mod plan;
pub mod estimator;
pub mod scheduler;
pub mod orchestrator;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod workload;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
