//! Progressive search-space reduction (§IV-D): data-intensity-aware
//! execution-plan accumulation.
//!
//! Instead of scoring the full cross product `O(N_p1 × N_p2 × …)`, Synergy
//! orders pipelines (by descending data intensity), then selects one
//! execution plan per pipeline in that order: every candidate is evaluated
//! *on top of* the plans already selected (joint memory + holistic
//! estimate), reducing the search to `O(N_p1 + N_p2 + …)`.

use std::collections::BTreeMap;

use crate::analysis::chunks_unit_bound;
use crate::device::Fleet;
use crate::estimator::{EstimateAccum, LatencyModel};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::collab::MemoryLedger;
use crate::plan::{
    enumerate_plans_with, enumerate_skeletons_all, CollabPlan, ExecutionPlan, PlannerCfg,
    SearchMode, Skeleton,
};
use crate::scheduler::Policy;

use super::objective::Objective;
use super::priority::Priority;
use super::{PlanError, Planner};

/// The configurable progressive planner. [`Synergy`] is the default
/// configuration (data-intensity-descending, TPUT-max, ATP execution);
/// Fig. 9's prioritization alternatives and Table III's objectives are the
/// other configurations. `cfg.search` switches between the exhaustive
/// paper-scale search and the bounded (beam + branch-and-bound) search
/// that scales to 8–16-device fleets.
#[derive(Clone, Debug)]
pub struct ProgressivePlanner {
    pub priority: Priority,
    pub objective: Objective,
    pub cfg: PlannerCfg,
    /// Execution policy deployed with the selected plan.
    pub policy: Policy,
    /// Number of candidate plans scored in the last `plan` call (search
    /// effort; Fig. 9's 5 576× reduction claim) — interior mutability so
    /// `Planner::plan` can stay `&self`.
    pub candidates_scored: std::cell::Cell<u64>,
    /// Cumulative search-effort counters across the planner's lifetime
    /// (unlike [`Self::candidates_scored`], never reset per call) — the
    /// flight recorder's planner metrics.
    pub counters: PlannerCounters,
}

/// Cumulative bounded-search effort counters, `Cell`-backed so selection
/// can stay `&self`. Deterministic for a fixed call history: the bounded
/// search is single-threaded and its pruning decisions are pure. Not
/// part of the cross-user plan signature ([`ProgressivePlanner::
/// signature_token`] reads configuration only).
#[derive(Clone, Debug, Default)]
pub struct PlannerCounters {
    /// Skeleton candidates that survived admission pruning and entered
    /// endpoint assignment/scoring.
    pub skeletons_considered: std::cell::Cell<u64>,
    /// Skeletons dropped by QoS admission pruning before scoring.
    pub admission_pruned: std::cell::Cell<u64>,
    /// Times the optimistic-score bound ended a pipeline's candidate
    /// scan early (branch-and-bound cutoffs).
    pub bound_cutoffs: std::cell::Cell<u64>,
}

/// Synergy's default planner configuration.
pub struct Synergy;

impl Synergy {
    pub fn planner() -> ProgressivePlanner {
        ProgressivePlanner::new(Priority::DataIntensityDesc, Objective::TputMax)
    }

    /// Synergy with bounded plan search (beam + branch-and-bound) — the
    /// large-fleet configuration. Identical selection quality on
    /// paper-scale fleets (the search is exact below
    /// [`crate::plan::BOUNDED_EXACT_THRESHOLD`]), tractable far beyond
    /// them.
    pub fn planner_bounded(beam_width: usize) -> ProgressivePlanner {
        let mut p = ProgressivePlanner::new(Priority::DataIntensityDesc, Objective::TputMax);
        p.cfg = PlannerCfg::bounded(beam_width);
        p
    }

    /// Synergy with a non-default objective (Table III). Power-min
    /// deploys with sequential execution: overlapping tasks raises
    /// instantaneous draw, so a power-minimizing deployment also avoids
    /// the parallelization (the paper's Power-min rows show the matching
    /// throughput collapse).
    pub fn with_objective(objective: Objective) -> ProgressivePlanner {
        let mut p = ProgressivePlanner::new(Priority::DataIntensityDesc, objective);
        if objective == Objective::PowerMin {
            p.policy = Policy::Sequential;
        }
        p
    }
}

impl ProgressivePlanner {
    pub fn new(priority: Priority, objective: Objective) -> ProgressivePlanner {
        ProgressivePlanner {
            priority,
            objective,
            cfg: PlannerCfg::default(),
            policy: Policy::atp(),
            candidates_scored: std::cell::Cell::new(0),
            counters: PlannerCounters::default(),
        }
    }

    /// Append this planner's configuration token to a cross-user plan
    /// signature (see [`crate::api::GlobalPlanCache`]): every knob that
    /// can change what [`Self::select`] returns — priority, objective,
    /// search/enumeration config, and the execution policy deployed with
    /// the plan. `Debug` renderings are stable and (for floats) shortest
    /// round-trip, so equal tokens mean equal configurations.
    pub fn signature_token(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(
            out,
            "planner{{{:?}|{:?}|{:?}|{:?}}}",
            self.priority, self.objective, self.cfg, self.policy
        );
    }

    /// Run the progressive selection, returning plans in pipeline order.
    ///
    /// Greedy accumulation can dead-end: an early pipeline's best plan may
    /// exhaust memory a later (larger) pipeline needed. When the primary
    /// ordering hits OOR, we retry once with a first-fit-decreasing order
    /// (largest model first) — the classic packing heuristic — before
    /// reporting OOR. The paper's selection needs the same property to be
    /// "runnable" across all Fig. 9 combinations.
    pub fn select(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
    ) -> Result<CollabPlan, PlanError> {
        self.select_inner(pipelines, fleet, None)
    }

    /// [`Self::select`] with QoS admission pruning: `min_rates` is
    /// index-aligned with `pipelines` (Hz floors, 0 = no floor). Under
    /// bounded search, skeletons whose static bottleneck bound —
    /// `1 / max(busiest own unit, chain/2)` over the chunk tasks alone,
    /// an admissible cap on any completed plan's isolated rate
    /// ([`crate::analysis::chunks_unit_bound`]) — already violates the
    /// floor are dropped *before* endpoint assignment and scoring. The
    /// exhaustive search ignores the floors (its streaming enumeration is
    /// bit-parity-pinned against the replan cache); so does a pipeline
    /// whose every skeleton would be dropped — the planner then selects
    /// normally and `verify_deployment` reports the infeasibility with
    /// its typed error instead of an opaque planning failure.
    pub fn select_admitted(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
        min_rates: &[f64],
    ) -> Result<CollabPlan, PlanError> {
        self.select_inner(pipelines, fleet, Some(min_rates))
    }

    fn select_inner(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
        floors: Option<&[f64]>,
    ) -> Result<CollabPlan, PlanError> {
        self.candidates_scored.set(0);
        if matches!(self.cfg.search, SearchMode::Bounded { .. }) {
            // Bounded search: enumerate pruned candidate lists once (in
            // parallel across pipelines), then select over them — the OOR
            // retry reuses the enumeration.
            let skels = enumerate_skeletons_all(pipelines, fleet, self.cfg);
            let mut run = |priority: Priority| {
                let order = priority.order(pipelines);
                let mut scored = 0;
                let result = self.select_over_skeletons_admitted(
                    pipelines, fleet, &order, &skels, &mut scored, floors,
                );
                self.candidates_scored
                    .set(self.candidates_scored.get() + scored);
                result
            };
            return match run(self.priority) {
                Err(PlanError::Oor { .. }) if self.priority != Priority::ModelSizeDesc => {
                    run(Priority::ModelSizeDesc)
                }
                other => other,
            };
        }
        match self.select_with_order(pipelines, fleet, self.priority) {
            Err(PlanError::Oor { .. }) if self.priority != Priority::ModelSizeDesc => {
                self.select_with_order(pipelines, fleet, Priority::ModelSizeDesc)
            }
            other => other,
        }
    }

    /// Progressive selection over pre-enumerated skeleton candidates — the
    /// engine behind both bounded search and the incremental replan cache
    /// ([`crate::api`]).
    ///
    /// KEEP IN SYNC with `run_selection` below: same Unsatisfiable check,
    /// same ledger/accumulator updates, same objective scoring with
    /// strict-`>` tie-break. With exhaustive-mode skeleton lists (which
    /// preserve enumeration order) the selected plan is bit-identical to
    /// the streaming loop — `api::replan::tests::
    /// cached_selection_matches_streaming_selection` pins that parity.
    /// Under bounded search the candidate lists are sorted by ascending
    /// chain bound, which makes the optimistic-score early-`break` safe:
    /// every later skeleton has an even weaker bound.
    pub(crate) fn select_over_skeletons(
        &self,
        specs: &[PipelineSpec],
        fleet: &Fleet,
        order: &[usize],
        skels: &BTreeMap<PipelineId, Vec<Skeleton>>,
        scored: &mut u64,
    ) -> Result<CollabPlan, PlanError> {
        self.select_over_skeletons_admitted(specs, fleet, order, skels, scored, None)
    }

    /// [`Self::select_over_skeletons`] with optional QoS admission
    /// pruning (see [`Self::select_admitted`]). `floors = None` is the
    /// bit-identical legacy path.
    pub(crate) fn select_over_skeletons_admitted(
        &self,
        specs: &[PipelineSpec],
        fleet: &Fleet,
        order: &[usize],
        skels: &BTreeMap<PipelineId, Vec<Skeleton>>,
        scored: &mut u64,
        floors: Option<&[f64]>,
    ) -> Result<CollabPlan, PlanError> {
        let lm = LatencyModel::new(fleet);
        let mut ledger = MemoryLedger::default();
        let mut accum = EstimateAccum::new(fleet);
        let mut selected: Vec<Option<ExecutionPlan>> = vec![None; specs.len()];
        // Scratch buffer reused across all candidate evaluations.
        let mut unit_scratch = Vec::with_capacity(16);
        let bounded = matches!(self.cfg.search, SearchMode::Bounded { .. });

        for &i in order {
            let spec = &specs[i];
            let sources = spec.source_candidates(fleet);
            let targets = spec.target_candidates(fleet);
            if sources.is_empty() || targets.is_empty() {
                return Err(PlanError::Unsatisfiable {
                    pipeline: spec.name.clone(),
                });
            }
            let skeletons = skels
                .get(&spec.id)
                .expect("skeletons enumerated for every pipeline");
            // Admission pruning (bounded search only): a skeleton whose
            // static bottleneck bound cannot reach the pipeline's rate
            // floor is dropped before endpoint assignment. Sound because
            // `chunks_unit_bound ≤` any completed plan's busiest own
            // unit and `chain_bound ≤` its chain, so the cap only
            // over-estimates what the plan could deliver in isolation —
            // nothing feasible is ever dropped. Pruning preserves the
            // ascending-`chain_bound` order, keeping the optimistic
            // early-`break` safe. If every skeleton would be dropped,
            // fall back to the full list: the planner still commits its
            // best effort and the verifier reports the typed
            // infeasibility.
            let floor = floors.and_then(|f| f.get(i)).copied().unwrap_or(0.0);
            let admitted: Vec<&Skeleton> = if bounded && floor > 0.0 {
                let adm: Vec<&Skeleton> = skeletons
                    .iter()
                    .filter(|s| {
                        let cap = 1.0
                            / chunks_unit_bound(&s.chunks, &spec.model, &lm)
                                .max(s.chain_bound / 2.0)
                                .max(1e-12);
                        floor <= cap
                    })
                    .collect();
                if adm.is_empty() {
                    skeletons.iter().collect()
                } else {
                    adm
                }
            } else {
                skeletons.iter().collect()
            };
            let c = &self.counters;
            c.skeletons_considered
                .set(c.skeletons_considered.get() + admitted.len() as u64);
            c.admission_pruned
                .set(c.admission_pruned.get() + (skeletons.len() - admitted.len()) as u64);
            let mut cand = ExecutionPlan {
                pipeline: spec.id,
                source_dev: sources[0],
                target_dev: targets[0],
                chunks: Vec::new(),
            };
            let mut best: Option<(f64, ExecutionPlan)> = None;
            for &skel in &admitted {
                if bounded {
                    if let Some((best_score, _)) = &best {
                        if self.objective.score_upper_bound(&accum, skel.chain_bound)
                            <= *best_score
                        {
                            c.bound_cutoffs.set(c.bound_cutoffs.get() + 1);
                            break;
                        }
                    }
                }
                cand.chunks.clear();
                cand.chunks.extend_from_slice(&skel.chunks);
                // Joint-memory fit is endpoint-independent: check once per
                // skeleton instead of once per enumerated plan.
                if !ledger.fits(&cand, &spec.model, fleet) {
                    continue;
                }
                for &s in &sources {
                    for &t in &targets {
                        cand.source_dev = s;
                        cand.target_dev = t;
                        *scored += 1;
                        let est = accum.peek_fast(&cand, spec, fleet, &lm, &mut unit_scratch);
                        let score = self.objective.score(&est);
                        if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
                            best = Some((score, cand.clone()));
                        }
                    }
                }
            }
            let Some((_, chosen)) = best else {
                return Err(PlanError::Oor {
                    pipeline: spec.name.clone(),
                });
            };
            ledger.commit(&chosen, &spec.model);
            accum.add_plan(&chosen, spec, fleet, &lm);
            selected[i] = Some(chosen);
        }

        Ok(CollabPlan::new(
            selected.into_iter().map(Option::unwrap).collect(),
        ))
    }

    // KEEP IN SYNC with `select_over_skeletons` above: the incremental
    // re-orchestration path replays this exact selection over cached
    // skeletons and must stay bit-identical (same scoring, same strict-`>`
    // tie-break, same ledger/accumulator updates). The parity is pinned by
    // `api::replan::tests::cached_selection_matches_streaming_selection`.
    fn select_with_order(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
        priority: Priority,
    ) -> Result<CollabPlan, PlanError> {
        let (result, scored) = self.run_selection(pipelines, fleet, priority);
        // Accumulate on every exit path — an aborted attempt did real
        // scoring work, and `select` zeroes the counter per call, so the
        // OOR retry sums attempts instead of reading a stale total.
        self.candidates_scored
            .set(self.candidates_scored.get() + scored);
        result
    }

    fn run_selection(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
        priority: Priority,
    ) -> (Result<CollabPlan, PlanError>, u64) {
        let lm = LatencyModel::new(fleet);
        let order = priority.order(pipelines);
        let mut ledger = MemoryLedger::default();
        let mut accum = EstimateAccum::new(fleet);
        let mut selected: Vec<Option<ExecutionPlan>> = vec![None; pipelines.len()];
        let mut scored: u64 = 0;

        // Scratch buffer reused across all candidate evaluations.
        let mut scratch = Vec::with_capacity(16);
        for &i in &order {
            let spec = &pipelines[i];
            if spec.source_candidates(fleet).is_empty()
                || spec.target_candidates(fleet).is_empty()
            {
                let err = PlanError::Unsatisfiable {
                    pipeline: spec.name.clone(),
                };
                return (Err(err), scored);
            }
            // Stream candidates (no materialization) and score each with
            // the clone-free fast path — the orchestration hot loop.
            let mut best: Option<(f64, ExecutionPlan)> = None;
            enumerate_plans_with(spec, fleet, self.cfg.enumerate, |cand| {
                if !ledger.fits(cand, &spec.model, fleet) {
                    return;
                }
                scored += 1;
                let est = accum.peek_fast(cand, spec, fleet, &lm, &mut scratch);
                let score = self.objective.score(&est);
                if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                    best = Some((score, cand.clone()));
                }
            });
            let Some((_, chosen)) = best else {
                let err = PlanError::Oor {
                    pipeline: spec.name.clone(),
                };
                return (Err(err), scored);
            };
            ledger.commit(&chosen, &spec.model);
            accum.add_plan(&chosen, spec, fleet, &lm);
            selected[i] = Some(chosen);
        }

        let plan = CollabPlan::new(selected.into_iter().map(Option::unwrap).collect());
        (Ok(plan), scored)
    }
}

impl Planner for ProgressivePlanner {
    fn name(&self) -> &'static str {
        "Synergy"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        self.select(pipelines, fleet)
    }

    fn exec_policy(&self) -> Policy {
        self.policy
    }

    fn as_progressive(&self) -> Option<&ProgressivePlanner> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceId, DeviceKind};
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn pipes(models: &[ModelName]) -> Vec<PipelineSpec> {
        models
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect()
    }

    #[test]
    fn selects_runnable_plan_for_three_pipelines() {
        let f = fleet(2);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet, ModelName::UNet]);
        let plan = Synergy::planner().select(&ps, &f).unwrap();
        assert_eq!(plan.plans.len(), 3);
        // Output order matches pipeline registration order.
        for (i, ep) in plan.plans.iter().enumerate() {
            assert_eq!(ep.pipeline.0, i);
            ep.validate(&ps[i].model).unwrap();
        }
        plan.check_runnable(&ps, &f).unwrap();
    }

    #[test]
    fn oversubscription_yields_oor() {
        // Three MobileNetV2s (821 KB each) cannot fit two MAX78000s
        // (2 × 442 KB weight memory).
        let f = fleet(2);
        let ps = pipes(&[
            ModelName::MobileNetV2,
            ModelName::MobileNetV2,
            ModelName::MobileNetV2,
        ]);
        let err = Synergy::planner().select(&ps, &f).unwrap_err();
        assert!(matches!(err, PlanError::Oor { .. }), "{err:?}");
    }

    #[test]
    fn large_model_splits_across_devices() {
        // MobileNetV2 (821 KB weights, 6.2 KB bias) cannot fit one
        // MAX78000 — and its bias footprint needs at least four 2 KB bias
        // memories, which is exactly Workload 4's device setup (§VI-A).
        let f = fleet(4);
        let ps = pipes(&[ModelName::MobileNetV2]);
        let plan = Synergy::planner().select(&ps, &f).unwrap();
        assert!(plan.plans[0].chunks.len() >= 2);
        plan.check_runnable(&ps, &f).unwrap();
    }

    #[test]
    fn objective_changes_selection_score() {
        let f = fleet(2);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet]);
        let lm = LatencyModel::new(&f);
        let tput = Synergy::planner().select(&ps, &f).unwrap();
        let power = Synergy::with_objective(Objective::PowerMin)
            .select(&ps, &f)
            .unwrap();
        let e_tput = crate::estimator::estimate_plan(&tput, &ps, &f, &lm);
        let e_power = crate::estimator::estimate_plan(&power, &ps, &f, &lm);
        assert!(e_tput.throughput >= e_power.throughput - 1e-12);
        assert!(e_power.power_w <= e_tput.power_w + 1e-12);
    }

    #[test]
    fn counts_scored_candidates() {
        let f = fleet(2);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet]);
        let planner = Synergy::planner();
        planner.select(&ps, &f).unwrap();
        let scored = planner.candidates_scored.get();
        // Linear accumulation: roughly N_KWS + N_SimpleNet (≤, memory may
        // filter some), far below the cross product.
        let n_kws = crate::plan::paper_plan_count(2, 9);
        let n_simple = crate::plan::paper_plan_count(2, 14);
        assert!(scored > 0);
        assert!(scored <= n_kws + n_simple);
        // Far below the cross product even at just two pipelines.
        assert!((scored as f64) < (n_kws * n_simple) as f64 * 0.1);
    }

    #[test]
    fn bounded_matches_exhaustive_on_small_fleets() {
        // Below the exact-search threshold the bounded planner enumerates
        // the complete space, so selected quality is identical.
        let f = fleet(2);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet]);
        let lm = LatencyModel::new(&f);
        let ex = Synergy::planner().select(&ps, &f).unwrap();
        let bo = Synergy::planner_bounded(8).select(&ps, &f).unwrap();
        let te = crate::estimator::estimate_plan(&ex, &ps, &f, &lm).throughput;
        let tb = crate::estimator::estimate_plan(&bo, &ps, &f, &lm).throughput;
        assert!(
            (te - tb).abs() <= 1e-9 * te.max(1.0),
            "exhaustive {te} vs bounded {tb}"
        );
    }

    #[test]
    fn bounded_scales_where_exhaustive_space_explodes() {
        // 8 devices: KWS alone has >3M skeletons, UNet/SimpleNet far more.
        let f = fleet(8);
        let ps = pipes(&[ModelName::KWS, ModelName::UNet, ModelName::SimpleNet]);
        let planner = Synergy::planner_bounded(8);
        let plan = planner.select(&ps, &f).unwrap();
        plan.check_runnable(&ps, &f).unwrap();
        assert_eq!(plan.plans.len(), 3);
        let space: u64 = ps
            .iter()
            .map(|p| crate::plan::skeleton_space(8, p.model.num_layers(), usize::MAX))
            .fold(0, u64::saturating_add);
        let scored = planner.candidates_scored.get();
        assert!(
            scored < space / 100,
            "bounded search must prune: scored {scored} of {space}"
        );
    }

    #[test]
    fn admission_pruning_keeps_quality_and_degrades_gracefully() {
        let f = fleet(8);
        let ps = pipes(&[ModelName::KWS, ModelName::UNet, ModelName::SimpleNet]);
        let lm = LatencyModel::new(&f);
        let planner = Synergy::planner_bounded(8);
        let base = planner.select(&ps, &f).unwrap();
        let base_tput = crate::estimator::estimate_plan(&base, &ps, &f, &lm).throughput;
        // A feasible floor (half each pipeline's shared steady-state
        // rate) must not cost selection quality.
        let feasible = base_tput / ps.len() as f64 * 0.5;
        let pruned = planner
            .select_admitted(&ps, &f, &vec![feasible; ps.len()])
            .unwrap();
        let pruned_tput = crate::estimator::estimate_plan(&pruned, &ps, &f, &lm).throughput;
        assert!(
            pruned_tput >= base_tput * 0.99,
            "admission pruning cost quality: {pruned_tput} vs {base_tput}"
        );
        // An impossible floor drops every skeleton: the planner falls
        // back to the unpruned lists and still commits its best effort
        // (the verifier owns the typed rejection).
        let hopeless = planner.select_admitted(&ps, &f, &vec![1e12; ps.len()]).unwrap();
        assert_eq!(hopeless, base);
    }

    #[test]
    fn search_counters_accumulate_across_calls() {
        let f = fleet(8);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet]);
        let planner = Synergy::planner_bounded(8);
        planner.select(&ps, &f).unwrap();
        let considered_once = planner.counters.skeletons_considered.get();
        let cutoffs_once = planner.counters.bound_cutoffs.get();
        assert!(considered_once > 0);
        // No floors → nothing admission-pruned.
        assert_eq!(planner.counters.admission_pruned.get(), 0);

        // Cumulative (not reset per call, unlike candidates_scored).
        planner.select(&ps, &f).unwrap();
        assert_eq!(planner.counters.skeletons_considered.get(), 2 * considered_once);
        assert_eq!(planner.counters.bound_cutoffs.get(), 2 * cutoffs_once);

        // Under floors the two counters stay conservative: every skeleton
        // lands in exactly one bucket, so considered + pruned tiles the
        // candidate lists (one full pass per selection order attempted).
        let lm = LatencyModel::new(&f);
        let base = planner.select(&ps, &f).unwrap();
        let tput = crate::estimator::estimate_plan(&base, &ps, &f, &lm).throughput;
        let before_c = planner.counters.skeletons_considered.get();
        let before_p = planner.counters.admission_pruned.get();
        planner
            .select_admitted(&ps, &f, &vec![tput / ps.len() as f64 * 0.5; ps.len()])
            .unwrap();
        let dc = planner.counters.skeletons_considered.get() - before_c;
        let dp = planner.counters.admission_pruned.get() - before_p;
        assert!(dc > 0, "a committed plan scored at least one skeleton");
        assert_eq!(
            (dc + dp) % considered_once,
            0,
            "considered ({dc}) + pruned ({dp}) must tile the skeleton lists"
        );
    }

    #[test]
    fn designated_devices_are_respected() {
        let f = fleet(3);
        let mut ps = pipes(&[ModelName::ConvNet5]);
        ps[0].source = SourceReq::Device(DeviceId(1));
        ps[0].target = TargetReq::Device(DeviceId(2));
        let plan = Synergy::planner().select(&ps, &f).unwrap();
        assert_eq!(plan.plans[0].source_dev, DeviceId(1));
        assert_eq!(plan.plans[0].target_dev, DeviceId(2));
    }
}
