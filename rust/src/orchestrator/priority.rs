//! Pipeline prioritization strategies (§IV-D, Fig. 9).
//!
//! Progressive execution-plan accumulation selects one pipeline at a time;
//! *which* pipeline goes first determines how close the result gets to the
//! complete search. Synergy sorts by descending data intensity — pipelines
//! that move the most bytes get first pick of placements, because their
//! plans are the most sensitive to resource conflicts. Fig. 9 compares this
//! against ascending data intensity, model size (both directions), layer
//! count (both directions), and no prioritization.

use crate::pipeline::PipelineSpec;

/// Ordering strategy for progressive plan accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Priority {
    /// Synergy: descending data intensity.
    #[default]
    DataIntensityDesc,
    DataIntensityAsc,
    ModelSizeDesc,
    ModelSizeAsc,
    NumLayersDesc,
    NumLayersAsc,
    /// Registration order (no prioritization).
    Sequential,
}

impl Priority {
    pub const ALL: [Priority; 7] = [
        Priority::DataIntensityDesc,
        Priority::DataIntensityAsc,
        Priority::ModelSizeDesc,
        Priority::ModelSizeAsc,
        Priority::NumLayersDesc,
        Priority::NumLayersAsc,
        Priority::Sequential,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Priority::DataIntensityDesc => "Synergy (DataIntensityDesc)",
            Priority::DataIntensityAsc => "DataIntensityAsc",
            Priority::ModelSizeDesc => "ModelSizeDes",
            Priority::ModelSizeAsc => "ModelSizeAsc",
            Priority::NumLayersDesc => "NumLayersDes",
            Priority::NumLayersAsc => "NumLayersAsc",
            Priority::Sequential => "Sequential",
        }
    }

    /// Indices of `pipelines` in selection order.
    pub fn order(&self, pipelines: &[PipelineSpec]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..pipelines.len()).collect();
        let key = |i: usize| -> f64 {
            let p = &pipelines[i];
            match self {
                Priority::DataIntensityDesc => -p.data_intensity(),
                Priority::DataIntensityAsc => p.data_intensity(),
                Priority::ModelSizeDesc => -(p.model.size_bytes() as f64),
                Priority::ModelSizeAsc => p.model.size_bytes() as f64,
                Priority::NumLayersDesc => -(p.model.num_layers() as f64),
                Priority::NumLayersAsc => p.model.num_layers() as f64,
                Priority::Sequential => i as f64,
            }
        };
        idx.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap().then(a.cmp(&b)));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn pipes() -> Vec<PipelineSpec> {
        [ModelName::KWS, ModelName::UNet, ModelName::SimpleNet]
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect()
    }

    #[test]
    fn data_intensity_puts_unet_first() {
        let ps = pipes();
        let order = Priority::DataIntensityDesc.order(&ps);
        assert_eq!(ps[order[0]].name, "UNet");
        let asc = Priority::DataIntensityAsc.order(&ps);
        assert_eq!(ps[asc[2]].name, "UNet");
    }

    #[test]
    fn layer_count_ordering() {
        let ps = pipes();
        let order = Priority::NumLayersDesc.order(&ps);
        // UNet 19, SimpleNet 14, KWS 9.
        assert_eq!(ps[order[0]].name, "UNet");
        assert_eq!(ps[order[1]].name, "SimpleNet");
        assert_eq!(ps[order[2]].name, "KWS");
    }

    #[test]
    fn sequential_is_identity() {
        let ps = pipes();
        assert_eq!(Priority::Sequential.order(&ps), vec![0, 1, 2]);
    }

    #[test]
    fn orderings_are_permutations() {
        let ps = pipes();
        for pr in Priority::ALL {
            let mut o = pr.order(&ps);
            o.sort();
            assert_eq!(o, vec![0, 1, 2], "{pr:?}");
        }
    }
}
