//! Pipeline prioritization strategies (§IV-D, Fig. 9).
//!
//! Progressive execution-plan accumulation selects one pipeline at a time;
//! *which* pipeline goes first determines how close the result gets to the
//! complete search. Synergy sorts by descending data intensity — pipelines
//! that move the most bytes get first pick of placements, because their
//! plans are the most sensitive to resource conflicts. Fig. 9 compares this
//! against ascending data intensity, model size (both directions), layer
//! count (both directions), and no prioritization.

use crate::pipeline::PipelineSpec;

/// Ordering strategy for progressive plan accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Priority {
    /// Synergy: descending data intensity.
    #[default]
    DataIntensityDesc,
    DataIntensityAsc,
    ModelSizeDesc,
    ModelSizeAsc,
    NumLayersDesc,
    NumLayersAsc,
    /// Registration order (no prioritization).
    Sequential,
}

impl Priority {
    pub const ALL: [Priority; 7] = [
        Priority::DataIntensityDesc,
        Priority::DataIntensityAsc,
        Priority::ModelSizeDesc,
        Priority::ModelSizeAsc,
        Priority::NumLayersDesc,
        Priority::NumLayersAsc,
        Priority::Sequential,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Priority::DataIntensityDesc => "Synergy (DataIntensityDesc)",
            Priority::DataIntensityAsc => "DataIntensityAsc",
            Priority::ModelSizeDesc => "ModelSizeDes",
            Priority::ModelSizeAsc => "ModelSizeAsc",
            Priority::NumLayersDesc => "NumLayersDes",
            Priority::NumLayersAsc => "NumLayersAsc",
            Priority::Sequential => "Sequential",
        }
    }

    /// Indices of `pipelines` in selection order.
    pub fn order(&self, pipelines: &[PipelineSpec]) -> Vec<usize> {
        let key = |i: usize| -> f64 {
            let p = &pipelines[i];
            match self {
                Priority::DataIntensityDesc => -p.data_intensity(),
                Priority::DataIntensityAsc => p.data_intensity(),
                Priority::ModelSizeDesc => -(p.model.size_bytes() as f64),
                Priority::ModelSizeAsc => p.model.size_bytes() as f64,
                Priority::NumLayersDesc => -(p.model.num_layers() as f64),
                Priority::NumLayersAsc => p.model.num_layers() as f64,
                Priority::Sequential => i as f64,
            }
        };
        sort_indices_by_f64(pipelines.len(), key)
    }
}

/// NaN-safe stable index ordering by a float key: `f64::total_cmp` gives a
/// total order (NaN sorts after +∞ instead of panicking the way
/// `partial_cmp().unwrap()` did on any degenerate key), ties fall back to
/// index order.
fn sort_indices_by_f64(n: usize, key: impl Fn(usize) -> f64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn pipes() -> Vec<PipelineSpec> {
        [ModelName::KWS, ModelName::UNet, ModelName::SimpleNet]
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect()
    }

    #[test]
    fn data_intensity_puts_unet_first() {
        let ps = pipes();
        let order = Priority::DataIntensityDesc.order(&ps);
        assert_eq!(ps[order[0]].name, "UNet");
        let asc = Priority::DataIntensityAsc.order(&ps);
        assert_eq!(ps[asc[2]].name, "UNet");
    }

    #[test]
    fn layer_count_ordering() {
        let ps = pipes();
        let order = Priority::NumLayersDesc.order(&ps);
        // UNet 19, SimpleNet 14, KWS 9.
        assert_eq!(ps[order[0]].name, "UNet");
        assert_eq!(ps[order[1]].name, "SimpleNet");
        assert_eq!(ps[order[2]].name, "KWS");
    }

    #[test]
    fn sequential_is_identity() {
        let ps = pipes();
        assert_eq!(Priority::Sequential.order(&ps), vec![0, 1, 2]);
    }

    #[test]
    fn orderings_are_permutations() {
        let ps = pipes();
        for pr in Priority::ALL {
            let mut o = pr.order(&ps);
            o.sort();
            assert_eq!(o, vec![0, 1, 2], "{pr:?}");
        }
    }

    #[test]
    fn nan_keys_sort_without_panicking() {
        // Regression: the comparator was `partial_cmp(..).unwrap()`, which
        // panics the moment any priority key degenerates to NaN (e.g. an
        // inf/inf ratio from a zero-duration estimate). `total_cmp` must
        // order NaN deterministically after every finite key instead.
        let keys = [1.0, f64::NAN, 0.5, f64::INFINITY, f64::NAN];
        let order = sort_indices_by_f64(keys.len(), |i| keys[i]);
        assert_eq!(order, vec![2, 0, 3, 1, 4]);
        let mut perm = order;
        perm.sort();
        assert_eq!(perm, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn degenerate_models_still_order_deterministically() {
        // A pipeline whose layer is synthesized with zero output channels
        // produces zero-byte keys on every metric — ordering must stay a
        // stable permutation, never panic.
        use crate::model::layer::{Layer, LayerKind, Shape};
        use crate::model::ModelGraph;
        let degenerate = ModelGraph::new(
            "degenerate",
            Shape::new(1, 1, 1),
            vec![Layer {
                kind: LayerKind::Conv2d { k: 1 },
                pool: 1,
                cout: 0,
                residual: false,
                has_bias: false,
            }],
        );
        let mut ps = pipes();
        ps.push(PipelineSpec::new(
            3,
            "degenerate",
            SourceReq::Any,
            degenerate,
            TargetReq::Any,
        ));
        for pr in Priority::ALL {
            let mut o = pr.order(&ps);
            o.sort();
            assert_eq!(o, vec![0, 1, 2, 3], "{pr:?}");
        }
    }
}
