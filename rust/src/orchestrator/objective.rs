//! System-wide objectives (§III-C, §VI-C4): Synergy defaults to maximizing
//! the unified round's inference throughput, but the selection metric is
//! pluggable — Table III evaluates latency- and power-minimizing variants.

use crate::estimator::PlanEstimate;

/// What the orchestrator optimizes when ranking holistic plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Objective {
    /// Maximize system-wide inference throughput (the default).
    #[default]
    TputMax,
    /// Minimize end-to-end round latency.
    LatencyMin,
    /// Minimize average power consumption.
    PowerMin,
}

impl Objective {
    /// Score an estimate; larger is better for every objective.
    pub fn score(&self, est: &PlanEstimate) -> f64 {
        match self {
            Objective::TputMax => est.throughput,
            Objective::LatencyMin => -est.round_latency,
            // Power-min deployments execute sequentially.
            Objective::PowerMin => -est.power_sequential_w,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::TputMax => "TPUT-max",
            Objective::LatencyMin => "Latency-min",
            Objective::PowerMin => "Power-min",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(tput: f64, lat: f64, power: f64) -> PlanEstimate {
        PlanEstimate {
            chain_latency: vec![lat],
            critical_path: lat,
            bottleneck: lat,
            round_latency: lat,
            throughput: tput,
            throughput_sequential: tput,
            power_w: power,
            power_sequential_w: power,
            active_energy_j: 0.0,
        }
    }

    #[test]
    fn each_objective_prefers_its_metric() {
        let fast_hungry = est(10.0, 0.1, 2.0);
        let slow_frugal = est(1.0, 1.0, 0.5);
        assert!(Objective::TputMax.score(&fast_hungry) > Objective::TputMax.score(&slow_frugal));
        assert!(
            Objective::LatencyMin.score(&fast_hungry) > Objective::LatencyMin.score(&slow_frugal)
        );
        assert!(
            Objective::PowerMin.score(&slow_frugal) > Objective::PowerMin.score(&fast_hungry)
        );
    }
}
