//! System-wide objectives (§III-C, §VI-C4): Synergy defaults to maximizing
//! the unified round's inference throughput, but the selection metric is
//! pluggable — Table III evaluates latency- and power-minimizing variants.

use crate::estimator::{EstimateAccum, PlanEstimate};

/// What the orchestrator optimizes when ranking holistic plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Objective {
    /// Maximize system-wide inference throughput (the default).
    #[default]
    TputMax,
    /// Minimize end-to-end round latency.
    LatencyMin,
    /// Minimize average power consumption.
    PowerMin,
}

impl Objective {
    /// Score an estimate; larger is better for every objective.
    pub fn score(&self, est: &PlanEstimate) -> f64 {
        match self {
            Objective::TputMax => est.throughput,
            Objective::LatencyMin => -est.round_latency,
            // Power-min deployments execute sequentially.
            Objective::PowerMin => -est.power_sequential_w,
        }
    }

    /// Optimistic (admissible) score bound for any candidate whose chain
    /// latency is at least `chain_lb` seconds, evaluated on top of
    /// `accum`'s committed state: no such candidate's real [`Self::score`]
    /// can exceed this value, because additions to the accumulator are
    /// monotone — the period never drops below the committed bottleneck,
    /// half the committed critical path, or half the candidate's own chain
    /// (and the round latency never below any of those chains whole).
    ///
    /// The bounded planner sorts skeleton candidates by `chain_lb` and
    /// stops scoring a pipeline once this bound cannot beat the incumbent.
    /// Power-min admits no cheap monotone bound (average power can fall as
    /// chains lengthen), so it returns `+∞` — never prune.
    pub fn score_upper_bound(&self, accum: &EstimateAccum, chain_lb: f64) -> f64 {
        let n = (accum.num_pipelines() + 1) as f64;
        match self {
            Objective::TputMax => {
                let period_lb = accum
                    .bottleneck()
                    .max(accum.critical_path() / 2.0)
                    .max(chain_lb / 2.0)
                    .max(1e-12);
                n / period_lb
            }
            Objective::LatencyMin => {
                -accum.bottleneck().max(accum.critical_path()).max(chain_lb)
            }
            Objective::PowerMin => f64::INFINITY,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::TputMax => "TPUT-max",
            Objective::LatencyMin => "Latency-min",
            Objective::PowerMin => "Power-min",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(tput: f64, lat: f64, power: f64) -> PlanEstimate {
        PlanEstimate {
            chain_latency: vec![lat],
            critical_path: lat,
            bottleneck: lat,
            round_latency: lat,
            throughput: tput,
            throughput_sequential: tput,
            power_w: power,
            power_sequential_w: power,
            active_energy_j: 0.0,
        }
    }

    #[test]
    fn upper_bound_dominates_real_scores() {
        use crate::device::{Device, DeviceId, DeviceKind, Fleet};
        use crate::estimator::LatencyModel;
        use crate::model::zoo::{model_by_name, ModelName};
        use crate::pipeline::{PipelineSpec, SourceReq, TargetReq};
        use crate::plan::ExecutionPlan;
        let fleet = Fleet::new(
            (0..2)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        );
        let ps: Vec<PipelineSpec> = [ModelName::KWS, ModelName::SimpleNet]
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect();
        let lm = LatencyModel::new(&fleet);
        let mut accum = EstimateAccum::new(&fleet);
        let d0 = DeviceId(0);
        accum.add_plan(
            &ExecutionPlan::monolithic(&ps[0], d0, d0, d0),
            &ps[0],
            &fleet,
            &lm,
        );
        let mut scratch = Vec::new();
        for dev in 0..2 {
            let d = DeviceId(dev);
            let cand = ExecutionPlan::monolithic(&ps[1], d, d, d);
            let est = accum.peek_fast(&cand, &ps[1], &fleet, &lm, &mut scratch);
            for obj in [Objective::TputMax, Objective::LatencyMin, Objective::PowerMin] {
                let real = obj.score(&est);
                assert!(
                    real <= obj.score_upper_bound(&accum, 0.0) + 1e-12,
                    "{obj:?}: real {real} above bound"
                );
            }
        }
        // The bound tightens (never rises) as the chain lower bound grows.
        for obj in [Objective::TputMax, Objective::LatencyMin] {
            assert!(
                obj.score_upper_bound(&accum, 10.0) <= obj.score_upper_bound(&accum, 0.0),
                "{obj:?}"
            );
        }
    }

    #[test]
    fn each_objective_prefers_its_metric() {
        let fast_hungry = est(10.0, 0.1, 2.0);
        let slow_frugal = est(1.0, 1.0, 0.5);
        assert!(Objective::TputMax.score(&fast_hungry) > Objective::TputMax.score(&slow_frugal));
        assert!(
            Objective::LatencyMin.score(&fast_hungry) > Objective::LatencyMin.score(&slow_frugal)
        );
        assert!(
            Objective::PowerMin.score(&slow_frugal) > Objective::PowerMin.score(&fast_hungry)
        );
    }
}
