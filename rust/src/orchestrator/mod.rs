//! §IV-D / §III-C — holistic collaboration plan selection.
//!
//! [`progressive`] implements Synergy's data-intensity-aware execution-plan
//! accumulation (exponential → linear search); [`priority`] the pipeline
//! orderings compared in Fig. 9; [`oracle`] the complete cross-product
//! search; [`objective`] the selectable system-wide objectives of §VI-C4.
//! Every plan-selection method (Synergy and the baselines in
//! [`crate::baselines`]) implements the [`Planner`] trait.

pub mod objective;
pub mod priority;
pub mod progressive;
pub mod oracle;

pub use objective::Objective;
pub use priority::Priority;
pub use progressive::{PlannerCounters, ProgressivePlanner, Synergy};

use crate::device::Fleet;
use crate::pipeline::PipelineSpec;
use crate::plan::CollabPlan;
use crate::scheduler::Policy;

/// Why planning failed.
#[derive(Clone, Debug, thiserror::Error)]
pub enum PlanError {
    /// No runnable execution plan exists for a pipeline given the resources
    /// already committed — the out-of-resource (OOR) outcome.
    #[error("OOR: no runnable plan for pipeline {pipeline:?}")]
    Oor { pipeline: String },
    /// A pipeline has no source/target candidates in this fleet.
    #[error("no device satisfies the requirements of pipeline {pipeline:?}")]
    Unsatisfiable { pipeline: String },
}

/// A plan-selection method: Synergy or one of the baselines.
pub trait Planner {
    fn name(&self) -> &'static str;

    /// Select a holistic collaboration plan for the concurrent pipelines.
    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError>;

    /// The runtime execution policy this method deploys with. Synergy runs
    /// its adaptive task parallelization; methods adapted from single-shot
    /// partitioning literature execute sequentially (§VI-A2).
    fn exec_policy(&self) -> Policy {
        Policy::Sequential
    }

    /// Downcast hook for the progressive planner. When a planner exposes
    /// its progressive configuration here, [`crate::api::SynergyRuntime`]
    /// replans *incrementally* — reusing cached per-app plan enumerations
    /// across app and fleet changes instead of re-enumerating everything.
    /// Baselines return `None` and are replanned from scratch every time.
    fn as_progressive(&self) -> Option<&ProgressivePlanner> {
        None
    }
}
