//! The complete search ("Oracle" in Fig. 9): scores every combination of
//! execution plans across pipelines — `O(N_p1 × N_p2 × …)` — with joint
//! memory backtracking. Tractable only for small instances (Fig. 9 uses
//! three pipelines over two devices); exists to quantify how close the
//! progressive selection gets.

use crate::device::Fleet;
use crate::estimator::{EstimateAccum, LatencyModel};
use crate::pipeline::PipelineSpec;
use crate::plan::collab::MemoryLedger;
use crate::plan::{enumerate_plans, CollabPlan, EnumerateCfg, ExecutionPlan};

use super::objective::Objective;

/// Result of a complete search.
#[derive(Clone, Debug)]
pub struct OracleResult {
    pub plan: Option<CollabPlan>,
    pub best_score: f64,
    /// Complete combinations evaluated (runnable leaves of the search tree).
    pub combinations_evaluated: u64,
    /// Size of the unpruned cross-product space (Π N_p).
    pub space_size: u64,
}

/// Exhaustively search the cross product of execution plans.
pub fn oracle_search(
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    objective: Objective,
    cfg: EnumerateCfg,
) -> OracleResult {
    let lm = LatencyModel::new(fleet);
    let per_pipeline: Vec<Vec<ExecutionPlan>> = pipelines
        .iter()
        .map(|p| enumerate_plans(p, fleet, cfg))
        .collect();
    let space_size = per_pipeline
        .iter()
        .map(|v| v.len() as u64)
        .product::<u64>();

    let mut best: Option<(f64, Vec<ExecutionPlan>)> = None;
    let mut evaluated = 0u64;
    let mut ledger = MemoryLedger::default();
    let mut chosen: Vec<ExecutionPlan> = Vec::with_capacity(pipelines.len());

    // Depth-first over pipelines with memory pruning; the estimate
    // accumulator is rebuilt per leaf via incremental peek at each level.
    fn dfs(
        level: usize,
        pipelines: &[PipelineSpec],
        per_pipeline: &[Vec<ExecutionPlan>],
        fleet: &Fleet,
        lm: &LatencyModel,
        objective: Objective,
        ledger: &mut MemoryLedger,
        accum: &EstimateAccum,
        chosen: &mut Vec<ExecutionPlan>,
        best: &mut Option<(f64, Vec<ExecutionPlan>)>,
        evaluated: &mut u64,
    ) {
        if level == pipelines.len() {
            *evaluated += 1;
            let score = objective.score(&accum.finish());
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                *best = Some((score, chosen.clone()));
            }
            return;
        }
        let spec = &pipelines[level];
        for cand in &per_pipeline[level] {
            if !ledger.fits(cand, &spec.model, fleet) {
                continue;
            }
            let mut next = accum.clone();
            next.add_plan(cand, spec, fleet, lm);
            let saved = ledger.clone();
            ledger.commit(cand, &spec.model);
            chosen.push(cand.clone());
            dfs(
                level + 1, pipelines, per_pipeline, fleet, lm, objective, ledger, &next, chosen,
                best, evaluated,
            );
            chosen.pop();
            *ledger = saved;
        }
    }

    let accum = EstimateAccum::new(fleet);
    dfs(
        0, pipelines, &per_pipeline, fleet, &lm, objective, &mut ledger, &accum, &mut chosen,
        &mut best, &mut evaluated,
    );

    match best {
        Some((score, plans)) => OracleResult {
            plan: Some(CollabPlan::new(plans)),
            best_score: score,
            combinations_evaluated: evaluated,
            space_size,
        },
        None => OracleResult {
            plan: None,
            best_score: f64::NEG_INFINITY,
            combinations_evaluated: evaluated,
            space_size,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::orchestrator::{Priority, ProgressivePlanner};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn tiny(id: usize, layers: usize) -> PipelineSpec {
        let model = ModelGraph::new(
            format!("m{id}"),
            Shape::new(12, 12, 4),
            (0..layers)
                .map(|_| Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true })
                .collect(),
        );
        PipelineSpec::new(id, format!("p{id}"), SourceReq::Any, model, TargetReq::Any)
    }

    #[test]
    fn oracle_at_least_matches_progressive() {
        let f = fleet(2);
        let ps = vec![tiny(0, 3), tiny(1, 4)];
        let oracle = oracle_search(&ps, &f, Objective::TputMax, EnumerateCfg::default());
        let prog = ProgressivePlanner::new(Priority::DataIntensityDesc, Objective::TputMax)
            .select(&ps, &f)
            .unwrap();
        let lm = LatencyModel::new(&f);
        let prog_score =
            Objective::TputMax.score(&crate::estimator::estimate_plan(&prog, &ps, &f, &lm));
        assert!(oracle.best_score >= prog_score - 1e-9);
        // And progressive is within a sane band of Oracle on tiny cases.
        assert!(prog_score >= 0.5 * oracle.best_score);
    }

    #[test]
    fn space_size_is_cross_product() {
        let f = fleet(2);
        let ps = vec![tiny(0, 3), tiny(1, 4)];
        let oracle = oracle_search(&ps, &f, Objective::TputMax, EnumerateCfg::default());
        let n0 = crate::plan::paper_plan_count(2, 3);
        let n1 = crate::plan::paper_plan_count(2, 4);
        assert_eq!(oracle.space_size, n0 * n1);
        assert!(oracle.combinations_evaluated <= oracle.space_size);
        assert!(oracle.combinations_evaluated > 0);
    }

    #[test]
    fn oracle_reports_unsatisfiable_as_none() {
        // No accelerator devices → no plans at all.
        let f = Fleet::new(vec![Device::new(0, "mcu", DeviceKind::McuMax32650, vec![], vec![])]);
        let ps = vec![tiny(0, 2)];
        let res = oracle_search(&ps, &f, Objective::TputMax, EnumerateCfg::default());
        assert!(res.plan.is_none());
        assert_eq!(res.space_size, 0);
    }
}
