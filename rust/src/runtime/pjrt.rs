//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute many
//! times. Adapted from /opt/xla-example/src/bin/load_hlo.rs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// A compiled model/chunk executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run on one f32 tensor of the given shape; returns the flat output.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the result is a
    /// 1-tuple that we unwrap here.
    pub fn run(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple")?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The PJRT engine: one CPU client plus a path-keyed executable cache.
///
/// Compilation is the expensive step; execution is reentrant. The cache is
/// behind a mutex so the threaded serving loop can share one engine.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

impl Engine {
    /// Create a CPU-backed engine.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(hit) = self.cache.lock().unwrap().get(&path) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let arc = Arc::new(Executable { exe });
        self.cache
            .lock()
            .unwrap()
            .insert(path, arc.clone());
        Ok(arc)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// Tests that require artifacts live in rust/tests/integration_runtime.rs;
// this module is exercised there against real HLO files.
