//! Artifact manifest: the index of AOT-compiled HLO files plus per-layer
//! metadata, written by `python/compile/aot.py`. The rust zoo is the
//! planning ground truth; this manifest is cross-checked against it (see
//! `rust/tests/integration_runtime.rs`) so L2 and L3 cannot drift.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::model::Shape;
use crate::util::json::Json;

/// One split-chunk artifact.
#[derive(Clone, Debug)]
pub struct ChunkMeta {
    pub start: usize,
    pub end: usize,
    pub file: String,
    pub in_shape: Shape,
    pub out_shape: Shape,
}

/// Per-layer metadata as emitted by the Python build path.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub kind: String,
    pub weight_bytes: u64,
    pub bias_bytes: u64,
    pub out_shape: Shape,
    pub macs: u64,
    pub cycles_accel_p64: u64,
}

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub input: Shape,
    pub layers: Vec<LayerMeta>,
    pub full: String,
    pub chunks: Vec<ChunkMeta>,
}

impl ModelManifest {
    /// Find the chunk artifact covering layers [start, end).
    pub fn chunk(&self, start: usize, end: usize) -> Option<&ChunkMeta> {
        self.chunks
            .iter()
            .find(|c| c.start == start && c.end == end)
    }

    /// Whether every chunk of a plan's split exists as an artifact.
    pub fn supports_split(&self, boundaries: &[usize]) -> bool {
        if boundaries.is_empty() {
            return true; // monolithic: use `full`
        }
        let n = self.layers.len();
        let mut prev = 0;
        for &b in boundaries.iter().chain([&n]) {
            if self.chunk(prev, b).is_none() {
                return false;
            }
            prev = b;
        }
        true
    }
}

/// The full manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

fn shape3(j: &Json) -> anyhow::Result<Shape> {
    let a = j.as_arr().context("shape must be an array")?;
    if a.len() != 3 {
        bail!("shape must have 3 dims, got {}", a.len());
    }
    Ok(Shape::new(
        a[0].as_usize().context("h")?,
        a[1].as_usize().context("w")?,
        a[2].as_usize().context("c")?,
    ))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let obj = root.as_obj().context("manifest must be an object")?;
        let mut models = BTreeMap::new();
        for (name, entry) in obj {
            let input = shape3(entry.get("input").context("input")?)?;
            let layers = entry
                .get("layers")
                .and_then(Json::as_arr)
                .context("layers")?
                .iter()
                .map(|l| {
                    Ok(LayerMeta {
                        kind: l.get("kind").and_then(Json::as_str).context("kind")?.into(),
                        weight_bytes: l
                            .get("weight_bytes")
                            .and_then(Json::as_u64)
                            .context("weight_bytes")?,
                        bias_bytes: l
                            .get("bias_bytes")
                            .and_then(Json::as_u64)
                            .context("bias_bytes")?,
                        out_shape: shape3(l.get("out_shape").context("out_shape")?)?,
                        macs: l.get("macs").and_then(Json::as_u64).context("macs")?,
                        cycles_accel_p64: l
                            .get("cycles_accel_p64")
                            .and_then(Json::as_u64)
                            .context("cycles")?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let arts = entry.get("artifacts").context("artifacts")?;
            let full = arts
                .get("full")
                .and_then(Json::as_str)
                .context("artifacts.full")?
                .to_string();
            let chunks = arts
                .get("chunks")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|c| {
                    Ok(ChunkMeta {
                        start: c.get("start").and_then(Json::as_usize).context("start")?,
                        end: c.get("end").and_then(Json::as_usize).context("end")?,
                        file: c.get("file").and_then(Json::as_str).context("file")?.into(),
                        in_shape: shape3(c.get("in_shape").context("in_shape")?)?,
                        out_shape: shape3(c.get("out_shape").context("out_shape")?)?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    input,
                    layers,
                    full,
                    chunks,
                },
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model {name} not in manifest"))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Cross-check a manifest model against the rust zoo (sizes, cycles,
    /// shapes must agree layer by layer).
    pub fn check_against_zoo(&self, name: &str) -> anyhow::Result<()> {
        use crate::estimator::clock;
        let mm = self.model(name)?;
        let zoo_model = crate::model::zoo::zoo()
            .get(name)
            .with_context(|| format!("{name} not in rust zoo"))?;
        if mm.layers.len() != zoo_model.num_layers() {
            bail!(
                "{name}: manifest {} layers vs zoo {}",
                mm.layers.len(),
                zoo_model.num_layers()
            );
        }
        if mm.input != zoo_model.input {
            bail!("{name}: input {} vs zoo {}", mm.input, zoo_model.input);
        }
        for (l, meta) in mm.layers.iter().enumerate() {
            let layer = &zoo_model.layers[l];
            let input = zoo_model.in_shape(l);
            if meta.weight_bytes != layer.weight_bytes(input)
                || meta.bias_bytes != layer.bias_bytes(input)
                || meta.out_shape != zoo_model.out_shape(l)
                || meta.macs != layer.macs(input)
                || meta.cycles_accel_p64 != clock::layer_cycles_accel(layer, input, 64)
            {
                bail!("{name} layer {l}: manifest and zoo disagree");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "Toy": {
        "input": [4, 4, 1],
        "layers": [
          {"kind": "conv", "k": 3, "pool": 1, "cout": 2, "bias": true,
           "weight_bytes": 18, "bias_bytes": 2, "in_shape": [4,4,1],
           "out_shape": [4, 4, 2], "macs": 288, "cycles_accel_p64": 32}
        ],
        "artifacts": {"full": "Toy_full.hlo.txt",
                      "chunks": [{"start": 0, "end": 1, "file": "Toy_0_1.hlo.txt",
                                  "in_shape": [4,4,1], "out_shape": [4,4,2]}]},
        "split_points": []
      }
    }"#;

    fn write_sample() -> tempdir::TempDir {
        let dir = tempdir::TempDir::new();
        std::fs::write(dir.path().join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    // Minimal self-cleaning temp dir (no tempfile crate vendored).
    mod tempdir {
        pub struct TempDir(std::path::PathBuf);
        impl TempDir {
            pub fn new() -> TempDir {
                let p = std::env::temp_dir().join(format!(
                    "synergy-test-{}-{:?}",
                    std::process::id(),
                    std::thread::current().id()
                ));
                std::fs::create_dir_all(&p).unwrap();
                TempDir(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }

    #[test]
    fn parses_sample_manifest() {
        let dir = write_sample();
        let m = Manifest::load(dir.path()).unwrap();
        let toy = m.model("Toy").unwrap();
        assert_eq!(toy.input, Shape::new(4, 4, 1));
        assert_eq!(toy.layers.len(), 1);
        assert_eq!(toy.layers[0].weight_bytes, 18);
        assert_eq!(toy.full, "Toy_full.hlo.txt");
        assert!(toy.chunk(0, 1).is_some());
        assert!(toy.chunk(0, 2).is_none());
    }

    #[test]
    fn supports_split_logic() {
        let dir = write_sample();
        let m = Manifest::load(dir.path()).unwrap();
        let toy = m.model("Toy").unwrap();
        assert!(toy.supports_split(&[])); // monolithic
        assert!(!toy.supports_split(&[1])); // would need chunk (1,1)… n=1 edge
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
