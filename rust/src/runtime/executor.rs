//! Model execution over artifacts: whole models, chunk chains, and the
//! split==full verification that underwrites model splitting.

use anyhow::{bail, Context, Result};

use crate::model::Shape;

use super::artifacts::Manifest;
use super::pjrt::Engine;

/// High-level executor over a manifest + engine.
pub struct ModelExecutor<'e> {
    pub engine: &'e Engine,
    pub manifest: &'e Manifest,
}

fn flat(shape: Shape) -> Vec<usize> {
    vec![shape.h, shape.w, shape.c]
}

impl<'e> ModelExecutor<'e> {
    pub fn new(engine: &'e Engine, manifest: &'e Manifest) -> ModelExecutor<'e> {
        ModelExecutor { engine, manifest }
    }

    /// Run the full model on an input tensor (flat, HWC order).
    pub fn run_full(&self, model: &str, input: &[f32]) -> Result<Vec<f32>> {
        let mm = self.manifest.model(model)?;
        if input.len() as u64 != mm.input.elements() {
            bail!(
                "{model}: input has {} elems, expected {}",
                input.len(),
                mm.input.elements()
            );
        }
        let exe = self.engine.load(self.manifest.path(&mm.full))?;
        exe.run(input, &flat(mm.input))
    }

    /// Run a chain of layer-range chunks, passing activations through —
    /// exactly what distributed split execution does across devices, here
    /// composed locally for verification and local serving.
    pub fn run_chunks(&self, model: &str, boundaries: &[usize], input: &[f32]) -> Result<Vec<f32>> {
        let mm = self.manifest.model(model)?;
        let n = mm.layers.len();
        let mut ranges = Vec::new();
        let mut prev = 0;
        for &b in boundaries {
            ranges.push((prev, b));
            prev = b;
        }
        ranges.push((prev, n));

        let mut act = input.to_vec();
        let mut shape = mm.input;
        for &(a, b) in &ranges {
            if a == 0 && b == n {
                return self.run_full(model, input);
            }
            let chunk = mm.chunk(a, b).with_context(|| {
                format!("{model}: no artifact for chunk {a}:{b} — re-run `make artifacts`")
            })?;
            let exe = self.engine.load(self.manifest.path(&chunk.file))?;
            act = exe.run(&act, &flat(shape))?;
            shape = chunk.out_shape;
        }
        Ok(act)
    }

    /// Assert that chunked execution equals full execution (float tol).
    /// Returns the maximum absolute error.
    pub fn verify_split(&self, model: &str, boundaries: &[usize], input: &[f32]) -> Result<f64> {
        let full = self.run_full(model, input)?;
        let split = self.run_chunks(model, boundaries, input)?;
        if full.len() != split.len() {
            bail!(
                "{model}: output length mismatch {} vs {}",
                full.len(),
                split.len()
            );
        }
        let max_err = full
            .iter()
            .zip(&split)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        let scale = full.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-6) as f64;
        if max_err > 1e-3 * scale + 1e-4 {
            bail!("{model} split {boundaries:?}: max err {max_err} (scale {scale})");
        }
        Ok(max_err)
    }

    /// Deterministic synthetic input for a model (seeded; the same
    /// generator the examples use).
    pub fn synth_input(&self, model: &str, seed: u64) -> Result<Vec<f32>> {
        let mm = self.manifest.model(model)?;
        let mut rng = crate::util::rng::Rng::new(seed);
        Ok((0..mm.input.elements())
            .map(|_| rng.next_gaussian() as f32)
            .collect())
    }
}
