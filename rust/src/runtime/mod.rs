//! The PJRT runtime bridge: load AOT-compiled HLO artifacts and run real
//! model inference from the rust request path (Python never runs here).
//!
//! [`artifacts`] indexes `artifacts/manifest.json` (emitted by
//! `python/compile/aot.py`); [`pjrt`] wraps the `xla` crate
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → compile →
//! execute) with an executable cache; [`executor`] runs whole models or
//! chunk chains and verifies that split execution composes to the full
//! model — the property that makes layer-wise splitting semantically free.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
pub mod service;

pub use artifacts::{ChunkMeta, Manifest, ModelManifest};
#[cfg(feature = "pjrt")]
pub use executor::ModelExecutor;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;
#[cfg(feature = "pjrt")]
pub use service::{InferHandle, InferenceService};
