//! Inference service: a dedicated thread owning the PJRT engine.
//!
//! The `xla` crate's handles wrap raw PJRT pointers and are not `Send`, and
//! the CPU PJRT plugin is a single host device regardless — so all real
//! inference funnels through one service thread, and the coordinator's
//! per-device threads talk to it over channels. (On physical hardware each
//! wearable owns its accelerator; here the *simulated* clock model provides
//! per-device timing while this service provides the actual numerics.)

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::artifacts::Manifest;
use super::pjrt::Engine;

enum Request {
    Run {
        file: PathBuf,
        input: Vec<f32>,
        shape: Vec<usize>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Preload {
        files: Vec<PathBuf>,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable handle to the inference service.
#[derive(Clone)]
pub struct InferHandle {
    tx: mpsc::Sender<Request>,
}

impl InferHandle {
    /// Execute one artifact synchronously.
    pub fn run(&self, file: PathBuf, input: Vec<f32>, shape: Vec<usize>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Run { file, input, shape, reply })
            .map_err(|_| anyhow!("inference service is down"))?;
        rx.recv().map_err(|_| anyhow!("inference service dropped reply"))?
    }

    /// Compile a set of artifacts ahead of serving (the deployment step).
    pub fn preload(&self, files: Vec<PathBuf>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Preload { files, reply })
            .map_err(|_| anyhow!("inference service is down"))?;
        rx.recv().map_err(|_| anyhow!("inference service dropped reply"))?
    }
}

/// The running service; dropping it shuts the thread down.
pub struct InferenceService {
    handle: InferHandle,
    join: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Spawn the service thread (creates the PJRT client inside it).
    pub fn start() -> Result<InferenceService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-inference".into())
            .spawn(move || {
                let engine = match Engine::cpu() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Run { file, input, shape, reply } => {
                            let res = engine
                                .load(&file)
                                .and_then(|exe| exe.run(&input, &shape));
                            let _ = reply.send(res);
                        }
                        Request::Preload { files, reply } => {
                            let res = files.iter().try_for_each(|f| {
                                engine.load(f).map(|_| ())
                            });
                            let _ = reply.send(res);
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("inference service died during startup"))??;
        Ok(InferenceService {
            handle: InferHandle { tx },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> InferHandle {
        self.handle.clone()
    }

    /// Convenience: absolute artifact path for a manifest file name.
    pub fn artifact_path(manifest: &Manifest, file: &str) -> PathBuf {
        manifest.path(file)
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
