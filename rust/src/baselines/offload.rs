//! Smartphone offloading (§II-B): every model runs on the phone; raw
//! sensor data streams from the source wearable to the phone and results
//! stream back to the target wearable. The phone's compute is effectively
//! free — the wearables' UART-bridged radios are the bottleneck, which is
//! precisely the paper's argument for accelerator collaboration (Fig. 3/4).

use crate::device::{DeviceId, DeviceKind, Fleet};
use crate::pipeline::PipelineSpec;
use crate::plan::{Assignment, CollabPlan, ExecutionPlan};
use crate::scheduler::Policy;

use crate::orchestrator::{PlanError, Planner};

/// The phone-offloading comparator. The fleet must contain a
/// [`DeviceKind::Phone`] device.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhoneOffload;

impl PhoneOffload {
    fn phone_id(fleet: &Fleet) -> Option<DeviceId> {
        fleet
            .devices
            .iter()
            .find(|d| d.spec.kind == DeviceKind::Phone)
            .map(|d| d.id)
    }

    /// First source/target candidate that is a wearable (sensing and
    /// interaction happen on the body, not on the phone).
    fn wearable_endpoint(cands: &[DeviceId], _fleet: &Fleet, phone: DeviceId) -> Option<DeviceId> {
        cands.iter().copied().find(|&d| d != phone).or_else(|| {
            // Degenerate fleets (phone only) fall back to the phone itself.
            cands.first().copied()
        })
    }
}

impl Planner for PhoneOffload {
    fn name(&self) -> &'static str {
        "PhoneOffload"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        let phone = Self::phone_id(fleet).ok_or_else(|| PlanError::Unsatisfiable {
            pipeline: "no phone in fleet".to_string(),
        })?;
        let mut out = Vec::with_capacity(pipelines.len());
        for spec in pipelines {
            let sources = spec.source_candidates(fleet);
            let targets = spec.target_candidates(fleet);
            let source = Self::wearable_endpoint(&sources, fleet, phone).ok_or_else(|| {
                PlanError::Unsatisfiable { pipeline: spec.name.clone() }
            })?;
            let target = Self::wearable_endpoint(&targets, fleet, phone).ok_or_else(|| {
                PlanError::Unsatisfiable { pipeline: spec.name.clone() }
            })?;
            out.push(ExecutionPlan {
                pipeline: spec.id,
                source_dev: source,
                target_dev: target,
                chunks: vec![Assignment { device: phone, range: spec.model.full() }],
            });
        }
        Ok(CollabPlan::new(out))
    }

    /// Offloading gets the benefit of the doubt: fully parallel execution
    /// on the phone side. The radio bottleneck dominates regardless.
    fn exec_policy(&self) -> Policy {
        Policy::atp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet_with_phone() -> Fleet {
        Fleet::new(vec![
            Device::new(0, "earbud", DeviceKind::Max78000, vec![], vec![]),
            Device::new(1, "ring", DeviceKind::Max78000, vec![], vec![]),
            Device::new(2, "phone", DeviceKind::Phone, vec![], vec![]),
        ])
    }

    #[test]
    fn all_inference_lands_on_the_phone() {
        let f = fleet_with_phone();
        let ps = vec![PipelineSpec::new(
            0,
            "kws",
            SourceReq::Device(DeviceId(0)),
            model_by_name(ModelName::KWS).clone(),
            TargetReq::Device(DeviceId(1)),
        )];
        let plan = PhoneOffload.plan(&ps, &f).unwrap();
        let ep = &plan.plans[0];
        assert_eq!(ep.chunks.len(), 1);
        assert_eq!(ep.chunks[0].device, DeviceId(2));
        assert_eq!(ep.source_dev, DeviceId(0));
        assert_eq!(ep.target_dev, DeviceId(1));
        // Raw input + result both cross the radio.
        assert_eq!(
            ep.radio_bytes(&ps[0].model),
            ps[0].model.in_bytes() + ps[0].model.output().bytes()
        );
    }

    #[test]
    fn endpoints_avoid_the_phone_under_any() {
        let f = fleet_with_phone();
        let ps = vec![PipelineSpec::new(
            0,
            "x",
            SourceReq::Any,
            model_by_name(ModelName::ConvNet5).clone(),
            TargetReq::Any,
        )];
        let plan = PhoneOffload.plan(&ps, &f).unwrap();
        assert_ne!(plan.plans[0].source_dev, DeviceId(2));
        assert_ne!(plan.plans[0].target_dev, DeviceId(2));
    }

    #[test]
    fn no_phone_is_unsatisfiable() {
        let f = Fleet::new(vec![Device::new(0, "d", DeviceKind::Max78000, vec![], vec![])]);
        let ps = vec![PipelineSpec::new(
            0,
            "x",
            SourceReq::Any,
            model_by_name(ModelName::ConvNet5).clone(),
            TargetReq::Any,
        )];
        assert!(matches!(
            PhoneOffload.plan(&ps, &f),
            Err(PlanError::Unsatisfiable { .. })
        ));
    }
}
