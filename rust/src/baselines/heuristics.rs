//! Heuristic baselines (§VI-A2): MinDev, MaxDev, PriMinDev, PriMaxDev.
//!
//! All four account for the resource usage of previously selected plans
//! (like Synergy) but rank candidates with fixed structural heuristics
//! instead of throughput estimation:
//!
//! - **MinDev** — fewest inference devices (avoid splitting), rationale:
//!   fewer devices ⇒ less communication.
//! - **MaxDev** — split across *all* available accelerators, rationale:
//!   more devices ⇒ more parallelism.
//! - **PriMinDev** — MinDev plus smarter tie-breaking: minimize
//!   intermediate output sizes and prefer MAX78002 over MAX78000.
//! - **PriMaxDev** — the same prioritization over all-device splits.

use crate::device::Fleet;
use crate::pipeline::PipelineSpec;
use crate::plan::collab::MemoryLedger;
use crate::plan::{enumerate_plans, CollabPlan, EnumerateCfg, ExecutionPlan};

use super::weight_share_on_78002;
use crate::orchestrator::{PlanError, Planner};

/// Ranking rule shared by the four heuristics. Lower key wins.
#[derive(Clone, Copy, Debug)]
enum Rank {
    MinDev,
    MaxDev,
    PriMinDev,
    PriMaxDev,
}

impl Rank {
    fn key(&self, ep: &ExecutionPlan, spec: &PipelineSpec, fleet: &Fleet) -> (f64, f64, f64) {
        let ndev = ep.num_infer_devices() as f64;
        let radio = ep.radio_bytes(&spec.model) as f64;
        let share02 = weight_share_on_78002(ep, spec, fleet);
        match self {
            Rank::MinDev => (ndev, radio, 0.0),
            Rank::MaxDev => (-ndev, radio, 0.0),
            Rank::PriMinDev => (ndev, -share02, radio),
            Rank::PriMaxDev => (-ndev, -share02, radio),
        }
    }
}

fn plan_with_rank(
    rank: Rank,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
) -> Result<CollabPlan, PlanError> {
    let mut ledger = MemoryLedger::default();
    let mut out = Vec::with_capacity(pipelines.len());
    for spec in pipelines {
        if spec.source_candidates(fleet).is_empty() || spec.target_candidates(fleet).is_empty() {
            return Err(PlanError::Unsatisfiable { pipeline: spec.name.clone() });
        }
        let candidates = enumerate_plans(spec, fleet, EnumerateCfg::default());
        let chosen = candidates
            .into_iter()
            .filter(|c| ledger.fits(c, &spec.model, fleet))
            .min_by(|a, b| {
                let (a0, a1, a2) = rank.key(a, spec, fleet);
                let (b0, b1, b2) = rank.key(b, spec, fleet);
                a0.total_cmp(&b0)
                    .then_with(|| a1.total_cmp(&b1))
                    .then_with(|| a2.total_cmp(&b2))
            })
            .ok_or_else(|| PlanError::Oor { pipeline: spec.name.clone() })?;
        ledger.commit(&chosen, &spec.model);
        out.push(chosen);
    }
    Ok(CollabPlan::new(out))
}

macro_rules! heuristic_planner {
    ($name:ident, $rank:expr, $label:literal) => {
        #[doc = concat!("The ", $label, " baseline.")]
        #[derive(Clone, Copy, Debug, Default)]
        pub struct $name;

        impl Planner for $name {
            fn name(&self) -> &'static str {
                $label
            }

            fn plan(
                &self,
                pipelines: &[PipelineSpec],
                fleet: &Fleet,
            ) -> Result<CollabPlan, PlanError> {
                plan_with_rank($rank, pipelines, fleet)
            }
        }
    };
}

heuristic_planner!(MinDev, Rank::MinDev, "MinDev");
heuristic_planner!(MaxDev, Rank::MaxDev, "MaxDev");
heuristic_planner!(PriMinDev, Rank::PriMinDev, "PriMinDev");
heuristic_planner!(PriMaxDev, Rank::PriMaxDev, "PriMaxDev");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet(kinds: &[DeviceKind]) -> Fleet {
        Fleet::new(
            kinds
                .iter()
                .enumerate()
                .map(|(i, &k)| Device::new(i, format!("d{i}"), k, vec![], vec![]))
                .collect(),
        )
    }

    fn pipes(models: &[ModelName]) -> Vec<PipelineSpec> {
        models
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(i, m.as_str(), SourceReq::Any, model_by_name(m).clone(), TargetReq::Any)
            })
            .collect()
    }

    #[test]
    fn mindev_avoids_splitting() {
        let f = fleet(&[DeviceKind::Max78000; 3]);
        let ps = pipes(&[ModelName::KWS]);
        let plan = MinDev.plan(&ps, &f).unwrap();
        assert_eq!(plan.plans[0].chunks.len(), 1);
    }

    #[test]
    fn maxdev_splits_to_all_devices() {
        let f = fleet(&[DeviceKind::Max78000; 3]);
        let ps = pipes(&[ModelName::KWS]);
        let plan = MaxDev.plan(&ps, &f).unwrap();
        assert_eq!(plan.plans[0].num_infer_devices(), 3);
    }

    #[test]
    fn primindev_packs_the_78002() {
        // Three pipelines, one 78002 among 78000s: PriMinDev routes models
        // to the big device until it fills (the Fig. 17 pathology).
        let f = fleet(&[
            DeviceKind::Max78000,
            DeviceKind::Max78000,
            DeviceKind::Max78000,
            DeviceKind::Max78002,
        ]);
        let ps = pipes(&[ModelName::ConvNet5, ModelName::UNet, ModelName::EfficientNetV2]);
        let plan = PriMinDev.plan(&ps, &f).unwrap();
        for ep in &plan.plans {
            assert_eq!(ep.chunks.len(), 1);
            assert_eq!(
                f.get(ep.chunks[0].device).spec.kind,
                DeviceKind::Max78002,
                "{ep}"
            );
        }
        plan.check_runnable(&ps, &f).unwrap();
    }

    #[test]
    fn heuristics_respect_joint_memory() {
        // Two MobileNetV2 (821 KB each) over two MAX78000 + one MAX78002:
        // whatever the heuristic, the result must be runnable.
        let f = fleet(&[DeviceKind::Max78000, DeviceKind::Max78000, DeviceKind::Max78002]);
        let ps = pipes(&[ModelName::MobileNetV2, ModelName::MobileNetV2]);
        for planner in [&MinDev as &dyn Planner, &MaxDev, &PriMinDev, &PriMaxDev] {
            match planner.plan(&ps, &f) {
                Ok(plan) => plan.check_runnable(&ps, &f).unwrap(),
                Err(PlanError::Oor { .. }) => {} // allowed: heuristic painted itself into a corner
                Err(e) => panic!("{}: {e:?}", planner.name()),
            }
        }
    }

    #[test]
    fn overcommitment_is_oor_not_panic() {
        let f = fleet(&[DeviceKind::Max78000]);
        let ps = pipes(&[ModelName::MobileNetV2]);
        for planner in [&MinDev as &dyn Planner, &MaxDev, &PriMinDev, &PriMaxDev] {
            assert!(matches!(
                planner.plan(&ps, &f),
                Err(PlanError::Oor { .. })
            ));
        }
    }
}
