//! The paper's comparison methods (§VI-A2) and phone offloading (§II-B).
//!
//! Four heuristics that *do* account for previously-committed resources
//! (MinDev, MaxDev, PriMinDev, PriMaxDev), three adaptations of
//! state-of-the-art single-model partitioning (IndModel, JointModel,
//! IndE2E), and smartphone offloading. All implement
//! [`crate::orchestrator::Planner`] and deploy with sequential execution —
//! adaptive task parallelization is Synergy's runtime contribution.

pub mod heuristics;
pub mod partitioning;
pub mod offload;

pub use heuristics::{MaxDev, MinDev, PriMaxDev, PriMinDev};
pub use offload::PhoneOffload;
pub use partitioning::{Cost, IndE2E, IndModel, JointE2E, JointModel};

use crate::device::Fleet;
use crate::estimator::LatencyModel;
use crate::pipeline::PipelineSpec;
use crate::plan::ExecutionPlan;

/// Chain latency of a single execution plan, end-to-end (sensing through
/// interaction) — what IndE2E optimizes.
pub fn e2e_chain_latency(ep: &ExecutionPlan, spec: &PipelineSpec, lm: &LatencyModel) -> f64 {
    let sensor = LatencyModel::source_sensor(spec);
    ep.tasks(&spec.model)
        .iter()
        .map(|t| lm.task_latency(t, &spec.model, sensor))
        .sum()
}

/// Model-centric latency: load/infer/unload per chunk plus inter-chunk
/// communication — *excluding* sensing, interaction, and the hops to/from
/// the source/target devices. This is the §III-A "model-centric joint
/// decision" view that state-of-the-art partitioning methods optimize.
pub fn model_centric_latency(ep: &ExecutionPlan, spec: &PipelineSpec, lm: &LatencyModel) -> f64 {
    use crate::plan::task::{PlanTask, TaskKind};
    let model = &spec.model;
    let mut total = 0.0;
    let mut lat = |device, kind| {
        total += lm.task_latency(
            &PlanTask { pipeline: ep.pipeline, seq: 0, device, kind },
            model,
            None,
        );
    };
    for (i, a) in ep.chunks.iter().enumerate() {
        let in_bytes = if a.range.start == 0 {
            model.in_bytes()
        } else {
            model.boundary_bytes(a.range.start - 1)
        };
        let out_bytes = model.boundary_bytes(a.range.end - 1);
        lat(a.device, TaskKind::Load { bytes: in_bytes });
        lat(a.device, TaskKind::Infer { range: a.range });
        lat(a.device, TaskKind::Unload { bytes: out_bytes });
        if let Some(next) = ep.chunks.get(i + 1) {
            lat(a.device, TaskKind::Tx { bytes: out_bytes, to: next.device });
            lat(next.device, TaskKind::Rx { bytes: out_bytes, from: a.device });
        }
    }
    total
}

/// Fraction of a plan's chunk weight bytes placed on MAX78002-class devices
/// (PriMinDev/PriMaxDev prefer the higher-resource accelerator).
pub fn weight_share_on_78002(ep: &ExecutionPlan, spec: &PipelineSpec, fleet: &Fleet) -> f64 {
    let total: u64 = ep
        .chunks
        .iter()
        .map(|a| spec.model.weight_bytes(a.range))
        .sum();
    if total == 0 {
        return 0.0;
    }
    let on_02: u64 = ep
        .chunks
        .iter()
        .filter(|a| fleet.get(a.device).spec.kind == crate::device::DeviceKind::Max78002)
        .map(|a| spec.model.weight_bytes(a.range))
        .sum();
    on_02 as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceId, DeviceKind};
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet() -> Fleet {
        Fleet::new(vec![
            Device::new(0, "a", DeviceKind::Max78000, vec![], vec![]),
            Device::new(1, "b", DeviceKind::Max78000, vec![], vec![]),
        ])
    }

    #[test]
    fn model_centric_ignores_endpoint_hops() {
        let f = fleet();
        let lm = LatencyModel::new(&f);
        let spec = PipelineSpec::new(
            0,
            "p",
            SourceReq::Device(DeviceId(0)),
            model_by_name(ModelName::ConvNet5).clone(),
            TargetReq::Device(DeviceId(0)),
        );
        // Inference on d1 with source/target on d0: e2e pays two radio
        // hops that the model-centric view ignores.
        let remote = ExecutionPlan::monolithic(&spec, DeviceId(0), DeviceId(1), DeviceId(0));
        let local = ExecutionPlan::monolithic(&spec, DeviceId(0), DeviceId(0), DeviceId(0));
        let mc_remote = model_centric_latency(&remote, &spec, &lm);
        let mc_local = model_centric_latency(&local, &spec, &lm);
        assert!((mc_remote - mc_local).abs() < 1e-9, "model view is placement-blind");
        let e2e_remote = e2e_chain_latency(&remote, &spec, &lm);
        let e2e_local = e2e_chain_latency(&local, &spec, &lm);
        assert!(e2e_remote > 2.0 * e2e_local);
    }

    #[test]
    fn weight_share_on_homogeneous_fleet_is_zero() {
        let f = fleet();
        let spec = PipelineSpec::new(
            0,
            "p",
            SourceReq::Any,
            model_by_name(ModelName::KWS).clone(),
            TargetReq::Any,
        );
        let ep = ExecutionPlan::monolithic(&spec, DeviceId(0), DeviceId(0), DeviceId(0));
        assert_eq!(weight_share_on_78002(&ep, &spec, &f), 0.0);
    }
}
