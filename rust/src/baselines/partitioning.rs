//! Adapted state-of-the-art model-partitioning baselines (§VI-A2).
//!
//! - **IndModel** — Neurosurgeon/DADS/SPINN-class methods: each pipeline
//!   independently picks the split minimizing *model-centric* latency.
//!   No joint resource view → out-of-resource (OOR) collisions when the
//!   independently chosen plans land on the same accelerator (Fig. 5a).
//! - **JointModel** — IndModel plus a joint resource assessment (the JRC
//!   ablation row of Table II): candidates that no longer fit are skipped.
//!   Still model-centric: blind to source/target placement (Fig. 5b).
//! - **IndE2E** — optimizes the full end-to-end chain (sensing → …  →
//!   interaction) per pipeline, but independently: no joint memory view,
//!   so it too can OOR under contention (it shines when resources are
//!   plentiful — Fig. 17).

use crate::device::Fleet;
use crate::estimator::LatencyModel;
use crate::pipeline::PipelineSpec;
use crate::plan::collab::MemoryLedger;
use crate::plan::{enumerate_plans, CollabPlan, EnumerateCfg};

use super::{e2e_chain_latency, model_centric_latency};
use crate::orchestrator::{PlanError, Planner};

/// What the adapted partitioning methods minimize. `Latency` is their
/// native objective; `Energy` is the Fig. 19 variant where every method
/// instead prioritizes minimal power.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Cost {
    #[default]
    Latency,
    Energy,
}

/// Independent model-centric partitioning (state of the art, single-model).
#[derive(Clone, Copy, Debug, Default)]
pub struct IndModel {
    pub cost: Cost,
}

/// IndModel with joint resource assessment (multi-tenant).
#[derive(Clone, Copy, Debug, Default)]
pub struct JointModel {
    pub cost: Cost,
}

/// Independent end-to-end optimization (no joint resources).
#[derive(Clone, Copy, Debug, Default)]
pub struct IndE2E {
    pub cost: Cost,
}

/// End-to-end optimization *with* joint resource assessment — the
/// JRC+STT ablation row of Table II (not a named baseline in Fig. 15).
#[derive(Clone, Copy, Debug, Default)]
pub struct JointE2E {
    pub cost: Cost,
}

/// Active energy of one pipeline's chain (for `Cost::Energy`).
fn e2e_chain_energy(
    ep: &crate::plan::ExecutionPlan,
    spec: &PipelineSpec,
    fleet: &Fleet,
    lm: &LatencyModel,
) -> f64 {
    let mut acc = crate::estimator::EstimateAccum::new(fleet);
    acc.add_plan(ep, spec, fleet, lm);
    acc.finish().active_energy_j
}

fn best_by<F: FnMut(&crate::plan::ExecutionPlan) -> f64>(
    spec: &PipelineSpec,
    fleet: &Fleet,
    mut cost: F,
    ledger: Option<&MemoryLedger>,
) -> Result<crate::plan::ExecutionPlan, PlanError> {
    if spec.source_candidates(fleet).is_empty() || spec.target_candidates(fleet).is_empty() {
        return Err(PlanError::Unsatisfiable { pipeline: spec.name.clone() });
    }
    let candidates = enumerate_plans(spec, fleet, EnumerateCfg::default());
    candidates
        .into_iter()
        .filter(|c| ledger.map(|l| l.fits(c, &spec.model, fleet)).unwrap_or(true))
        .map(|c| (cost(&c), c))
        .min_by(|a, b| a.0.total_cmp(&b.0))
        .map(|(_, c)| c)
        .ok_or_else(|| PlanError::Oor { pipeline: spec.name.clone() })
}

impl Planner for IndModel {
    fn name(&self) -> &'static str {
        "IndModel"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        let lm = LatencyModel::new(fleet);
        let mut out = Vec::with_capacity(pipelines.len());
        for spec in pipelines {
            // Independent decision: no ledger.
            out.push(best_by(
                spec,
                fleet,
                |c| match self.cost {
                    Cost::Latency => model_centric_latency(c, spec, &lm),
                    Cost::Energy => e2e_chain_energy(c, spec, fleet, &lm),
                },
                None,
            )?);
        }
        let plan = CollabPlan::new(out);
        // Aggregation can exceed joint capacity — the IndModel failure mode.
        plan.check_runnable(pipelines, fleet)
            .map_err(|e| PlanError::Oor { pipeline: format!("joint ({e})") })?;
        Ok(plan)
    }
}

impl Planner for JointModel {
    fn name(&self) -> &'static str {
        "JointModel"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        let lm = LatencyModel::new(fleet);
        let mut ledger = MemoryLedger::default();
        let mut out = Vec::with_capacity(pipelines.len());
        for spec in pipelines {
            let chosen = best_by(
                spec,
                fleet,
                |c| match self.cost {
                    Cost::Latency => model_centric_latency(c, spec, &lm),
                    Cost::Energy => e2e_chain_energy(c, spec, fleet, &lm),
                },
                Some(&ledger),
            )?;
            ledger.commit(&chosen, &spec.model);
            out.push(chosen);
        }
        Ok(CollabPlan::new(out))
    }
}

impl Planner for IndE2E {
    fn name(&self) -> &'static str {
        "IndE2E"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        let lm = LatencyModel::new(fleet);
        let mut out = Vec::with_capacity(pipelines.len());
        for spec in pipelines {
            out.push(best_by(
                spec,
                fleet,
                |c| match self.cost {
                    Cost::Latency => e2e_chain_latency(c, spec, &lm),
                    Cost::Energy => e2e_chain_energy(c, spec, fleet, &lm),
                },
                None,
            )?);
        }
        let plan = CollabPlan::new(out);
        plan.check_runnable(pipelines, fleet)
            .map_err(|e| PlanError::Oor { pipeline: format!("joint ({e})") })?;
        Ok(plan)
    }
}

impl Planner for JointE2E {
    fn name(&self) -> &'static str {
        "JointE2E"
    }

    fn plan(&self, pipelines: &[PipelineSpec], fleet: &Fleet) -> Result<CollabPlan, PlanError> {
        let lm = LatencyModel::new(fleet);
        let mut ledger = MemoryLedger::default();
        let mut out = Vec::with_capacity(pipelines.len());
        for spec in pipelines {
            let chosen = best_by(
                spec,
                fleet,
                |c| match self.cost {
                    Cost::Latency => e2e_chain_latency(c, spec, &lm),
                    Cost::Energy => e2e_chain_energy(c, spec, fleet, &lm),
                },
                Some(&ledger),
            )?;
            ledger.commit(&chosen, &spec.model);
            out.push(chosen);
        }
        Ok(CollabPlan::new(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceId, DeviceKind};
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{SourceReq, TargetReq};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn pipes(models: &[ModelName]) -> Vec<PipelineSpec> {
        models
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(i, m.as_str(), SourceReq::Any, model_by_name(m).clone(), TargetReq::Any)
            })
            .collect()
    }

    #[test]
    fn indmodel_collides_where_jointmodel_survives() {
        // Workload-2-like contention: three mid-size models, two devices.
        // IndModel puts every model on its individually-best accelerator
        // (they all look identical) and trips joint OOR; JointModel spreads.
        let f = fleet(2);
        let ps = pipes(&[ModelName::KWS, ModelName::SimpleNet, ModelName::WideNet]);
        let ind = IndModel::default().plan(&ps, &f);
        let joint = JointModel::default().plan(&ps, &f);
        // KWS+SimpleNet+WideNet = 649 KB > 442 KB: independent picks that
        // stack on one device OOR. (If the independent optimum happens to
        // spread, both succeed — assert consistency instead of exact OOR.)
        match ind {
            Err(PlanError::Oor { .. }) => {}
            Ok(plan) => plan.check_runnable(&ps, &f).unwrap(),
            Err(e) => panic!("{e:?}"),
        }
        joint.unwrap().check_runnable(&ps, &f).unwrap();
    }

    #[test]
    fn inde2e_places_near_endpoints() {
        let f = fleet(3);
        let mut ps = pipes(&[ModelName::ConvNet5]);
        ps[0].source = SourceReq::Device(DeviceId(2));
        ps[0].target = TargetReq::Device(DeviceId(2));
        let plan = IndE2E::default().plan(&ps, &f).unwrap();
        // E2E view keeps inference on the endpoint device (no radio hops).
        assert_eq!(plan.plans[0].chunks[0].device, DeviceId(2));
        // Model-centric IndModel is indifferent — whatever it picks, its
        // cost ignores the endpoints; verify it scores all devices equally.
        let lm = LatencyModel::new(&f);
        let c0 = model_centric_latency(
            &crate::plan::ExecutionPlan::monolithic(&ps[0], DeviceId(2), DeviceId(0), DeviceId(2)),
            &ps[0], &lm,
        );
        let c2 = model_centric_latency(
            &crate::plan::ExecutionPlan::monolithic(&ps[0], DeviceId(2), DeviceId(2), DeviceId(2)),
            &ps[0], &lm,
        );
        assert!((c0 - c2).abs() < 1e-12);
    }

    #[test]
    fn all_three_handle_single_pipeline() {
        let f = fleet(2);
        let ps = pipes(&[ModelName::UNet]);
        let (ind, joint, inde) = (IndModel::default(), JointModel::default(), IndE2E::default());
        for planner in [&ind as &dyn Planner, &joint, &inde] {
            let plan = planner.plan(&ps, &f).unwrap();
            plan.check_runnable(&ps, &f).unwrap();
        }
    }
}
