//! `synergy` — the leader entrypoint.
//!
//! Subcommands:
//!   exp <id|all> [--runs N] [--seed S] [--full]   reproduce a paper table/figure
//!   plan --workload N [--fleet F] [--beam W]      plan + print a deployment
//!   scenario [--name jog|churn8|bursty8|cascade8] live session with mid-run churn
//!   serve [--scenario jog]                        streaming serving (worker threads,
//!                                                 live plan rebinds; PJRT without
//!                                                 --scenario, needs artifacts)
//!   check [--workload N|--fleet F|--scenario S]   static verification sweep,
//!                                                 no execution (plans + scripts)
//!   population --users N --seed-range A..B        Monte-Carlo fleet of sampled
//!                                                 users through one shared
//!                                                 planning service
//!   zoo                                           print the Table I model zoo
//!   list                                          list experiments

use synergy::api::{RunConfig, SessionCfg, SessionReport, SynergyRuntime};
use synergy::experiments;
use synergy::orchestrator::{Planner, Synergy};
use synergy::util::cli::Args;
use synergy::util::table::Table;
use synergy::workload;

const VALUE_OPTS: &[&str] = &[
    "runs", "seed", "workload", "combos", "artifacts", "inflight", "fleet", "beam", "name",
    "until", "scenario", "rate", "users", "seed-range", "workers", "fleet-mix", "out",
    "trace-user",
];

fn main() {
    let args = Args::parse(std::env::args().skip(1), VALUE_OPTS);
    let code = match args.cmd() {
        Some("exp") => cmd_exp(&args),
        Some("plan") => cmd_plan(&args),
        Some("explain") => cmd_explain(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("serve") => cmd_serve(&args),
        Some("check") => cmd_check(&args),
        Some("population") => cmd_population(&args),
        Some("zoo") => cmd_zoo(),
        Some("trace") => cmd_trace(&args),
        Some("blame") => cmd_blame(&args),
        Some("trace-diff") => cmd_trace_diff(&args),
        Some("list") => cmd_list(),
        _ => {
            eprint!("{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "usage: synergy <exp|plan|explain|scenario|serve|check|population|blame|trace-diff|zoo|list> \
     [options]\n\
     \n\
     exp <id|all>   reproduce a paper experiment (see `synergy list`)\n\
     \u{20}              --runs N (sim rounds), --seed S, --full (fig9 full sweep)\n\
     plan           --workload 1..4|mixed8, print the selected plan\n\
     \u{20}              --fleet 4|4h|8|12h, --beam W (bounded plan search;\n\
     \u{20}              default exhaustive — required beyond ~5 devices)\n\
     explain        static capacity analysis of the selected plan: per-unit\n\
     \u{20}              utilization, the bottleneck unit, per-pipeline\n\
     \u{20}              throughput bounds vs QoS with headroom — no\n\
     \u{20}              execution. --workload/--fleet/--beam as in plan;\n\
     \u{20}              --rate R arms a uniform min-rate floor (Hz) on\n\
     \u{20}              every app (planner admission pruning + feasibility\n\
     \u{20}              verdicts; exit 1 if statically infeasible);\n\
     \u{20}              --json (machine-readable capacity report)\n\
     scenario       live session with mid-run churn: time-series report,\n\
     \u{20}              plan-switch timeline, QoS spans (cascade8 = battery-\n\
     \u{20}              driven departure cascade with event-driven depletion)\n\
     \u{20}              --name jog|churn8|bursty8|cascade8, --seed S, --until T,\n\
     \u{20}              --json (machine-readable session report)\n\
     serve          streaming serving on real worker threads\n\
     \u{20}              --scenario jog|churn8|bursty8|cascade8: live session on the\n\
     \u{20}              virtual-time engine (stock toolchain) with mid-run\n\
     \u{20}              plan switches rebinding the workers; without\n\
     \u{20}              --scenario: PJRT demo (needs `make artifacts` and\n\
     \u{20}              the pjrt feature), --runs N, --inflight K,\n\
     \u{20}              --artifacts DIR\n\
     check          static verification, no execution: plan every canned\n\
     \u{20}              workload/fleet combo and verify the selected plans\n\
     \u{20}              (device refs, chain shape, unit booking, memory\n\
     \u{20}              fit, QoS bounds), lint every canned scenario\n\
     \u{20}              script; narrow with --workload 1..4|mixed8\n\
     \u{20}              --fleet 4|4h|8|12h, or --scenario NAME\n\
     population     Monte-Carlo fleet: N sampled users (fleet + app mix +\n\
     \u{20}              churn journey per seed) each replayed as a live\n\
     \u{20}              session, all sharing one cross-user plan cache;\n\
     \u{20}              prints population distributions (p50/p95/p99),\n\
     \u{20}              cache hit rate, and a determinism fingerprint\n\
     \u{20}              --users N, --seed-range A..B, --workers W (0=auto),\n\
     \u{20}              --beam W, --fleet-mix mixed|fleet4|fleet8|hetero,\n\
     \u{20}              --no-cache (baseline: every user replans alone),\n\
     \u{20}              --json (machine-readable report), --trace-user\n\
     \u{20}              S|p50|p95|p99 (flight-record user seed S, or the\n\
     \u{20}              user at that completions percentile, picked\n\
     \u{20}              without perturbing the cohort fingerprint;\n\
     \u{20}              --out FILE writes the Chrome trace)\n\
     blame          measured critical-path attribution of a canned\n\
     \u{20}              scenario: flight-record the session, reconstruct\n\
     \u{20}              each round's critical path, and print where every\n\
     \u{20}              nanosecond went (compute/radio/queue/pacing) plus\n\
     \u{20}              the measured bottleneck unit\n\
     \u{20}              --scenario jog|churn8|bursty8|cascade8, --serve\n\
     \u{20}              (streaming engine), --seed S, --until T, --json\n\
     trace-diff     A.json B.json: structural diff of two exported Chrome\n\
     \u{20}              traces — ranked per-track deltas and per-pipeline\n\
     \u{20}              blame movement; exit 0 identical, 1 differences\n\
     \u{20}              --json (machine-readable delta report)\n\
     zoo            print the Table I model zoo\n\
     trace          --workload 1..4 [--runs N]: per-unit utilization +\n\
     \u{20}              task timeline of the deployed plan; or\n\
     \u{20}              --scenario jog|churn8|bursty8|cascade8 [--serve]\n\
     \u{20}              [--out FILE]: flight-record the live session and\n\
     \u{20}              export Chrome/Perfetto trace-event JSON (load at\n\
     \u{20}              ui.perfetto.dev)\n\
     list           list experiment ids\n"
        .to_string()
}

/// Build the runtime + scenario for a canned name (bounded plan search
/// past ~5 devices, where exhaustive enumeration is intractable and
/// replans inside the timeline need to stay interactive), applying the
/// `--until`/`--seed` overrides. `Err` carries the exit code.
fn canned_runtime(
    name: &str,
    args: &Args,
) -> Result<(SynergyRuntime, synergy::api::Scenario, SessionCfg), i32> {
    let Some(canned) = workload::canned_scenario(name) else {
        eprintln!(
            "unknown scenario {name:?}: valid scenarios are {}",
            workload::canned_scenario_names()
        );
        return Err(2);
    };
    let mut scenario = canned.scenario;
    if let Some(until) = args.opt("until") {
        match until.parse::<f64>() {
            Ok(t) => scenario = scenario.until(t),
            Err(_) => {
                eprintln!("--until takes seconds, got {until:?}");
                return Err(2);
            }
        }
    }
    let fleet = canned.fleet;
    let builder = SynergyRuntime::builder();
    let builder = if fleet.len() > 5 {
        eprintln!(
            "note: {}-device fleet — using bounded plan search (--beam {})",
            fleet.len(),
            synergy::plan::DEFAULT_BEAM_WIDTH
        );
        builder.planner(Synergy::planner_bounded(synergy::plan::DEFAULT_BEAM_WIDTH))
    } else {
        builder
    };
    let runtime = builder.fleet(fleet).build();
    let cfg = SessionCfg { seed: args.opt_parse("seed", 42u64), ..SessionCfg::default() };
    Ok((runtime, scenario, cfg))
}

/// Print a session's time series, plan-switch timeline, QoS spans, and —
/// for served sessions — the streaming-engine summary.
fn print_session_report(header: &str, report: &SessionReport) {
    println!(
        "{header} — {:.1} s timeline, {} rounds, {:.2} inf/s overall, {:.2} W\n",
        report.duration, report.completions, report.throughput, report.power_w
    );

    let serving = report.served.is_some();
    println!("plan-switch timeline:");
    let mut t = if serving {
        Table::new(["t", "event", "apps", "incremental", "replan", "rebind", "est inf/s"])
    } else {
        Table::new(["t", "event", "apps", "incremental", "replan", "est inf/s"])
    };
    for sw in &report.switches {
        let mut row = vec![
            format!("{:.2}s", sw.t),
            sw.cause.clone(),
            sw.apps.to_string(),
            if sw.incremental {
                "yes".to_string()
            } else {
                format!("{} enum", sw.enumerated_apps)
            },
            synergy::util::fmt_secs(sw.replan_wall_s),
        ];
        if serving {
            row.push(synergy::util::fmt_secs(sw.rebind_wall_s));
        }
        row.push(format!("{:.2}", sw.est_throughput));
        t.row(row);
    }
    t.print();

    println!("\ntime series (per interval, per app):");
    let mut t = Table::new(["interval", "app", "runs", "inf/s", "latency", "power"]);
    for iv in &report.intervals {
        t.row([
            format!("{:.2}–{:.2}s", iv.start, iv.end),
            "(all)".to_string(),
            iv.completions.to_string(),
            format!("{:.2}", iv.throughput),
            synergy::util::fmt_secs(iv.avg_latency_s),
            format!("{:.2} W", iv.power_w),
        ]);
        for app in &iv.per_app {
            t.row([
                String::new(),
                app.name.clone(),
                app.completions.to_string(),
                format!("{:.2}", app.throughput),
                synergy::util::fmt_secs(app.mean_latency_s),
                String::new(),
            ]);
        }
    }
    t.print();

    // Per-device state of charge at the interval boundaries, batteries
    // armed (e.g. cascade8) — plottable straight from the report.
    let mut battery_devs: Vec<synergy::device::DeviceId> = report
        .intervals
        .iter()
        .flat_map(|iv| iv.battery_j.iter().map(|&(d, _)| d))
        .collect();
    battery_devs.sort();
    battery_devs.dedup();
    if !battery_devs.is_empty() {
        println!("\nbattery state of charge (J at interval end):");
        let mut header = vec!["t".to_string()];
        header.extend(battery_devs.iter().map(|d| d.to_string()));
        let mut t = Table::new(header);
        for iv in &report.intervals {
            let mut row = vec![format!("{:.2}s", iv.end)];
            for d in &battery_devs {
                row.push(
                    iv.battery_j
                        .iter()
                        .find(|&&(dev, _)| dev == *d)
                        .map(|&(_, j)| format!("{j:.2}"))
                        .unwrap_or_else(|| "-".to_string()),
                );
            }
            t.row(row);
        }
        t.print();
    }

    if report.qos_spans.is_empty() {
        println!("\nno QoS violations");
    } else {
        println!("\nQoS-violation spans:");
        let mut t = Table::new(["app", "span", "violation"]);
        for span in &report.qos_spans {
            t.row([
                span.name.clone(),
                format!("{:.2}–{:.2}s", span.start, span.end),
                format!("{}", span.violation),
            ]);
        }
        t.print();
    }

    if let Some(s) = &report.served {
        println!(
            "\nstreaming engine ({}): {} rounds admitted, {} completed \
             (conserved: {}), {} rebinds over {} workers",
            s.executor,
            s.admitted_rounds,
            s.completed_rounds,
            s.admitted_rounds == s.completed_rounds,
            s.rebinds,
            s.workers,
        );
    }
}

/// Replay a canned churn scenario through the live-session API and print
/// its time series: the headline demonstration of mid-run replanning.
fn cmd_scenario(args: &Args) -> i32 {
    let name = args.opt("name").unwrap_or("jog");
    let (runtime, scenario, cfg) = match canned_runtime(name, args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let session = match runtime.session_with(scenario, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("scenario failed to start: {e}");
            return 1;
        }
    };
    let report = match session.finish() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("scenario failed: {e}");
            return 1;
        }
    };
    if args.flag("json") {
        println!(
            "{}",
            synergy::obs::export::session_report_json(&report).to_string_pretty()
        );
    } else {
        print_session_report(&format!("scenario {name:?}"), &report);
    }
    0
}

/// Serve a canned scenario on the streaming engine: the same session API,
/// but every plan switch rebinds live worker threads mid-stream. Runs on
/// the deterministic virtual-time executor, so it needs no artifacts and
/// no vendored toolchain.
fn cmd_serve_scenario(name: &str, args: &Args) -> i32 {
    let (runtime, scenario, cfg) = match canned_runtime(name, args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let session = match runtime
        .session_with(scenario, cfg)
        .and_then(|s| s.serve(synergy::serving::ServeCfg::default()))
    {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve failed to start: {e}");
            return 1;
        }
    };
    let report = match session.finish() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve failed: {e}");
            return 1;
        }
    };
    print_session_report(&format!("served scenario {name:?}"), &report);
    if report
        .served
        .as_ref()
        .is_some_and(|s| s.admitted_rounds == s.completed_rounds)
    {
        0
    } else {
        1
    }
}

/// Run a Monte-Carlo population: N sampled users (one live session each)
/// through one shared planning service, and print the population-level
/// distributions, cache effectiveness, and determinism fingerprint.
fn cmd_population(args: &Args) -> i32 {
    use synergy::population::{run_population, Dist, Pctl, PopulationCfg};
    use synergy::workload::FleetMix;

    let users = args.opt_parse("users", 100usize);
    let (seed_lo, seed_hi) = match args.opt("seed-range") {
        None => (0, users as u64),
        Some(s) => {
            let parsed = s
                .split_once("..")
                .and_then(|(a, b)| Some((a.parse::<u64>().ok()?, b.parse::<u64>().ok()?)));
            match parsed {
                Some(range) => range,
                None => {
                    eprintln!("--seed-range takes A..B (two integers), got {s:?}");
                    return 2;
                }
            }
        }
    };
    let mix = match args.opt("fleet-mix") {
        None => FleetMix::Mixed,
        Some(s) => match FleetMix::parse(s) {
            Some(m) => m,
            None => {
                eprintln!(
                    "unknown fleet mix {s:?}: valid mixes are {}",
                    FleetMix::names()
                );
                return 2;
            }
        },
    };
    let mut trace_user = None;
    let mut trace_percentile = None;
    if let Some(s) = args.opt("trace-user") {
        match s.parse::<u64>() {
            Ok(seed) => trace_user = Some(seed),
            Err(_) => match s.parse::<Pctl>() {
                Ok(p) => trace_percentile = Some(p),
                Err(_) => {
                    eprintln!(
                        "--trace-user takes a user seed (integer) or a completions \
                         percentile (p50, p95, p99), got {s:?}"
                    );
                    return 2;
                }
            },
        }
    }
    let cfg = PopulationCfg {
        users,
        seed_lo,
        seed_hi,
        workers: args.opt_parse("workers", 0usize),
        beam: args.opt_parse("beam", synergy::plan::DEFAULT_BEAM_WIDTH),
        shared_cache: !args.flag("no-cache"),
        mix,
        trace_user,
        trace_percentile,
        ..PopulationCfg::default()
    };

    let t0 = std::time::Instant::now();
    let report = match run_population(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("population failed: {e}");
            return 1;
        }
    };
    let wall = t0.elapsed().as_secs_f64();

    // Flight-recorded user: export the Chrome trace before the summary so
    // `--trace-user S --out FILE` composes with both output modes.
    if let Some(rec) = &report.trace {
        let chrome = synergy::obs::to_chrome_json(rec);
        let seed = report.traced_seed.unwrap_or_default();
        match args.opt("out") {
            Some(path) => {
                if let Err(e) = std::fs::write(path, &chrome) {
                    eprintln!("failed to write {path}: {e}");
                    return 1;
                }
                eprintln!("trace: user {seed} — {} events → {path}", rec.len());
            }
            None => eprintln!(
                "trace: user {seed} — {} events recorded (pass --out FILE to export)",
                rec.len()
            ),
        }
        if let Some(b) = &report.blame {
            match b.measured_bottleneck {
                Some((d, u)) => eprintln!(
                    "blame: user {seed} — {} rounds, measured bottleneck d{} {u:?}",
                    b.rounds, d.0
                ),
                None => eprintln!("blame: user {seed} — no complete rounds to attribute"),
            }
        }
    } else if trace_user.is_some() {
        eprintln!(
            "note: --trace-user {} matched no sampled user seed",
            trace_user.unwrap_or_default()
        );
    }

    if args.flag("json") {
        println!(
            "{}",
            synergy::obs::export::population_report_json(&report).to_string_pretty()
        );
        return 0;
    }

    println!(
        "population — {} users (seeds {}..{}), {} workers, {:.2} s wall ({:.0} users/s)",
        report.users,
        cfg.seed_lo,
        cfg.seed_hi,
        report.workers,
        wall,
        report.users as f64 / wall.max(1e-9),
    );
    match &report.cache {
        Some(c) => println!(
            "shared plan cache: {} lookups, {} distinct planning problems, \
             {} plans resident — {:.1}% hit rate",
            c.lookups,
            c.unique_signatures,
            c.unique_plans,
            100.0 * c.hit_rate(),
        ),
        None => println!("shared plan cache: off (--no-cache)"),
    }
    println!("fingerprint: {:016x}\n", report.fingerprint);

    let mut t = Table::new(["metric", "min", "p50", "p95", "p99", "max", "mean"]);
    let mut row = |name: &str, d: &Dist, scale: f64, unit: &str| {
        t.row([
            name.to_string(),
            format!("{:.2}{unit}", d.min * scale),
            format!("{:.2}{unit}", d.p50 * scale),
            format!("{:.2}{unit}", d.p95 * scale),
            format!("{:.2}{unit}", d.p99 * scale),
            format!("{:.2}{unit}", d.max * scale),
            format!("{:.2}{unit}", d.mean * scale),
        ]);
    };
    row("completions", &report.completions, 1.0, "");
    row("energy", &report.energy_j, 1.0, " J");
    row("plan switches", &report.switches, 1.0, "");
    row("QoS violation", &report.qos_violation_s, 1.0, " s");
    row("replan latency", &report.replan_wall_s, 1e3, " ms");
    t.print();
    println!(
        "\ntotal replan wall across the population: {:.1} ms",
        1e3 * report.replan_wall_total_s
    );
    0
}

fn cmd_list() -> i32 {
    let mut t = Table::new(["id", "reproduces"]);
    for e in experiments::registry() {
        t.row([e.id.to_string(), e.paper_ref.to_string()]);
    }
    t.print();
    0
}

fn cmd_exp(args: &Args) -> i32 {
    let id = args.positionals.get(1).map(|s| s.as_str()).unwrap_or("all");
    match experiments::run(id, args) {
        Some(report) => {
            print!("{report}");
            0
        }
        None => {
            eprintln!("unknown experiment {id:?}; try `synergy list`");
            2
        }
    }
}

fn cmd_zoo() -> i32 {
    let mut t = Table::new([
        "model", "layers", "size", "input", "avg out", "data intensity",
    ]);
    for (name, m) in synergy::model::zoo::zoo() {
        t.row([
            name.clone(),
            format!("{}", m.num_layers()),
            synergy::util::fmt_bytes(m.size_bytes()),
            format!("{}", m.input),
            format!("{:.0} B", m.avg_out_bytes()),
            format!("{:.0}", m.data_intensity()),
        ]);
    }
    t.print();
    0
}

fn cmd_plan(args: &Args) -> i32 {
    let fleet = match args.opt("fleet").unwrap_or("4") {
        "4" => workload::fleet4(),
        "4h" => workload::fleet4_hetero(),
        "8" => workload::fleet8(),
        "12h" => workload::fleet12_hetero(),
        other => {
            eprintln!("unknown fleet {other:?}: valid fleets are 4, 4h, 8, 12h");
            return 2;
        }
    };
    let w = match args.opt("workload") {
        // Workload 1 is a fixed Table I definition; surface the error
        // instead of panicking if it ever regresses.
        None => match workload::workload(1) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        Some("mixed8") => workload::workload_mixed8(fleet.len()),
        // A non-numeric, non-"mixed8" value must error, not silently fall
        // back to Workload 1.
        Some(s) => match s.parse::<usize>() {
            Ok(id) => match workload::workload(id) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("{e} (or mixed8)");
                    return 2;
                }
            },
            Err(_) => {
                eprintln!(
                    "unknown workload {s:?}: valid workloads are {}, mixed8",
                    workload::workload_names()
                );
                return 2;
            }
        },
    };
    let mut planner = Synergy::planner();
    if let Some(beam) = args.opt("beam") {
        let Ok(width) = beam.parse::<usize>() else {
            eprintln!("--beam takes a positive integer, got {beam:?}");
            return 2;
        };
        planner = Synergy::planner_bounded(width.max(1));
    } else if fleet.len() > 5 {
        // Exhaustive enumeration is intractable past ~5 devices; default
        // to bounded search rather than hanging the CLI.
        eprintln!(
            "note: {}-device fleet — using bounded plan search (--beam {})",
            fleet.len(),
            synergy::plan::DEFAULT_BEAM_WIDTH
        );
        planner = Synergy::planner_bounded(synergy::plan::DEFAULT_BEAM_WIDTH);
    }
    let runtime = SynergyRuntime::builder().fleet(fleet).planner(planner).build();
    for p in w.pipelines {
        if let Err(e) = runtime.register(p) {
            eprintln!("orchestration failed: {e}");
            return 1;
        }
    }
    let Some(dep) = runtime.deployment() else {
        eprintln!("orchestration selected no deployment (no apps registered)");
        return 1;
    };
    println!("{} — selected holistic collaboration plan:", w.name);
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }
    println!(
        "estimate: {:.2} inf/s, round latency {}, power {:.2} W",
        dep.estimate.throughput,
        synergy::util::fmt_secs(dep.estimate.round_latency),
        dep.estimate.power_w
    );
    let runs = args.opt_parse("runs", 24usize);
    match runtime.run(&RunConfig {
        runs,
        seed: args.opt_parse("seed", 7u64),
        ..RunConfig::default()
    }) {
        Ok(rep) => {
            println!(
                "simulated ({} runs): {:.2} inf/s, latency {}, power {:.2} W",
                runs,
                rep.throughput,
                synergy::util::fmt_secs(rep.avg_latency_s),
                rep.power_w.unwrap_or(0.0)
            );
            0
        }
        Err(e) => {
            eprintln!("simulation failed: {e}");
            1
        }
    }
}

/// `synergy explain` — the static capacity analysis as a command: plan
/// the workload, then print per-unit utilization, the bottleneck unit,
/// and per-pipeline static throughput bounds vs QoS with headroom.
/// Nothing executes. `--rate R` arms a uniform `min_rate_hz` floor on
/// every app, which both engages the bounded planner's skeleton
/// admission pruning and drives the feasibility verdicts. Exit 0 =
/// statically feasible, 1 = infeasible (the typed diagnostic is
/// printed), 2 = usage.
fn cmd_explain(args: &Args) -> i32 {
    let Some(fleet) = fleet_by_name(args.opt("fleet").unwrap_or("4")) else {
        eprintln!(
            "unknown fleet {:?}: valid fleets are 4, 4h, 8, 12h",
            args.opt("fleet").unwrap_or("4")
        );
        return 2;
    };
    let w = match args.opt("workload") {
        None => match workload::workload(1) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        Some("mixed8") => workload::workload_mixed8(fleet.len()),
        Some(s) => match s.parse::<usize>() {
            Ok(id) => match workload::workload(id) {
                Ok(w) => w,
                Err(e) => {
                    eprintln!("{e} (or mixed8)");
                    return 2;
                }
            },
            Err(_) => {
                eprintln!(
                    "unknown workload {s:?}: valid workloads are {}, mixed8",
                    workload::workload_names()
                );
                return 2;
            }
        },
    };
    let rate = match args.opt("rate") {
        None => 0.0,
        Some(r) => match r.parse::<f64>() {
            Ok(v) if v >= 0.0 && v.is_finite() => v,
            _ => {
                eprintln!("--rate takes a non-negative rate in Hz, got {r:?}");
                return 2;
            }
        },
    };
    let mut planner = Synergy::planner();
    if let Some(beam) = args.opt("beam") {
        let Ok(width) = beam.parse::<usize>() else {
            eprintln!("--beam takes a positive integer, got {beam:?}");
            return 2;
        };
        planner = Synergy::planner_bounded(width.max(1));
    } else if fleet.len() > 5 {
        eprintln!(
            "note: {}-device fleet — using bounded plan search (--beam {})",
            fleet.len(),
            synergy::plan::DEFAULT_BEAM_WIDTH
        );
        planner = Synergy::planner_bounded(synergy::plan::DEFAULT_BEAM_WIDTH);
    }
    let selection = if rate > 0.0 {
        planner.select_admitted(&w.pipelines, &fleet, &vec![rate; w.pipelines.len()])
    } else {
        planner.select(&w.pipelines, &fleet)
    };
    let plan = match selection {
        Ok(p) => p,
        Err(e) => {
            eprintln!("orchestration failed: {e}");
            return 1;
        }
    };
    let qos: Vec<synergy::api::Qos> = w
        .pipelines
        .iter()
        .map(|_| synergy::api::Qos { min_rate_hz: rate, ..synergy::api::Qos::default() })
        .collect();
    let report = match synergy::analysis::analyze_capacity(&plan, &w.pipelines, &fleet, Some(&qos))
    {
        Ok(r) => r,
        Err(e) => {
            eprintln!("capacity analysis failed: {e}");
            return 1;
        }
    };
    if args.flag("json") {
        println!(
            "{}",
            synergy::obs::export::capacity_report_json(&report).to_string_pretty()
        );
        return match report.check() {
            Ok(()) => 0,
            Err(_) => 1,
        };
    }
    println!("{} — static capacity analysis:", w.name);
    for ep in &plan.plans {
        println!("  {ep}");
    }
    println!();
    print!("{}", synergy::analysis::render_explain(&report, &w.pipelines));
    match report.check() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("\nstatically infeasible: {e}");
            1
        }
    }
}

/// Resolve a `--fleet` value to a named fleet (shared by plan/check).
fn fleet_by_name(name: &str) -> Option<synergy::device::Fleet> {
    match name {
        "4" => Some(workload::fleet4()),
        "4h" => Some(workload::fleet4_hetero()),
        "8" => Some(workload::fleet8()),
        "12h" => Some(workload::fleet12_hetero()),
        _ => None,
    }
}

/// Plan one workload on one fleet and statically verify the selection —
/// no execution. Returns the number of verified execution plans.
fn check_combo(
    w: &workload::Workload,
    fleet_name: &str,
    fleet: &synergy::device::Fleet,
) -> Result<usize, String> {
    // Exhaustive enumeration is intractable past ~5 devices; bounded
    // search keeps the sweep interactive (same default as `plan`).
    let planner = if fleet.len() > 5 {
        Synergy::planner_bounded(synergy::plan::DEFAULT_BEAM_WIDTH)
    } else {
        Synergy::planner()
    };
    let plan = planner
        .plan(&w.pipelines, fleet)
        .map_err(|e| format!("{} on fleet {fleet_name}: planning failed: {e}", w.name))?;
    let qos: Vec<synergy::api::Qos> =
        w.pipelines.iter().map(|_| synergy::api::Qos::default()).collect();
    synergy::analysis::verify_deployment(&plan, &w.pipelines, fleet, Some(&qos))
        .map_err(|e| format!("{} on fleet {fleet_name}: {e}", w.name))?;
    Ok(plan.plans.len())
}

/// Lint one canned scenario script against its starting fleet.
fn check_scenario(name: &str) -> Result<(), String> {
    let canned = workload::canned_scenario(name).ok_or_else(|| {
        format!(
            "unknown scenario {name:?}: valid scenarios are {}",
            workload::canned_scenario_names()
        )
    })?;
    synergy::analysis::verify_scenario(&canned.scenario, &canned.fleet)
        .map_err(|e| format!("scenario {name:?}: {e}"))
}

/// `synergy check` — the static verifier as a command: plan canned
/// workload/fleet combos and verify the selected plans, lint canned
/// scenario scripts. Nothing executes. With no options it sweeps every
/// canned combo and scenario; `--workload`/`--fleet`/`--scenario` narrow
/// the run. Exit 0 = everything verified, 1 = a check failed, 2 = usage.
fn cmd_check(args: &Args) -> i32 {
    // Scenario-only mode.
    if let Some(name) = args.opt("scenario") {
        return match check_scenario(name) {
            Ok(()) => {
                println!("ok   scenario {name:?}");
                0
            }
            Err(e) => {
                eprintln!("FAIL {e}");
                if workload::canned_scenario(name).is_none() { 2 } else { 1 }
            }
        };
    }

    // Single-combo mode when either knob is given.
    if args.opt("workload").is_some() || args.opt("fleet").is_some() {
        let fleet_name = args.opt("fleet").unwrap_or("4");
        let Some(fleet) = fleet_by_name(fleet_name) else {
            eprintln!("unknown fleet {fleet_name:?}: valid fleets are 4, 4h, 8, 12h");
            return 2;
        };
        let w = match args.opt("workload").unwrap_or("1") {
            "mixed8" => workload::workload_mixed8(fleet.len()),
            s => match s.parse::<usize>().map(workload::workload) {
                Ok(Ok(w)) => w,
                Ok(Err(e)) => {
                    eprintln!("{e} (or mixed8)");
                    return 2;
                }
                Err(_) => {
                    eprintln!(
                        "unknown workload {s:?}: valid workloads are {}, mixed8",
                        workload::workload_names()
                    );
                    return 2;
                }
            },
        };
        return match check_combo(&w, fleet_name, &fleet) {
            Ok(n) => {
                println!("ok   {} on fleet {fleet_name}: {n} execution plans verified", w.name);
                0
            }
            Err(e) => {
                eprintln!("FAIL {e}");
                1
            }
        };
    }

    // Full sweep: every canned workload × fleet combo, every scenario.
    let mut failures = 0usize;
    let mut combos: Vec<(workload::Workload, &str, synergy::device::Fleet)> = Vec::new();
    for w in workload::all_workloads() {
        combos.push((w.clone(), "4", workload::fleet4()));
        combos.push((w, "4h", workload::fleet4_hetero()));
    }
    combos.push((workload::workload_mixed8(8), "8", workload::fleet8()));
    combos.push((workload::workload_mixed8(12), "12h", workload::fleet12_hetero()));
    for (w, fleet_name, fleet) in &combos {
        match check_combo(w, fleet_name, fleet) {
            Ok(n) => {
                println!("ok   {} on fleet {fleet_name}: {n} execution plans verified", w.name)
            }
            Err(e) => {
                eprintln!("FAIL {e}");
                failures += 1;
            }
        }
    }
    for name in ["jog", "churn8", "bursty8", "cascade8"] {
        match check_scenario(name) {
            Ok(()) => println!("ok   scenario {name:?}"),
            Err(e) => {
                eprintln!("FAIL {e}");
                failures += 1;
            }
        }
    }
    let total = combos.len() + 4;
    if failures == 0 {
        println!("all {total} checks passed");
        0
    } else {
        eprintln!("{failures}/{total} checks FAILED");
        1
    }
}

/// `serve --scenario NAME` streams a live session on the virtual-time
/// engine (stock toolchain); plain `serve` is the real-PJRT demo.
fn cmd_serve(args: &Args) -> i32 {
    if let Some(name) = args.opt("scenario") {
        return cmd_serve_scenario(name, args);
    }
    cmd_serve_pjrt(args)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args) -> i32 {
    eprintln!(
        "the plain serve subcommand needs real PJRT inference — rebuild \
         with `cargo run --release --features pjrt -- serve`, or stream a \
         live scenario on the virtual-time engine with \
         `synergy serve --scenario jog`"
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args) -> i32 {
    use synergy::api::PjrtBackend;
    use synergy::plan::EnumerateCfg;
    let backend = match PjrtBackend::load(args.opt("artifacts").unwrap_or("artifacts")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    // The serving demo uses the three models aot.py emits split chunks
    // for, restricted to 2-way splits so every chunk has an artifact.
    let mut planner = Synergy::planner();
    planner.cfg.enumerate = EnumerateCfg { max_split_devices: 2 };
    let runtime = SynergyRuntime::builder()
        .fleet(workload::fleet4())
        .planner(planner)
        .backend(backend)
        .build();
    use synergy::model::zoo::ModelName;
    for (i, m) in [ModelName::ConvNet5, ModelName::KWS, ModelName::SimpleNet]
        .iter()
        .enumerate()
    {
        let spec = workload::pipeline(i, *m, i % 4, (i + 1) % 4);
        if let Err(e) = runtime.register(spec) {
            eprintln!("orchestration failed: {e}");
            return 1;
        }
    }
    let Some(dep) = runtime.deployment() else {
        eprintln!("orchestration selected no deployment (no apps registered)");
        return 1;
    };
    println!("deployment:");
    for ep in &dep.plan.plans {
        println!("  {ep}");
    }
    let cfg = RunConfig {
        runs: args.opt_parse("runs", 8),
        max_inflight: args.opt_parse("inflight", 2),
        verify: true,
        seed: args.opt_parse("seed", 42),
    };
    match runtime.run(&cfg) {
        Ok(rep) => {
            let verified = rep.verified == Some(true);
            println!(
                "served {} runs in {:.2}s — {:.1} inf/s wall-clock, verified={}",
                rep.completions,
                rep.wall_s.unwrap_or(0.0),
                rep.throughput,
                verified
            );
            for p in &rep.per_app {
                println!(
                    "  {}: {} runs, mean latency {:.1} ms, max split err {:.2e}",
                    p.name,
                    p.completions,
                    p.mean_latency_s * 1e3,
                    p.max_split_err.unwrap_or(0.0)
                );
            }
            if verified {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("serving failed: {e}");
            1
        }
    }
}

/// Flight-record a canned scenario session and export the recording as
/// Chrome/Perfetto trace-event JSON: one track per (device, unit), instant
/// markers for plan switches, counter tracks for power/battery/in-flight
/// rounds. `--serve` re-seats the session on the streaming engine so the
/// per-worker busy lanes land in the trace too.
fn cmd_trace_scenario(name: &str, args: &Args) -> i32 {
    let (runtime, scenario, mut cfg) = match canned_runtime(name, args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    cfg.record_trace = true;
    let session = match runtime.session_with(scenario, cfg).and_then(|s| {
        if args.flag("serve") {
            s.serve(synergy::serving::ServeCfg::default())
        } else {
            Ok(s)
        }
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace failed to start: {e}");
            return 1;
        }
    };
    let traced = match session.finish_traced() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trace failed: {e}");
            return 1;
        }
    };
    let chrome = synergy::obs::to_chrome_json(&traced.recording);
    eprintln!(
        "scenario {name:?} — {} trace events over {:.1} s simulated ({} tracks); \
         load the JSON at ui.perfetto.dev",
        traced.recording.len(),
        traced.report.duration,
        traced.recording.tracks.len(),
    );
    match args.opt("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &chrome) {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{chrome}"),
    }
    0
}

/// `synergy blame` — measured critical-path attribution of a canned
/// scenario: flight-record the session (sim engine, or the streaming
/// engine with `--serve`), reconstruct each round's critical path from
/// the recording, and print where every nanosecond of round latency
/// went (compute / radio / queue / pacing) plus the measured bottleneck
/// unit. Attribution is conservation-checked before printing: the four
/// categories sum bit-exactly to each round's latency.
fn cmd_blame(args: &Args) -> i32 {
    let name = args.opt("scenario").unwrap_or("cascade8");
    let (runtime, scenario, mut cfg) = match canned_runtime(name, args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    cfg.record_trace = true;
    let session = match runtime.session_with(scenario, cfg).and_then(|s| {
        if args.flag("serve") {
            s.serve(synergy::serving::ServeCfg::default())
        } else {
            Ok(s)
        }
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("blame failed to start: {e}");
            return 1;
        }
    };
    let traced = match session.finish_traced() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("blame failed: {e}");
            return 1;
        }
    };
    let blame = match synergy::obs::BlameReport::from_recording(&traced.recording) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("blame extraction failed: {e}");
            return 1;
        }
    };
    if let Err(e) = blame.check_conservation() {
        eprintln!("blame conservation violated: {e}");
        return 1;
    }
    if args.flag("json") {
        println!(
            "{}",
            synergy::obs::export::blame_report_json(&blame).to_string_pretty()
        );
        return 0;
    }
    let engine = if args.flag("serve") { "streaming" } else { "sim" };
    println!(
        "scenario {name:?} ({engine} engine) — blame over {} rounds ({} incomplete dropped):\n",
        blame.rounds, blame.incomplete_rounds
    );
    let secs = |ns: i64| synergy::util::fmt_secs(ns as f64 / 1e9);
    let mut t = Table::new([
        "pipeline", "rounds", "compute", "radio", "queue", "pacing", "mean latency", "dominant",
    ]);
    for p in &blame.pipelines {
        t.row([
            format!("p{}", p.pipeline),
            p.rounds.to_string(),
            secs(p.compute_ns),
            secs(p.radio_ns),
            secs(p.queue_ns),
            secs(p.pacing_ns),
            synergy::util::fmt_secs(p.mean_latency_s()),
            p.dominant().to_string(),
        ]);
    }
    t.print();
    println!("\nper-(device, unit) load on the critical path:");
    let mut t = Table::new(["device/unit", "busy", "queue caused", "normalized busy"]);
    for u in &blame.units {
        t.row([
            format!("d{} {:?}", u.device.0, u.unit),
            secs(u.busy_ns),
            secs(u.queue_caused_ns),
            format!("{:.3} s/round", u.normalized_busy_s),
        ]);
    }
    t.print();
    match blame.measured_bottleneck {
        Some((d, u)) => println!("\nmeasured bottleneck: d{} {u:?}", d.0),
        None => println!("\nmeasured bottleneck: none (no complete rounds)"),
    }
    0
}

/// `synergy trace-diff A.json B.json` — structural diff of two exported
/// Chrome traces: re-import both recordings, aggregate per
/// (process, thread, name), and print the ranked deltas plus the
/// per-pipeline blame movement. Exit 0 = identical, 1 = differences,
/// 2 = usage or parse error.
fn cmd_trace_diff(args: &Args) -> i32 {
    let (Some(path_a), Some(path_b)) = (args.positionals.get(1), args.positionals.get(2)) else {
        eprintln!("usage: synergy trace-diff A.json B.json [--json]");
        return 2;
    };
    let load = |path: &str| -> Result<synergy::obs::FlightRecording, i32> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("failed to read {path}: {e}");
                return Err(2);
            }
        };
        match synergy::obs::recording_from_chrome_json(&text) {
            Ok(r) => Ok(r),
            Err(e) => {
                eprintln!("{path}: {e}");
                Err(2)
            }
        }
    };
    let a = match load(path_a) {
        Ok(r) => r,
        Err(code) => return code,
    };
    let b = match load(path_b) {
        Ok(r) => r,
        Err(code) => return code,
    };
    let diff = synergy::obs::diff_recordings(&a, &b);
    if args.flag("json") {
        println!(
            "{}",
            synergy::obs::export::trace_diff_json(&diff).to_string_pretty()
        );
        return if diff.is_empty() { 0 } else { 1 };
    }
    if diff.is_empty() {
        println!("traces identical: {path_a} == {path_b}");
        return 0;
    }
    println!(
        "{} track deltas ({path_a} → {path_b}), largest first:\n",
        diff.entries.len()
    );
    let mut t = Table::new(["track", "name", "kind", "count", "total", "delta"]);
    for e in &diff.entries {
        t.row([
            format!("{}/{}", e.process, e.thread),
            e.name.clone(),
            e.kind.to_string(),
            format!("{} → {}", e.count_a, e.count_b),
            format!("{:.4} → {:.4}", e.total_a, e.total_b),
            format!("{:+.4}", e.delta()),
        ]);
    }
    t.print();
    if !diff.pipelines.is_empty() {
        println!("\nper-pipeline blame movement:");
        let mut t = Table::new(["pipeline", "rounds", "mean latency", "delta", "moved"]);
        for p in &diff.pipelines {
            t.row([
                format!("p{}", p.pipeline),
                format!("{} → {}", p.rounds_a, p.rounds_b),
                format!(
                    "{} → {}",
                    synergy::util::fmt_secs(p.mean_latency_a_s),
                    synergy::util::fmt_secs(p.mean_latency_b_s)
                ),
                format!("{:+.4} s", p.delta_latency_s()),
                p.moved.map(|c| c.to_string()).unwrap_or_else(|| "-".to_string()),
            ]);
        }
        t.print();
    }
    1
}

/// Per-unit utilization and a task timeline of a deployed workload — the
/// diagnostic view of what adaptive task parallelization actually does on
/// each computation unit (Fig. 12's story, measured).
fn cmd_trace(args: &Args) -> i32 {
    use synergy::scheduler::{simulate, GroundTruth, SimConfig};
    if let Some(name) = args.opt("scenario") {
        return cmd_trace_scenario(name, args);
    }
    // Strict parse: a typo must error, not silently trace Workload 1.
    let w = match args.opt("workload") {
        None => match workload::workload(1) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        Some(s) => match s.parse::<usize>().map(workload::workload) {
            Ok(Ok(w)) => w,
            Ok(Err(e)) => {
                eprintln!("{e}");
                return 2;
            }
            Err(_) => {
                eprintln!(
                    "unknown workload {s:?}: valid workloads are {}",
                    workload::workload_names()
                );
                return 2;
            }
        },
    };
    let fleet = workload::fleet4();
    let planner = Synergy::planner();
    let plan = match planner.plan(&w.pipelines, &fleet) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("orchestration failed: {e}");
            return 1;
        }
    };
    let runs = args.opt_parse("runs", 12usize);
    let rep = simulate(
        &plan,
        &w.pipelines,
        &fleet,
        &GroundTruth::with_seed(args.opt_parse("seed", 7u64)),
        SimConfig {
            runs,
            warmup: (runs / 6).min(4),
            policy: planner.exec_policy(),
            record_trace: true,
        },
    );
    println!(
        "{} — {:.2} inf/s over {} rounds (makespan {})\n",
        w.name,
        rep.throughput,
        runs,
        synergy::util::fmt_secs(rep.makespan)
    );
    let mut t = Table::new(["device/unit", "busy", "utilization", "timeline"]);
    let Some(trace) = rep.trace.as_ref() else {
        eprintln!("simulation recorded no trace despite record_trace");
        return 1;
    };
    const COLS: usize = 56;
    for (&(dev, unit), &busy) in &rep.unit_busy {
        // Coarse occupancy strip: one cell per makespan/COLS slice.
        let mut cells = [false; COLS];
        for s in trace.spans.iter().filter(|s| s.device == dev && s.unit == unit) {
            let a = ((s.start / rep.makespan) * COLS as f64) as usize;
            let b = ((s.end / rep.makespan) * COLS as f64).ceil() as usize;
            for c in cells.iter_mut().take(b.min(COLS)).skip(a.min(COLS - 1)) {
                *c = true;
            }
        }
        let strip: String = cells.iter().map(|&b| if b { '█' } else { '·' }).collect();
        t.row([
            format!("{} {:?}", fleet.get(dev).name, unit),
            synergy::util::fmt_secs(busy),
            format!("{:.0}%", 100.0 * busy / rep.makespan),
            strip,
        ]);
    }
    t.print();
    0
}
