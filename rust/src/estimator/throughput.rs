//! Online throughput estimation of holistic collaboration plans (§IV-E3).
//!
//! A holistic collaboration plan expands into a DAG of tasks: each
//! pipeline's tasks form a chain, and chains are independent. Two bounds
//! govern one "unified round" (every pipeline executed once, §III-C):
//!
//! - the **critical path** — the longest chain's cumulative latency (the
//!   paper's "longest path from any source task to any target task"), and
//! - the **bottleneck unit** — the busiest computation unit's total work;
//!   with adaptive task parallelization (§IV-F) rounds pipeline through
//!   units, so steady-state round period approaches this bound.
//!
//! Estimated round latency is `max(critical path, bottleneck)`;
//! steady-state throughput is `#pipelines / bottleneck-period`
//! (`inverse of end-to-end latency × number of pipelines` for the
//! non-pipelined reading); power follows from per-unit active energy.

use std::collections::BTreeMap;

use crate::device::{DeviceId, Fleet};
use crate::pipeline::PipelineSpec;
use crate::plan::task::{TaskKind, UnitKind};
use crate::plan::CollabPlan;

use super::tasks::LatencyModel;

/// Estimator output for one holistic collaboration plan.
#[derive(Clone, Debug)]
pub struct PlanEstimate {
    /// Per-pipeline chain latency (sequential execution of its own tasks),
    /// index-aligned with the plan's pipelines.
    pub chain_latency: Vec<f64>,
    /// Longest chain — the DAG critical path.
    pub critical_path: f64,
    /// Busiest (device, unit) total work per round.
    pub bottleneck: f64,
    /// Estimated latency of one unified round.
    pub round_latency: f64,
    /// Steady-state throughput under ATP, in model executions per second.
    pub throughput: f64,
    /// Throughput if pipelines run strictly back-to-back (no ATP).
    pub throughput_sequential: f64,
    /// Average power in watts (active energy / round period + base).
    pub power_w: f64,
    /// Average power under sequential (non-ATP) execution.
    pub power_sequential_w: f64,
    /// Active energy per round in joules (excludes base draw).
    pub active_energy_j: f64,
}

/// Incremental estimate accumulator for progressive plan accumulation
/// (§IV-D): holds per-unit busy sums, chain latencies and active energy of
/// already-selected execution plans, so each candidate for the next
/// pipeline is evaluated in O(its own task count) with a cheap clone.
#[derive(Clone, Debug)]
pub struct EstimateAccum {
    unit_busy: BTreeMap<(DeviceId, UnitKind), f64>,
    chains: Vec<f64>,
    active_energy_j: f64,
    base_w: f64,
}

impl EstimateAccum {
    pub fn new(fleet: &Fleet) -> EstimateAccum {
        EstimateAccum {
            unit_busy: BTreeMap::new(),
            chains: Vec::new(),
            active_energy_j: 0.0,
            base_w: fleet.devices.iter().map(|d| d.spec.power.base_w).sum(),
        }
    }

    /// Busiest unit's committed work — adding a plan can only raise it, so
    /// it lower-bounds every reachable period (used by the bounded search's
    /// optimistic-score pruning, `Objective::score_upper_bound`).
    pub fn bottleneck(&self) -> f64 {
        self.unit_busy.values().copied().fold(0.0, f64::max)
    }

    /// Longest committed chain (same monotonicity as [`Self::bottleneck`]).
    pub fn critical_path(&self) -> f64 {
        self.chains.iter().copied().fold(0.0, f64::max)
    }

    /// Number of committed pipelines.
    pub fn num_pipelines(&self) -> usize {
        self.chains.len()
    }

    /// Fold one execution plan into the accumulator.
    pub fn add_plan(
        &mut self,
        ep: &crate::plan::exec_plan::ExecutionPlan,
        spec: &PipelineSpec,
        fleet: &Fleet,
        lm: &LatencyModel,
    ) {
        let sensor = LatencyModel::source_sensor(spec);
        let mut chain = 0.0;
        for task in ep.tasks(&spec.model) {
            let lat = lm.task_latency(&task, &spec.model, sensor);
            chain += lat;
            *self.unit_busy.entry((task.device, task.unit())).or_default() += lat;
            let p = &fleet.get(task.device).spec.power;
            self.active_energy_j += lat
                * match task.kind {
                    TaskKind::Sense { .. } => p.sensor_active_w,
                    TaskKind::Load { .. } | TaskKind::Unload { .. } | TaskKind::Interact { .. } => {
                        p.cpu_active_w
                    }
                    TaskKind::Infer { .. } => {
                        if fleet.get(task.device).has_accel() {
                            p.accel_active_w
                        } else {
                            p.cpu_active_w
                        }
                    }
                    TaskKind::Tx { .. } => p.radio_tx_w,
                    TaskKind::Rx { .. } => p.radio_rx_w,
                };
        }
        self.chains.push(chain);
    }

    /// Evaluate the accumulator plus one tentative plan without committing.
    pub fn peek(
        &self,
        ep: &crate::plan::exec_plan::ExecutionPlan,
        spec: &PipelineSpec,
        fleet: &Fleet,
        lm: &LatencyModel,
    ) -> PlanEstimate {
        let mut tmp = self.clone();
        tmp.add_plan(ep, spec, fleet, lm);
        tmp.finish()
    }

    /// Allocation- and clone-free candidate evaluation: computes the same
    /// estimate as [`Self::peek`] (modulo the per-pipeline chain vector,
    /// which scoring never reads) by tracking only the candidate's own
    /// per-unit deltas in the caller-provided scratch buffer. Additions are
    /// monotone, so the new bottleneck is `max(old, touched keys)`. This is
    /// the progressive search's inner loop (EXPERIMENTS.md §Perf).
    pub fn peek_fast(
        &self,
        ep: &crate::plan::exec_plan::ExecutionPlan,
        spec: &PipelineSpec,
        fleet: &Fleet,
        lm: &LatencyModel,
        scratch: &mut Vec<((DeviceId, UnitKind), f64)>,
    ) -> PlanEstimate {
        let sensor = LatencyModel::source_sensor(spec);
        scratch.clear();
        let mut chain = 0.0;
        let mut energy = 0.0;
        ep.for_each_task(&spec.model, |task| {
            let lat = lm.task_latency(&task, &spec.model, sensor);
            chain += lat;
            let key = (task.device, task.unit());
            match scratch.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => *v += lat,
                None => scratch.push((key, lat)),
            }
            let p = &fleet.get(task.device).spec.power;
            energy += lat
                * match task.kind {
                    TaskKind::Sense { .. } => p.sensor_active_w,
                    TaskKind::Load { .. } | TaskKind::Unload { .. } | TaskKind::Interact { .. } => {
                        p.cpu_active_w
                    }
                    TaskKind::Infer { .. } => {
                        if fleet.get(task.device).has_accel() {
                            p.accel_active_w
                        } else {
                            p.cpu_active_w
                        }
                    }
                    TaskKind::Tx { .. } => p.radio_tx_w,
                    TaskKind::Rx { .. } => p.radio_rx_w,
                };
        });

        let mut bottleneck = self.unit_busy.values().copied().fold(0.0, f64::max);
        for (key, delta) in scratch.iter() {
            let busy = self.unit_busy.get(key).copied().unwrap_or(0.0) + delta;
            bottleneck = bottleneck.max(busy);
        }
        let prior_critical = self.chains.iter().copied().fold(0.0, f64::max);
        let critical_path = prior_critical.max(chain);
        let prior_total: f64 = self.chains.iter().sum();
        let total_chain = prior_total + chain;
        let round_latency = critical_path.max(bottleneck);
        let n = (self.chains.len() + 1) as f64;
        let period = bottleneck.max(critical_path / 2.0).max(1e-12);
        let active_energy_j = self.active_energy_j + energy;
        PlanEstimate {
            chain_latency: Vec::new(), // not used by scoring
            critical_path,
            bottleneck,
            round_latency,
            throughput: n / period,
            throughput_sequential: n / total_chain.max(1e-12),
            power_w: self.base_w + active_energy_j / period,
            power_sequential_w: self.base_w + active_energy_j / total_chain.max(1e-12),
            active_energy_j,
        }
    }

    /// Produce the plan-level estimate from the accumulated state.
    pub fn finish(&self) -> PlanEstimate {
        let chain_latency = self.chains.clone();
        let critical_path = chain_latency.iter().copied().fold(0.0, f64::max);
        let bottleneck = self.unit_busy.values().copied().fold(0.0, f64::max);
        let round_latency = critical_path.max(bottleneck);
        let n = chain_latency.len() as f64;
        // ATP steady state: rounds pipeline through the units, so the
        // period approaches the bottleneck unit's work — bounded by the
        // critical path over the double-buffer window (max 2 in flight).
        let period = bottleneck.max(critical_path / 2.0).max(1e-12);
        let throughput = n / period;
        let total_chain: f64 = chain_latency.iter().sum();
        let throughput_sequential = n / total_chain.max(1e-12);
        // Average power over the steady-state period (same denominator as
        // throughput, so the estimate matches the measured duty cycle).
        let power_w = self.base_w + self.active_energy_j / period;
        let power_sequential_w = self.base_w + self.active_energy_j / total_chain.max(1e-12);
        PlanEstimate {
            chain_latency,
            critical_path,
            bottleneck,
            round_latency,
            throughput,
            throughput_sequential,
            power_w,
            power_sequential_w,
            active_energy_j: self.active_energy_j,
        }
    }
}

/// Estimate a holistic collaboration plan. `pipelines` must contain every
/// pipeline referenced by the plan.
pub fn estimate_plan(
    plan: &CollabPlan,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    lm: &LatencyModel,
) -> PlanEstimate {
    let mut acc = EstimateAccum::new(fleet);
    for ep in &plan.plans {
        let spec = pipelines
            .iter()
            .find(|p| p.id == ep.pipeline)
            .expect("plan for unknown pipeline");
        acc.add_plan(ep, spec, fleet, lm);
    }
    acc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::plan::exec_plan::ExecutionPlan;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn model() -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(16, 16, 3),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 2, cout: 16, residual: false, has_bias: true },
            ],
        )
    }

    fn pipelines(n: usize) -> Vec<PipelineSpec> {
        (0..n)
            .map(|i| PipelineSpec::new(i, format!("p{i}"), SourceReq::Any, model(), TargetReq::Any))
            .collect()
    }

    fn local_plan(pid: usize, dev: usize, ps: &[PipelineSpec]) -> ExecutionPlan {
        ExecutionPlan::monolithic(&ps[pid], DeviceId(dev), DeviceId(dev), DeviceId(dev))
    }

    #[test]
    fn single_pipeline_chain_is_critical_path() {
        let f = fleet(1);
        let ps = pipelines(1);
        let lm = LatencyModel::new(&f);
        let plan = CollabPlan::new(vec![local_plan(0, 0, &ps)]);
        let est = estimate_plan(&plan, &ps, &f, &lm);
        assert_eq!(est.chain_latency.len(), 1);
        assert!((est.critical_path - est.chain_latency[0]).abs() < 1e-12);
        assert!(est.round_latency >= est.critical_path);
        assert!(est.throughput > 0.0);
    }

    #[test]
    fn spreading_pipelines_beats_stacking() {
        let f = fleet(2);
        let ps = pipelines(2);
        let lm = LatencyModel::new(&f);
        let stacked = estimate_plan(
            &CollabPlan::new(vec![local_plan(0, 0, &ps), local_plan(1, 0, &ps)]),
            &ps, &f, &lm,
        );
        let spread = estimate_plan(
            &CollabPlan::new(vec![local_plan(0, 0, &ps), local_plan(1, 1, &ps)]),
            &ps, &f, &lm,
        );
        // Stacking doubles the bottleneck unit's work.
        assert!(spread.bottleneck < stacked.bottleneck);
        assert!(spread.throughput > stacked.throughput);
    }

    #[test]
    fn atp_throughput_at_least_sequential() {
        let f = fleet(2);
        let ps = pipelines(2);
        let lm = LatencyModel::new(&f);
        let plan = CollabPlan::new(vec![local_plan(0, 0, &ps), local_plan(1, 1, &ps)]);
        let est = estimate_plan(&plan, &ps, &f, &lm);
        assert!(est.throughput >= est.throughput_sequential - 1e-12);
    }

    #[test]
    fn power_includes_base_draw() {
        let f = fleet(2);
        let ps = pipelines(1);
        let lm = LatencyModel::new(&f);
        let plan = CollabPlan::new(vec![local_plan(0, 0, &ps)]);
        let est = estimate_plan(&plan, &ps, &f, &lm);
        let base: f64 = f.devices.iter().map(|d| d.spec.power.base_w).sum();
        assert!(est.power_w > base);
    }

    #[test]
    fn cross_device_plan_pays_radio_time() {
        let f = fleet(2);
        let ps = pipelines(1);
        let lm = LatencyModel::new(&f);
        let local = estimate_plan(
            &CollabPlan::new(vec![local_plan(0, 0, &ps)]),
            &ps, &f, &lm,
        );
        let remote = estimate_plan(
            &CollabPlan::new(vec![ExecutionPlan::monolithic(
                &ps[0], DeviceId(0), DeviceId(1), DeviceId(0),
            )]),
            &ps, &f, &lm,
        );
        assert!(remote.critical_path > 2.0 * local.critical_path);
    }
}
