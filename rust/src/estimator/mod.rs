//! §IV-E — latency and throughput estimation for distributed tiny AI
//! accelerators.
//!
//! The planner never measures: it predicts. Inference latency comes from
//! the clock-cycle model ([`clock`]); memory-op latency from a linear
//! regression fitted on a handful of profiled samples ([`memops`]);
//! communication from size-over-bandwidth ([`comm`]); sensing from profiles
//! ([`sensing`]). [`throughput`] composes per-task estimates into plan-level
//! latency/throughput/power figures used for holistic plan selection.

pub mod clock;
pub mod memops;
pub mod comm;
pub mod sensing;
pub mod tasks;
pub mod throughput;

pub use tasks::LatencyModel;
pub use throughput::{estimate_plan, EstimateAccum, PlanEstimate};
