//! Sensing and interaction latency profiles (§IV-E2): measured per sensor
//! kind and parameters during the profiling phase; the estimator matches an
//! app's requirements against these profiles. Values model typical capture
//! latencies of wearable-class parts.

use crate::device::SensorKind;

/// Capture latency of one sensing window/frame.
pub fn sense_latency(kind: SensorKind) -> f64 {
    match kind {
        // One camera frame at ~30 fps.
        SensorKind::Camera => 33e-3,
        // One audio feature window.
        SensorKind::Microphone => 64e-3,
        // IMU / PPG / pressure windows are short.
        SensorKind::Imu => 20e-3,
        SensorKind::Ppg => 25e-3,
        SensorKind::Pressure => 15e-3,
    }
}

/// Sensing latency when only the data size is known (source device chosen
/// by the planner without a declared sensor kind): bytes at a generic
/// capture rate, floored at a minimal frame time.
pub fn sense_latency_bytes(bytes: u64) -> f64 {
    (bytes as f64 / 2.0e6).max(10e-3)
}

/// Interaction (actuation) latency: haptic pulse setup, audio cue start,
/// display update — all a few milliseconds.
pub const INTERACT_LATENCY_S: f64 = 5e-3;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn camera_frame_is_30fps() {
        assert!((sense_latency(SensorKind::Camera) - 1.0 / 30.0).abs() < 1e-3);
    }

    #[test]
    fn generic_latency_scales_with_bytes_with_floor() {
        assert_eq!(sense_latency_bytes(100), 10e-3);
        assert!((sense_latency_bytes(2_000_000) - 1.0).abs() < 1e-9);
    }
}
