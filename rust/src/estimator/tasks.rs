//! Per-task latency estimation: dispatches each of the seven task types to
//! its model — clock cycles for inference, fitted regression for memory
//! ops, size/bandwidth for communication, profiles for sensing/interaction.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::device::{DeviceId, Fleet, SensorKind};
use crate::model::ModelGraph;
use crate::pipeline::{PipelineSpec, SourceReq};
use crate::plan::task::{PlanTask, TaskKind};

use super::clock;
use super::comm;
use super::memops::MemopModel;
use super::sensing;

/// The planner's latency model over a fleet: per-device memory-op
/// regressions plus the closed-form models for everything else.
///
/// Inference latencies off the P = 64 prefix-cache fast path (phone-class
/// accelerators, plain cores) are O(range length) to compute, so they are
/// memoized per `(device platform, model instance, layer range)` — the
/// progressive search re-evaluates the same chunk on the same platform
/// thousands of times per orchestration. The memo is interior-mutable so
/// `task_latency` stays `&self` on the hot path (which makes the model
/// `!Sync`; per-thread models are cheap to build).
pub struct LatencyModel<'f> {
    pub fleet: &'f Fleet,
    memops: Vec<Option<MemopModel>>,
    /// Dense device index → index of the first device with an identical
    /// platform spec (identical spec ⇒ identical latency for every task).
    slot_of: Vec<usize>,
    /// `(slot, model uid, range start, range end)` → inference seconds.
    infer_memo: RefCell<HashMap<(usize, u64, usize, usize), f64>>,
}

/// Dense device index → first device index with an identical spec.
fn slots_of(fleet: &Fleet) -> Vec<usize> {
    (0..fleet.len())
        .map(|i| {
            let spec = &fleet.devices[i].spec;
            (0..i)
                .find(|&j| fleet.devices[j].spec == *spec)
                .unwrap_or(i)
        })
        .collect()
}

impl<'f> LatencyModel<'f> {
    /// Build from the devices' bus constants directly (exact regression).
    pub fn new(fleet: &'f Fleet) -> LatencyModel<'f> {
        let memops = fleet
            .devices
            .iter()
            .map(|d| {
                d.spec
                    .accel
                    .as_ref()
                    .map(|a| MemopModel::from_bus(a.bus_bytes_per_s, a.bus_overhead_s))
            })
            .collect();
        LatencyModel {
            fleet,
            memops,
            slot_of: slots_of(fleet),
            infer_memo: RefCell::new(HashMap::new()),
        }
    }

    /// Build by profiling a ground-truth probe per device (the paper's
    /// measurement-driven path): `probe(device, bytes) -> seconds`.
    pub fn from_profile(
        fleet: &'f Fleet,
        mut probe: impl FnMut(DeviceId, u64) -> f64,
    ) -> LatencyModel<'f> {
        let memops = fleet
            .devices
            .iter()
            .map(|d| {
                d.spec
                    .accel
                    .as_ref()
                    .map(|_| MemopModel::fit(|bytes| probe(d.id, bytes)))
            })
            .collect();
        LatencyModel {
            fleet,
            memops,
            slot_of: slots_of(fleet),
            infer_memo: RefCell::new(HashMap::new()),
        }
    }

    /// Sensor kind declared by the pipeline's source requirement, if any.
    pub fn source_sensor(pipeline: &PipelineSpec) -> Option<SensorKind> {
        match pipeline.source {
            SourceReq::Sensor(s) => Some(s),
            _ => None,
        }
    }

    /// Estimated latency of one plan task.
    ///
    /// `model` is the pipeline's model (for inference cycle counts);
    /// `sensor` the declared sensor kind (for the sensing profile).
    pub fn task_latency(
        &self,
        task: &PlanTask,
        model: &ModelGraph,
        sensor: Option<SensorKind>,
    ) -> f64 {
        let dev = self.fleet.get(task.device);
        match task.kind {
            TaskKind::Sense { bytes } => sensor
                .map(sensing::sense_latency)
                .unwrap_or_else(|| sensing::sense_latency_bytes(bytes)),
            TaskKind::Load { bytes } | TaskKind::Unload { bytes } => self.memops[task.device.0]
                .as_ref()
                .map(|m| m.latency(bytes))
                // Loading into a phone-class runtime or plain MCU memory
                // still costs a copy; model as the CPU touching each byte.
                .unwrap_or(bytes as f64 / dev.spec.cpu_clock_hz),
            TaskKind::Infer { range } => match &dev.spec.accel {
                // P = 64 accelerators are O(1) via the model's prefix
                // cache — no memo needed on the ubiquitous case.
                Some(a) if a.parallel_procs == 64 => {
                    clock::infer_latency_accel(model, range, a.parallel_procs, a.clock_hz)
                }
                _ => {
                    let key = (
                        self.slot_of[task.device.0],
                        model.uid(),
                        range.start,
                        range.end,
                    );
                    let cached = self.infer_memo.borrow().get(&key).copied();
                    match cached {
                        Some(v) => v,
                        None => {
                            let v = match &dev.spec.accel {
                                Some(a) => clock::infer_latency_accel(
                                    model,
                                    range,
                                    a.parallel_procs,
                                    a.clock_hz,
                                ),
                                None => clock::infer_latency_sequential(
                                    model,
                                    range,
                                    dev.spec.cpu_clock_hz,
                                    dev.spec.cycles_per_mac,
                                ),
                            };
                            self.infer_memo.borrow_mut().insert(key, v);
                            v
                        }
                    }
                }
            },
            TaskKind::Tx { bytes, to } => comm::tx_latency(dev, self.fleet.get(to), bytes),
            TaskKind::Rx { bytes, from } => comm::tx_latency(self.fleet.get(from), dev, bytes),
            TaskKind::Interact { .. } => sensing::INTERACT_LATENCY_S,
        }
    }

    /// Number of memoized inference entries (test instrumentation).
    #[cfg(test)]
    pub(crate) fn infer_memo_entries(&self) -> usize {
        self.infer_memo.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::SplitRange;
    use crate::pipeline::{PipelineId, TargetReq};

    fn fleet() -> Fleet {
        Fleet::new(vec![
            Device::new(0, "a", DeviceKind::Max78000, vec![SensorKind::Camera], vec![]),
            Device::new(1, "b", DeviceKind::Max78002, vec![], vec![]),
        ])
    }

    fn model() -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(32, 32, 3),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 16, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 2, cout: 32, residual: false, has_bias: true },
            ],
        )
    }

    fn task(device: usize, kind: TaskKind) -> PlanTask {
        PlanTask { pipeline: PipelineId(0), seq: 0, device: DeviceId(device), kind }
    }

    #[test]
    fn infer_uses_accelerator_clock() {
        let f = fleet();
        let lm = LatencyModel::new(&f);
        let m = model();
        let r = SplitRange::new(0, 2);
        let t0 = lm.task_latency(&task(0, TaskKind::Infer { range: r }), &m, None);
        let t1 = lm.task_latency(&task(1, TaskKind::Infer { range: r }), &m, None);
        // MAX78002's CNN clock is 2× the MAX78000's.
        assert!((t0 / t1 - 2.0).abs() < 1e-9, "t0={t0} t1={t1}");
    }

    #[test]
    fn memops_match_bus_constants() {
        let f = fleet();
        let lm = LatencyModel::new(&f);
        let t = lm.task_latency(&task(0, TaskKind::Load { bytes: 100_000 }), &model(), None);
        assert!((t - (120e-6 + 0.01)).abs() < 1e-9);
    }

    #[test]
    fn profile_fit_agrees_with_direct() {
        let f = fleet();
        let direct = LatencyModel::new(&f);
        let probed = LatencyModel::from_profile(&f, |dev, bytes| {
            // Ground truth equals the bus constants here.
            let a = f.get(dev).spec.accel.as_ref().unwrap();
            a.bus_overhead_s + bytes as f64 / a.bus_bytes_per_s
        });
        let t = task(1, TaskKind::Unload { bytes: 50_000 });
        let a = direct.task_latency(&t, &model(), None);
        let b = probed.task_latency(&t, &model(), None);
        assert!((a - b).abs() / a < 1e-6);
    }

    #[test]
    fn sensing_uses_profile_when_kind_known() {
        let f = fleet();
        let lm = LatencyModel::new(&f);
        let t = task(0, TaskKind::Sense { bytes: 3072 });
        let with_kind = lm.task_latency(&t, &model(), Some(SensorKind::Camera));
        assert!((with_kind - 33e-3).abs() < 1e-9);
        let without = lm.task_latency(&t, &model(), None);
        assert_eq!(without, 10e-3); // generic floor
    }

    #[test]
    fn infer_latency_is_memoized_off_the_fast_path() {
        use crate::model::SplitRange;
        // A phone accelerator has 256 lanes, so it misses the P = 64
        // prefix cache and takes the memoized path.
        let f = Fleet::new(vec![Device::new(0, "phone", DeviceKind::Phone, vec![], vec![])]);
        let lm = LatencyModel::new(&f);
        let m = model();
        let t = task(0, TaskKind::Infer { range: SplitRange::new(0, 2) });
        let a = lm.task_latency(&t, &m, None);
        assert_eq!(lm.infer_memo_entries(), 1);
        let b = lm.task_latency(&t, &m, None);
        assert_eq!(lm.infer_memo_entries(), 1, "repeat query must hit the memo");
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn infer_memo_shares_platforms_but_not_model_instances() {
        use crate::model::SplitRange;
        // Two identical MCUs share one platform slot; two independently
        // built models (even with the same name) never collide (uid key).
        let f = Fleet::new(vec![
            Device::new(0, "a", DeviceKind::McuMax32650, vec![], vec![]),
            Device::new(1, "b", DeviceKind::McuMax32650, vec![], vec![]),
        ]);
        let lm = LatencyModel::new(&f);
        let m1 = model();
        let m2 = model();
        let r = SplitRange::new(0, 2);
        let a0 = lm.task_latency(&task(0, TaskKind::Infer { range: r }), &m1, None);
        let a1 = lm.task_latency(&task(1, TaskKind::Infer { range: r }), &m1, None);
        assert_eq!(lm.infer_memo_entries(), 1, "identical platforms share a slot");
        assert_eq!(a0.to_bits(), a1.to_bits());
        let _ = lm.task_latency(&task(0, TaskKind::Infer { range: r }), &m2, None);
        assert_eq!(lm.infer_memo_entries(), 2, "distinct model instances do not");
    }

    #[test]
    fn tx_rx_are_symmetric_link_times() {
        let f = fleet();
        let lm = LatencyModel::new(&f);
        let m = model();
        let tx = lm.task_latency(
            &task(0, TaskKind::Tx { bytes: 4096, to: DeviceId(1) }),
            &m,
            None,
        );
        let rx = lm.task_latency(
            &task(1, TaskKind::Rx { bytes: 4096, from: DeviceId(0) }),
            &m,
            None,
        );
        assert!((tx - rx).abs() < 1e-12);
        assert!(tx > 0.3); // 4 KB over ~11.5 kB/s
    }
}
