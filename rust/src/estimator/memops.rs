//! Memory-operation latency model (§IV-E1, measurement-driven).
//!
//! Data moves between SRAM and accelerator memory over the central bus at a
//! dedicated rate, so latency is linear in data size; the paper profiles a
//! few sizes and fits a linear regression rather than deriving constants
//! from datasheets. We do the same: `fit` samples the provided ground-truth
//! probe (the simulated hardware) at a handful of sizes and regresses.

use crate::util::stats::{linear_fit, LinearFit};

/// Fitted `latency = slope · bytes + intercept` model for load/unload ops.
#[derive(Clone, Copy, Debug)]
pub struct MemopModel {
    fit: LinearFit,
}

/// Sizes profiled during the fit (bytes). A few samples suffice because the
/// relationship is linear by construction of the bus.
pub const PROFILE_SIZES: [u64; 5] = [1 << 10, 8 << 10, 32 << 10, 128 << 10, 512 << 10];

impl MemopModel {
    /// Fit from a ground-truth probe (measured transfer time per size).
    pub fn fit(mut probe: impl FnMut(u64) -> f64) -> MemopModel {
        let xs: Vec<f64> = PROFILE_SIZES.iter().map(|&s| s as f64).collect();
        let ys: Vec<f64> = PROFILE_SIZES.iter().map(|&s| probe(s)).collect();
        MemopModel {
            fit: linear_fit(&xs, &ys),
        }
    }

    /// Construct directly from bus parameters (no profiling) — used by the
    /// estimator when exact constants are given.
    pub fn from_bus(bytes_per_s: f64, overhead_s: f64) -> MemopModel {
        MemopModel {
            fit: LinearFit {
                slope: 1.0 / bytes_per_s,
                intercept: overhead_s,
                r2: 1.0,
            },
        }
    }

    /// Predicted load/unload latency for `bytes`.
    pub fn latency(&self, bytes: u64) -> f64 {
        self.fit.predict(bytes as f64).max(0.0)
    }

    /// Fit quality (diagnostics; the paper's premise is r² ≈ 1).
    pub fn r2(&self) -> f64 {
        self.fit.r2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_bus_parameters() {
        // Ground truth: 10 MB/s bus with 120 µs setup.
        let m = MemopModel::fit(|bytes| 120e-6 + bytes as f64 / 10.0e6);
        let expect = 120e-6 + 65_536.0 / 10.0e6;
        assert!((m.latency(65_536) - expect).abs() < 1e-9);
        assert!(m.r2() > 0.999_999);
    }

    #[test]
    fn fit_tolerates_noise() {
        // ±2% multiplicative noise on the probe.
        let mut flip = 1.0f64;
        let m = MemopModel::fit(|bytes| {
            flip = -flip;
            (120e-6 + bytes as f64 / 10.0e6) * (1.0 + 0.02 * flip)
        });
        let ideal = 120e-6 + 100_000.0 / 10.0e6;
        let err = (m.latency(100_000) - ideal).abs() / ideal;
        assert!(err < 0.05, "err {err}");
    }

    #[test]
    fn from_bus_matches_formula() {
        let m = MemopModel::from_bus(16.0e6, 100e-6);
        assert!((m.latency(160_000) - (100e-6 + 0.01)).abs() < 1e-12);
    }

    #[test]
    fn latency_is_monotone_in_size() {
        let m = MemopModel::from_bus(10.0e6, 120e-6);
        assert!(m.latency(1000) < m.latency(2000));
        assert!(m.latency(0) >= 0.0);
    }
}
