//! The clock-cycle latency model (§IV-E1, Eq. 2–5).
//!
//! Tiny AI accelerators run inference on dedicated hardware, so cycle
//! counts translate to latency by construction — unlike parameter-count
//! regressions, which correlate weakly (Fig. 11a vs 11b). The accelerator
//! has `P` parallel per-channel processors and a convolution engine that
//! computes a K×K window in a single cycle, hence:
//!
//!   sequential core:  Ĉ_MLP = H_in·W_in·C_in·C_out
//!                     Ĉ_CNN = K²·H_in·W_out·C_in·C_out      (Eq. 2–3)
//!   accelerator:      C_MLP = H_in·W_in·⌈C_in/P⌉·C_out
//!                     C_CNN = H_in·W_out·⌈C_in/P⌉·C_out     (Eq. 4–5)
//!
//! Spatial dims are the layer's *pooled* input dims (pooling precedes the
//! convolution on these parts). Latency of a layer range is Σ C_l / F.

use crate::model::{Layer, LayerKind, ModelGraph, Shape, SplitRange};

/// Cycle count of one layer on an accelerator with `p` parallel processors.
pub fn layer_cycles_accel(layer: &Layer, input: Shape, p: usize) -> u64 {
    let pin = layer.pooled(input);
    let out = layer.out_shape(input);
    let cin_blocks = pin.c.div_ceil(p) as u64;
    match layer.kind {
        // Eq. 5 — the K×K window costs a single cycle.
        LayerKind::Conv2d { .. } => pin.h as u64 * out.w as u64 * cin_blocks * out.c as u64,
        // Depthwise: each channel is handled by its own processor lane; the
        // engine still walks H·W positions per channel block.
        LayerKind::DepthwiseConv2d { .. } => pin.h as u64 * out.w as u64 * cin_blocks,
        // Transpose conv writes a 2× grid: H_in rows, W_out columns.
        LayerKind::ConvTranspose2d { .. } => {
            pin.h as u64 * out.w as u64 * cin_blocks * out.c as u64
        }
        // Eq. 4.
        LayerKind::Linear => pin.h as u64 * pin.w as u64 * cin_blocks * out.c as u64,
    }
}

/// Cycle count of one layer on a sequential core (Eq. 2–3): no channel
/// parallelism and the K×K window is K² MAC iterations.
pub fn layer_cycles_sequential(layer: &Layer, input: Shape) -> u64 {
    let pin = layer.pooled(input);
    let out = layer.out_shape(input);
    let k2 = (layer.kernel() * layer.kernel()) as u64;
    match layer.kind {
        LayerKind::Conv2d { .. } | LayerKind::ConvTranspose2d { .. } => {
            k2 * pin.h as u64 * out.w as u64 * pin.c as u64 * out.c as u64
        }
        LayerKind::DepthwiseConv2d { .. } => k2 * pin.h as u64 * out.w as u64 * pin.c as u64,
        LayerKind::Linear => pin.h as u64 * pin.w as u64 * pin.c as u64 * out.c as u64,
    }
}

/// Total accelerator cycles of a layer range (O(1) for the ubiquitous
/// P = 64 via the model's prefix cache).
pub fn range_cycles_accel(model: &ModelGraph, r: SplitRange, p: usize) -> u64 {
    if p == 64 {
        return model.cycles_p64(r);
    }
    (r.start..r.end)
        .map(|l| layer_cycles_accel(&model.layers[l], model.in_shape(l), p))
        .sum()
}

/// Total sequential-core cycles of a layer range.
pub fn range_cycles_sequential(model: &ModelGraph, r: SplitRange) -> u64 {
    (r.start..r.end)
        .map(|l| layer_cycles_sequential(&model.layers[l], model.in_shape(l)))
        .sum()
}

/// `L_inf = Σ_l C_l / F` for a chunk on an accelerator (§IV-E1).
pub fn infer_latency_accel(model: &ModelGraph, r: SplitRange, p: usize, clock_hz: f64) -> f64 {
    range_cycles_accel(model, r, p) as f64 / clock_hz
}

/// Inference latency of a chunk on a plain core (Fig. 2's MCU baselines).
/// `cycles_per_mac` converts ideal MAC counts into core cycles (software
/// kernels spend several cycles per 8-bit MAC on loads/stores/requant).
pub fn infer_latency_sequential(
    model: &ModelGraph,
    r: SplitRange,
    clock_hz: f64,
    cycles_per_mac: f64,
) -> f64 {
    range_cycles_sequential(model, r) as f64 * cycles_per_mac / clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{model_by_name, ModelName};

    fn conv(cout: usize, pool: usize) -> Layer {
        Layer { kind: LayerKind::Conv2d { k: 3 }, pool, cout, residual: false, has_bias: true }
    }

    #[test]
    fn eq5_hand_computed() {
        // 28×28×16 input, 3×3 conv to 32 channels, P=64:
        // C = 28 · 28 · ⌈16/64⌉ · 32 = 28·28·1·32 = 25 088.
        let l = conv(32, 1);
        let c = layer_cycles_accel(&l, Shape::new(28, 28, 16), 64);
        assert_eq!(c, 28 * 28 * 32);
    }

    #[test]
    fn channel_blocks_round_up() {
        // 100 input channels on P=64 → 2 blocks.
        let l = conv(8, 1);
        let c = layer_cycles_accel(&l, Shape::new(10, 10, 100), 64);
        assert_eq!(c, 10 * 10 * 2 * 8);
    }

    #[test]
    fn eq3_sequential_has_k_squared() {
        let l = conv(32, 1);
        let shape = Shape::new(28, 28, 16);
        let seq = layer_cycles_sequential(&l, shape);
        assert_eq!(seq, 9 * 28 * 28 * 16 * 32);
        // Accelerator speedup on this layer: K²·C_in/⌈C_in/P⌉ = 9·16 = 144×.
        let acc = layer_cycles_accel(&l, shape, 64);
        assert_eq!(seq / acc, 144);
    }

    #[test]
    fn pooling_shrinks_cycle_count() {
        let no_pool = layer_cycles_accel(&conv(8, 1), Shape::new(16, 16, 8), 64);
        let pooled = layer_cycles_accel(&conv(8, 2), Shape::new(16, 16, 8), 64);
        assert_eq!(no_pool / pooled, 4);
    }

    #[test]
    fn linear_uses_eq4() {
        let l = Layer { kind: LayerKind::Linear, pool: 1, cout: 10, residual: false, has_bias: true };
        let c = layer_cycles_accel(&l, Shape::new(4, 4, 128), 64);
        assert_eq!(c, 4 * 4 * 2 * 10);
    }

    #[test]
    fn kws_latency_on_max78000_is_milliseconds() {
        // Fig. 2: KWS on the MAX78000 takes ~2 ms; on a 120 MHz Cortex-M4
        // it takes ~350 ms. Check our model lands in those regimes.
        let kws = model_by_name(ModelName::KWS);
        let accel_ms = infer_latency_accel(kws, kws.full(), 64, 50e6) * 1e3;
        let mcu_ms = infer_latency_sequential(kws, kws.full(), 120e6, 8.0) * 1e3;
        assert!((0.5..20.0).contains(&accel_ms), "accel {accel_ms} ms");
        assert!((100.0..2000.0).contains(&mcu_ms), "mcu {mcu_ms} ms");
        assert!(mcu_ms / accel_ms > 50.0, "speedup {}", mcu_ms / accel_ms);
    }

    #[test]
    fn range_cycles_are_additive() {
        let m = model_by_name(ModelName::SimpleNet);
        let total = range_cycles_accel(m, m.full(), 64);
        let a = range_cycles_accel(m, SplitRange::new(0, 7), 64);
        let b = range_cycles_accel(m, SplitRange::new(7, m.num_layers()), 64);
        assert_eq!(total, a + b);
    }
}
