//! Communication latency estimation (§IV-E2): data size divided by the
//! wireless channel bandwidth. Fluctuation-adaptive estimators are out of
//! scope, as in the paper.

use crate::device::{radio::link_time, Device};

/// Estimated one-hop transfer time between two devices.
pub fn tx_latency(from: &Device, to: &Device, bytes: u64) -> f64 {
    link_time(&from.spec.radio, &to.spec.radio, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceKind;

    #[test]
    fn wearable_to_wearable_is_uart_bound() {
        let a = crate::device::Device::new(0, "a", DeviceKind::Max78000, vec![], vec![]);
        let b = crate::device::Device::new(1, "b", DeviceKind::Max78000, vec![], vec![]);
        let t = tx_latency(&a, &b, 11_520);
        assert!((t - (8e-3 + 1.0)).abs() < 1e-9, "t = {t}");
    }

    #[test]
    fn wearable_to_phone_still_uart_bound() {
        let a = crate::device::Device::new(0, "a", DeviceKind::Max78000, vec![], vec![]);
        let p = crate::device::Device::new(1, "phone", DeviceKind::Phone, vec![], vec![]);
        // The wearable's bridge is the bottleneck in both directions.
        assert!((tx_latency(&a, &p, 11_520) - tx_latency(&p, &a, 11_520)).abs() < 1e-12);
    }
}
