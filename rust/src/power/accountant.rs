//! Per-device energy integration with presence banking — the accounting
//! core shared by the DES and the streaming engine.
//!
//! The arithmetic is the paper's monitor model: every computation unit
//! accumulates busy seconds, and a device's energy over a horizon `T` is
//! `P_base · T_present + Σ_unit P_active · t_busy`. Devices that leave the
//! body *bank* their accumulated energy (base draw stops; active energy of
//! still-draining in-flight tasks keeps counting), and devices that swap
//! platforms bank-and-restart under the new power spec. Slots never
//! shrink: a departed device keeps its history.
//!
//! Unchurned slots use the legacy single-expression energy formula so the
//! refactored accounting stays *bit-identical* to the pre-`power/` DES
//! numbers (pinned by `energy_accounting_matches_closed_form` in the
//! scheduler tests).

use crate::device::power::{BusyTimes, PowerSpec};
use crate::device::{DeviceId, Fleet};
use crate::plan::task::{TaskKind, UnitKind};

/// The energy category a completed busy interval charges. This is the
/// same mapping the DES always applied to [`TaskKind`]s, factored out so
/// the streaming engine's workers charge identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BusyKind {
    /// Sensor frontend sampling.
    Sensor,
    /// Core busy: memory ops, interaction glue, MCU inference.
    Cpu,
    /// CNN accelerator inferring.
    Accel,
    /// Radio transmitting.
    RadioTx,
    /// Radio receiving.
    RadioRx,
}

/// The energy category of one task, given the unit it actually ran on
/// (inference on an accelerator-less device runs — and is charged — on
/// the core).
pub fn busy_kind(kind: TaskKind, unit: UnitKind) -> BusyKind {
    match kind {
        TaskKind::Sense { .. } => BusyKind::Sensor,
        TaskKind::Load { .. } | TaskKind::Unload { .. } | TaskKind::Interact { .. } => {
            BusyKind::Cpu
        }
        TaskKind::Infer { .. } => {
            if unit == UnitKind::Accel {
                BusyKind::Accel
            } else {
                BusyKind::Cpu
            }
        }
        TaskKind::Tx { .. } => BusyKind::RadioTx,
        TaskKind::Rx { .. } => BusyKind::RadioRx,
    }
}

/// One completed busy interval on a device, as the streaming engine's
/// workers report them (virtual-time stamped, collected asynchronously
/// and replayed chronologically through [`EnergyReplay`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BusySpan {
    /// The device whose unit was busy.
    pub device: DeviceId,
    /// Which active draw the interval charges.
    pub kind: BusyKind,
    /// Busy seconds.
    pub dur: f64,
    /// Engine time the interval completed (the DES charges a task's full
    /// duration at its completion event; spans replay in `end` order).
    pub end: f64,
}

/// Per-device energy accounting slot. Indexed by dense device id and
/// never shrinking: a departed device keeps its accumulated energy, and
/// keeps accruing *active* energy while its last in-flight tasks drain.
struct Slot {
    power: PowerSpec,
    present: bool,
    /// When the current presence interval began.
    present_since: f64,
    /// Base (idle) energy banked from closed presence intervals.
    base_banked_j: f64,
    /// Active energy banked when the device departed or changed platform.
    active_banked_j: f64,
    /// Busy time accumulated since the last banking point.
    busy: BusyTimes,
    /// Whether this slot was ever banked (fleet churn). Unchurned slots
    /// use the legacy single-expression energy formula for bit-parity
    /// with the pre-session batch engine.
    churned: bool,
}

impl Slot {
    fn energy_j(&self, horizon: f64) -> f64 {
        if !self.churned && self.present {
            // No churn: identical arithmetic to the batch engine.
            self.busy.energy_j(&self.power, horizon - self.present_since)
        } else {
            let active = self.busy.energy_j(&self.power, 0.0);
            let mut e = self.base_banked_j + self.active_banked_j + active;
            if self.present && horizon > self.present_since {
                e += self.power.base_w * (horizon - self.present_since);
            }
            e
        }
    }

    /// Close the running accumulation at time `t` (departure or platform
    /// change).
    fn bank(&mut self, t: f64) {
        if self.present {
            self.base_banked_j += self.power.base_w * (t - self.present_since);
        }
        self.active_banked_j += self.busy.energy_j(&self.power, 0.0);
        self.busy = BusyTimes::default();
        self.present_since = t;
        self.churned = true;
    }
}

/// Per-device energy integration with presence banking (see the module
/// docs). One accountant serves one engine run.
pub struct Accountant {
    slots: Vec<Slot>,
}

impl Accountant {
    /// Open accounting for a fleet whose devices are all present at t=0.
    pub fn new(fleet: &Fleet) -> Accountant {
        Accountant {
            slots: fleet
                .devices
                .iter()
                .map(|d| Slot {
                    power: d.spec.power,
                    present: true,
                    present_since: 0.0,
                    base_banked_j: 0.0,
                    active_banked_j: 0.0,
                    busy: BusyTimes::default(),
                    churned: false,
                })
                .collect(),
        }
    }

    /// Reconcile the slots with a fleet change at time `t`: presence
    /// intervals close for departed devices (they stop accruing base
    /// power; in-flight tasks still drain and their active energy still
    /// counts) and open for new or platform-swapped ones.
    pub fn apply_fleet(&mut self, old: &Fleet, new: &Fleet, t: f64) {
        let (o, n) = (old.len(), new.len());
        for slot in self.slots.iter_mut().take(o).skip(n) {
            if slot.present {
                slot.bank(t);
                slot.present = false;
            }
        }
        for i in 0..o.min(n) {
            let (a, b) = (&old.devices[i], &new.devices[i]);
            if a.spec != b.spec {
                self.slots[i].bank(t);
                self.slots[i].power = b.spec.power;
            }
        }
        for i in o..n {
            if i < self.slots.len() {
                // A previously departed slot rejoined.
                let slot = &mut self.slots[i];
                slot.power = new.devices[i].spec.power;
                slot.present = true;
                slot.present_since = t;
                slot.churned = true;
            } else {
                self.slots.push(Slot {
                    power: new.devices[i].spec.power,
                    present: true,
                    present_since: t,
                    base_banked_j: 0.0,
                    active_banked_j: 0.0,
                    busy: BusyTimes::default(),
                    churned: true,
                });
            }
        }
    }

    /// Charge `dur` busy seconds of `kind` to `device`. Unknown devices
    /// (never part of any fleet this accountant saw) are ignored.
    pub fn record(&mut self, device: DeviceId, kind: BusyKind, dur: f64) {
        debug_assert!(device.0 < self.slots.len(), "busy on unknown {device}");
        let Some(slot) = self.slots.get_mut(device.0) else {
            return;
        };
        let b = &mut slot.busy;
        match kind {
            BusyKind::Sensor => b.sensor_s += dur,
            BusyKind::Cpu => b.cpu_s += dur,
            BusyKind::Accel => b.accel_s += dur,
            BusyKind::RadioTx => b.radio_tx_s += dur,
            BusyKind::RadioRx => b.radio_rx_s += dur,
        }
    }

    /// Total energy in joules if the horizon ended at `horizon` seconds.
    pub fn energy_total_j(&self, horizon: f64) -> f64 {
        let mut e = 0.0;
        for slot in &self.slots {
            e += slot.energy_j(horizon);
        }
        e
    }

    /// One device's energy in joules up to `horizon`.
    pub fn device_energy_j(&self, device: DeviceId, horizon: f64) -> f64 {
        self.slots.get(device.0).map_or(0.0, |s| s.energy_j(horizon))
    }

    /// Whether the device is currently on the body (its slot is accruing
    /// base power).
    pub fn present(&self, device: DeviceId) -> bool {
        self.slots.get(device.0).is_some_and(|s| s.present)
    }

    /// Whether the device was on the body at some point and has since
    /// left (distinct from a device no fleet has ever contained).
    pub fn departed(&self, device: DeviceId) -> bool {
        self.slots.get(device.0).is_some_and(|s| !s.present)
    }
}

/// Chronological replay of busy spans and fleet changes into an
/// [`Accountant`] — how the streaming serve path integrates energy after
/// the fact. Feed events in nondecreasing time order (spans by `end`,
/// spans before a fleet change at the same instant, matching the DES's
/// completions-before-churn event order) and query [`Self::energy_at`]
/// between them.
pub struct EnergyReplay {
    accountant: Accountant,
    fleet: Fleet,
}

impl EnergyReplay {
    /// Start a replay from the fleet that was present at t=0.
    pub fn new(fleet: Fleet) -> EnergyReplay {
        EnergyReplay {
            accountant: Accountant::new(&fleet),
            fleet,
        }
    }

    /// Apply a fleet change at time `t`.
    pub fn set_fleet(&mut self, new: Fleet, t: f64) {
        self.accountant.apply_fleet(&self.fleet, &new, t);
        self.fleet = new;
    }

    /// Charge one completed busy span.
    pub fn record(&mut self, span: &BusySpan) {
        self.accountant.record(span.device, span.kind, span.dur);
    }

    /// Total energy at `t`, given everything replayed so far.
    pub fn energy_at(&self, t: f64) -> f64 {
        self.accountant.energy_total_j(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    #[test]
    fn idle_fleet_accrues_base_power_only() {
        let f = fleet(2);
        let acct = Accountant::new(&f);
        let base: f64 = f.devices.iter().map(|d| d.spec.power.base_w).sum();
        let e = acct.energy_total_j(10.0);
        assert!((e - base * 10.0).abs() < 1e-12, "{e}");
    }

    #[test]
    fn busy_charges_land_on_the_right_draw() {
        let f = fleet(1);
        let mut acct = Accountant::new(&f);
        acct.record(DeviceId(0), BusyKind::RadioTx, 2.0);
        let p = f.get(DeviceId(0)).spec.power;
        let expect = p.base_w * 5.0 + p.radio_tx_w * 2.0;
        assert_eq!(acct.energy_total_j(5.0), expect);
        // The closed-form matches BusyTimes exactly (bit parity with the
        // legacy unchurned slot formula).
        let busy = BusyTimes { radio_tx_s: 2.0, ..Default::default() };
        assert_eq!(acct.device_energy_j(DeviceId(0), 5.0), busy.energy_j(&p, 5.0));
    }

    #[test]
    fn departure_banks_base_power_but_not_active_drain() {
        let (f2, f1) = (fleet(2), fleet(1));
        let mut acct = Accountant::new(&f2);
        acct.apply_fleet(&f2, &f1, 1.0);
        assert!(acct.departed(DeviceId(1)));
        let at_leave = acct.device_energy_j(DeviceId(1), 1.0);
        // Base stays frozen after departure…
        assert_eq!(acct.device_energy_j(DeviceId(1), 3.0), at_leave);
        // …but a draining in-flight task still charges active energy.
        acct.record(DeviceId(1), BusyKind::Accel, 0.5);
        assert!(acct.device_energy_j(DeviceId(1), 3.0) > at_leave);
        // The survivor keeps accruing.
        assert!(acct.device_energy_j(DeviceId(0), 3.0) > acct.device_energy_j(DeviceId(0), 1.0));
    }

    #[test]
    fn rejoin_reopens_presence_at_the_rejoin_instant() {
        let (f2, f1) = (fleet(2), fleet(1));
        let mut acct = Accountant::new(&f2);
        acct.apply_fleet(&f2, &f1, 1.0);
        acct.apply_fleet(&f1, &f2, 3.0);
        assert!(acct.present(DeviceId(1)));
        let base = f2.get(DeviceId(1)).spec.power.base_w;
        // 1 s before departure + 1 s after rejoin; the 2 s gap is free.
        let e = acct.device_energy_j(DeviceId(1), 4.0);
        assert!((e - base * 2.0).abs() < 1e-12, "{e}");
    }

    #[test]
    fn replay_matches_direct_accounting() {
        let f2 = fleet(2);
        let f1 = fleet(1);
        let mut direct = Accountant::new(&f2);
        direct.record(DeviceId(0), BusyKind::Cpu, 0.25);
        direct.apply_fleet(&f2, &f1, 2.0);
        direct.record(DeviceId(0), BusyKind::RadioTx, 0.5);

        let mut replay = EnergyReplay::new(f2.clone());
        replay.record(&BusySpan { device: DeviceId(0), kind: BusyKind::Cpu, dur: 0.25, end: 1.0 });
        replay.set_fleet(f1, 2.0);
        replay.record(&BusySpan {
            device: DeviceId(0),
            kind: BusyKind::RadioTx,
            dur: 0.5,
            end: 3.0,
        });
        assert_eq!(replay.energy_at(4.0), direct.energy_total_j(4.0));
    }

    #[test]
    fn busy_kind_matches_task_units() {
        use crate::model::SplitRange;
        let infer = TaskKind::Infer { range: SplitRange::new(0, 1) };
        assert_eq!(busy_kind(infer, UnitKind::Accel), BusyKind::Accel);
        // MCU inference charges the core.
        assert_eq!(busy_kind(infer, UnitKind::Cpu), BusyKind::Cpu);
        assert_eq!(busy_kind(TaskKind::Sense { bytes: 1 }, UnitKind::Sensor), BusyKind::Sensor);
        assert_eq!(
            busy_kind(TaskKind::Tx { bytes: 1, to: DeviceId(0) }, UnitKind::Radio),
            BusyKind::RadioTx
        );
        assert_eq!(
            busy_kind(TaskKind::Rx { bytes: 1, from: DeviceId(0) }, UnitKind::Radio),
            BusyKind::RadioRx
        );
        assert_eq!(busy_kind(TaskKind::Interact { bytes: 1 }, UnitKind::Cpu), BusyKind::Cpu);
    }
}
