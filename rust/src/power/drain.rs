//! Modeled per-device power draw of a deployed plan — the battery
//! subsystem's drain rates.
//!
//! Uses the same latency model and steady-state period as the planner's
//! estimator ([`crate::estimator::estimate_plan`]): per round, each
//! device's active energy is `Σ_task lat · P_active(task)` over the tasks
//! assigned to it, the round period is
//! `max(bottleneck, critical_path / 2)` (the ATP double-buffer window),
//! and a device's draw is `base + active_energy / period`. Devices with
//! no assigned tasks draw base power only. The drain is therefore
//! deterministic and engine-independent, which is what makes battery
//! depletion instants identical on the simulator and the serving engine.

use std::collections::BTreeMap;

use crate::device::{DeviceId, Fleet};
use crate::estimator::LatencyModel;
use crate::pipeline::PipelineSpec;
use crate::plan::task::UnitKind;
use crate::plan::CollabPlan;

use super::accountant::{busy_kind, BusyKind};

/// Modeled full draw (base + plan-induced active) per device, in watts,
/// indexed by dense device id. `plan = None` (deployment cleared) is base
/// draw everywhere. `pipelines` must contain every pipeline the plan
/// references (extra entries are ignored).
pub fn plan_device_draw(
    plan: Option<&CollabPlan>,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
) -> Vec<f64> {
    let mut draw: Vec<f64> = fleet.devices.iter().map(|d| d.spec.power.base_w).collect();
    let Some(plan) = plan else {
        return draw;
    };
    if plan.plans.is_empty() {
        return draw;
    }

    let lm = LatencyModel::new(fleet);
    let mut unit_busy: BTreeMap<(DeviceId, UnitKind), f64> = BTreeMap::new();
    let mut active: Vec<f64> = vec![0.0; fleet.len()];
    let mut critical = 0.0f64;
    for ep in &plan.plans {
        let Some(spec) = pipelines.iter().find(|p| p.id == ep.pipeline) else {
            continue;
        };
        let sensor = LatencyModel::source_sensor(spec);
        let mut chain = 0.0;
        for task in ep.tasks(&spec.model) {
            if task.device.0 >= fleet.len() {
                continue; // retiring plan may reference departed devices
            }
            let lat = lm.task_latency(&task, &spec.model, sensor);
            chain += lat;
            *unit_busy.entry((task.device, task.unit())).or_default() += lat;
            let p = &fleet.get(task.device).spec.power;
            let unit = if fleet.get(task.device).has_accel() {
                UnitKind::Accel
            } else {
                UnitKind::Cpu
            };
            active[task.device.0] += lat
                * match busy_kind(task.kind, unit) {
                    BusyKind::Sensor => p.sensor_active_w,
                    BusyKind::Cpu => p.cpu_active_w,
                    BusyKind::Accel => p.accel_active_w,
                    BusyKind::RadioTx => p.radio_tx_w,
                    BusyKind::RadioRx => p.radio_rx_w,
                };
        }
        critical = critical.max(chain);
    }
    let bottleneck = unit_busy.values().copied().fold(0.0, f64::max);
    let period = bottleneck.max(critical / 2.0).max(1e-12);
    for (d, a) in draw.iter_mut().zip(&active) {
        *d += a / period;
    }
    draw
}

/// Sound per-device upper bound on the draw *any* deployment can induce,
/// in watts, indexed by dense device id: base plus every unit active at
/// once (radio at the larger of Tx/Rx power). Real draws are strictly
/// lower — each unit's busy time per round is at most the bottleneck's,
/// which is at most the round period — so `active_energy / period ≤
/// Σ_unit P_active(unit)`. The scenario linter uses this for static
/// earliest-depletion windows ([`crate::analysis::battery_depletion_windows`]).
pub fn peak_device_draw(fleet: &Fleet) -> Vec<f64> {
    fleet
        .devices
        .iter()
        .map(|d| {
            let p = &d.spec.power;
            p.base_w
                + p.sensor_active_w
                + p.cpu_active_w
                + p.accel_active_w
                + p.radio_tx_w.max(p.radio_rx_w)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::device::DeviceKind;
    use crate::estimator::estimate_plan;
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::plan::exec_plan::ExecutionPlan;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn pipes(n: usize) -> Vec<PipelineSpec> {
        let layer =
            Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true };
        let model = ModelGraph::new("m", Shape::new(16, 16, 3), vec![layer]);
        (0..n)
            .map(|i| {
                PipelineSpec::new(i, format!("p{i}"), SourceReq::Any, model.clone(), TargetReq::Any)
            })
            .collect()
    }

    #[test]
    fn no_plan_draws_base_everywhere() {
        let f = fleet(3);
        let draw = plan_device_draw(None, &[], &f);
        for (d, dev) in draw.iter().zip(&f.devices) {
            assert_eq!(*d, dev.spec.power.base_w);
        }
    }

    #[test]
    fn loaded_devices_draw_above_base_and_sum_matches_the_estimator() {
        let f = fleet(2);
        let ps = pipes(1);
        let plan = CollabPlan::new(vec![ExecutionPlan::monolithic(
            &ps[0],
            DeviceId(0),
            DeviceId(0),
            DeviceId(0),
        )]);
        let draw = plan_device_draw(Some(&plan), &ps, &f);
        assert!(draw[0] > f.get(DeviceId(0)).spec.power.base_w);
        assert_eq!(draw[1], f.get(DeviceId(1)).spec.power.base_w, "idle device draws base");
        // Summing per-device draws reproduces the estimator's system power.
        let lm = LatencyModel::new(&f);
        let est = estimate_plan(&plan, &ps, &f, &lm);
        let total: f64 = draw.iter().sum();
        assert!((total - est.power_w).abs() < 1e-9, "{total} vs {}", est.power_w);
    }

    #[test]
    fn cross_device_plans_charge_the_radio_on_both_ends() {
        let f = fleet(2);
        let ps = pipes(1);
        let local = plan_device_draw(
            Some(&CollabPlan::new(vec![ExecutionPlan::monolithic(
                &ps[0],
                DeviceId(0),
                DeviceId(0),
                DeviceId(0),
            )])),
            &ps,
            &f,
        );
        let remote = plan_device_draw(
            Some(&CollabPlan::new(vec![ExecutionPlan::monolithic(
                &ps[0],
                DeviceId(0),
                DeviceId(1),
                DeviceId(0),
            )])),
            &ps,
            &f,
        );
        // The compute host now also receives/transmits; the second device
        // stops idling.
        assert!(remote[1] > local[1]);
        // Both deployments stay under the static peak bound.
        let peak = peak_device_draw(&f);
        for draw in [&local, &remote] {
            for (d, p) in draw.iter().zip(&peak) {
                assert!(d <= p, "plan draw {d} W exceeds peak bound {p} W");
            }
        }
    }
}
