//! Event-driven battery depletion.
//!
//! The session's old battery model polled the DES energy integral at a
//! fixed granularity, so depletion timing quantized to the poll step and
//! the streaming engine (with no mid-run energy probe) could not support
//! batteries at all. [`BatteryManager`] replaces the poll with a closed
//! form: each battery drains at the *modeled* per-device draw of the
//! currently deployed plan ([`super::plan_device_draw`]), a
//! piecewise-constant rate that changes only at timeline events (plan
//! switches, churn, recharges). Between events the depletion instant is
//! exact — `t_now + remaining / drain` — so the session schedules it as a
//! timeline event of its own, independent of any poll granularity and
//! identical across the simulator and the serving engine.
//!
//! [`BatteryCfg::peukert`] adds load-dependent capacity scaling: with
//! exponent `k > 1`, drawing above the device's reference (base) draw
//! depletes super-linearly (`drain = draw · (draw / ref)^(k−1)`), the
//! classic Peukert capacity derating. `k = 1` (the default) is the ideal
//! battery.

use crate::device::DeviceId;

/// Per-battery model configuration (see [`crate::api::Scenario::battery_with`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatteryCfg {
    /// Peukert load exponent: effective drain is
    /// `draw · (draw / ref)^(peukert − 1)` with `ref` the device's base
    /// draw. `1.0` (default) disables the derating.
    pub peukert: f64,
}

impl Default for BatteryCfg {
    fn default() -> BatteryCfg {
        BatteryCfg { peukert: 1.0 }
    }
}

#[derive(Clone, Debug)]
struct Battery {
    device: DeviceId,
    capacity_j: f64,
    remaining_j: f64,
    cfg: BatteryCfg,
    /// Reference draw for the Peukert derating (device base watts).
    ref_w: f64,
    /// Modeled draw under the current plan, watts.
    draw_w: f64,
    /// Whether the device has ever been on the body (a battery declared
    /// for a device scripted to join later stays armed, drain-free).
    started: bool,
    /// Whether the device is on the body right now (draining).
    active: bool,
    /// Modeled joules actually subtracted since the last re-anchor (the
    /// amount [`BatteryManager::reanchor`] credits back before charging
    /// the measured integral instead).
    modeled_since_anchor: f64,
    /// Timeline position of the last re-anchor (set when the device
    /// first starts draining).
    anchor_t: f64,
}

impl Battery {
    fn drain_w(&self) -> f64 {
        if !self.active || self.draw_w <= 0.0 {
            return 0.0;
        }
        if self.cfg.peukert == 1.0 || self.ref_w <= 0.0 {
            return self.draw_w;
        }
        self.draw_w * (self.draw_w / self.ref_w).powf(self.cfg.peukert - 1.0)
    }
}

/// The session's battery timeline: piecewise-constant drains, exact
/// depletion instants (see the module docs). Drive it with
/// [`Self::advance`] to the current engine time before changing loads.
#[derive(Clone, Debug, Default)]
pub struct BatteryManager {
    batteries: Vec<Battery>,
    now: f64,
}

impl BatteryManager {
    /// Build from scenario declarations `(device, capacity_j, cfg)`.
    pub fn new(declared: &[(DeviceId, f64, BatteryCfg)]) -> BatteryManager {
        BatteryManager {
            batteries: declared
                .iter()
                .map(|&(device, capacity_j, cfg)| Battery {
                    device,
                    capacity_j,
                    remaining_j: capacity_j,
                    cfg,
                    ref_w: 0.0,
                    draw_w: 0.0,
                    started: false,
                    active: false,
                    modeled_since_anchor: 0.0,
                    anchor_t: 0.0,
                })
                .collect(),
            now: 0.0,
        }
    }

    /// Whether any battery is (still) armed.
    pub fn is_empty(&self) -> bool {
        self.batteries.is_empty()
    }

    /// Integrate the drains up to time `to` (clamped at empty).
    pub fn advance(&mut self, to: f64) {
        let dt = to - self.now;
        if dt > 0.0 {
            for b in &mut self.batteries {
                // Spend is capped at the remaining charge so a later
                // [`Self::reanchor`] credits back exactly what was taken.
                let spend = (b.drain_w() * dt).min(b.remaining_j);
                b.remaining_j -= spend;
                b.modeled_since_anchor += spend;
            }
            self.now = to;
        }
    }

    /// Re-anchor one battery to the engine's *measured* energy integral:
    /// credit back the modeled joules subtracted since the last anchor
    /// and charge `measured_j` — the DES accountant's actual per-device
    /// energy over the anchor window ([base + executed-task active
    /// draws](crate::power::Accountant::device_energy_j)) — instead. The
    /// session calls this at every plan switch, so between anchors the
    /// drain stays the exact piecewise-constant closed form (depletion
    /// instants remain poll-free events), while across switches the
    /// state of charge tracks what the device actually executed rather
    /// than the plan's steady-state estimate.
    ///
    /// Under a Peukert exponent above 1 the measured window is derated
    /// through the same law as the modeled drain, using the window's
    /// average draw: `drained = avg_w · (avg_w / ref_w)^(k−1) · dt`.
    pub fn reanchor(&mut self, device: DeviceId, measured_j: f64) {
        let now = self.now;
        for b in &mut self.batteries {
            if b.device != device || !b.active {
                continue;
            }
            let dt = now - b.anchor_t;
            let measured = measured_j.max(0.0);
            let drained = if b.cfg.peukert == 1.0 || b.ref_w <= 0.0 || dt <= 0.0 {
                measured
            } else {
                let avg_w = measured / dt;
                avg_w * (avg_w / b.ref_w).powf(b.cfg.peukert - 1.0) * dt
            };
            b.remaining_j =
                (b.remaining_j + b.modeled_since_anchor - drained).clamp(0.0, b.capacity_j);
            b.modeled_since_anchor = 0.0;
            b.anchor_t = now;
        }
    }

    /// Devices whose batteries are currently draining (the set the
    /// session re-anchors at each plan switch), in declaration order.
    pub fn active_devices(&self) -> Vec<DeviceId> {
        self.batteries.iter().filter(|b| b.active).map(|b| b.device).collect()
    }

    /// Reconcile with the (dense-id) fleet size after a churn event: a
    /// battery whose device is on the body starts/keeps draining; one
    /// whose device has *left* the body departs with it; one whose device
    /// has yet to join stays armed but drain-free. Call at the current
    /// timeline position (after [`Self::advance`]).
    pub fn sync_presence(&mut self, fleet_len: usize) {
        self.batteries.retain_mut(|b| {
            if b.device.0 < fleet_len {
                if !b.started {
                    // First time on the body: the measured-energy anchor
                    // window starts here, not at t = 0.
                    b.anchor_t = self.now;
                }
                b.started = true;
                b.active = true;
                true
            } else if b.started {
                // Scripted departures take their battery with them.
                false
            } else {
                b.active = false;
                true
            }
        });
    }

    /// Install the modeled per-device draw of the new deployment
    /// (`draw_w(d)` full watts including base, `ref_w(d)` the Peukert
    /// reference). Call at the current timeline position.
    pub fn set_loads(&mut self, draw_w: impl Fn(DeviceId) -> f64, ref_w: impl Fn(DeviceId) -> f64) {
        for b in &mut self.batteries {
            if b.active {
                b.draw_w = draw_w(b.device);
                b.ref_w = ref_w(b.device);
            }
        }
    }

    /// Script a recharge: add `joules`, clamped to the declared capacity.
    pub fn recharge(&mut self, device: DeviceId, joules: f64) {
        for b in &mut self.batteries {
            if b.device == device {
                b.remaining_j = (b.remaining_j + joules.max(0.0)).min(b.capacity_j);
            }
        }
    }

    /// Drop a battery (its device depleted and departed).
    pub fn remove(&mut self, device: DeviceId) {
        self.batteries.retain(|b| b.device != device);
    }

    /// Remaining charge of a device's battery, if one is armed.
    pub fn remaining_j(&self, device: DeviceId) -> Option<f64> {
        self.batteries.iter().find(|b| b.device == device).map(|b| b.remaining_j)
    }

    /// State of charge of every armed battery at the current timeline
    /// position, sorted by device id — the session samples this at each
    /// report-interval boundary so [`crate::api::Interval`] carries a
    /// plottable per-device series.
    pub fn snapshot(&self) -> Vec<(DeviceId, f64)> {
        let mut soc: Vec<(DeviceId, f64)> =
            self.batteries.iter().map(|b| (b.device, b.remaining_j)).collect();
        soc.sort_by_key(|&(d, _)| d);
        soc
    }

    /// The exact next depletion instant, if any. Device ids are dense, so
    /// only the fleet's current highest id can depart: a depleted
    /// non-suffix battery defers until churn frees the suffix (this is
    /// re-evaluated at every event).
    pub fn next_depletion(&self, fleet_len: usize) -> Option<(DeviceId, f64)> {
        let b = self
            .batteries
            .iter()
            .find(|b| b.active && b.device.0 + 1 == fleet_len)?;
        if b.remaining_j <= 0.0 {
            return Some((b.device, self.now));
        }
        let drain = b.drain_w();
        if drain <= 0.0 {
            return None;
        }
        Some((b.device, self.now + b.remaining_j / drain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manager(caps: &[(usize, f64)]) -> BatteryManager {
        let decls: Vec<(DeviceId, f64, BatteryCfg)> = caps
            .iter()
            .map(|&(d, c)| (DeviceId(d), c, BatteryCfg::default()))
            .collect();
        BatteryManager::new(&decls)
    }

    #[test]
    fn depletion_instant_is_exact_and_poll_free() {
        let mut m = manager(&[(2, 1.0)]);
        m.sync_presence(3);
        m.set_loads(|_| 0.25, |_| 0.25);
        // 1 J at 0.25 W → exactly t = 4.
        assert_eq!(m.next_depletion(3), Some((DeviceId(2), 4.0)));
        // Advancing halfway leaves half the charge and the same instant.
        m.advance(2.0);
        assert_eq!(m.remaining_j(DeviceId(2)), Some(0.5));
        assert_eq!(m.next_depletion(3), Some((DeviceId(2), 4.0)));
    }

    #[test]
    fn load_changes_move_the_depletion_instant() {
        let mut m = manager(&[(1, 1.0)]);
        m.sync_presence(2);
        m.set_loads(|_| 0.25, |_| 0.25);
        m.advance(2.0); // 0.5 J left
        m.set_loads(|_| 0.5, |_| 0.25); // plan switch doubles the draw
        assert_eq!(m.next_depletion(2), Some((DeviceId(1), 3.0)));
    }

    #[test]
    fn non_suffix_batteries_defer_until_the_suffix_frees() {
        let mut m = manager(&[(1, 0.1)]);
        m.sync_presence(3);
        m.set_loads(|_| 1.0, |_| 1.0);
        // d1 is not the suffix of a 3-device fleet: nothing fires…
        assert_eq!(m.next_depletion(3), None);
        m.advance(5.0); // …even though the charge is long gone…
        assert_eq!(m.remaining_j(DeviceId(1)), Some(0.0));
        // …until churn makes it the suffix, then it fires immediately.
        assert_eq!(m.next_depletion(2), Some((DeviceId(1), 5.0)));
    }

    #[test]
    fn recharge_extends_the_timeline_and_clamps_at_capacity() {
        let mut m = manager(&[(0, 2.0)]);
        m.sync_presence(1);
        m.set_loads(|_| 1.0, |_| 1.0);
        m.advance(1.5);
        m.recharge(DeviceId(0), 10.0);
        assert_eq!(m.remaining_j(DeviceId(0)), Some(2.0), "clamped at capacity");
        assert_eq!(m.next_depletion(1), Some((DeviceId(0), 3.5)));
    }

    #[test]
    fn peukert_derating_depletes_super_linearly_above_reference() {
        let decls = [(DeviceId(0), 1.0, BatteryCfg { peukert: 1.2 })];
        let mut m = BatteryManager::new(&decls);
        m.sync_presence(1);
        // At the reference draw the derating is neutral.
        m.set_loads(|_| 0.25, |_| 0.25);
        let at_ref = m.next_depletion(1).unwrap().1;
        assert!((at_ref - 4.0).abs() < 1e-12);
        // At 4× the reference, depletion comes sooner than the ideal 1 s.
        m.set_loads(|_| 1.0, |_| 0.25);
        let derated = m.next_depletion(1).unwrap().1;
        assert!(derated < 1.0, "{derated}");
    }

    #[test]
    fn reanchor_to_the_modeled_integral_is_a_no_op() {
        let mut m = manager(&[(0, 2.0)]);
        m.sync_presence(1);
        m.set_loads(|_| 0.5, |_| 0.5);
        m.advance(2.0); // modeled spend: 1 J
        m.reanchor(DeviceId(0), 1.0);
        assert_eq!(m.remaining_j(DeviceId(0)), Some(1.0));
        assert_eq!(m.next_depletion(1), Some((DeviceId(0), 4.0)));
    }

    #[test]
    fn reanchor_shifts_the_depletion_instant_with_the_measured_window() {
        // A device that actually executed more than the plan's
        // steady-state estimate depletes sooner; one that idled depletes
        // later. Same modeled draw either way.
        let mut hot = manager(&[(0, 2.0)]);
        hot.sync_presence(1);
        hot.set_loads(|_| 0.5, |_| 0.5);
        hot.advance(2.0);
        hot.reanchor(DeviceId(0), 1.5); // measured 1.5 J > modeled 1 J
        assert_eq!(hot.remaining_j(DeviceId(0)), Some(0.5));
        assert_eq!(hot.next_depletion(1), Some((DeviceId(0), 3.0)));

        let mut cool = manager(&[(0, 2.0)]);
        cool.sync_presence(1);
        cool.set_loads(|_| 0.5, |_| 0.5);
        cool.advance(2.0);
        cool.reanchor(DeviceId(0), 0.25); // mostly idle window
        assert_eq!(cool.remaining_j(DeviceId(0)), Some(1.75));
        assert_eq!(cool.next_depletion(1), Some((DeviceId(0), 5.5)));
    }

    #[test]
    fn reanchor_clamps_at_capacity_and_empty_and_resets_the_window() {
        let mut m = manager(&[(0, 1.0)]);
        m.sync_presence(1);
        m.set_loads(|_| 0.5, |_| 0.5);
        m.advance(1.0); // 0.5 J left, 0.5 J modeled
        m.reanchor(DeviceId(0), 10.0); // measured overdraw → empty, not negative
        assert_eq!(m.remaining_j(DeviceId(0)), Some(0.0));
        // The window reset means a second re-anchor has nothing modeled
        // left to credit back.
        m.reanchor(DeviceId(0), 0.0);
        assert_eq!(m.remaining_j(DeviceId(0)), Some(0.0));

        let mut m = manager(&[(0, 1.0)]);
        m.sync_presence(1);
        m.set_loads(|_| 0.5, |_| 0.5);
        m.advance(1.0);
        m.reanchor(DeviceId(0), 0.0); // measured-zero window credits back…
        assert_eq!(m.remaining_j(DeviceId(0)), Some(1.0), "…but clamps at capacity");
    }

    #[test]
    fn reanchor_ignores_inactive_batteries_and_respects_peukert() {
        // Not on the body yet: nothing to re-anchor.
        let mut m = manager(&[(5, 1.0)]);
        m.sync_presence(3);
        m.advance(1.0);
        m.reanchor(DeviceId(5), 0.7);
        assert_eq!(m.remaining_j(DeviceId(5)), Some(1.0));

        // Peukert: a measured window above the reference derates
        // super-linearly, exactly like the modeled drain at that draw.
        let decls = [(DeviceId(0), 4.0, BatteryCfg { peukert: 2.0 })];
        let mut m = BatteryManager::new(&decls);
        m.sync_presence(1);
        m.set_loads(|_| 0.5, |_| 0.5);
        m.advance(2.0); // modeled spend 1 J (at reference: no derating)
        // Measured 2 J over dt=2 → avg 1 W = 2× ref → derated ×2 → 4 J.
        m.reanchor(DeviceId(0), 2.0);
        assert_eq!(m.remaining_j(DeviceId(0)), Some(0.0));
    }

    #[test]
    fn active_devices_tracks_presence() {
        let mut m = manager(&[(1, 1.0), (4, 1.0)]);
        m.sync_presence(2); // d4 not on the body yet
        assert_eq!(m.active_devices(), vec![DeviceId(1)]);
        m.sync_presence(5);
        assert_eq!(m.active_devices(), vec![DeviceId(1), DeviceId(4)]);
    }

    #[test]
    fn scripted_departure_takes_the_battery_and_late_joiners_stay_armed() {
        let mut m = manager(&[(3, 1.0), (5, 1.0)]);
        m.sync_presence(4); // d5 not on the body yet: armed, not draining
        m.set_loads(|_| 1.0, |_| 1.0);
        m.advance(0.5);
        assert_eq!(m.remaining_j(DeviceId(5)), Some(1.0), "not draining before join");
        m.sync_presence(3); // d3 left by script: battery gone
        assert_eq!(m.remaining_j(DeviceId(3)), None);
        m.sync_presence(6); // d5 joined: now draining
        m.set_loads(|_| 1.0, |_| 1.0);
        assert_eq!(m.next_depletion(6), Some((DeviceId(5), 1.5)));
    }
}
