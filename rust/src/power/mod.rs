//! The unified energy & battery subsystem shared by every execution path.
//!
//! Power is a headline metric of the paper (§VII: Synergy cuts system
//! power 15.8% against the baselines precisely *because* maximizing
//! throughput minimizes radio time), so energy accounting cannot be a
//! private detail of one engine. This module owns it for all of them:
//!
//! - [`Accountant`] — per-device energy integration (`E = Σ_unit
//!   P_active · t_busy + P_base · T`) with presence banking across fleet
//!   churn. Extracted from the discrete-event engine's per-device slots
//!   and bit-identical to them: the DES ([`crate::scheduler::SimEngine`])
//!   feeds it completed busy intervals as events retire, and the
//!   streaming engine ([`crate::serving::ServeEngine`]) feeds it the same
//!   integration through [`BusySpan`]s reported by its workers — which is
//!   what makes served sessions report real `power_w`/`energy_j` and
//!   lets sim-vs-serve energy be compared on identical plans.
//! - [`EnergyReplay`] — post-hoc chronological replay of busy spans and
//!   fleet changes into an [`Accountant`], for engines (the streaming
//!   path) whose completions surface asynchronously.
//! - [`BatteryManager`] — *event-driven* battery depletion. Each battery
//!   drains at the current plan's modeled per-device draw
//!   ([`plan_device_draw`]); the exact depletion instant is solved in
//!   closed form and scheduled as a timeline event, recomputed on every
//!   plan switch, churn event, or recharge — no poll-step quantization,
//!   and identical instants on the simulator and the serving engine.
//!   [`BatteryCfg`] adds Peukert-style load-dependent capacity scaling;
//!   [`crate::api::ScenarioAction::Recharge`] scripts mid-run top-ups.
//!
//! Live sessions ([`crate::api::Session`]) tie it together: battery ramps
//! run on both engines, `scenario_cascade8` scripts a battery-driven
//! departure cascade, and `benches/power_benches.rs` gates that the
//! event-driven machinery stays within a few percent of a battery-free
//! session.

pub mod accountant;
pub mod battery;
pub mod drain;

pub use accountant::{busy_kind, Accountant, BusyKind, BusySpan, EnergyReplay};
pub use battery::{BatteryCfg, BatteryManager};
pub use drain::{peak_device_draw, plan_device_draw};
