//! `xtask` — repository lints and maintenance chores that rustc and
//! clippy don't enforce.
//!
//! Subcommands (CI runs all three):
//!
//! - `lint` — the rule set below.
//! - `bench-merge` — fold the measured snapshots the bench targets write
//!   to `target/BENCH_*.json` into the checked-in `benches/BENCH_*.json`
//!   trajectories: each metric's `baseline` is set to its latest measured
//!   value, arming the `max_delta_pct` regression window (a zero baseline
//!   means unseeded — only the hard `budget` gates).
//! - `validate-trace <file>` — structural validation that a file emitted
//!   by `synergy trace` parses as Chrome trace-event JSON (the format
//!   ui.perfetto.dev loads), via [`synergy::obs::validate_chrome_trace`].
//!
//! Run as `cargo run --bin xtask -- lint`. Five rules, all scoped to
//! non-test library code under `src/` (test modules, `tests/`, and
//! `benches/` are exempt — tests may unwrap freely):
//!
//! 1. **forbid-partial-cmp** — no `.partial_cmp(` call sites. Every float
//!    ordering in this crate is a time or a score; `partial_cmp().unwrap()`
//!    panics the moment a NaN appears (a zero-duration estimate, an
//!    inf/inf ratio), and silently-`None` comparisons corrupt sorts. Use
//!    `f64::total_cmp` (or derive `Ord`).
//! 2. **float-comparator** — comparator closures handed to `sort_by` /
//!    `min_by` / `max_by` / `binary_search_by` must order through a total
//!    order (`total_cmp` or `Ord::cmp`), the same rule, caught even when
//!    the comparison avoids `partial_cmp` (e.g. `a < b` on floats).
//! 3. **unwrap-budget** — a ratchet on `.unwrap()` / `.expect(` in
//!    non-test library code. The count must not grow; shrink it and lower
//!    [`UNWRAP_BUDGET`]. New code paths that can fail want typed errors
//!    ([`synergy::api::RuntimeError`] / [`synergy::analysis::AnalysisError`]),
//!    not panics.
//! 4. **forbid-wall-clock** — no `Instant::now(` / `SystemTime::now(`
//!    outside the whitelist in [`WALL_CLOCK_ALLOWED`]. Simulated time is
//!    the only clock the library reasons with: a stray wall-clock read in
//!    planner, estimator, or analysis code makes results irreproducible
//!    (and breaks the DES/serve cross-validation the CI gates on). The
//!    whitelisted sites are the real-execution measurement points, where
//!    wall time *is* the measurand.
//! 5. **obs-simulated-time** — `std::time` must not appear at all under
//!    `src/obs/`. The flight recorder stamps events in *simulated* (or
//!    caller-injected) time only; a wall-clock read anywhere in the
//!    tracing path would break the bit-identical-trace guarantees CI
//!    replays (reruns, 1/4/8 population workers, sim vs serve).
//!
//! The scanner strips comments, string/char literals, and `#[cfg(test)]`
//! modules with a small brace-tracking lexer — crude next to a real AST,
//! but dependency-free and byte-exact on this codebase's idioms.

use std::path::{Path, PathBuf};

/// Ratchet for rule 3: the number of `.unwrap()`/`.expect(` sites allowed
/// in non-test code under `src/` (counting feature-gated files too). Only
/// ever lower this — the lint prints the current count.
const UNWRAP_BUDGET: usize = 68;

/// Whitelist for rule 4: files allowed to read the wall clock in non-test
/// code, with the number of permitted call sites. All are measurement
/// points timing *real* execution (PJRT dispatch, serve-engine stage
/// timing, session wall-time accounting, the population CLI's end-to-end
/// serving-rate readout); everything else must take time from the
/// simulation clock or a caller-provided timestamp.
const WALL_CLOCK_ALLOWED: [(&str, usize); 5] = [
    ("api/session.rs", 1),
    ("main.rs", 1),
    ("serving/backend.rs", 1),
    ("serving/engine.rs", 2),
    ("serving/pjrt.rs", 3),
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => std::process::exit(lint()),
        Some("bench-merge") => std::process::exit(bench_merge()),
        Some("validate-trace") => match args.get(1) {
            Some(path) => std::process::exit(validate_trace(path)),
            None => {
                eprintln!("usage: cargo run --bin xtask -- validate-trace <file>");
                std::process::exit(2);
            }
        },
        _ => {
            eprintln!("usage: cargo run --bin xtask -- <lint|bench-merge|validate-trace FILE>");
            std::process::exit(2);
        }
    }
}

/// `validate-trace <file>`: structural Chrome trace-event validation of an
/// exported flight recording (CI smoke-checks the `synergy trace` output
/// with this before anyone loads it into Perfetto).
fn validate_trace(path: &str) -> i32 {
    let text = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("xtask validate-trace: cannot read {path}: {e}");
            return 2;
        }
    };
    match synergy::obs::validate_chrome_trace(&text) {
        Ok(events) => {
            println!("xtask validate-trace: {path}: ok ({events} trace events)");
            0
        }
        Err(e) => {
            eprintln!("xtask validate-trace: {path}: {e}");
            1
        }
    }
}

/// `bench-merge`: fold `target/BENCH_*.json` measured snapshots (written
/// by the bench targets) into the checked-in `benches/BENCH_*.json`
/// trajectories. For every metric with a measured value, `baseline` is
/// set to that value — arming the `max_delta_pct` regression window the
/// benches gate against on the next run. Files are rewritten in the
/// canonical pretty-printed form of [`synergy::util::json`] (sorted
/// keys), so reruns are byte-stable.
fn bench_merge() -> i32 {
    use synergy::util::json::Json;

    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut baselines: Vec<PathBuf> = match std::fs::read_dir(root.join("benches")) {
        Ok(entries) => entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect(),
        Err(e) => {
            eprintln!("xtask bench-merge: cannot read benches/: {e}");
            return 2;
        }
    };
    baselines.sort();

    let mut errors = 0usize;
    let mut merged_files = 0usize;
    for path in &baselines {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let measured_path = root.join("target").join(name);
        let Ok(measured_raw) = std::fs::read_to_string(&measured_path) else {
            println!("bench-merge: {name}: no snapshot in target/ (run the bench) — skipped");
            continue;
        };
        let (doc, measured) = match (
            std::fs::read_to_string(path).map_err(|e| e.to_string()).and_then(|s| {
                Json::parse(&s).map_err(|e| format!("benches/{name} does not parse: {e}"))
            }),
            Json::parse(&measured_raw).map_err(|e| format!("target/{name} does not parse: {e}")),
        ) {
            (Ok(d), Ok(m)) => (d, m),
            (a, b) => {
                for r in [a.err(), b.err()].into_iter().flatten() {
                    eprintln!("xtask bench-merge: {r}");
                }
                errors += 1;
                continue;
            }
        };
        let Some(samples) = measured.get("measured").and_then(Json::as_obj).cloned() else {
            eprintln!("xtask bench-merge: target/{name} has no `measured` object");
            errors += 1;
            continue;
        };
        let mut doc = doc;
        let mut armed = 0usize;
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Arr(metrics)) = top.get_mut("metrics") {
                for metric in metrics.iter_mut() {
                    let Json::Obj(fields) = metric else { continue };
                    let Some(value) = fields
                        .get("name")
                        .and_then(Json::as_str)
                        .and_then(|n| samples.get(n))
                        .and_then(Json::as_f64)
                    else {
                        continue;
                    };
                    fields.insert("baseline".to_string(), Json::Num(value));
                    armed += 1;
                }
            }
        }
        if armed == 0 {
            println!("bench-merge: {name}: snapshot names match no metric — skipped");
            continue;
        }
        let mut text = doc.to_string_pretty();
        text.push('\n');
        if let Err(e) = std::fs::write(path, text) {
            eprintln!("xtask bench-merge: cannot write benches/{name}: {e}");
            errors += 1;
            continue;
        }
        println!("bench-merge: {name}: armed {armed} baseline(s) from target/{name}");
        merged_files += 1;
    }
    println!(
        "xtask bench-merge: {merged_files}/{} trajectories updated",
        baselines.len()
    );
    if errors == 0 {
        0
    } else {
        1
    }
}

fn lint() -> i32 {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();

    let mut errors = 0usize;
    let mut unwraps = 0usize;
    for path in &files {
        let raw = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask: cannot read {}: {e}", path.display());
                return 2;
            }
        };
        let code = NonTestCode::strip(&raw);
        let rel = path.strip_prefix(&src).unwrap_or(path).display().to_string();

        for (line_no, line) in code.lines() {
            if line.contains(".partial_cmp(") {
                eprintln!(
                    "src/{rel}:{line_no}: forbidden `.partial_cmp(` — \
                     use f64::total_cmp (NaN-safe total order)"
                );
                errors += 1;
            }
        }
        for (line_no, body) in code.comparator_bodies() {
            if !(body.contains("total_cmp") || body.contains(".cmp(") || body.contains("cmp::")) {
                eprintln!(
                    "src/{rel}:{line_no}: comparator closure without a total \
                     order — order floats with f64::total_cmp, not `<`/`>`"
                );
                errors += 1;
            }
        }
        // The ratchet skips `src/bin/` (this tool and future dev tools are
        // not library code).
        if !rel.starts_with("bin/") && !rel.starts_with("bin\\") {
            for (_, line) in code.lines() {
                unwraps += count_calls(line, ".unwrap()") + count_calls(line, ".expect(");
            }
        }
        // Rule 5: the flight recorder stamps simulated/injected time only
        // — no `std::time` anywhere under src/obs/ (stricter than rule 4:
        // even a Duration import is suspect in the tracing path).
        if rel.starts_with("obs/") || rel.starts_with("obs\\") {
            for (line_no, line) in code.lines() {
                if line.contains("std::time") {
                    eprintln!(
                        "src/{rel}:{line_no}: `std::time` in the flight \
                         recorder — obs/ stamps simulated/injected time \
                         only (bit-identical traces are a CI gate)"
                    );
                    errors += 1;
                }
            }
        }
        // Rule 4: determinism — wall-clock reads only at the whitelisted
        // measurement points.
        let mut clock_sites = 0usize;
        for (line_no, line) in code.lines() {
            let n = count_calls(line, "Instant::now(") + count_calls(line, "SystemTime::now(");
            if n > 0 {
                clock_sites += n;
                let allowed = WALL_CLOCK_ALLOWED
                    .iter()
                    .find(|(f, _)| *f == rel)
                    .map_or(0, |&(_, k)| k);
                if clock_sites > allowed {
                    eprintln!(
                        "src/{rel}:{line_no}: wall-clock read outside the \
                         whitelist — simulated/injected time only (see \
                         WALL_CLOCK_ALLOWED in xtask.rs)"
                    );
                    errors += 1;
                }
            }
        }
    }

    println!("xtask lint: {} non-test unwrap/expect sites (budget {UNWRAP_BUDGET})", unwraps);
    if unwraps > UNWRAP_BUDGET {
        eprintln!(
            "unwrap-budget exceeded: {unwraps} > {UNWRAP_BUDGET} — new code \
             paths that can fail want typed errors, not panics"
        );
        errors += 1;
    }
    if errors == 0 {
        println!("xtask lint: clean ({} files)", files.len());
        0
    } else {
        eprintln!("xtask lint: {errors} finding(s)");
        1
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn count_calls(line: &str, needle: &str) -> usize {
    line.matches(needle).count()
}

/// Source with comments, string/char literals, and `#[cfg(test)]` modules
/// blanked out (line structure preserved, so reported line numbers match
/// the file on disk).
struct NonTestCode {
    lines: Vec<String>,
}

impl NonTestCode {
    fn strip(raw: &str) -> NonTestCode {
        let blanked = blank_comments_and_literals(raw);
        let mut lines: Vec<String> = blanked.lines().map(str::to_string).collect();

        // Blank `#[cfg(test)] mod … { … }` bodies by brace depth.
        let mut depth: i64 = 0;
        let mut pending_cfg_test = false;
        let mut test_until: Option<i64> = None;
        for line in &mut lines {
            let opens = line.matches('{').count() as i64;
            let closes = line.matches('}').count() as i64;
            if test_until.is_none() {
                if line.contains("#[cfg(test)]") {
                    pending_cfg_test = true;
                }
                if pending_cfg_test && line.contains("mod ") && opens > 0 {
                    test_until = Some(depth);
                    pending_cfg_test = false;
                }
            }
            depth += opens - closes;
            if let Some(d) = test_until {
                line.clear();
                if depth <= d {
                    test_until = None;
                }
            }
        }
        NonTestCode { lines }
    }

    fn lines(&self) -> impl Iterator<Item = (usize, &str)> {
        self.lines.iter().enumerate().map(|(i, l)| (i + 1, l.as_str()))
    }

    /// Comparator-call bodies: for each `sort_by(` / `min_by(` /
    /// `max_by(` / `binary_search_by(` call site, the text from the
    /// opening paren to its balanced close (possibly spanning lines).
    fn comparator_bodies(&self) -> Vec<(usize, String)> {
        const CALLS: [&str; 4] = [".sort_by(", ".min_by(", ".max_by(", ".binary_search_by("];
        let mut out = Vec::new();
        for (i, line) in self.lines.iter().enumerate() {
            for call in CALLS {
                let Some(at) = line.find(call) else { continue };
                let mut body = String::new();
                let mut depth = 0i64;
                let mut pos = at + call.len() - 1; // at the '('
                let mut row = i;
                'scan: loop {
                    let l = &self.lines[row];
                    for c in l[pos..].chars() {
                        body.push(c);
                        match c {
                            '(' => depth += 1,
                            ')' => {
                                depth -= 1;
                                if depth == 0 {
                                    break 'scan;
                                }
                            }
                            _ => {}
                        }
                    }
                    body.push('\n');
                    row += 1;
                    pos = 0;
                    if row >= self.lines.len() {
                        break;
                    }
                }
                out.push((i + 1, body));
            }
        }
        out
    }
}

/// Replace the contents of comments, string literals, and char literals
/// with spaces, preserving newlines (and therefore line numbers and brace
/// structure outside literals).
fn blank_comments_and_literals(raw: &str) -> String {
    let b: Vec<char> = raw.chars().collect();
    let mut out = String::with_capacity(raw.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        // Line comment (covers `///` and `//!` doc comments too).
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        // Block comment (nesting handled).
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string literal: `r"…"`, `r#"…"#`, `br#"…"#` — no escapes,
        // closes on `"` followed by the same number of `#`s.
        if c == 'r' || (c == 'b' && b.get(i + 1) == Some(&'r')) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0;
            while b.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&'"') {
                j += 1;
                'raw: while j < b.len() {
                    if b[j] == '\n' {
                        out.push('\n');
                    }
                    if b[j] == '"' && b[j + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                        j += 1 + hashes;
                        break 'raw;
                    }
                    j += 1;
                }
                out.push('"');
                out.push('"');
                i = j;
                continue;
            }
            // not a raw string — fall through
        }
        // String literal.
        if c == '"' {
            out.push('"');
            i += 1;
            while i < b.len() && b[i] != '"' {
                if b[i] == '\\' {
                    i += 1; // skip the escaped char
                }
                if b.get(i) == Some(&'\n') {
                    out.push('\n');
                }
                i += 1;
            }
            out.push('"');
            i += 1;
            continue;
        }
        // Char literal vs lifetime: a `'` is a char literal iff it closes
        // within a few chars (`'x'`, `'\n'`, `b'{'`) — lifetimes never
        // close.
        if c == '\'' {
            let close = if b.get(i + 1) == Some(&'\\') {
                // escaped char: find the next quote
                (i + 2..b.len().min(i + 8)).find(|&j| b[j] == '\'')
            } else if b.get(i + 2) == Some(&'\'') {
                Some(i + 2)
            } else {
                None
            };
            if let Some(j) = close {
                out.push('\'');
                out.push(' ');
                out.push('\'');
                i = j + 1;
                continue;
            }
            // lifetime — fall through
        }
        out.push(c);
        i += 1;
    }
    out
}
