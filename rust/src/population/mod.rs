//! Population-scale serving: many bodies, one runtime stack.
//!
//! A [`Population`](run_population) drives N independent *users* — each a
//! sampled fleet + day-in-the-life [`crate::api::Scenario`]
//! ([`crate::workload::sample_user`]) replayed on its own
//! [`crate::api::SynergyRuntime`] session — through one shared planning
//! service: every runtime joins the same [`GlobalPlanCache`], so
//! signature-equal planning problems across users run bounded search
//! once and share the selected plan
//! ([`crate::api::RuntimeBuilder::shared_plan_cache`]).
//!
//! **Determinism contract.** The aggregate [`PopulationReport`] —
//! distributions and the [`PopulationReport::fingerprint`] over every
//! user's simulated timeline — is bit-identical for a fixed
//! (users, seed range, fleet mix, beam, same-time policy), regardless of
//! the worker-pool size *and* of whether the shared cache is on: a cache
//! hit re-endpoints a plan that is bit-equal to the fresh search it
//! replaces (see [`crate::api::shared_cache`]), so only wall-clock
//! replan latency and the racy raw hit count vary between runs — both
//! are reported as a non-fingerprinted annex. `tests/population.rs`
//! pins all of this.
//!
//! CLI: `synergy population --users 1000 --seed-range 0..1000`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;

use crate::analysis::SameTimePolicy;
use crate::api::{
    GlobalPlanCache, PlanCacheStats, RuntimeError, SessionCfg, SessionReport, SynergyRuntime,
};
use crate::obs::{BlameReport, FlightRecording, MetricsRegistry, MetricsSnapshot};
use crate::orchestrator::Synergy;
use crate::plan::{FnvWriter, DEFAULT_BEAM_WIDTH};
use crate::util::stats::{mean, percentile};
use crate::workload::{sample_user, FleetMix};

/// Configures one population run (see [`run_population`]).
#[derive(Clone, Copy, Debug)]
pub struct PopulationCfg {
    /// How many user sessions to run.
    pub users: usize,
    /// Seed range `[seed_lo, seed_hi)`; user `i` draws seed
    /// `seed_lo + (i % (seed_hi − seed_lo))`, so ranges narrower than
    /// `users` deliberately repeat cohort members.
    pub seed_lo: u64,
    pub seed_hi: u64,
    /// Worker threads (0 = available parallelism). Any value produces
    /// the same report fingerprint.
    pub workers: usize,
    /// Beam width for each user's bounded plan search.
    pub beam: usize,
    /// Same-time tie policy for every user session.
    pub same_time: SameTimePolicy,
    /// Share one [`GlobalPlanCache`] across users (`false` replans every
    /// user from scratch — the bench baseline).
    pub shared_cache: bool,
    /// Which fleets the cohort draws from.
    pub mix: FleetMix,
    /// Record a flight-recorder trace for the cohort member(s) sampled
    /// with this seed (`None` = no tracing). When a narrow seed range
    /// repeats the seed, the lowest user index wins. The recording is
    /// emitted post-hoc from the user's deterministic report, so it is
    /// bit-identical across worker counts.
    pub trace_user: Option<u64>,
    /// Trace the user at this completions percentile instead of a fixed
    /// seed: the cohort runs untraced first, the seed at the percentile
    /// rank is picked deterministically, and that one session is
    /// replayed traced — so distributions, fingerprint, and cache
    /// counters are exactly those of an untraced run.
    /// [`PopulationCfg::trace_user`] takes precedence when both are set.
    pub trace_percentile: Option<Pctl>,
}

/// Completion-percentile selector for [`PopulationCfg::trace_percentile`]
/// (the CLI's `--trace-user p50|p95|p99`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pctl {
    P50,
    P95,
    P99,
}

impl Pctl {
    /// The percentile as a fraction of the rank range.
    pub fn fraction(self) -> f64 {
        match self {
            Pctl::P50 => 0.50,
            Pctl::P95 => 0.95,
            Pctl::P99 => 0.99,
        }
    }
}

impl std::str::FromStr for Pctl {
    type Err = String;

    fn from_str(s: &str) -> Result<Pctl, String> {
        match s {
            "p50" => Ok(Pctl::P50),
            "p95" => Ok(Pctl::P95),
            "p99" => Ok(Pctl::P99),
            other => Err(format!("unknown percentile {other:?} (expected p50, p95, or p99)")),
        }
    }
}

impl Default for PopulationCfg {
    fn default() -> PopulationCfg {
        PopulationCfg {
            users: 100,
            seed_lo: 0,
            seed_hi: 100,
            workers: 0,
            beam: DEFAULT_BEAM_WIDTH,
            same_time: SameTimePolicy::Deterministic,
            shared_cache: true,
            mix: FleetMix::Mixed,
            trace_user: None,
            trace_percentile: None,
        }
    }
}

/// Summary statistics of one per-user metric across the population.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dist {
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub mean: f64,
}

impl Dist {
    /// Distribution of a sample set (all zeros for empty input).
    pub fn of(xs: &[f64]) -> Dist {
        if xs.is_empty() {
            return Dist { min: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0, mean: 0.0 };
        }
        Dist {
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean: mean(xs),
        }
    }
}

/// One user's deterministic outcome (the population's per-row record).
#[derive(Clone, Debug)]
pub struct UserOutcome {
    pub seed: u64,
    /// Sampled fleet / journey labels ([`crate::workload::SampledUser`]).
    pub fleet_name: &'static str,
    pub journey: &'static str,
    /// Rounds completed over the session horizon.
    pub completions: usize,
    /// Session energy, joules.
    pub energy_j: f64,
    /// Plan switches over the timeline (including battery departures).
    pub switches: usize,
    /// Violated app-seconds: Σ span lengths over the session's
    /// QoS-violation spans (can exceed the horizon when several apps
    /// violate at once).
    pub qos_violation_s: f64,
    /// Σ wall-clock replan latency across this user's switches, seconds.
    /// Wall clock — excluded from [`Self::digest`].
    pub replan_wall_s: f64,
    /// FNV-1a digest of the user's simulated timeline: completions,
    /// energy, every switch's (t, cause, apps, estimated throughput),
    /// every QoS span. Excludes wall-clock fields and cache bookkeeping,
    /// which legitimately differ between cache-on/off and across worker
    /// interleavings.
    pub digest: u64,
}

/// Aggregate view of one population run.
#[derive(Clone, Debug)]
pub struct PopulationReport {
    pub users: usize,
    /// Worker threads actually used.
    pub workers: usize,
    /// Per-user rounds completed.
    pub completions: Dist,
    /// Per-user session energy, joules.
    pub energy_j: Dist,
    /// Per-user plan-switch counts.
    pub switches: Dist,
    /// Per-user violated app-seconds (see [`UserOutcome::qos_violation_s`]).
    pub qos_violation_s: Dist,
    /// Per-switch wall-clock replan latency across all users, seconds.
    /// Wall clock — a non-fingerprinted annex.
    pub replan_wall_s: Dist,
    /// Σ wall-clock replan latency across the whole population, seconds
    /// (the bench's cache-on vs cache-off planning-cost metric).
    pub replan_wall_total_s: f64,
    /// Shared-cache counters when [`PopulationCfg::shared_cache`] is on.
    /// [`PlanCacheStats::hit_rate`] is deterministic; the raw hit count
    /// is not (see [`crate::api::shared_cache`]).
    pub cache: Option<PlanCacheStats>,
    /// FNV-1a fingerprint over every user's (seed, digest) in user-index
    /// order — the bit-identity witness across worker counts and cache
    /// modes.
    pub fingerprint: u64,
    /// Per-user rows in user-index order.
    pub outcomes: Vec<UserOutcome>,
    /// Aggregate metrics: per-user outcome histograms, cohort counters,
    /// shared-cache counters, and the wall-clock annex (scrub with
    /// [`MetricsSnapshot::scrub_annex`] before determinism comparisons).
    pub metrics: MetricsSnapshot,
    /// Flight recording of the traced member ([`PopulationCfg::trace_user`]
    /// or the [`PopulationCfg::trace_percentile`] pick; lowest user index
    /// when the seed repeats); `None` when tracing was off or no user
    /// drew the seed.
    pub trace: Option<FlightRecording>,
    /// Seed of the traced member, when a recording was produced.
    pub traced_seed: Option<u64>,
    /// Blame summary of the traced member's recording — where that
    /// user's round latency went ([`BlameReport`]).
    pub blame: Option<BlameReport>,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Digest the deterministic slice of one session report (see
/// [`UserOutcome::digest`] for what is excluded and why).
fn digest_report(seed: u64, report: &SessionReport) -> u64 {
    use std::fmt::Write as _;
    let mut w = FnvWriter::new();
    let _ = write!(
        w,
        "u{seed}|c{}|d{:016x}|e{:016x}|",
        report.completions,
        report.duration.to_bits(),
        report.energy_j.to_bits()
    );
    for s in &report.switches {
        let _ = write!(
            w,
            "s{:016x}:{}:{}:{:016x};",
            s.t.to_bits(),
            s.cause,
            s.apps,
            s.est_throughput.to_bits()
        );
    }
    for q in &report.qos_spans {
        let _ = write!(
            w,
            "q{}:{}:{:?}:{:016x}:{:016x};",
            q.app,
            q.name,
            q.violation,
            q.start.to_bits(),
            q.end.to_bits()
        );
    }
    w.finish()
}

fn run_user(
    seed: u64,
    cfg: &PopulationCfg,
    cache: Option<&Arc<GlobalPlanCache>>,
) -> Result<(UserOutcome, Option<FlightRecording>), RuntimeError> {
    let user = sample_user(seed, cfg.mix);
    let mut builder = SynergyRuntime::builder()
        .fleet(user.fleet)
        .planner(Synergy::planner_bounded(cfg.beam));
    if let Some(c) = cache {
        builder = builder.shared_plan_cache(c.clone());
    }
    let runtime = builder.build();
    let traced = cfg.trace_user == Some(seed);
    let session = runtime.session_with(
        user.scenario,
        SessionCfg {
            seed,
            same_time: cfg.same_time,
            record_trace: traced,
            ..SessionCfg::default()
        },
    )?;
    let (report, recording) = if traced {
        let t = session.finish_traced()?;
        (t.report, Some(t.recording))
    } else {
        (session.finish()?, None)
    };
    let outcome = UserOutcome {
        seed,
        fleet_name: user.fleet_name,
        journey: user.journey,
        completions: report.completions,
        energy_j: report.energy_j,
        switches: report.switches.len(),
        qos_violation_s: report.qos_spans.iter().map(|q| q.end - q.start).sum(),
        replan_wall_s: report.switches.iter().map(|s| s.replan_wall_s).sum(),
        digest: digest_report(seed, &report),
    };
    Ok((outcome, recording))
}

/// Run the whole population: sample each user from the seed range, drive
/// every session to its horizon on a bounded worker pool, aggregate.
/// Per-user work depends only on (seed, cfg) and the *contents* of the
/// shared cache — which plan-selection purity makes order-independent —
/// so the report fingerprint is identical for every `workers` value.
///
/// The first failing user (by user index, deterministic) aborts the run
/// with its error.
pub fn run_population(cfg: &PopulationCfg) -> Result<PopulationReport, RuntimeError> {
    if cfg.users == 0 {
        return Err(RuntimeError::InvalidScenario(
            "population needs at least one user".into(),
        ));
    }
    if cfg.seed_hi <= cfg.seed_lo {
        return Err(RuntimeError::InvalidScenario(format!(
            "empty seed range {}..{} — need seed_lo < seed_hi",
            cfg.seed_lo, cfg.seed_hi
        )));
    }
    if cfg.beam == 0 {
        return Err(RuntimeError::InvalidScenario(
            "bounded search needs a beam width ≥ 1".into(),
        ));
    }

    let span = cfg.seed_hi - cfg.seed_lo;
    let seeds: Vec<u64> = (0..cfg.users)
        .map(|i| cfg.seed_lo + (i as u64 % span))
        .collect();
    let workers = if cfg.workers == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.workers
    }
    .clamp(1, cfg.users);

    let cache = if cfg.shared_cache {
        Some(Arc::new(GlobalPlanCache::new()))
    } else {
        None
    };

    // Bounded pool over an atomic work dispenser: workers pull the next
    // user index, so any pool size covers every user exactly once.
    let next = AtomicUsize::new(0);
    type Row = (usize, Result<(UserOutcome, Option<FlightRecording>), RuntimeError>);
    let rows: Mutex<Vec<Row>> = Mutex::new(Vec::with_capacity(cfg.users));
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= seeds.len() {
                    break;
                }
                let out = run_user(seeds[i], cfg, cache.as_ref());
                lock(&rows).push((i, out));
            });
        }
    });
    let mut rows = match rows.into_inner() {
        Ok(v) => v,
        Err(e) => e.into_inner(),
    };
    rows.sort_by(|a, b| a.0.cmp(&b.0));

    let mut outcomes = Vec::with_capacity(cfg.users);
    let mut trace = None;
    for (_, row) in rows {
        let (outcome, recording) = row?;
        // Rows arrive index-sorted, so the first recording seen is the
        // lowest-index user that drew the traced seed.
        if trace.is_none() {
            trace = recording;
        }
        outcomes.push(outcome);
    }

    let mut traced_seed = cfg.trace_user.filter(|_| trace.is_some());
    if trace.is_none() && cfg.trace_user.is_none() {
        if let Some(p) = cfg.trace_percentile {
            // Percentile pick, phase two: the cohort above ran untraced,
            // so its distributions, fingerprint, and cache counters are
            // exactly those of an untraced run. Rank users by
            // (completions, seed) — nearest rank, ties broken by seed —
            // and replay just that session traced. The replay skips the
            // shared cache so cohort cache counters stay untouched;
            // plan-selection purity makes the session bit-identical
            // either way.
            let mut ranked: Vec<(usize, u64)> =
                outcomes.iter().map(|o| (o.completions, o.seed)).collect();
            ranked.sort_unstable();
            let idx = ((ranked.len() - 1) as f64 * p.fraction()).round() as usize;
            let seed = ranked[idx].1;
            let mut traced_cfg = *cfg;
            traced_cfg.trace_user = Some(seed);
            let (outcome, recording) = run_user(seed, &traced_cfg, None)?;
            debug_assert!(
                outcomes.iter().any(|o| o.seed == seed && o.digest == outcome.digest),
                "traced replay diverged from the cohort pass"
            );
            trace = recording;
            traced_seed = Some(outcome.seed);
        }
    }
    let blame = match &trace {
        Some(rec) => Some(BlameReport::from_recording(rec).map_err(RuntimeError::InvalidScenario)?),
        None => None,
    };

    use std::fmt::Write as _;
    let mut fp = FnvWriter::new();
    let mut walls = Vec::new();
    for o in &outcomes {
        let _ = write!(fp, "{}:{:016x};", o.seed, o.digest);
        walls.push(o.replan_wall_s);
    }
    // Aggregate metrics: per-user outcome histograms (deterministic —
    // fed in user-index order), cohort counters, shared-cache counters,
    // and the wall-clock annex.
    let registry = MetricsRegistry::new();
    registry.counter("population.users").add(cfg.users as u64);
    registry.counter("population.workers").add(workers as u64);
    for o in &outcomes {
        registry.observe("user.completions", o.completions as f64);
        registry.observe("user.energy_j", o.energy_j);
        registry.observe("user.switches", o.switches as f64);
        registry.observe("user.qos_violation_s", o.qos_violation_s);
        registry.observe("annex.user.replan_wall_s", o.replan_wall_s);
    }
    registry.set_gauge("annex.population.replan_wall_total_s", walls.iter().sum());
    let cache_stats = cache.as_ref().map(|c| c.stats());
    if let Some(s) = &cache_stats {
        registry.counter("plan_cache.lookups").add(s.lookups);
        registry.counter("plan_cache.unique_signatures").add(s.unique_signatures as u64);
        registry.counter("plan_cache.unique_plans").add(s.unique_plans as u64);
        registry.set_gauge("plan_cache.hit_rate", s.hit_rate());
    }
    let mut metrics = registry.snapshot();
    if let Some(c) = &cache {
        // Pull the cache's own annex counters (the racy raw hit count).
        metrics.absorb_counters(&c.metrics().snapshot());
    }
    Ok(finish_report(
        cfg,
        workers,
        outcomes,
        walls,
        cache_stats,
        fp.finish(),
        metrics,
        trace,
        traced_seed,
        blame,
    ))
}

#[allow(clippy::too_many_arguments)]
fn finish_report(
    cfg: &PopulationCfg,
    workers: usize,
    outcomes: Vec<UserOutcome>,
    walls: Vec<f64>,
    cache: Option<PlanCacheStats>,
    fingerprint: u64,
    metrics: MetricsSnapshot,
    trace: Option<FlightRecording>,
    traced_seed: Option<u64>,
    blame: Option<BlameReport>,
) -> PopulationReport {
    let per_user = |f: fn(&UserOutcome) -> f64| -> Vec<f64> { outcomes.iter().map(f).collect() };
    PopulationReport {
        users: cfg.users,
        workers,
        completions: Dist::of(&per_user(|o| o.completions as f64)),
        energy_j: Dist::of(&per_user(|o| o.energy_j)),
        switches: Dist::of(&per_user(|o| o.switches as f64)),
        qos_violation_s: Dist::of(&per_user(|o| o.qos_violation_s)),
        replan_wall_s: Dist::of(&walls),
        replan_wall_total_s: walls.iter().sum(),
        cache,
        fingerprint,
        outcomes,
        metrics,
        trace,
        traced_seed,
        blame,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(users: usize, workers: usize, shared_cache: bool) -> PopulationCfg {
        PopulationCfg {
            users,
            seed_lo: 0,
            seed_hi: users as u64,
            workers,
            shared_cache,
            ..PopulationCfg::default()
        }
    }

    #[test]
    fn empty_or_inverted_cfgs_are_typed_errors() {
        assert!(run_population(&cfg(0, 1, true)).is_err());
        let bad = PopulationCfg { seed_lo: 5, seed_hi: 5, ..PopulationCfg::default() };
        assert!(run_population(&bad).is_err());
        let bad = PopulationCfg { beam: 0, ..PopulationCfg::default() };
        assert!(run_population(&bad).is_err());
    }

    #[test]
    fn small_population_runs_and_aggregates() {
        let r = run_population(&cfg(8, 2, true)).unwrap();
        assert_eq!(r.users, 8);
        assert_eq!(r.outcomes.len(), 8);
        assert!(r.outcomes.iter().all(|o| o.completions > 0), "{r:?}");
        assert!(r.completions.min > 0.0);
        assert!(r.completions.max >= r.completions.p99);
        assert!(r.completions.p99 >= r.completions.p50);
        assert!(r.energy_j.mean > 0.0);
        let stats = r.cache.expect("shared cache on");
        assert!(stats.lookups > 0);
        assert!(stats.unique_signatures as u64 <= stats.lookups);
    }

    #[test]
    fn narrow_seed_ranges_repeat_cohort_members() {
        let narrow = PopulationCfg { users: 6, seed_lo: 0, seed_hi: 2, ..PopulationCfg::default() };
        let r = run_population(&narrow).unwrap();
        assert_eq!(r.outcomes[0].digest, r.outcomes[2].digest);
        assert_eq!(r.outcomes[1].digest, r.outcomes[3].digest);
        assert_ne!(
            r.outcomes[0].seed, r.outcomes[1].seed,
            "adjacent users still differ"
        );
    }

    #[test]
    fn cache_mode_and_worker_count_leave_the_fingerprint_alone() {
        // The full matrix lives in tests/population.rs; this is the
        // fast in-crate smoke over a tiny cohort.
        let a = run_population(&cfg(6, 1, true)).unwrap();
        let b = run_population(&cfg(6, 3, true)).unwrap();
        let c = run_population(&cfg(6, 2, false)).unwrap();
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.fingerprint, c.fingerprint);
        assert!(c.cache.is_none());
    }

    #[test]
    fn percentile_pick_parses_and_ranks() {
        assert_eq!("p50".parse::<Pctl>(), Ok(Pctl::P50));
        assert_eq!("p95".parse::<Pctl>(), Ok(Pctl::P95));
        assert_eq!("p99".parse::<Pctl>(), Ok(Pctl::P99));
        assert!("p42".parse::<Pctl>().is_err());
        assert!(Pctl::P50.fraction() < Pctl::P95.fraction());
        assert!(Pctl::P95.fraction() < Pctl::P99.fraction());
    }

    #[test]
    fn percentile_tracing_leaves_the_fingerprint_alone_and_records() {
        let plain = run_population(&cfg(6, 2, true)).unwrap();
        let traced_cfg = PopulationCfg { trace_percentile: Some(Pctl::P95), ..cfg(6, 2, true) };
        let traced = run_population(&traced_cfg).unwrap();
        // Phase one is the untraced cohort, so everything fingerprinted
        // (and the deterministic cache counters — raw hits are
        // scheduling-dependent) match the plain run bit-for-bit.
        assert_eq!(traced.fingerprint, plain.fingerprint);
        let (tc, pc) = (traced.cache.unwrap(), plain.cache.unwrap());
        assert_eq!(tc.lookups, pc.lookups);
        assert_eq!(tc.unique_signatures, pc.unique_signatures);
        assert_eq!(tc.unique_plans, pc.unique_plans);
        // Phase two produced a recording, its seed, and a blame summary.
        let seed = traced.traced_seed.expect("percentile pick traced a user");
        assert!(traced.outcomes.iter().any(|o| o.seed == seed));
        let rec = traced.trace.as_ref().expect("recording present");
        assert!(!rec.events.is_empty());
        let blame = traced.blame.as_ref().expect("blame summary present");
        assert!(blame.rounds > 0, "{blame:?}");
        blame.check_conservation().unwrap();
        // An explicit --trace-user wins over the percentile selector.
        let both = PopulationCfg {
            trace_user: Some(1),
            trace_percentile: Some(Pctl::P50),
            ..cfg(6, 2, true)
        };
        let r = run_population(&both).unwrap();
        assert_eq!(r.traced_seed, Some(1));
    }
}
