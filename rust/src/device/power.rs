//! Per-computation-unit power model (the Monsoon-monitor stand-in).
//!
//! Draws are calibrated to published MAX78000 characterizations and the
//! magnitudes the paper reports (Table II: ~1.5 J/s for four devices under
//! Workload 1; radio TX is the dominant consumer, which is why maximizing
//! throughput — i.e. minimizing communication — *reduces* power in Fig. 15).
//! Energy is integrated by the scheduler from per-unit busy intervals:
//! `E = Σ_unit P_active · t_busy + P_base · T`.

/// Active power draws per computation unit, in watts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerSpec {
    /// CNN accelerator while inferring.
    pub accel_active_w: f64,
    /// Core while executing memory ops / sensing glue / MCU inference.
    pub cpu_active_w: f64,
    /// Radio while transmitting (ESP8266 TX is the big one).
    pub radio_tx_w: f64,
    /// Radio while receiving.
    pub radio_rx_w: f64,
    /// Sensor while sampling.
    pub sensor_active_w: f64,
    /// Baseline draw while powered (core sleep + radio idle/associated).
    pub base_w: f64,
}

impl PowerSpec {
    /// MAX78000 platform: ultra-low-power accelerator, ESP8266 radio.
    pub fn max78000() -> PowerSpec {
        PowerSpec {
            accel_active_w: 0.030,
            cpu_active_w: 0.025,
            radio_tx_w: 0.320,
            radio_rx_w: 0.100,
            sensor_active_w: 0.010,
            // ESP8266 associated-idle (~70 mA @ 3.3 V) dominates the
            // platform baseline; core sleep adds a few mW. This is what
            // puts the paper's absolute power near 1.5 J/s for 4 devices.
            base_w: 0.250,
        }
    }

    /// MAX78002: faster clocks, proportionally higher draws.
    pub fn max78002() -> PowerSpec {
        PowerSpec {
            accel_active_w: 0.060,
            cpu_active_w: 0.030,
            radio_tx_w: 0.320,
            radio_rx_w: 0.100,
            sensor_active_w: 0.010,
            base_w: 0.260,
        }
    }

    /// Conventional MCU (Fig. 2 comparison): all compute on the core, which
    /// burns far more energy per inference than the accelerator.
    pub fn mcu() -> PowerSpec {
        PowerSpec {
            accel_active_w: 0.0, // no accelerator
            cpu_active_w: 0.120,
            radio_tx_w: 0.320,
            radio_rx_w: 0.100,
            sensor_active_w: 0.010,
            base_w: 0.250,
        }
    }

    /// High-performance MCU (STM32F7 @ 216 MHz): faster than the M4 but at
    /// a much higher core draw — which is why Fig. 2 shows it *worst* in
    /// energy despite beating the M4 on latency.
    pub fn mcu_m7() -> PowerSpec {
        PowerSpec {
            accel_active_w: 0.0,
            cpu_active_w: 0.700,
            radio_tx_w: 0.320,
            radio_rx_w: 0.100,
            sensor_active_w: 0.010,
            base_w: 0.300,
        }
    }

    /// Smartphone (offload comparison). Phone-side draw is large in
    /// absolute terms; the paper's Fig. 4 power comparison counts the whole
    /// system (wearables + phone).
    pub fn phone() -> PowerSpec {
        PowerSpec {
            accel_active_w: 1.5,
            cpu_active_w: 0.8,
            radio_tx_w: 0.9,
            radio_rx_w: 0.5,
            sensor_active_w: 0.0,
            base_w: 0.35, // screen-off baseline
        }
    }
}

/// Accumulated busy time per unit of one device, used for energy
/// integration over a simulated horizon.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BusyTimes {
    pub accel_s: f64,
    pub cpu_s: f64,
    pub radio_tx_s: f64,
    pub radio_rx_s: f64,
    pub sensor_s: f64,
}

impl BusyTimes {
    /// Energy in joules over a horizon of `total_s` seconds.
    pub fn energy_j(&self, p: &PowerSpec, total_s: f64) -> f64 {
        p.base_w * total_s
            + p.accel_active_w * self.accel_s
            + p.cpu_active_w * self.cpu_s
            + p.radio_tx_w * self.radio_tx_s
            + p.radio_rx_w * self.radio_rx_s
            + p.sensor_active_w * self.sensor_s
    }

    pub fn add(&mut self, other: &BusyTimes) {
        self.accel_s += other.accel_s;
        self.cpu_s += other.cpu_s;
        self.radio_tx_s += other.radio_tx_s;
        self.radio_rx_s += other.radio_rx_s;
        self.sensor_s += other.sensor_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_device_draws_base_only() {
        let p = PowerSpec::max78000();
        let busy = BusyTimes::default();
        let e = busy.energy_j(&p, 10.0);
        assert!((e - 2.5).abs() < 1e-12);
    }

    #[test]
    fn radio_dominates_when_transmitting() {
        // Compare *active* energy (above baseline): radio TX is the
        // dominant active consumer, ~10× the accelerator.
        let p = PowerSpec::max78000();
        let base = BusyTimes::default().energy_j(&p, 10.0);
        let e_tx = BusyTimes { radio_tx_s: 9.0, ..Default::default() }.energy_j(&p, 10.0) - base;
        let e_accel = BusyTimes { accel_s: 9.0, ..Default::default() }.energy_j(&p, 10.0) - base;
        assert!(e_tx > 3.0 * e_accel, "tx {e_tx} vs accel {e_accel}");
    }

    #[test]
    fn mcu_inference_energy_exceeds_accelerator() {
        // Fig. 2's energy story: same work takes the MCU both longer and at
        // higher draw. 2 ms on the accelerator vs 350 ms on the core.
        let acc = BusyTimes { accel_s: 0.002, ..Default::default() }
            .energy_j(&PowerSpec::max78000(), 0.002)
            - PowerSpec::max78000().base_w * 0.002;
        let mcu = BusyTimes { cpu_s: 0.350, ..Default::default() }
            .energy_j(&PowerSpec::mcu(), 0.350)
            - PowerSpec::mcu().base_w * 0.350;
        assert!(mcu / acc > 100.0, "ratio {}", mcu / acc);
    }

    #[test]
    fn busy_times_accumulate() {
        let mut a = BusyTimes { accel_s: 1.0, ..Default::default() };
        a.add(&BusyTimes { accel_s: 2.0, cpu_s: 3.0, ..Default::default() });
        assert_eq!(a.accel_s, 3.0);
        assert_eq!(a.cpu_s, 3.0);
    }
}
