//! Sensor and interaction capabilities a wearable advertises and an app's
//! pipeline requires (§IV-B: requirement types are "designated device or
//! sensor type" for sensing and "designated device or interface type" for
//! interaction).

/// Sensing capabilities.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SensorKind {
    Microphone,
    Camera,
    Imu,
    /// Optical heart-rate (photoplethysmography).
    Ppg,
    /// Foot pressure (smart shoes).
    Pressure,
}

impl SensorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SensorKind::Microphone => "microphone",
            SensorKind::Camera => "camera",
            SensorKind::Imu => "imu",
            SensorKind::Ppg => "ppg",
            SensorKind::Pressure => "pressure",
        }
    }
}

/// Interaction (output) capabilities.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum InteractionKind {
    Haptic,
    Audio,
    Display,
    Led,
}

impl InteractionKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            InteractionKind::Haptic => "haptic",
            InteractionKind::Audio => "audio",
            InteractionKind::Display => "display",
            InteractionKind::Led => "led",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_stable() {
        assert_eq!(SensorKind::Camera.as_str(), "camera");
        assert_eq!(InteractionKind::Haptic.as_str(), "haptic");
    }
}
