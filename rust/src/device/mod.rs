//! The hardware substrate: tiny-AI-accelerator device models.
//!
//! The paper prototypes on MAX78000/MAX78002 boards with ESP8266 Wi-Fi and
//! measures power with a Monsoon monitor. None of that hardware exists here,
//! so this module is the faithful stand-in (see DESIGN.md §2): published
//! memory capacities and clock rates, a serial-bridged radio model, and a
//! per-computation-unit power model calibrated to the magnitudes the paper
//! reports. Everything downstream (planner, estimator, scheduler) consumes
//! only these specs.

pub mod capability;
pub mod spec;
pub mod memory;
pub mod power;
pub mod radio;

pub use capability::{InteractionKind, SensorKind};
pub use memory::{AccelMemory, OorError};
pub use power::PowerSpec;
pub use radio::RadioSpec;
pub use spec::{AccelSpec, Device, DeviceId, DeviceKind, DeviceSpec, Fleet};
