//! Accelerator memory accounting — the out-of-resource (OOR) rules of
//! §IV-C: a collaboration plan is *runnable* iff, on every accelerator, the
//! total weight memory, bias memory, and layer count of all assigned model
//! chunks stay within capacity.

use super::spec::AccelSpec;

/// Why an assignment does not fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum OorError {
    #[error("weight memory exhausted")]
    WeightMem,
    #[error("bias memory exhausted")]
    BiasMem,
    #[error("layer-count limit exhausted")]
    Layers,
}

/// Running usage tally for one accelerator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AccelMemory {
    pub weight_bytes: u64,
    pub bias_bytes: u64,
    pub layers: usize,
}

impl AccelMemory {
    /// Check whether adding a chunk with the given footprint fits `spec`.
    pub fn check(
        &self,
        spec: &AccelSpec,
        weight_bytes: u64,
        bias_bytes: u64,
        layers: usize,
    ) -> Result<(), OorError> {
        if self.weight_bytes + weight_bytes > spec.weight_mem {
            return Err(OorError::WeightMem);
        }
        if self.bias_bytes + bias_bytes > spec.bias_mem {
            return Err(OorError::BiasMem);
        }
        if self.layers + layers > spec.max_layers {
            return Err(OorError::Layers);
        }
        Ok(())
    }

    /// Check-and-commit an allocation.
    pub fn alloc(
        &mut self,
        spec: &AccelSpec,
        weight_bytes: u64,
        bias_bytes: u64,
        layers: usize,
    ) -> Result<(), OorError> {
        self.check(spec, weight_bytes, bias_bytes, layers)?;
        self.weight_bytes += weight_bytes;
        self.bias_bytes += bias_bytes;
        self.layers += layers;
        Ok(())
    }

    /// Release an allocation (used when backtracking during plan search).
    pub fn free(&mut self, weight_bytes: u64, bias_bytes: u64, layers: usize) {
        debug_assert!(self.weight_bytes >= weight_bytes);
        debug_assert!(self.bias_bytes >= bias_bytes);
        debug_assert!(self.layers >= layers);
        self.weight_bytes -= weight_bytes;
        self.bias_bytes -= bias_bytes;
        self.layers -= layers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::DeviceKind;

    fn max78000() -> AccelSpec {
        DeviceKind::Max78000.spec().accel.unwrap()
    }

    #[test]
    fn fits_until_weight_exhausted() {
        let spec = max78000();
        let mut mem = AccelMemory::default();
        // Two 200 KB chunks fit in 442 KB; a third does not.
        assert!(mem.alloc(&spec, 200 * 1024, 256, 5).is_ok());
        assert!(mem.alloc(&spec, 200 * 1024, 256, 5).is_ok());
        assert_eq!(
            mem.alloc(&spec, 200 * 1024, 256, 5),
            Err(OorError::WeightMem)
        );
    }

    #[test]
    fn layer_limit_is_enforced() {
        let spec = max78000();
        let mut mem = AccelMemory::default();
        assert!(mem.alloc(&spec, 1024, 16, 30).is_ok());
        assert_eq!(mem.alloc(&spec, 1024, 16, 3), Err(OorError::Layers));
        assert!(mem.alloc(&spec, 1024, 16, 2).is_ok());
    }

    #[test]
    fn bias_limit_is_enforced() {
        let spec = max78000();
        let mut mem = AccelMemory::default();
        assert_eq!(
            mem.alloc(&spec, 1024, 3 * 1024, 1),
            Err(OorError::BiasMem)
        );
    }

    #[test]
    fn free_backtracks() {
        let spec = max78000();
        let mut mem = AccelMemory::default();
        mem.alloc(&spec, 400 * 1024, 1024, 20).unwrap();
        assert!(mem.check(&spec, 100 * 1024, 256, 5).is_err());
        mem.free(400 * 1024, 1024, 20);
        assert_eq!(mem, AccelMemory::default());
        assert!(mem.check(&spec, 100 * 1024, 256, 5).is_ok());
    }

    #[test]
    fn failed_alloc_leaves_state_unchanged() {
        let spec = max78000();
        let mut mem = AccelMemory::default();
        mem.alloc(&spec, 100, 10, 1).unwrap();
        let before = mem;
        let _ = mem.alloc(&spec, u64::MAX / 2, 0, 0);
        assert_eq!(mem, before);
    }
}
