//! Device specifications: published MAX78000/MAX78002 capacities and clock
//! rates, the conventional-MCU comparison points of Fig. 2, and the phone
//! used by the offloading baseline (§II-B).

use super::capability::{InteractionKind, SensorKind};
use super::power::PowerSpec;
use super::radio::RadioSpec;

/// CNN accelerator specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccelSpec {
    /// Dedicated weight memory in bytes (MAX78000: 442 KB, MAX78002: 2 MB).
    pub weight_mem: u64,
    /// Dedicated bias memory in bytes (MAX78000: 2 KB, MAX78002: 8 KB).
    pub bias_mem: u64,
    /// Dedicated data (activation) memory in bytes.
    pub data_mem: u64,
    /// Maximum number of layers the accelerator can hold (32 / 128).
    pub max_layers: usize,
    /// Parallel convolutional processors, `P` in Eq. 4–5 (64 on both).
    pub parallel_procs: usize,
    /// Accelerator clock in Hz (`F` in §IV-E1).
    pub clock_hz: f64,
    /// SRAM ↔ accelerator-memory transfer rate in bytes/s, for the
    /// load/unload tasks ((2)/(4) in Fig. 10); the central-bus rate that
    /// makes memory-op latency linear in data size.
    pub bus_bytes_per_s: f64,
    /// Fixed per-transfer setup cost in seconds.
    pub bus_overhead_s: f64,
}

/// Kind of device platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceKind {
    Max78000,
    Max78002,
    /// MAX32650: conventional Cortex-M4 MCU @ 120 MHz (Fig. 2 baseline);
    /// no CNN accelerator — inference runs sequentially on the core.
    McuMax32650,
    /// STM32F7: high-performance Cortex-M7 MCU @ 216 MHz (Fig. 2 baseline).
    McuStm32F7,
    /// Smartphone for the offloading comparison (§II-B): effectively
    /// unconstrained compute/memory; still behind the same radio.
    Phone,
}

impl DeviceKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DeviceKind::Max78000 => "MAX78000",
            DeviceKind::Max78002 => "MAX78002",
            DeviceKind::McuMax32650 => "MAX32650",
            DeviceKind::McuStm32F7 => "STM32F7",
            DeviceKind::Phone => "Phone",
        }
    }

    /// Full platform specification for this kind.
    pub fn spec(&self) -> DeviceSpec {
        match self {
            DeviceKind::Max78000 => DeviceSpec {
                kind: *self,
                cpu_clock_hz: 100e6, // Cortex-M4 @ 100 MHz
                cycles_per_mac: 8.0,
                accel: Some(AccelSpec {
                    weight_mem: 442 * 1024,
                    bias_mem: 2 * 1024,
                    data_mem: 512 * 1024,
                    max_layers: 32,
                    parallel_procs: 64,
                    clock_hz: 50e6, // CNN clock
                    bus_bytes_per_s: 10.0e6,
                    bus_overhead_s: 120e-6,
                }),
                radio: RadioSpec::esp8266_bridged(),
                power: PowerSpec::max78000(),
            },
            DeviceKind::Max78002 => DeviceSpec {
                kind: *self,
                cpu_clock_hz: 120e6,
                cycles_per_mac: 8.0,
                accel: Some(AccelSpec {
                    // §II-A: data 1.3 MB, weight 2 MB, bias 8 KB (see
                    // DESIGN.md §4 on the §IV-C typo), 128 layers.
                    weight_mem: 2 * 1024 * 1024,
                    bias_mem: 8 * 1024,
                    data_mem: 1331 * 1024,
                    max_layers: 128,
                    parallel_procs: 64,
                    clock_hz: 100e6, // MAX78002 CNN clock is 2× faster
                    bus_bytes_per_s: 16.0e6,
                    bus_overhead_s: 100e-6,
                }),
                radio: RadioSpec::esp8266_bridged(),
                power: PowerSpec::max78002(),
            },
            DeviceKind::McuMax32650 => DeviceSpec {
                kind: *self,
                cpu_clock_hz: 120e6,
                cycles_per_mac: 8.0,
                accel: None,
                radio: RadioSpec::esp8266_bridged(),
                power: PowerSpec::mcu(),
            },
            DeviceKind::McuStm32F7 => DeviceSpec {
                kind: *self,
                cpu_clock_hz: 216e6,
                cycles_per_mac: 3.0,
                accel: None,
                radio: RadioSpec::esp8266_bridged(),
                power: PowerSpec::mcu_m7(),
            },
            DeviceKind::Phone => DeviceSpec {
                kind: *self,
                cpu_clock_hz: 2.0e9,
                cycles_per_mac: 1.0,
                accel: Some(AccelSpec {
                    // Phone NPU: effectively unconstrained for these models.
                    weight_mem: 1 << 32,
                    bias_mem: 1 << 24,
                    data_mem: 1 << 32,
                    max_layers: 4096,
                    parallel_procs: 256,
                    clock_hz: 1.0e9,
                    bus_bytes_per_s: 1.0e9,
                    bus_overhead_s: 10e-6,
                }),
                radio: RadioSpec::phone_wifi(),
                power: PowerSpec::phone(),
            },
        }
    }
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Full platform spec: core + optional accelerator + radio + power.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub kind: DeviceKind,
    /// General-purpose core clock (runs sensing glue and memory ops, and
    /// the whole inference when there is no accelerator).
    pub cpu_clock_hz: f64,
    /// Effective core cycles per 8-bit MAC for software inference (CMSIS-NN
    /// class kernels: ~8 on a Cortex-M4, ~3 on a dual-issue M7 with DSP
    /// extensions, ~1 on an application-class core). Scales Eq. 2–3 into
    /// wall-clock on cores without an accelerator.
    pub cycles_per_mac: f64,
    pub accel: Option<AccelSpec>,
    pub radio: RadioSpec,
    pub power: PowerSpec,
}

/// Identifier of a device within a fleet (dense index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "d{}", self.0)
    }
}

// Ergonomic conversion for scenario scripts (`.at(2.5).device_left(3)`).
impl From<usize> for DeviceId {
    fn from(i: usize) -> DeviceId {
        DeviceId(i)
    }
}

/// A concrete wearable in the fleet: a platform plus its on-body role.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: DeviceId,
    /// Human-readable role, e.g. "earbud", "glasses", "watch", "ring".
    pub name: String,
    pub spec: DeviceSpec,
    pub sensors: Vec<SensorKind>,
    pub interactions: Vec<InteractionKind>,
}

impl Device {
    pub fn new(
        id: usize,
        name: impl Into<String>,
        kind: DeviceKind,
        sensors: Vec<SensorKind>,
        interactions: Vec<InteractionKind>,
    ) -> Device {
        Device {
            id: DeviceId(id),
            name: name.into(),
            spec: kind.spec(),
            sensors,
            interactions,
        }
    }

    pub fn has_accel(&self) -> bool {
        self.spec.accel.is_some()
    }

    pub fn has_sensor(&self, s: SensorKind) -> bool {
        self.sensors.contains(&s)
    }

    pub fn has_interaction(&self, i: InteractionKind) -> bool {
        self.interactions.contains(&i)
    }
}

/// The set of devices currently on the body.
#[derive(Clone, Debug, Default)]
pub struct Fleet {
    pub devices: Vec<Device>,
}

impl Fleet {
    pub fn new(devices: Vec<Device>) -> Fleet {
        for (i, d) in devices.iter().enumerate() {
            assert_eq!(d.id.0, i, "device ids must be dense and ordered");
        }
        Fleet { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn get(&self, id: DeviceId) -> &Device {
        &self.devices[id.0]
    }

    pub fn ids(&self) -> impl Iterator<Item = DeviceId> + '_ {
        (0..self.devices.len()).map(DeviceId)
    }

    /// Devices that have an AI accelerator (candidates for model chunks).
    pub fn accel_ids(&self) -> Vec<DeviceId> {
        self.ids()
            .filter(|&id| self.get(id).has_accel())
            .collect()
    }

    /// Devices satisfying a sensing capability.
    pub fn with_sensor(&self, s: SensorKind) -> Vec<DeviceId> {
        self.ids()
            .filter(|&id| self.get(id).has_sensor(s))
            .collect()
    }

    /// Devices satisfying an interaction capability.
    pub fn with_interaction(&self, i: InteractionKind) -> Vec<DeviceId> {
        self.ids()
            .filter(|&id| self.get(id).has_interaction(i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_memory_capacities() {
        let m0 = DeviceKind::Max78000.spec().accel.unwrap();
        assert_eq!(m0.weight_mem, 452_608);
        assert_eq!(m0.bias_mem, 2048);
        assert_eq!(m0.max_layers, 32);
        assert_eq!(m0.parallel_procs, 64);
        let m2 = DeviceKind::Max78002.spec().accel.unwrap();
        assert_eq!(m2.weight_mem, 2 * 1024 * 1024);
        assert_eq!(m2.bias_mem, 8192);
        assert_eq!(m2.max_layers, 128);
    }

    #[test]
    fn mcus_have_no_accelerator() {
        assert!(DeviceKind::McuMax32650.spec().accel.is_none());
        assert!(DeviceKind::McuStm32F7.spec().accel.is_none());
        assert!(DeviceKind::Phone.spec().accel.is_some());
    }

    #[test]
    fn fleet_capability_lookup() {
        let fleet = Fleet::new(vec![
            Device::new(0, "earbud", DeviceKind::Max78000,
                vec![SensorKind::Microphone], vec![InteractionKind::Audio]),
            Device::new(1, "glasses", DeviceKind::Max78000,
                vec![SensorKind::Camera], vec![InteractionKind::Display]),
            Device::new(2, "ring", DeviceKind::Max78000,
                vec![], vec![InteractionKind::Haptic]),
        ]);
        assert_eq!(fleet.with_sensor(SensorKind::Camera), vec![DeviceId(1)]);
        assert_eq!(
            fleet.with_interaction(InteractionKind::Haptic),
            vec![DeviceId(2)]
        );
        assert_eq!(fleet.accel_ids().len(), 3);
    }

    #[test]
    #[should_panic(expected = "dense and ordered")]
    fn fleet_rejects_sparse_ids() {
        Fleet::new(vec![Device::new(
            1,
            "x",
            DeviceKind::Max78000,
            vec![],
            vec![],
        )]);
    }
}
