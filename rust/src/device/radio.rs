//! Radio model.
//!
//! The prototype bridges the MAX78000 to an ESP8266 Wi-Fi module over a
//! serial line with round-robin scheduling (§V), so the *effective*
//! device-to-device rate is UART-bound (~115.2 kbaud ≈ 11.5 kB/s), which is
//! what makes communication dominate everything else on these platforms
//! (Fig. 8: comm ≈ 4579× inference latency). The model is
//! `latency = overhead + bytes / bandwidth`, matching §IV-E2's
//! size-over-bandwidth estimator; contention is handled by the scheduler's
//! per-radio queues, not here.

/// Point-to-point radio characteristics of one device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RadioSpec {
    /// Effective application-level bandwidth in bytes/s.
    pub bytes_per_s: f64,
    /// Fixed per-message overhead in seconds (connection + framing).
    pub overhead_s: f64,
}

impl RadioSpec {
    /// ESP8266 behind a UART bridge, as in the paper's prototype.
    pub fn esp8266_bridged() -> RadioSpec {
        RadioSpec {
            bytes_per_s: 11_520.0, // 115.2 kbaud, 8N1 → ~11.5 kB/s
            overhead_s: 8e-3,
        }
    }

    /// A phone's native Wi-Fi — but a d2d transfer is limited by the
    /// *wearable* end of the link, so this only matters phone→phone.
    pub fn phone_wifi() -> RadioSpec {
        RadioSpec {
            bytes_per_s: 2.0e6,
            overhead_s: 2e-3,
        }
    }

    /// One-way transfer time for `bytes`.
    pub fn tx_time(&self, bytes: u64) -> f64 {
        self.overhead_s + bytes as f64 / self.bytes_per_s
    }
}

/// Effective link between two devices: bounded by the slower radio.
pub fn link_time(a: &RadioSpec, b: &RadioSpec, bytes: u64) -> f64 {
    let bw = a.bytes_per_s.min(b.bytes_per_s);
    let overhead = a.overhead_s.max(b.overhead_s);
    overhead + bytes as f64 / bw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uart_bound_transfer() {
        let r = RadioSpec::esp8266_bridged();
        // 110 KB (a UNet boundary tensor) takes ~9.8 s — comm dominates.
        let t = r.tx_time(110 * 1024);
        assert!((9.0..11.0).contains(&t), "t = {t}");
    }

    #[test]
    fn overhead_dominates_tiny_messages() {
        let r = RadioSpec::esp8266_bridged();
        let t = r.tx_time(10);
        assert!(t < 0.01, "t = {t}");
        assert!(t > r.overhead_s);
    }

    #[test]
    fn link_is_bounded_by_slower_end() {
        let wearable = RadioSpec::esp8266_bridged();
        let phone = RadioSpec::phone_wifi();
        let via_link = link_time(&wearable, &phone, 100_000);
        let wearable_alone = wearable.tx_time(100_000);
        assert!((via_link - wearable_alone).abs() < 1e-9);
    }

    #[test]
    fn latency_linear_in_size() {
        let r = RadioSpec::esp8266_bridged();
        let t1 = r.tx_time(1000);
        let t2 = r.tx_time(2000);
        let t3 = r.tx_time(3000);
        assert!(((t3 - t2) - (t2 - t1)).abs() < 1e-12);
    }
}
