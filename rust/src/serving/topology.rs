//! Static extraction of the serve engine's channel topology, so the
//! "backpressure deadlock is impossible" argument is a checked invariant
//! instead of folklore.
//!
//! [`ServeEngine::set_plan`](super::ServeEngine::set_plan) binds each
//! pipeline's expanded task chain to per-(device, unit) worker mergers:
//! stage `j` produces into stage `j+1`'s merger, and a worker admits a
//! stage only when every earlier stage of that round has completed. A
//! cycle in that producer→consumer graph would be a deadlock: some stage
//! would wait (transitively) on its own output. [`plan_channel_graph`]
//! rebuilds exactly the graph `set_plan` would bind — same task
//! expansion, same [`GroundTruth::unit_of`] worker resolution — and
//! [`ChannelGraph::check_acyclic`] proves it cycle-free with a
//! topological sort, returning [`AnalysisError::ChannelCycle`] naming a
//! stage on the cycle otherwise. `verify_deployment` runs this on every
//! plan, so the invariant is re-proved for each deployment rather than
//! assumed from the chain-shaped construction.

use crate::analysis::AnalysisError;
use crate::device::{DeviceId, Fleet};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::{CollabPlan, UnitKind};
use crate::scheduler::GroundTruth;

/// The producer→consumer stage graph one deployment binds onto the serve
/// engine's workers. Nodes are chain stages `(pipeline, stage index)`;
/// `workers[i]` is the (device, effective unit) worker that executes node
/// `i`; edges point from a stage to the stage consuming its output.
#[derive(Clone, Debug, Default)]
pub struct ChannelGraph {
    pub nodes: Vec<(PipelineId, usize)>,
    pub workers: Vec<(DeviceId, UnitKind)>,
    /// Directed `(producer, consumer)` pairs, indices into `nodes`.
    pub edges: Vec<(usize, usize)>,
}

impl ChannelGraph {
    /// Prove the stage graph cycle-free (Kahn's algorithm). On failure,
    /// names a stage that sits on a cycle — a stage whose admission
    /// transitively waits on its own output.
    pub fn check_acyclic(&self) -> Result<(), AnalysisError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(a, b) in &self.edges {
            out[a].push(b);
            indeg[b] += 1;
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = ready.pop() {
            seen += 1;
            for &j in &out[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    ready.push(j);
                }
            }
        }
        if seen == n {
            return Ok(());
        }
        // Every unprocessed node has residual in-degree — each lies on or
        // downstream of a cycle; report the first for determinism.
        let stuck = indeg.iter().position(|&d| d > 0).unwrap_or_default();
        let (pipeline, stage) = self.nodes.get(stuck).copied().unwrap_or((PipelineId(0), 0));
        let (dev, unit) = self
            .workers
            .get(stuck)
            .copied()
            .unwrap_or((DeviceId(0), UnitKind::Cpu));
        Err(AnalysisError::ChannelCycle {
            pipeline,
            detail: format!(
                "stage {stage} (on {unit:?} of {dev}) waits transitively on its own output"
            ),
        })
    }
}

/// Rebuild the channel graph [`ServeEngine::set_plan`] would bind for
/// this deployment, without touching any engine state. KEEP IN SYNC with
/// the binding loop in `set_plan`: one node per expanded task, worker =
/// `GroundTruth::unit_of`, one edge per adjacent stage pair. Fails with
/// [`AnalysisError::UnknownPipeline`] exactly where `set_plan` would.
///
/// [`ServeEngine::set_plan`]: super::ServeEngine::set_plan
pub fn plan_channel_graph(
    plan: &CollabPlan,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
) -> Result<ChannelGraph, AnalysisError> {
    let mut g = ChannelGraph::default();
    for ep in &plan.plans {
        let spec = pipelines
            .iter()
            .find(|p| p.id == ep.pipeline)
            .ok_or(AnalysisError::UnknownPipeline { pipeline: ep.pipeline })?;
        let base = g.nodes.len();
        for (j, t) in ep.tasks(&spec.model).iter().enumerate() {
            g.nodes.push((spec.id, j));
            g.workers.push((t.device, GroundTruth::unit_of(fleet, t)));
            if j > 0 {
                g.edges.push((base + j - 1, base + j));
            }
        }
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::{Planner, Synergy};
    use crate::workload::{all_workloads, fleet4, fleet4_hetero};

    /// Every planner output binds to a forward-only chain per pipeline —
    /// the graph the engine would build is provably acyclic.
    #[test]
    fn planner_outputs_bind_acyclic_graphs() {
        for fleet in [fleet4(), fleet4_hetero()] {
            for w in all_workloads() {
                let plan = Synergy::planner().plan(&w.pipelines, &fleet).unwrap();
                let g = plan_channel_graph(&plan, &w.pipelines, &fleet).unwrap();
                assert!(!g.nodes.is_empty());
                assert_eq!(g.nodes.len(), g.workers.len());
                g.check_acyclic()
                    .unwrap_or_else(|e| panic!("{} on {}-dev fleet: {e}", w.name, fleet.len()));
            }
        }
    }

    /// Workers on devices without an accelerator resolve Infer to the
    /// core — the graph reflects the engine's effective units, not the
    /// plan's nominal ones.
    #[test]
    fn workers_use_effective_units() {
        let fleet = fleet4_hetero();
        let w = &all_workloads()[0];
        let plan = Synergy::planner().plan(&w.pipelines, &fleet).unwrap();
        let g = plan_channel_graph(&plan, &w.pipelines, &fleet).unwrap();
        for &(dev, unit) in &g.workers {
            if unit == UnitKind::Accel {
                assert!(fleet.get(dev).has_accel());
            }
        }
    }

    /// A hand-built cyclic graph (inexpressible as a `CollabPlan`, which
    /// only yields chains) is rejected with the stage on the cycle.
    #[test]
    fn hand_built_cycle_is_rejected() {
        let g = ChannelGraph {
            nodes: vec![(PipelineId(7), 0), (PipelineId(7), 1), (PipelineId(7), 2)],
            workers: vec![(DeviceId(0), UnitKind::Cpu); 3],
            edges: vec![(0, 1), (1, 2), (2, 1)],
        };
        let err = g.check_acyclic().unwrap_err();
        assert!(
            matches!(err, AnalysisError::ChannelCycle { pipeline: PipelineId(7), .. }),
            "{err}"
        );
        // The empty graph and a diamond are fine.
        ChannelGraph::default().check_acyclic().unwrap();
        let diamond = ChannelGraph {
            nodes: vec![(PipelineId(0), 0); 4],
            workers: vec![(DeviceId(0), UnitKind::Cpu); 4],
            edges: vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        };
        diamond.check_acyclic().unwrap();
    }
}
