//! Real PJRT execution for the serving subsystem (requires the `pjrt`
//! cargo feature and `make artifacts`).
//!
//! Two entry points:
//!
//! - [`serve`]: the one-shot serving loop behind
//!   [`crate::api::PjrtBackend`] — per-device worker threads, mpsc radio
//!   links, double-buffered inter-run overlap, and split-vs-full
//!   verification. (Formerly `coordinator::serve`; absorbed here so all
//!   serving lives in one subsystem.)
//! - [`PjrtChunkExecutor`]: the [`ChunkExecutor`] adapter that plugs real
//!   AOT-compiled HLO chunk inference into the streaming
//!   [`super::ServeEngine`] — sensing tasks synthesize the input frame,
//!   inference tasks run the mapped artifact and pass the activation
//!   along, and every task reports its measured wall duration, so a
//!   served session streams real numerics while plan switches rebind the
//!   workers live.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::api::core::Deployment;
use crate::api::RuntimeError;
use crate::device::{DeviceId, Fleet};
use crate::model::Shape;
use crate::pipeline::PipelineSpec;
use crate::plan::task::TaskKind;
use crate::runtime::{InferHandle, InferenceService, Manifest};

use super::executor::{ChunkExecutor, TaskCtx};

/// Deterministic synthetic sensor frame: one f32 per tensor *element*.
///
/// Sizing audit: `Shape::bytes()` is the on-accelerator 8-bit byte count
/// and only coincidentally equals the element count; an f32 frame sized in
/// bytes would be 4× too large the moment dtype widths diverge. Buffers on
/// the PJRT path are therefore sized with [`Shape::elements`] exclusively
/// (`run_full` rejects anything else).
fn synth_frame(shape: Shape, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..shape.elements())
        .map(|_| rng.next_gaussian() as f32)
        .collect()
}

/// One synthetic sensor frame per app, sized from the manifest's input
/// shapes. Fallible: an app whose model is absent from the manifest is an
/// error naming the app, not a panic mid-deployment.
fn synth_inputs(apps: &[PipelineSpec], manifest: &Manifest, seed: u64) -> Result<Vec<Vec<f32>>> {
    apps.iter()
        .enumerate()
        .map(|(i, spec)| {
            let mm = manifest
                .model(&spec.name)
                .with_context(|| format!("sensor frame for app {:?}", spec.name))?;
            Ok(synth_frame(mm.input, seed ^ ((i as u64) << 32)))
        })
        .collect()
}

/// Streaming chunk execution through PJRT (see the module docs). Timing
/// is measured wall time on this testbed; on-body *timing* claims still
/// come from the device model, numerics from here.
pub struct PjrtChunkExecutor {
    /// The service thread owning the PJRT client; kept alive for the
    /// executor's lifetime.
    _service: InferenceService,
    /// `InferHandle` wraps an mpsc sender (not `Sync`); the lock
    /// serializes access, which the single-client service does anyway.
    handle: Mutex<InferHandle>,
    manifest: Manifest,
    seed: u64,
}

impl PjrtChunkExecutor {
    /// Start the inference service and wrap it for streaming execution.
    pub fn new(manifest: Manifest, seed: u64) -> Result<PjrtChunkExecutor> {
        let service = InferenceService::start()?;
        let handle = Mutex::new(service.handle());
        Ok(PjrtChunkExecutor {
            _service: service,
            handle,
            manifest,
            seed,
        })
    }

    fn backend_err(&self, message: String) -> RuntimeError {
        RuntimeError::Backend {
            backend: "pjrt",
            message,
        }
    }
}

impl ChunkExecutor for PjrtChunkExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(
        &self,
        ctx: &TaskCtx<'_>,
        payload: &mut Option<Vec<f32>>,
    ) -> Result<f64, RuntimeError> {
        let t0 = Instant::now();
        match ctx.task.kind {
            TaskKind::Sense { .. } => {
                let mm = self
                    .manifest
                    .model(&ctx.spec.name)
                    .map_err(|e| self.backend_err(format!("{e:#}")))?;
                let seed = self.seed ^ ((ctx.spec.id.0 as u64) << 32) ^ ctx.round as u64;
                *payload = Some(synth_frame(mm.input, seed));
            }
            TaskKind::Infer { range } => {
                let mm = self
                    .manifest
                    .model(&ctx.spec.name)
                    .map_err(|e| self.backend_err(format!("{e:#}")))?;
                let n = mm.layers.len();
                let (file, in_shape) = if range.start == 0 && range.end == n {
                    (mm.full.clone(), mm.input)
                } else {
                    let c = mm
                        .chunk(range.start, range.end)
                        .map_err(|e| self.backend_err(format!("{e:#}")))?;
                    (c.file.clone(), c.in_shape)
                };
                let activation = payload
                    .take()
                    .ok_or_else(|| self.backend_err("inference reached before sensing".into()))?;
                let out = self
                    .handle
                    .lock()
                    .unwrap()
                    .run(
                        self.manifest.path(&file),
                        activation,
                        vec![in_shape.h, in_shape.w, in_shape.c],
                    )
                    .map_err(|e| self.backend_err(format!("{e:#}")))?;
                *payload = Some(out);
            }
            // Memory ops, radio hops, and interaction are timing-only on
            // this testbed; the activation just rides along.
            TaskKind::Load { .. }
            | TaskKind::Unload { .. }
            | TaskKind::Tx { .. }
            | TaskKind::Rx { .. }
            | TaskKind::Interact { .. } => {}
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// Serving parameters for the one-shot [`serve`] loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Continuous-inference runs per pipeline.
    pub runs: usize,
    /// In-flight runs per pipeline (2 = double-buffered inter-run overlap).
    pub max_inflight: usize,
    /// Verify run outputs against whole-model execution.
    pub verify: bool,
    /// Seed for the synthetic sensor frames.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            runs: 8,
            max_inflight: 2,
            verify: true,
            seed: 42,
        }
    }
}

/// Per-pipeline serving stats.
#[derive(Clone, Debug)]
pub struct PipelineStats {
    pub name: String,
    pub completions: usize,
    pub mean_latency_s: f64,
    pub max_split_err: f64,
}

/// Serving results.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub wall_s: f64,
    pub completions: usize,
    /// Real inferences per second on this testbed (wall clock).
    pub throughput: f64,
    pub per_pipeline: Vec<PipelineStats>,
    pub verified: bool,
}

/// One hop of a pipeline's chunk chain.
#[derive(Clone, Debug)]
struct Stage {
    device: DeviceId,
    file: PathBuf,
    in_shape: Vec<usize>,
}

enum Msg {
    Work {
        pipeline: usize,
        run: usize,
        stage: usize,
        activation: Vec<f32>,
        started: Instant,
    },
    Stop,
}

struct Done {
    pipeline: usize,
    output: Vec<f32>,
    latency_s: f64,
}

/// Execute a deployment with real inference. `apps` must be the runtime's
/// active pipeline list; `manifest` must contain chunk artifacts for every
/// split the plan uses (plan with `EnumerateCfg { max_split_devices: 2 }`
/// for the models aot.py splits).
pub fn serve(
    deployment: &Deployment,
    apps: &[PipelineSpec],
    fleet: &Fleet,
    manifest: &Manifest,
    cfg: ServeConfig,
) -> Result<ServeReport> {
    assert!(cfg.max_inflight >= 1);
    let service = InferenceService::start()?;

    // Expand plans into stage chains and collect artifacts to preload.
    let mut stage_chains: Vec<Vec<Stage>> = Vec::new();
    let mut preload = Vec::new();
    for ep in &deployment.plan.plans {
        let spec = apps
            .iter()
            .find(|a| a.id == ep.pipeline)
            .context("plan references unknown app")?;
        let mm = manifest.model(&spec.name)?;
        let n = mm.layers.len();
        let mut chain = Vec::new();
        for a in &ep.chunks {
            let (file, in_shape) = if a.range.start == 0 && a.range.end == n {
                (mm.full.clone(), mm.input)
            } else {
                let c = mm.chunk(a.range.start, a.range.end).with_context(|| {
                    format!(
                        "{}: no artifact for chunk {} — restrict the planner \
                         to 2-way splits of the aot split models",
                        spec.name, a.range
                    )
                })?;
                (c.file.clone(), c.in_shape)
            };
            let path = manifest.path(&file);
            preload.push(path.clone());
            chain.push(Stage {
                device: a.device,
                file: path,
                in_shape: vec![in_shape.h, in_shape.w, in_shape.c],
            });
        }
        stage_chains.push(chain);
    }
    // Deployment step: compile everything before timing starts.
    service.handle().preload(preload)?;

    // Synthetic sensor frames (element-count sized; see `synth_frame`).
    // A missing manifest entry is a typed error surfaced to the caller —
    // these lookups were `.unwrap()`s that took the whole serving process
    // down when an app's model had no AOT artifacts.
    let inputs = synth_inputs(apps, manifest, cfg.seed)?;
    let reference: Vec<Option<Vec<f32>>> = if cfg.verify {
        let mut refs = Vec::with_capacity(apps.len());
        for (i, spec) in apps.iter().enumerate() {
            let mm = manifest
                .model(&spec.name)
                .with_context(|| format!("verification reference for app {:?}", spec.name))?;
            refs.push(
                service
                    .handle()
                    .run(
                        manifest.path(&mm.full),
                        inputs[i].clone(),
                        vec![mm.input.h, mm.input.w, mm.input.c],
                    )
                    .ok(),
            );
        }
        refs
    } else {
        vec![None; apps.len()]
    };

    // Per-device worker threads with radio-link channels.
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut senders: BTreeMap<DeviceId, mpsc::Sender<Msg>> = BTreeMap::new();
    let mut workers = Vec::new();
    let devices: Vec<DeviceId> = fleet.ids().collect();
    let mut receivers: BTreeMap<DeviceId, mpsc::Receiver<Msg>> = BTreeMap::new();
    for &d in &devices {
        let (tx, rx) = mpsc::channel::<Msg>();
        senders.insert(d, tx);
        receivers.insert(d, rx);
    }
    let chains = std::sync::Arc::new(stage_chains);
    for &d in &devices {
        let rx = receivers.remove(&d).unwrap();
        let handle: InferHandle = service.handle();
        let chains = chains.clone();
        let senders = senders.clone();
        let done_tx = done_tx.clone();
        workers.push(std::thread::spawn(move || -> Result<()> {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Stop => break,
                    Msg::Work { pipeline, run, stage, activation, started } => {
                        let chain = &chains[pipeline];
                        let st = &chain[stage];
                        debug_assert_eq!(st.device, d);
                        let out = handle.run(
                            st.file.clone(),
                            activation,
                            st.in_shape.clone(),
                        )?;
                        if stage + 1 < chain.len() {
                            // "Radio" hop to the next chunk device.
                            let _ = senders[&chain[stage + 1].device].send(Msg::Work {
                                pipeline,
                                run,
                                stage: stage + 1,
                                activation: out,
                                started,
                            });
                        } else {
                            let _ = done_tx.send(Done {
                                pipeline,
                                output: out,
                                latency_s: started.elapsed().as_secs_f64(),
                            });
                        }
                    }
                }
            }
            Ok(())
        }));
    }
    drop(done_tx);

    // Drive runs with a bounded in-flight window per pipeline.
    let t0 = Instant::now();
    let n = apps.len();
    let mut inflight = vec![0usize; n];
    let mut emitted = vec![0usize; n];
    let mut stats: Vec<PipelineStats> = apps
        .iter()
        .map(|a| PipelineStats {
            name: a.name.clone(),
            completions: 0,
            mean_latency_s: 0.0,
            max_split_err: 0.0,
        })
        .collect();
    let emit = |p: usize, emitted: &mut [usize], inflight: &mut [usize]| {
        let chain = &chains[p];
        let _ = senders[&chain[0].device].send(Msg::Work {
            pipeline: p,
            run: emitted[p],
            stage: 0,
            activation: inputs[p].clone(),
            started: Instant::now(),
        });
        emitted[p] += 1;
        inflight[p] += 1;
    };
    for p in 0..n {
        while emitted[p] < cfg.runs.min(cfg.max_inflight) {
            emit(p, &mut emitted, &mut inflight);
        }
    }
    let mut total_done = 0;
    let mut verified = true;
    while total_done < n * cfg.runs {
        let done = done_rx.recv().context("serving workers died")?;
        let p = done.pipeline;
        stats[p].completions += 1;
        stats[p].mean_latency_s += done.latency_s;
        if let Some(reference) = &reference[p] {
            let err = reference
                .iter()
                .zip(&done.output)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0, f64::max);
            stats[p].max_split_err = stats[p].max_split_err.max(err);
            let scale = reference.iter().map(|v| v.abs()).fold(0.0f32, f32::max) as f64;
            if err > 1e-3 * scale.max(1e-3) {
                verified = false;
            }
        }
        inflight[p] -= 1;
        total_done += 1;
        if emitted[p] < cfg.runs {
            emit(p, &mut emitted, &mut inflight);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    for tx in senders.values() {
        let _ = tx.send(Msg::Stop);
    }
    for w in workers {
        match w.join() {
            Ok(res) => res?,
            Err(_) => bail!("worker thread panicked"),
        }
    }

    for s in &mut stats {
        if s.completions > 0 {
            s.mean_latency_s /= s.completions as f64;
        }
    }
    Ok(ServeReport {
        wall_s,
        completions: total_done,
        throughput: total_done as f64 / wall_s.max(1e-9),
        per_pipeline: stats,
        verified,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_inputs_are_sized_by_element_count() {
        // Regression: frames must have exactly h·w·c f32 entries — sizing
        // them off a *byte* count would 4×-overallocate the moment any
        // dtype wider than 8 bits enters the path, and `run_full` rejects
        // length mismatches outright.
        let shape = Shape::new(64, 64, 3);
        let frame = synth_frame(shape, 42);
        assert_eq!(frame.len(), 64 * 64 * 3);
        assert_eq!(frame.len() as u64, shape.elements());
        assert_ne!(frame.len(), 4 * 64 * 64 * 3, "f32-byte-count confusion");
    }

    #[test]
    fn missing_manifest_model_is_an_error_not_a_panic() {
        // Regression: `manifest.model(..).unwrap()` panicked mid-serving
        // when an app's model had no AOT artifacts; the lookup must
        // propagate a typed error naming the app instead.
        use crate::model::zoo::{model_by_name, ModelName};
        use crate::pipeline::{SourceReq, TargetReq};
        let spec = PipelineSpec::new(
            0,
            "ghost",
            SourceReq::Any,
            model_by_name(ModelName::KWS).clone(),
            TargetReq::Any,
        );
        let err = synth_inputs(&[spec], &Manifest::default(), 42).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("ghost") && msg.contains("not in manifest"),
            "{msg}"
        );
    }

    #[test]
    fn synthetic_inputs_are_seeded_and_nontrivial() {
        let shape = Shape::new(8, 8, 2);
        let a = synth_frame(shape, 7);
        let b = synth_frame(shape, 7);
        let c = synth_frame(shape, 8);
        assert_eq!(a, b, "same seed must reproduce the frame");
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.iter().any(|v| *v != 0.0));
    }
}
