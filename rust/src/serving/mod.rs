//! The live streaming serving subsystem: execute deployments on real
//! worker threads and survive plan switches.
//!
//! The planner and the discrete-event simulator answer *which* plan is
//! best and *what* it would do on the modeled hardware; this module is the
//! execution path that actually runs one — multi-threaded, streaming, and
//! rebindable while rounds are in flight:
//!
//! - [`ServeEngine`]: one worker thread per (device, computation unit)
//!   admitting work through a *deterministic conservative merge*
//!   (ready-time-ordered per-unit queues with propagated bounds, so
//!   shared-unit replays are bit-comparable), a sensor-rate ticker per
//!   app pacing round admission, and *live plan switches* — a replanned
//!   deployment rebinds onto the same threads while the old epoch's
//!   in-flight rounds drain gracefully, with the measured rebind pause
//!   reported and no admitted round ever dropped. Workers report their
//!   busy intervals as [`crate::power::BusySpan`]s, so served sessions
//!   integrate real energy through the shared power accountant.
//! - [`ChunkExecutor`] / [`VirtualExecutor`]: what "run this chunk" means.
//!   The device-model cost estimator doubles as a deterministic
//!   virtual-time executor on stock toolchains; real AOT-compiled HLO
//!   inference plugs in behind the `pjrt` cargo feature (the
//!   feature-gated `pjrt` submodule).
//! - [`ServeBackend`]: the streaming engine as a third execution backend
//!   next to [`crate::api::SimBackend`] and the PJRT backend, measured
//!   with the simulator's conventions so the reports compare directly.
//!
//! Live sessions drive the same engine through scenarios:
//! [`crate::api::Session::serve`] swaps a session onto the streaming
//! engine, so scripted churn replans incrementally and every switch
//! rebinds the workers mid-stream (`synergy serve --scenario jog` on the
//! CLI). Round-index continuity across switches is shared with the DES
//! through [`crate::scheduler::EpochLedger`].

pub mod backend;
pub mod engine;
pub mod executor;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod topology;

pub use backend::ServeBackend;
pub use engine::{Rebind, ServeCfg, ServeEngine, ServeOutcome};
pub use executor::{ChunkExecutor, TaskCtx, VirtualExecutor};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtChunkExecutor;
pub use topology::{plan_channel_graph, ChannelGraph};
