//! The chunk-execution abstraction: what "run this task" means.
//!
//! The streaming engine ([`super::ServeEngine`]) is execution-agnostic: it
//! owns threads, queues, pacing, and plan rebinding, and delegates the
//! actual work of each task instance to a [`ChunkExecutor`]. Two
//! executors exist:
//!
//! - [`VirtualExecutor`] (always available): the device-model cost
//!   estimator doubles as a deterministic *virtual-time* executor — each
//!   task "runs" for exactly the duration the ground-truth hardware model
//!   assigns it ([`crate::scheduler::GroundTruth`]), including the
//!   deterministic per-round jitter stream, so a served session is
//!   directly comparable to the same plans under the discrete-event
//!   simulator.
//! - `PjrtChunkExecutor` (behind the `pjrt` cargo feature, in the gated
//!   `serving::pjrt` submodule): real AOT-compiled HLO chunk inference
//!   through the PJRT runtime bridge; durations are measured wall-clock
//!   seconds and activations flow through the [`TaskCtx`] payload.

use crate::device::{Fleet, SensorKind};
use crate::pipeline::PipelineSpec;
use crate::plan::task::PlanTask;
use crate::scheduler::GroundTruth;

use crate::api::RuntimeError;

/// Everything an executor can know about one task instance.
pub struct TaskCtx<'a> {
    /// The fleet the task's epoch was bound against.
    pub fleet: &'a Fleet,
    /// The app the task belongs to (model, endpoints, name).
    pub spec: &'a PipelineSpec,
    /// The bound task (device, kind, sequence position).
    pub task: &'a PlanTask,
    /// The app's declared source sensor, if any.
    pub sensor: Option<SensorKind>,
    /// Global round index (continuous across plan switches; keys the
    /// deterministic jitter stream).
    pub round: usize,
}

/// Executes one task instance and reports how long it took, in engine
/// seconds (virtual time for model-driven executors, measured wall time
/// for real ones).
///
/// `payload` is the activation flowing along the pipeline's chunk chain:
/// real executors fill it at the sensing task and transform it at each
/// inference chunk; virtual-time executors ignore it.
pub trait ChunkExecutor: Send + Sync {
    /// Short backend label for reports (`"virtual"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Run the task; returns its duration in engine seconds.
    fn execute(
        &self,
        ctx: &TaskCtx<'_>,
        payload: &mut Option<Vec<f32>>,
    ) -> Result<f64, RuntimeError>;
}

/// Deterministic virtual-time execution on the ground-truth device model
/// (see the module docs). Needs no artifacts and no vendored toolchain.
#[derive(Clone, Debug)]
pub struct VirtualExecutor {
    gt: GroundTruth,
}

impl VirtualExecutor {
    pub fn new(gt: GroundTruth) -> VirtualExecutor {
        VirtualExecutor { gt }
    }

    /// A virtual-time executor over the default hardware model with the
    /// given jitter seed (matches [`crate::scheduler::GroundTruth::with_seed`],
    /// so served and simulated sessions share one jitter stream).
    pub fn with_seed(seed: u64) -> VirtualExecutor {
        VirtualExecutor {
            gt: GroundTruth::with_seed(seed),
        }
    }
}

impl ChunkExecutor for VirtualExecutor {
    fn name(&self) -> &'static str {
        "virtual"
    }

    fn execute(
        &self,
        ctx: &TaskCtx<'_>,
        _payload: &mut Option<Vec<f32>>,
    ) -> Result<f64, RuntimeError> {
        Ok(self
            .gt
            .duration(ctx.fleet, ctx.task, &ctx.spec.model, ctx.sensor, ctx.round))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceId;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::pipeline::{PipelineId, SourceReq, TargetReq};
    use crate::plan::task::TaskKind;

    #[test]
    fn virtual_executor_matches_ground_truth_durations() {
        let fleet = crate::workload::fleet4();
        let spec = PipelineSpec::new(
            0,
            "kws",
            SourceReq::Any,
            model_by_name(ModelName::KWS).clone(),
            TargetReq::Any,
        );
        let task = PlanTask {
            pipeline: PipelineId(0),
            seq: 1,
            device: DeviceId(0),
            kind: TaskKind::Infer { range: spec.model.full() },
        };
        let exec = VirtualExecutor::with_seed(7);
        let ctx = TaskCtx { fleet: &fleet, spec: &spec, task: &task, sensor: None, round: 3 };
        let mut payload = None;
        let d = exec.execute(&ctx, &mut payload).unwrap();
        let expect = GroundTruth::with_seed(7).duration(&fleet, &task, &spec.model, None, 3);
        assert_eq!(d, expect);
        assert!(payload.is_none(), "virtual execution carries no data");
        // Deterministic per (task, round); different rounds jitter apart.
        let again = exec.execute(&ctx, &mut payload).unwrap();
        assert_eq!(d, again);
        let other = exec
            .execute(&TaskCtx { round: 4, ..ctx }, &mut payload)
            .unwrap();
        assert_ne!(d, other);
    }
}
