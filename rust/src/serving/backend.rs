//! [`ServeBackend`]: the streaming engine as an execution backend, next to
//! [`crate::api::SimBackend`] and the PJRT backend.
//!
//! `runtime.run(cfg)` on a `ServeBackend` executes the current deployment
//! as one bounded streaming epoch — `cfg.runs` rounds per app on real
//! worker threads — and measures it with the *same* warmup/round
//! conventions as the simulator backend, so the two reports are directly
//! comparable: a virtual-time serve is expected to land within a few
//! percent of [`crate::scheduler::simulate`] on the same plan.

use std::sync::Arc;

use crate::api::backend::sim_config;
use crate::api::core::Deployment;
use crate::api::{AppRunStats, ExecutionBackend, RunConfig, RunReport, RuntimeError};
use crate::device::Fleet;
use crate::pipeline::PipelineSpec;
use crate::scheduler::Policy;

use super::engine::{ServeCfg, ServeEngine};
use super::executor::{ChunkExecutor, VirtualExecutor};

/// Streaming execution behind [`crate::api::SynergyRuntime::run`] (see the
/// module docs).
pub struct ServeBackend {
    /// `None` builds a fresh [`VirtualExecutor`] per run, seeded from the
    /// [`RunConfig`] (matching the simulator's jitter stream); `Some`
    /// serves every run on the given executor.
    executor: Option<Arc<dyn ChunkExecutor>>,
    cfg: ServeCfg,
}

impl ServeBackend {
    /// Virtual-time streaming on the device-model executor — runs on a
    /// stock toolchain, no artifacts needed.
    pub fn virtual_time() -> ServeBackend {
        ServeBackend {
            executor: None,
            cfg: ServeCfg::default(),
        }
    }

    /// Stream through a specific executor (e.g. the PJRT chunk executor).
    pub fn with_executor(executor: Arc<dyn ChunkExecutor>) -> ServeBackend {
        ServeBackend {
            executor: Some(executor),
            cfg: ServeCfg::default(),
        }
    }

    /// Override the engine configuration (in-flight window, queue depth,
    /// wall-time pacing).
    pub fn cfg(mut self, cfg: ServeCfg) -> ServeBackend {
        self.cfg = cfg;
        self
    }
}

impl ExecutionBackend for ServeBackend {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn run(
        &self,
        deployment: &Deployment,
        apps: &[PipelineSpec],
        fleet: &Fleet,
        cfg: &RunConfig,
    ) -> Result<RunReport, RuntimeError> {
        assert!(cfg.runs > 0, "need at least one run");
        let executor = self
            .executor
            .clone()
            .unwrap_or_else(|| Arc::new(VirtualExecutor::with_seed(cfg.seed)));
        let mut serve_cfg = self.cfg;
        // Match the deployed policy's inter-run window so virtual-time
        // serving paces rounds exactly like the DES would (the streaming
        // engine always runs the paper's per-app ATP admission; barrier
        // policies degrade to a single-round window).
        serve_cfg.max_inflight = match deployment.policy {
            Policy::Atp { max_inflight } => max_inflight.max(1),
            Policy::Sequential | Policy::InterPipeline => 1,
        };
        let wall = std::time::Instant::now();
        let mut engine = ServeEngine::new(executor, serve_cfg, fleet.clone());
        engine.set_plan(&deployment.plan, apps, Some(cfg.runs))?;
        engine.run_until(f64::INFINITY);
        let outcome = engine.finish()?;
        let wall_s = wall.elapsed().as_secs_f64();

        // Measure with the simulator's conventions (unified rounds, warmup
        // excluded) so serve and sim reports compare apples to apples.
        let n = deployment.plan.plans.len();
        let runs = cfg.runs;
        let warmup = sim_config(runs, deployment.policy).warmup;
        let mut start_of = vec![vec![f64::NAN; runs]; n];
        let mut end_of = vec![vec![f64::NAN; runs]; n];
        for rec in &outcome.records {
            let Some(p) = deployment
                .plan
                .plans
                .iter()
                .position(|ep| ep.pipeline == rec.pipeline)
            else {
                continue;
            };
            if rec.run < runs {
                start_of[p][rec.run] = rec.start;
                end_of[p][rec.run] = rec.end;
            }
        }
        let round_done: Vec<f64> = (0..runs)
            .map(|r| (0..n).map(|p| end_of[p][r]).fold(0.0, f64::max))
            .collect();
        let t0 = if warmup == 0 {
            0.0
        } else {
            round_done[warmup - 1]
        };
        let measured = runs - warmup;
        let throughput = (n * measured) as f64 / (round_done[runs - 1] - t0).max(1e-12);
        let mut lat_sum = 0.0;
        let mut lat_cnt = 0usize;
        for r in warmup..runs {
            for p in 0..n {
                lat_sum += end_of[p][r] - start_of[p][r];
                lat_cnt += 1;
            }
        }
        let avg_latency_s = lat_sum / lat_cnt.max(1) as f64;

        // Energy over the horizon: replay the workers' busy spans through
        // the shared power accountant — the same integration the DES
        // performs (base draw over the makespan + active draws per busy
        // second).
        let makespan = outcome.records.iter().map(|r| r.end).fold(0.0, f64::max);
        let mut replay = crate::power::EnergyReplay::new(fleet.clone());
        for span in outcome.busy.iter().filter(|s| s.end <= makespan + 1e-9) {
            replay.record(span);
        }
        let energy_j = replay.energy_at(makespan);

        let per_app: Vec<AppRunStats> = deployment
            .plan
            .plans
            .iter()
            .enumerate()
            .map(|(p, ep)| {
                let name = apps
                    .iter()
                    .find(|a| a.id == ep.pipeline)
                    .map(|a| a.name.clone())
                    .unwrap_or_default();
                let lat: f64 = (0..runs).map(|r| end_of[p][r] - start_of[p][r]).sum();
                AppRunStats {
                    app: ep.pipeline,
                    name,
                    completions: runs,
                    mean_latency_s: lat / runs.max(1) as f64,
                    max_split_err: None,
                }
            })
            .collect();

        Ok(RunReport {
            backend: self.name(),
            completions: outcome.completed,
            throughput,
            avg_latency_s,
            power_w: Some(energy_j / makespan.max(1e-12)),
            energy_j: Some(energy_j),
            wall_s: Some(wall_s),
            verified: None,
            per_app,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{SimBackend, SynergyRuntime};
    use crate::workload::{fleet4, workload};

    #[test]
    fn serve_backend_reports_virtual_time_throughput() {
        let runtime = SynergyRuntime::builder()
            .fleet(fleet4())
            .backend(ServeBackend::virtual_time())
            .build();
        for spec in workload(2).unwrap().pipelines {
            runtime.register(spec).unwrap();
        }
        let cfg = RunConfig {
            runs: 12,
            seed: 7,
            ..RunConfig::default()
        };
        let rep = runtime.run(&cfg).unwrap();
        assert_eq!(rep.backend, "serve");
        assert_eq!(rep.completions, 3 * 12);
        assert!(rep.throughput > 0.0);
        assert!(rep.avg_latency_s > 0.0);
        assert_eq!(rep.per_app.len(), 3);
        assert!(rep.per_app.iter().all(|a| a.completions == 12));
        assert!(rep.wall_s.is_some());
        let power = rep.power_w.expect("virtual-time serving integrates energy");
        let base: f64 = fleet4().devices.iter().map(|d| d.spec.power.base_w).sum();
        assert!(power > base, "active work must draw above base: {power}");
    }

    #[test]
    fn virtual_serve_tracks_the_simulator_closely() {
        // The acceptance bar: one-shot virtual-time serving lands within
        // 10% of the DES on the same deployment and seed.
        let cfg = RunConfig {
            runs: 24,
            seed: 7,
            ..RunConfig::default()
        };
        let serve = {
            let runtime = SynergyRuntime::builder()
                .fleet(fleet4())
                .backend(ServeBackend::virtual_time())
                .build();
            for spec in workload(1).unwrap().pipelines {
                runtime.register(spec).unwrap();
            }
            runtime.run(&cfg).unwrap()
        };
        let sim = {
            let runtime = SynergyRuntime::builder()
                .fleet(fleet4())
                .backend(SimBackend)
                .build();
            for spec in workload(1).unwrap().pipelines {
                runtime.register(spec).unwrap();
            }
            runtime.run(&cfg).unwrap()
        };
        assert_eq!(serve.completions, sim.completions);
        let tput_gap = (serve.throughput - sim.throughput).abs() / sim.throughput;
        assert!(
            tput_gap < 0.10,
            "serve {} vs sim {} inf/s (gap {tput_gap:.3})",
            serve.throughput,
            sim.throughput
        );
        // Latency gets a slightly wider bar: when two pipelines share a
        // computation unit, worker arrival order (OS scheduling) can queue
        // a round behind a different neighbor than the DES's ready-time
        // order did, shifting individual round latencies by a task.
        let lat_gap = (serve.avg_latency_s - sim.avg_latency_s).abs() / sim.avg_latency_s;
        assert!(
            lat_gap < 0.15,
            "serve {} vs sim {} s latency (gap {lat_gap:.3})",
            serve.avg_latency_s,
            sim.avg_latency_s
        );
    }
}
