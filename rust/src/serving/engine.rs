//! The streaming execution engine: a deployment run on real worker
//! threads, surviving plan switches.
//!
//! Topology mirrors §IV-F on actual threads: one worker per
//! (device, computation unit) processing a per-unit admission queue,
//! chunk chains as the links between a pipeline's stages, and a
//! sensor-rate ticker per app that admits rounds with the paper's
//! adaptive-task-parallelization pacing (round `r+1` enters when round
//! `r`'s sensing completed and at most `max_inflight` rounds are
//! outstanding). What "run this task" means is delegated to a
//! [`ChunkExecutor`]: the deterministic virtual-time device model on
//! stock toolchains, real PJRT inference behind the `pjrt` feature (see
//! [`super::executor`]).
//!
//! Time is *engine seconds* carried on the messages themselves: each
//! worker keeps a per-unit clock, starts a task at
//! `max(ready, unit_clock)`, and stamps completions — so unit exclusivity
//! and round latency accounting hold in virtual time regardless of how the
//! OS schedules the threads, and a served session is directly comparable
//! to the discrete-event simulator on the same plans.
//!
//! **Deterministic merge.** Each worker admits work through a
//! conservative ready-time-ordered merge, not arrival order: every
//! (chain, stage) bound to a unit is a *source* carrying a monotone
//! stream of items plus a lower bound on its next delivery (tickers
//! publish the next admission's ready time, and every enqueued item
//! propagates its ready time to all later stages of its chain as a
//! bound). A worker executes the (ready, source)-minimal queued item only
//! once every other open source provably cannot deliver anything
//! smaller — the classic conservative-simulation admission rule — so two
//! pipelines sharing a computation unit produce *bit-comparable* served
//! replays, independent of OS scheduling. A generous wait timeout
//! ([`ServeCfg::liveness_valve_s`], 5 s by default) acts as a liveness
//! valve: under continuous driving the bounds never stall, but a session
//! parked mid-run for longer than the valve (or a wall-time executor
//! chunk outlasting it) falls back to the minimal *available* item —
//! degraded ordering, never a hang or a dropped round. Equal-ready-time
//! ties resolve by source-key order, perturbable for race exploration via
//! [`ServeCfg::same_time`] (see [`crate::analysis::SameTimePolicy`]).
//!
//! **Energy.** Workers report every completed busy interval as a
//! [`BusySpan`] (the same task→draw mapping the DES charges); the engine
//! returns them, chronologically replayable through
//! [`crate::power::EnergyReplay`], alongside a fleet-change history — so
//! served sessions integrate real `power_w`/`energy_j` and battery ramps
//! run on the serve path too.
//!
//! **Live plan switches** are the headline: [`ServeEngine::set_plan`]
//! retires the current binding epoch (its tickers stop admitting rounds;
//! everything already admitted drains gracefully through the workers),
//! rebinds the chunk chains of the new deployment onto the *same* worker
//! threads, and records the measured rebind pause — mirroring the
//! discrete-event engine's epoch semantics
//! ([`crate::scheduler::SimEngine::set_plan`]), with round-index
//! continuity shared through [`crate::scheduler::EpochLedger`]. No
//! admitted round is ever dropped: at [`ServeEngine::finish`] the engine
//! reports admitted vs. completed rounds so callers can assert
//! conservation across switches.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analysis::{AnalysisError, SameTimePolicy};
use crate::device::{DeviceId, Fleet, SensorKind};
use crate::estimator::LatencyModel;
use crate::pipeline::PipelineSpec;
use crate::plan::task::{PlanTask, UnitKind};
use crate::plan::CollabPlan;
use crate::power::{busy_kind, BusySpan};
use crate::scheduler::{EpochLedger, GroundTruth, RoundRecord, TaskSpan};

use crate::api::RuntimeError;

use super::executor::{ChunkExecutor, TaskCtx};

/// Streaming-engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Rounds a pipeline may have in flight at once (2 = the paper's
    /// double-buffered inter-run overlap).
    pub max_inflight: usize,
    /// Legacy queue-depth knob. Admission is bounded by the per-app
    /// pacing window (`max_inflight`), so the per-unit merge queues never
    /// grow past a few items per bound chain; the field is kept for
    /// configuration compatibility.
    pub channel_depth: usize,
    /// Wall seconds each worker sleeps per engine second of task time.
    /// `0.0` (default) free-runs — virtual time advances as fast as the
    /// threads can carry it; `1.0` paces serving to real time.
    pub time_scale: f64,
    /// Liveness valve in wall seconds: how long a worker waits on
    /// admission bounds before falling back to the minimal *available*
    /// item (degraded merge order, never a hang). Raise it for real
    /// executors with long chunks; lower it for tests that park sessions
    /// deliberately.
    pub liveness_valve_s: f64,
    /// How equal-virtual-time admission ties are ordered (race
    /// exploration; the default reproduces the causal source-key order).
    pub same_time: SameTimePolicy,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            max_inflight: 2,
            channel_depth: 64,
            time_scale: 0.0,
            liveness_valve_s: 5.0,
            same_time: SameTimePolicy::Deterministic,
        }
    }
}

/// One measured plan rebind (see [`ServeEngine::set_plan`]).
#[derive(Clone, Copy, Debug)]
pub struct Rebind {
    /// Engine time the switch landed.
    pub t: f64,
    /// Measured wall-clock pause: retiring the old epoch's tickers plus
    /// binding the new chains onto the workers.
    pub wall_s: f64,
    /// Apps in the new binding (0 = deployment cleared).
    pub apps: usize,
}

/// What the engine produced over its lifetime (see [`ServeEngine::finish`]).
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// The executor that ran the chunks (`"virtual"`, `"pjrt"`).
    pub executor: &'static str,
    /// Retained completed rounds, ordered by completion time. Includes
    /// rounds that drained past the last horizon; a record cap
    /// ([`ServeEngine::set_record_cap`]) retains only the most recent.
    pub records: Vec<RoundRecord>,
    /// Rounds admitted by the tickers across all epochs. Equal to
    /// [`Self::completed`] when no executor fault occurred — the
    /// conservation invariant across plan switches.
    pub admitted: usize,
    /// Rounds completed across all epochs — the full count, independent
    /// of the record cap.
    pub completed: usize,
    /// Plan-rebind timeline with measured pauses.
    pub rebinds: Vec<Rebind>,
    /// Worker threads spawned over the engine's lifetime.
    pub workers: usize,
    /// Every completed busy interval, sorted by completion time — replay
    /// through [`crate::power::EnergyReplay`] (with [`Self::fleet_history`])
    /// to integrate energy exactly as the DES does.
    pub busy: Vec<BusySpan>,
    /// Every executed task instance, sorted by (pipeline, run, seq) — the
    /// serve-path analogue of the DES task trace. Collected post-hoc at
    /// [`ServeEngine::finish`] (workers only ever send into a channel, the
    /// same discipline as [`Self::busy`]), so downstream consumers — the
    /// flight recorder, blame attribution — stay bit-identical across
    /// worker counts and reruns.
    pub tasks: Vec<TaskSpan>,
    /// The fleet over time: the starting fleet at `t = 0.0` plus one
    /// entry per [`ServeEngine::set_fleet`], in order.
    pub fleet_history: Vec<(f64, Fleet)>,
}

/// A round's activation flowing between chunk stages (real executors
/// only; the virtual executor carries `None`).
type Payload = Option<Vec<f32>>;

/// Identifies one stream of items into a unit: (pipeline id, stage
/// position, binding epoch). The tuple order doubles as the
/// deterministic tie-break for equal ready times — earlier stages of
/// lower-numbered pipelines win, matching causal order.
type SourceKey = (usize, usize, usize);

/// A (merger, source) address of one chain stage.
type Stage = (Arc<Merger>, SourceKey);

/// One pipeline's chunk chain bound to workers for one epoch.
struct ChainBinding {
    spec: PipelineSpec,
    tasks: Vec<PlanTask>,
    /// Per-stage admission address, index-aligned with `tasks`.
    stages: Vec<Stage>,
    /// Back to this chain's ticker (pacing feedback).
    feedback: mpsc::Sender<Feedback>,
    /// To the engine's completion collector.
    done: mpsc::Sender<DoneMsg>,
    /// The fleet this epoch was bound against (device specs for costing).
    fleet: Arc<Fleet>,
    sensor: Option<SensorKind>,
}

impl ChainBinding {
    /// Deliver `item` to its stage's merge queue, first propagating its
    /// ready time to every later stage of the chain as a delivery lower
    /// bound (the conservative-merge invariant: a queued item is always
    /// visible downstream as a bound before it is poppable).
    fn deliver(&self, item: WorkItem) {
        let ready = item.ready;
        for (merger, key) in self.stages.iter().skip(item.seq + 1) {
            merger.bound(*key, ready);
        }
        let (merger, key) = &self.stages[item.seq];
        merger.push(*key, item);
    }
}

/// One task instance traveling a chain.
struct WorkItem {
    chain: Arc<ChainBinding>,
    seq: usize,
    /// Global round index (continuous across epochs).
    round: usize,
    /// Engine time the item became ready for its unit.
    ready: f64,
    /// Start time of the round's sensing task (filled at seq 0).
    round_start: f64,
    payload: Payload,
    /// An executor fault upstream: the item still traverses the chain
    /// (zero-duration) so pacing, closure, and conservation bookkeeping
    /// stay sound, but executes nothing and records no round.
    poisoned: bool,
}

enum Feedback {
    SenseDone { round: usize, end: f64 },
    RoundDone { round: usize, end: f64 },
}

enum DoneMsg {
    Round(RoundRecord),
    Fault(String),
}

/// One upstream stream into a unit's merge queue.
struct Source {
    /// Delivered, not-yet-executed items (FIFO in global round order;
    /// their ready times are nondecreasing).
    items: VecDeque<WorkItem>,
    /// Lower bound on the ready time of the next item *beyond* those
    /// queued — raised by ticker pre-announcements and by upstream
    /// enqueues propagating down the chain.
    lb: f64,
    /// One past the last global round this source will carry, set when
    /// its epoch's ticker exits.
    close_at: Option<usize>,
    /// Global round index of the next item expected from upstream.
    next_round: usize,
}

/// A unit's admission state: every source bound to it across epochs.
struct MergerSt {
    sources: BTreeMap<SourceKey, Source>,
    shutdown: bool,
}

/// The per-unit conservative ready-time-ordered merge queue (see the
/// module docs).
struct Merger {
    st: Mutex<MergerSt>,
    cv: Condvar,
    /// The liveness valve ([`ServeCfg::liveness_valve_s`]): how long a
    /// worker waits on admission bounds before falling back to the minimal
    /// available item, degrading merge order instead of hanging. With the
    /// engine actively driven, correct bound propagation never trips this.
    /// It *can* trip — by design — when a driver parks a session mid-run
    /// for longer than the valve with work queued behind a horizon-parked
    /// ticker, or when a real (PJRT) executor runs one chunk longer than
    /// the valve: conservation still holds, but the replay is no longer
    /// bit-comparable to an unpaused run.
    valve: Duration,
    /// Equal-ready-time tie ordering (race exploration).
    same_time: SameTimePolicy,
}

/// Lock, recovering the data on poison: a panicking worker thread must
/// not cascade `PoisonError` panics through every peer draining the same
/// merger — the fault surfaces once, as a typed `Backend` error at
/// [`ServeEngine::finish`].
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Merger {
    fn new(valve: Duration, same_time: SameTimePolicy) -> Merger {
        Merger {
            st: Mutex::new(MergerSt {
                sources: BTreeMap::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            valve,
            same_time,
        }
    }

    /// Strict total tie order over source keys: the seeded rank first
    /// (all zeros under the deterministic policy), causal key order last.
    fn key_lt(&self, a: SourceKey, b: SourceKey) -> bool {
        (self.same_time.key_rank(a), a) < (self.same_time.key_rank(b), b)
    }

    /// Bind a new source (chain stage) to this unit.
    fn register(&self, key: SourceKey, base_round: usize, t: f64) {
        let mut st = lock_recover(&self.st);
        st.sources.insert(
            key,
            Source {
                items: VecDeque::new(),
                lb: t,
                close_at: None,
                next_round: base_round,
            },
        );
        self.cv.notify_all();
    }

    /// Raise a source's delivery lower bound.
    fn bound(&self, key: SourceKey, lb: f64) {
        let mut st = lock_recover(&self.st);
        if let Some(s) = st.sources.get_mut(&key) {
            if lb > s.lb {
                s.lb = lb;
                self.cv.notify_all();
            }
        }
    }

    /// Enqueue an item (also raises the source's bound to its ready).
    fn push(&self, key: SourceKey, item: WorkItem) {
        let mut st = lock_recover(&self.st);
        let s = st.sources.get_mut(&key).expect("push to unregistered source");
        if item.ready > s.lb {
            s.lb = item.ready;
        }
        s.items.push_back(item);
        self.cv.notify_all();
    }

    /// Announce that no round at or past `close_at` will arrive on `key`.
    fn close(&self, key: SourceKey, close_at: usize) {
        let mut st = lock_recover(&self.st);
        if let Some(s) = st.sources.get_mut(&key) {
            s.close_at = Some(close_at);
        }
        self.cv.notify_all();
    }

    /// Let the worker exit once every source is exhausted.
    fn shutdown(&self) {
        let mut st = lock_recover(&self.st);
        st.shutdown = true;
        self.cv.notify_all();
    }

    /// The (ready, key)-minimal queued head, if any — key ties under the
    /// same-time policy's total order.
    fn min_head(&self, st: &MergerSt) -> Option<(f64, SourceKey)> {
        let mut best: Option<(f64, SourceKey)> = None;
        for (&key, s) in &st.sources {
            if let Some(head) = s.items.front() {
                let better = match best {
                    None => true,
                    Some((br, bk)) => match head.ready.total_cmp(&br) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => self.key_lt(key, bk),
                    },
                };
                if better {
                    best = Some((head.ready, key));
                }
            }
        }
        best
    }

    fn take(st: &mut MergerSt, key: SourceKey) -> WorkItem {
        let s = st.sources.get_mut(&key).expect("pop from missing source");
        let item = s.items.pop_front().expect("pop from empty source");
        s.next_round = item.round + 1;
        item
    }

    /// Block until an item is safely admissible (or the merger shuts
    /// down with nothing left). `None` means the worker should exit.
    fn pop(&self) -> Option<WorkItem> {
        let mut st = lock_recover(&self.st);
        loop {
            // Drop exhausted sources (their epoch closed and every round
            // passed through).
            st.sources.retain(|_, s| {
                !(s.items.is_empty() && s.close_at.is_some_and(|c| s.next_round >= c))
            });
            if st.sources.is_empty() {
                if st.shutdown {
                    return None;
                }
            } else if let Some((ready, key)) = self.min_head(&st) {
                // Safe iff every *other* open source provably delivers
                // nothing smaller: a queued head already lost the min
                // comparison; an empty source must have a bound past the
                // candidate (ties resolve by the policy's total order).
                let safe = st.sources.iter().all(|(&k, s)| {
                    k == key
                        || !s.items.is_empty()
                        || match s.lb.total_cmp(&ready) {
                            std::cmp::Ordering::Greater => true,
                            std::cmp::Ordering::Less => false,
                            std::cmp::Ordering::Equal => self.key_lt(key, k),
                        }
                });
                if safe {
                    return Some(Self::take(&mut st, key));
                }
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(st, self.valve)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
            if timeout.timed_out() {
                if let Some((_, key)) = self.min_head(&st) {
                    return Some(Self::take(&mut st, key));
                }
            }
        }
    }
}

/// Ticker ⇄ driver rendezvous: the admission horizon, retirement, and the
/// parked/finished state the driver waits on.
struct GateSt {
    horizon: f64,
    retired: bool,
    parked: bool,
    next_ready: f64,
    done: bool,
}

struct Gate {
    st: Mutex<GateSt>,
    cv: Condvar,
}

impl Gate {
    fn new(horizon: f64) -> Gate {
        Gate {
            st: Mutex::new(GateSt {
                horizon,
                retired: false,
                parked: false,
                next_ready: 0.0,
                done: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Ticker side: block until `ready` falls inside the horizon; `false`
    /// means the epoch retired instead.
    fn admit(&self, ready: f64) -> bool {
        let mut st = lock_recover(&self.st);
        loop {
            if st.retired {
                return false;
            }
            if ready < st.horizon {
                st.parked = false;
                return true;
            }
            st.parked = true;
            st.next_ready = ready;
            self.cv.notify_all();
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn finish(&self) {
        let mut st = lock_recover(&self.st);
        st.done = true;
        self.cv.notify_all();
    }

    fn set_horizon(&self, t: f64) {
        let mut st = lock_recover(&self.st);
        if t > st.horizon {
            st.horizon = t;
        }
        self.cv.notify_all();
    }

    fn retire(&self) {
        let mut st = lock_recover(&self.st);
        st.retired = true;
        self.cv.notify_all();
    }

    /// Driver side: wait until the ticker can admit nothing more before
    /// `t` — parked at or past it, finished its round budget, or retired.
    fn wait_idle(&self, t: f64) {
        let mut st = lock_recover(&self.st);
        while !(st.done || st.retired || (st.parked && st.next_ready >= t)) {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

struct Worker {
    merger: Arc<Merger>,
    join: JoinHandle<()>,
}

struct TickerHandle {
    gate: Arc<Gate>,
    join: JoinHandle<usize>,
}

/// Everything one ticker thread needs.
struct TickerTask {
    chain: Arc<ChainBinding>,
    feedback: mpsc::Receiver<Feedback>,
    gate: Arc<Gate>,
    /// Engine time the epoch started (earliest possible admission).
    start_t: f64,
    base_round: usize,
    max_inflight: usize,
    /// Round budget (`None` = run against horizons).
    max_rounds: Option<usize>,
    ledger: Arc<Mutex<EpochLedger>>,
}

/// Pull feedback until the wanted entry arrives; `None` = channel closed.
fn recv_until(
    feedback: &mpsc::Receiver<Feedback>,
    sense_ends: &mut BTreeMap<usize, f64>,
    round_ends: &mut BTreeMap<usize, f64>,
    want_sense: bool,
    round: usize,
) -> Option<f64> {
    loop {
        let map = if want_sense {
            &mut *sense_ends
        } else {
            &mut *round_ends
        };
        if let Some(end) = map.remove(&round) {
            return Some(end);
        }
        match feedback.recv() {
            Ok(Feedback::SenseDone { round, end }) => {
                sense_ends.insert(round, end);
            }
            Ok(Feedback::RoundDone { round, end }) => {
                round_ends.insert(round, end);
            }
            Err(_) => return None,
        }
    }
}

/// The per-app sensor-rate ticker: admits round `r` once round `r-1`'s
/// sensing completed (the sensor cadence) and at most `max_inflight`
/// rounds are outstanding — the ATP pacing the DES expresses as
/// dependency edges, here as blocking feedback reads.
fn ticker_loop(t: TickerTask) -> usize {
    let TickerTask {
        chain,
        feedback,
        gate,
        start_t,
        base_round,
        max_inflight,
        max_rounds,
        ledger,
    } = t;
    let mut sense_ends: BTreeMap<usize, f64> = BTreeMap::new();
    let mut round_ends: BTreeMap<usize, f64> = BTreeMap::new();
    let mut admitted = 0usize;
    loop {
        if let Some(m) = max_rounds {
            if admitted >= m {
                break;
            }
        }
        let local = admitted;
        let mut ready = start_t;
        if local > 0 {
            match recv_until(
                &feedback,
                &mut sense_ends,
                &mut round_ends,
                true,
                base_round + local - 1,
            ) {
                Some(end) => ready = ready.max(end),
                None => break,
            }
        }
        if local >= max_inflight {
            match recv_until(
                &feedback,
                &mut sense_ends,
                &mut round_ends,
                false,
                base_round + local - max_inflight,
            ) {
                Some(end) => ready = ready.max(end),
                None => break,
            }
        }
        // Pre-announce the admission to the stage-0 merge queue *before*
        // (possibly) parking at the horizon gate, so no worker ever waits
        // on a parked ticker's stale bound.
        {
            let (merger, key) = &chain.stages[0];
            merger.bound(*key, ready);
        }
        if !gate.admit(ready) {
            break;
        }
        let round = base_round + local;
        lock_recover(&ledger).note_round(chain.spec.id, round);
        chain.deliver(WorkItem {
            chain: chain.clone(),
            seq: 0,
            round,
            ready,
            round_start: 0.0,
            payload: None,
            poisoned: false,
        });
        admitted += 1;
    }
    // Epoch over (budget, retirement, or a closed feedback loop): no
    // round at or past `base_round + admitted` will ever exist, so every
    // stage's source can retire once the admitted prefix drains through.
    for (merger, key) in &chain.stages {
        merger.close(*key, base_round + admitted);
    }
    gate.finish();
    admitted
}

/// One (device, unit) worker: execute the unit's merge queue in
/// conservative ready-time order against a per-unit engine clock, forward
/// along the chain, report completions and busy spans.
fn worker_loop(
    merger: Arc<Merger>,
    device: DeviceId,
    unit: UnitKind,
    executor: Arc<dyn ChunkExecutor>,
    time_scale: f64,
    acct: mpsc::Sender<BusySpan>,
    tasks: mpsc::Sender<TaskSpan>,
) {
    let mut clock = 0.0f64;
    while let Some(mut item) = merger.pop() {
        let chain = item.chain.clone();
        let task = chain.tasks[item.seq];
        let start = clock.max(item.ready);
        let mut dur = 0.0;
        if !item.poisoned {
            let ctx = TaskCtx {
                fleet: &chain.fleet,
                spec: &chain.spec,
                task: &task,
                sensor: chain.sensor,
                round: item.round,
            };
            match executor.execute(&ctx, &mut item.payload) {
                Ok(d) => dur = d.max(0.0),
                Err(e) => {
                    let _ = chain.done.send(DoneMsg::Fault(e.to_string()));
                    item.poisoned = true;
                }
            }
        }
        let end = start + dur;
        clock = end;
        if !item.poisoned {
            let _ = acct.send(BusySpan {
                device,
                kind: busy_kind(task.kind, unit),
                dur,
                end,
            });
            let _ = tasks.send(TaskSpan {
                pipeline: chain.spec.id.0,
                seq: item.seq,
                run: item.round,
                device,
                unit,
                kind: task.kind,
                start,
                end,
            });
        }
        if time_scale > 0.0 && dur > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dur * time_scale));
        }
        if item.seq == 0 {
            item.round_start = start;
            let _ = chain
                .feedback
                .send(Feedback::SenseDone { round: item.round, end });
        }
        if item.seq + 1 < chain.tasks.len() {
            item.seq += 1;
            item.ready = end;
            chain.deliver(item);
        } else {
            if !item.poisoned {
                let _ = chain.done.send(DoneMsg::Round(RoundRecord {
                    pipeline: chain.spec.id,
                    run: item.round,
                    start: item.round_start,
                    end,
                }));
            }
            let _ = chain
                .feedback
                .send(Feedback::RoundDone { round: item.round, end });
        }
    }
}

/// The multi-threaded streaming engine (see the module docs). Driven like
/// the DES: `set_plan` / `set_fleet` / `run_until(t)` / `finish()`.
pub struct ServeEngine {
    executor: Arc<dyn ChunkExecutor>,
    cfg: ServeCfg,
    fleet: Arc<Fleet>,
    now: f64,
    workers: BTreeMap<(DeviceId, UnitKind), Worker>,
    /// The live epoch's tickers.
    active: Vec<TickerHandle>,
    /// Retired epochs' tickers, joined (for admitted counts) at finish.
    drained: Vec<TickerHandle>,
    /// Binding epochs bound so far (disambiguates source keys).
    epochs: usize,
    ledger: Arc<Mutex<EpochLedger>>,
    /// `Some` until [`Self::finish`] drops it to close the collector.
    done_tx: Option<mpsc::Sender<DoneMsg>>,
    done_rx: mpsc::Receiver<DoneMsg>,
    /// Busy-span collector (energy integration), same lifecycle.
    acct_tx: Option<mpsc::Sender<BusySpan>>,
    acct_rx: mpsc::Receiver<BusySpan>,
    /// Task-span collector (trace/blame attribution), same lifecycle.
    task_tx: Option<mpsc::Sender<TaskSpan>>,
    task_rx: mpsc::Receiver<TaskSpan>,
    /// Fleet over time: (t, fleet) — index 0 is the starting fleet.
    fleet_history: Vec<(f64, Fleet)>,
    rebinds: Vec<Rebind>,
    record_cap: Option<usize>,
}

impl Drop for ServeEngine {
    /// Dropping an engine without [`Self::finish`] must not strand its
    /// threads: retire every ticker (they close their sources once their
    /// in-flight feedback drains) and let the workers shut down after the
    /// drain.
    fn drop(&mut self) {
        for h in self.active.iter().chain(&self.drained) {
            h.gate.retire();
        }
        for w in self.workers.values() {
            w.merger.shutdown();
        }
    }
}

impl ServeEngine {
    pub fn new(executor: Arc<dyn ChunkExecutor>, cfg: ServeCfg, fleet: Fleet) -> ServeEngine {
        let (done_tx, done_rx) = mpsc::channel();
        let (acct_tx, acct_rx) = mpsc::channel();
        let (task_tx, task_rx) = mpsc::channel();
        ServeEngine {
            executor,
            cfg,
            fleet: Arc::new(fleet.clone()),
            now: 0.0,
            workers: BTreeMap::new(),
            active: Vec::new(),
            drained: Vec::new(),
            epochs: 0,
            ledger: Arc::new(Mutex::new(EpochLedger::new())),
            done_tx: Some(done_tx),
            done_rx,
            acct_tx: Some(acct_tx),
            acct_rx,
            task_tx: Some(task_tx),
            task_rx,
            fleet_history: vec![(0.0, fleet)],
            rebinds: Vec::new(),
            record_cap: None,
        }
    }

    /// The engine time reached by [`Self::run_until`].
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The plan-rebind timeline so far.
    pub fn rebinds(&self) -> &[Rebind] {
        &self.rebinds
    }

    /// Measured wall pause of the most recent rebind (0 before any).
    pub fn last_rebind_wall_s(&self) -> f64 {
        self.rebinds.last().map_or(0.0, |r| r.wall_s)
    }

    /// Cap the records retained by [`Self::finish`] to the most recent
    /// `cap` (long-session memory bound; admitted/completed totals keep
    /// counting everything).
    pub fn set_record_cap(&mut self, cap: Option<usize>) {
        self.record_cap = cap;
    }

    /// Replace the fleet new epochs bind against. Workers of departed
    /// devices stay up (in-flight work drains through them); workers for
    /// new devices spawn at the next [`Self::set_plan`]. The change is
    /// recorded in the fleet history for energy replay.
    pub fn set_fleet(&mut self, fleet: Fleet) {
        self.fleet = Arc::new(fleet.clone());
        self.fleet_history.push((self.now, fleet));
    }

    fn worker_merger(&mut self, device: DeviceId, unit: UnitKind) -> Result<Arc<Merger>, RuntimeError> {
        if let Some(w) = self.workers.get(&(device, unit)) {
            return Ok(w.merger.clone());
        }
        let backend = self.executor.name();
        let merger = Arc::new(Merger::new(
            Duration::from_secs_f64(self.cfg.liveness_valve_s.max(0.0)),
            self.cfg.same_time,
        ));
        let executor = self.executor.clone();
        let scale = self.cfg.time_scale;
        let acct = self
            .acct_tx
            .as_ref()
            .ok_or(RuntimeError::Backend {
                backend,
                message: "serving engine already finished".into(),
            })?
            .clone();
        let tasks = self
            .task_tx
            .as_ref()
            .ok_or(RuntimeError::Backend {
                backend,
                message: "serving engine already finished".into(),
            })?
            .clone();
        let m = merger.clone();
        let join = std::thread::Builder::new()
            .name(format!("serve-{device}-{unit:?}"))
            .spawn(move || worker_loop(m, device, unit, executor, scale, acct, tasks))
            .map_err(|e| RuntimeError::Backend {
                backend,
                message: format!("failed to spawn serve worker: {e}"),
            })?;
        self.workers.insert((device, unit), Worker { merger: merger.clone(), join });
        Ok(merger)
    }

    fn retire_active(&mut self) {
        for h in &self.active {
            h.gate.retire();
        }
        self.drained.append(&mut self.active);
    }

    /// Retire the current epoch: tickers stop admitting rounds; everything
    /// already admitted drains gracefully through the workers.
    pub fn clear_plan(&mut self) {
        if self.active.is_empty() {
            return;
        }
        let t0 = Instant::now();
        self.retire_active();
        self.rebinds.push(Rebind {
            t: self.now,
            wall_s: t0.elapsed().as_secs_f64(),
            apps: 0,
        });
    }

    /// Bind a deployment as a new epoch at the current engine time,
    /// retiring any current one — worker threads are reused, only the
    /// chain bindings and tickers change. With `max_rounds = Some(m)` each
    /// pipeline executes exactly `m` rounds (one-shot mode); with `None`
    /// admission is bounded by [`Self::run_until`] horizons.
    ///
    /// Fails with [`RuntimeError::Analysis`] when the plan references a
    /// pipeline absent from `pipelines`, and [`RuntimeError::Backend`] on
    /// thread-spawn failure. The current epoch is retired either way (the
    /// engine never half-deploys): chains bound before the failure drain
    /// gracefully like any retired epoch.
    pub fn set_plan(
        &mut self,
        plan: &CollabPlan,
        pipelines: &[PipelineSpec],
        max_rounds: Option<usize>,
    ) -> Result<(), RuntimeError> {
        let t0 = Instant::now();
        self.retire_active();
        let epoch = self.epochs;
        self.epochs += 1;
        let backend = self.executor.name();
        let mut apps = 0usize;
        for ep in &plan.plans {
            let spec = pipelines
                .iter()
                .find(|p| p.id == ep.pipeline)
                .cloned()
                .ok_or(AnalysisError::UnknownPipeline { pipeline: ep.pipeline })?;
            let tasks = ep.tasks(&spec.model);
            let base_round = lock_recover(&self.ledger).base_round(spec.id);
            let mut stages: Vec<Stage> = Vec::with_capacity(tasks.len());
            for (j, t) in tasks.iter().enumerate() {
                let unit = GroundTruth::unit_of(&self.fleet, t);
                let merger = self.worker_merger(t.device, unit)?;
                let key: SourceKey = (spec.id.0, j, epoch);
                merger.register(key, base_round, self.now);
                stages.push((merger, key));
            }
            let sensor = LatencyModel::source_sensor(&spec);
            let ticker_name = format!("serve-ticker-{}", spec.id);
            let (feedback_tx, feedback_rx) = mpsc::channel();
            let done = self
                .done_tx
                .as_ref()
                .ok_or(RuntimeError::Backend {
                    backend,
                    message: "serving engine already finished".into(),
                })?
                .clone();
            let chain = Arc::new(ChainBinding {
                spec,
                tasks,
                stages,
                feedback: feedback_tx,
                done,
                fleet: self.fleet.clone(),
                sensor,
            });
            let gate = Arc::new(Gate::new(self.now));
            let task = TickerTask {
                chain,
                feedback: feedback_rx,
                gate: gate.clone(),
                start_t: self.now,
                base_round,
                max_inflight: self.cfg.max_inflight.max(1),
                max_rounds,
                ledger: self.ledger.clone(),
            };
            let join = std::thread::Builder::new()
                .name(ticker_name)
                .spawn(move || ticker_loop(task))
                .map_err(|e| RuntimeError::Backend {
                    backend,
                    message: format!("failed to spawn serve ticker: {e}"),
                })?;
            self.active.push(TickerHandle { gate, join });
            apps += 1;
        }
        self.rebinds.push(Rebind {
            t: self.now,
            wall_s: t0.elapsed().as_secs_f64(),
            apps,
        });
        Ok(())
    }

    /// Raise the admission horizon to `t` and wait until every live ticker
    /// has admitted all rounds that become ready before it. In-flight
    /// rounds keep draining asynchronously — completion records are
    /// collected at [`Self::finish`].
    ///
    /// `f64::INFINITY` is only meaningful for bounded epochs
    /// (`max_rounds = Some(..)`): it waits for every ticker to exhaust its
    /// round budget.
    pub fn run_until(&mut self, horizon: f64) {
        for h in &self.active {
            h.gate.set_horizon(horizon);
        }
        for h in &self.active {
            h.gate.wait_idle(horizon);
        }
        if horizon.is_finite() && horizon > self.now {
            self.now = horizon;
        }
    }

    /// Shut down: retire the live epoch, drain every in-flight round, join
    /// all threads, and return the collected records plus the conservation
    /// totals, busy spans, and fleet history.
    pub fn finish(mut self) -> Result<ServeOutcome, RuntimeError> {
        let backend = self.executor.name();
        self.retire_active();
        let mut admitted = 0usize;
        for h in self.drained.drain(..) {
            admitted += h.join.join().map_err(|_| RuntimeError::Backend {
                backend,
                message: "serving ticker thread panicked".into(),
            })?;
        }
        // Every ticker has exited and closed its sources; the workers
        // drain what is left and exit once told to shut down. Dropping
        // our collector senders closes the channels after the last
        // in-flight clone goes with its chain.
        self.done_tx.take();
        self.acct_tx.take();
        self.task_tx.take();
        let workers = std::mem::take(&mut self.workers);
        let worker_count = workers.len();
        let mut joins = Vec::with_capacity(worker_count);
        for (_, w) in workers {
            w.merger.shutdown();
            joins.push(w.join);
        }
        let mut records: Vec<RoundRecord> = Vec::new();
        let mut completed = 0usize;
        let mut fault: Option<String> = None;
        while let Ok(msg) = self.done_rx.recv() {
            match msg {
                DoneMsg::Round(r) => {
                    completed += 1;
                    records.push(r);
                }
                DoneMsg::Fault(m) => fault = Some(m),
            }
        }
        for j in joins {
            j.join().map_err(|_| RuntimeError::Backend {
                backend,
                message: "serving worker thread panicked".into(),
            })?;
        }
        if let Some(message) = fault {
            return Err(RuntimeError::Backend { backend, message });
        }
        records.sort_by(|a, b| {
            a.end
                .total_cmp(&b.end)
                .then_with(|| a.pipeline.cmp(&b.pipeline))
                .then_with(|| a.run.cmp(&b.run))
        });
        if let Some(cap) = self.record_cap {
            if records.len() > cap {
                let overflow = records.len() - cap;
                records.drain(..overflow);
            }
        }
        let mut busy: Vec<BusySpan> = self.acct_rx.try_iter().collect();
        busy.sort_by(|a, b| {
            a.end
                .total_cmp(&b.end)
                .then_with(|| a.device.cmp(&b.device))
                .then_with(|| a.kind.cmp(&b.kind))
                .then_with(|| a.dur.total_cmp(&b.dur))
        });
        let mut tasks: Vec<TaskSpan> = self.task_rx.try_iter().collect();
        // (pipeline, run, seq) names each task instance exactly once, so
        // the order is canonical regardless of channel arrival order.
        tasks.sort_by_key(|s| (s.pipeline, s.run, s.seq));
        Ok(ServeOutcome {
            executor: backend,
            records,
            admitted,
            completed,
            rebinds: self.rebinds.clone(),
            workers: worker_count,
            busy,
            tasks,
            fleet_history: self.fleet_history.clone(),
        })
    }

    /// Rebinds performed so far (the rebind timeline's length).
    pub fn rebind_count(&self) -> usize {
        self.rebinds.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{PipelineId, SourceReq, TargetReq};
    use crate::plan::exec_plan::ExecutionPlan;
    use crate::serving::VirtualExecutor;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn model(layers: usize) -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(16, 16, 3),
            (0..layers)
                .map(|_| Layer {
                    kind: LayerKind::Conv2d { k: 3 },
                    pool: 1,
                    cout: 8,
                    residual: false,
                    has_bias: true,
                })
                .collect(),
        )
    }

    fn pipes(n: usize) -> Vec<PipelineSpec> {
        (0..n)
            .map(|i| {
                PipelineSpec::new(i, format!("p{i}"), SourceReq::Any, model(2), TargetReq::Any)
            })
            .collect()
    }

    fn plan_spread(ps: &[PipelineSpec], ndev: usize) -> CollabPlan {
        CollabPlan::new(
            ps.iter()
                .enumerate()
                .map(|(i, p)| {
                    let d = DeviceId(i % ndev);
                    ExecutionPlan::monolithic(p, d, d, d)
                })
                .collect(),
        )
    }

    fn engine(n: usize) -> ServeEngine {
        ServeEngine::new(
            Arc::new(VirtualExecutor::with_seed(7)),
            ServeCfg::default(),
            fleet(n),
        )
    }

    #[test]
    fn bounded_run_completes_every_admitted_round() {
        let ps = pipes(3);
        let plan = plan_spread(&ps, 2);
        let mut eng = engine(2);
        eng.set_plan(&plan, &ps, Some(12)).unwrap();
        eng.run_until(f64::INFINITY);
        let out = eng.finish().unwrap();
        assert_eq!(out.admitted, 3 * 12);
        assert_eq!(out.records.len(), 3 * 12);
        // Per pipeline: rounds 0..12, each exactly once, causally ordered.
        for p in 0..3 {
            let mut runs: Vec<usize> = out
                .records
                .iter()
                .filter(|r| r.pipeline == PipelineId(p))
                .map(|r| r.run)
                .collect();
            runs.sort_unstable();
            assert_eq!(runs, (0..12).collect::<Vec<_>>());
        }
        assert!(out.records.iter().all(|r| r.end > r.start && r.start >= 0.0));
        assert_eq!(out.rebinds.len(), 1);
        assert!(out.workers > 0);
        // Energy accounting: one busy span per executed task, all within
        // the virtual timeline.
        assert!(!out.busy.is_empty());
        assert!(out.busy.iter().all(|s| s.dur >= 0.0 && s.end > 0.0));
        // Task trace: one span per executed task, causally ordered within
        // each (pipeline, run) chain.
        assert_eq!(out.tasks.len(), out.busy.len());
        assert!(out.tasks.iter().all(|s| s.end >= s.start && s.start >= 0.0));
        let trace = crate::scheduler::Trace { spans: out.tasks.clone() };
        trace.check_causality().unwrap();
        trace.check_unit_exclusivity().unwrap();
        assert_eq!(out.fleet_history.len(), 1);
    }

    #[test]
    fn horizon_gates_round_admission() {
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let mut eng = engine(1);
        eng.set_plan(&plan, &ps, None).unwrap();
        eng.run_until(0.5);
        let short = eng.finish().unwrap();

        let mut eng = engine(1);
        eng.set_plan(&plan_spread(&pipes(1), 1), &pipes(1), None).unwrap();
        eng.run_until(2.0);
        let long = eng.finish().unwrap();

        assert!(short.admitted > 0, "{short:?}");
        assert!(
            long.admitted > 2 * short.admitted,
            "longer horizon must admit more rounds: {} vs {}",
            short.admitted,
            long.admitted
        );
        // Every admitted round completed (conservation).
        assert_eq!(short.admitted, short.records.len());
        assert_eq!(long.admitted, long.records.len());
    }

    #[test]
    fn plan_switch_rebinds_without_dropping_rounds() {
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let mut eng = engine(2);
        eng.set_plan(&plan, &ps, None).unwrap();
        eng.run_until(0.5);
        // Switch to a solo plan mid-stream; the old epoch drains.
        let solo = CollabPlan::new(vec![plan.plans[0].clone()]);
        eng.set_plan(&solo, &ps[..1], None).unwrap();
        eng.run_until(1.0);
        let out = eng.finish().unwrap();
        assert_eq!(out.rebinds.len(), 2);
        assert_eq!(
            out.admitted,
            out.records.len(),
            "a switch must not drop in-flight rounds: {out:?}"
        );
        // Pipeline 0 spans both epochs with strictly unique global rounds.
        let mut p0: Vec<usize> = out
            .records
            .iter()
            .filter(|r| r.pipeline == PipelineId(0))
            .map(|r| r.run)
            .collect();
        let n = p0.len();
        p0.sort_unstable();
        p0.dedup();
        assert_eq!(p0.len(), n, "global round indices must not repeat");
        // Pipeline 1 stops producing once its epoch retires and drains.
        let p1_last = out
            .records
            .iter()
            .filter(|r| r.pipeline == PipelineId(1))
            .map(|r| r.start)
            .fold(0.0, f64::max);
        assert!(p1_last < 1.0, "retired pipeline kept starting rounds");
    }

    #[test]
    fn virtual_time_is_deterministic_across_runs() {
        let run = || {
            let ps = pipes(2);
            let plan = plan_spread(&ps, 2);
            let mut eng = engine(2);
            eng.set_plan(&plan, &ps, Some(8)).unwrap();
            eng.run_until(f64::INFINITY);
            eng.finish().unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.pipeline, y.pipeline);
            assert_eq!(x.run, y.run);
            assert_eq!(x.start, y.start);
            assert_eq!(x.end, y.end);
        }
    }

    /// The deterministic-merge acceptance: two pipelines sharing every
    /// computation unit of one device replay *bit-identically* — records
    /// and busy spans — across repeated runs, despite OS scheduling.
    #[test]
    fn shared_unit_replays_are_bit_identical() {
        let run = || {
            let ps = pipes(2);
            // Both pipelines entirely on device 0: sensor, cpu, accel all
            // shared — the maximal merge-contention shape.
            let plan = plan_spread(&ps, 1);
            let mut eng = engine(1);
            eng.set_plan(&plan, &ps, Some(10)).unwrap();
            eng.run_until(f64::INFINITY);
            eng.finish().unwrap()
        };
        let a = run();
        for _ in 0..3 {
            let b = run();
            assert_eq!(a.records.len(), b.records.len());
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!((x.pipeline, x.run), (y.pipeline, y.run));
                assert_eq!(x.start.to_bits(), y.start.to_bits(), "{x:?} vs {y:?}");
                assert_eq!(x.end.to_bits(), y.end.to_bits(), "{x:?} vs {y:?}");
            }
            assert_eq!(a.busy.len(), b.busy.len());
            for (x, y) in a.busy.iter().zip(&b.busy) {
                assert_eq!(x.device, y.device);
                assert_eq!(x.kind, y.kind);
                assert_eq!(x.dur.to_bits(), y.dur.to_bits());
                assert_eq!(x.end.to_bits(), y.end.to_bits());
            }
            assert_eq!(a.tasks.len(), b.tasks.len());
            for (x, y) in a.tasks.iter().zip(&b.tasks) {
                assert_eq!((x.pipeline, x.run, x.seq), (y.pipeline, y.run, y.seq));
                assert_eq!((x.device, x.unit), (y.device, y.unit));
                assert_eq!(x.start.to_bits(), y.start.to_bits(), "{x:?} vs {y:?}");
                assert_eq!(x.end.to_bits(), y.end.to_bits(), "{x:?} vs {y:?}");
            }
        }
    }

    #[test]
    fn record_cap_bounds_retained_records() {
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let mut eng = engine(1);
        eng.set_record_cap(Some(5));
        eng.set_plan(&plan, &ps, Some(20)).unwrap();
        eng.run_until(f64::INFINITY);
        let out = eng.finish().unwrap();
        assert_eq!(out.admitted, 20);
        assert_eq!(out.completed, 20, "the window must not eat the totals");
        assert_eq!(out.records.len(), 5, "ring window must cap records");
        // The retained records are the most recent ones.
        assert!(out.records.iter().all(|r| r.run >= 15));
    }

    /// Busy spans replayed through the power accountant integrate the
    /// same energy the DES would charge for the same busy time.
    #[test]
    fn busy_spans_integrate_into_energy() {
        use crate::power::EnergyReplay;
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let mut eng = engine(1);
        eng.set_plan(&plan, &ps, Some(6)).unwrap();
        eng.run_until(f64::INFINITY);
        let out = eng.finish().unwrap();
        let horizon = out.records.iter().map(|r| r.end).fold(0.0, f64::max);
        let mut replay = EnergyReplay::new(out.fleet_history[0].1.clone());
        for s in &out.busy {
            replay.record(s);
        }
        let base = fleet(1).get(DeviceId(0)).spec.power.base_w;
        let e = replay.energy_at(horizon);
        assert!(e > base * horizon, "active work must show above base: {e}");
    }

    #[test]
    fn set_plan_for_unknown_pipeline_is_a_typed_error() {
        // Regression: this used to panic via `expect` on the serve path.
        let ps = pipes(2);
        let plan = plan_spread(&ps, 1);
        let mut eng = engine(1);
        let err = eng.set_plan(&plan, &ps[..1], None).unwrap_err();
        assert!(matches!(
            err,
            RuntimeError::Analysis(AnalysisError::UnknownPipeline { pipeline: PipelineId(1) })
        ));
        // The engine stays usable: bind a valid plan afterwards.
        eng.set_plan(&plan_spread(&ps[..1], 1), &ps[..1], Some(3)).unwrap();
        eng.run_until(f64::INFINITY);
        let out = eng.finish().unwrap();
        assert_eq!(out.admitted, out.completed);
    }

    #[test]
    fn configured_liveness_valve_replaces_the_hardcoded_default() {
        // A tiny valve must not break conservation — only (possibly)
        // degrade merge order. This pins the ServeCfg knob end to end.
        let ps = pipes(2);
        let plan = plan_spread(&ps, 1);
        let mut eng = ServeEngine::new(
            Arc::new(VirtualExecutor::with_seed(7)),
            ServeCfg { liveness_valve_s: 0.05, ..ServeCfg::default() },
            fleet(1),
        );
        eng.set_plan(&plan, &ps, Some(8)).unwrap();
        eng.run_until(f64::INFINITY);
        let out = eng.finish().unwrap();
        assert_eq!(out.admitted, 2 * 8);
        assert_eq!(out.completed, 2 * 8);
    }

    #[test]
    fn randomized_same_time_keeps_conservation_and_per_seed_determinism() {
        let run = |seed: u64| {
            let ps = pipes(2);
            let plan = plan_spread(&ps, 1);
            let mut eng = ServeEngine::new(
                Arc::new(VirtualExecutor::with_seed(7)),
                ServeCfg {
                    same_time: SameTimePolicy::Randomized { seed },
                    ..ServeCfg::default()
                },
                fleet(1),
            );
            eng.set_plan(&plan, &ps, Some(10)).unwrap();
            eng.run_until(f64::INFINITY);
            eng.finish().unwrap()
        };
        for seed in 0..4u64 {
            let a = run(seed);
            assert_eq!(a.admitted, 2 * 10, "seed {seed}");
            assert_eq!(a.completed, 2 * 10, "seed {seed}");
            let b = run(seed);
            assert_eq!(a.records.len(), b.records.len());
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!((x.pipeline, x.run), (y.pipeline, y.run), "seed {seed}");
                assert_eq!(x.start.to_bits(), y.start.to_bits(), "seed {seed}");
                assert_eq!(x.end.to_bits(), y.end.to_bits(), "seed {seed}");
            }
        }
    }
}
