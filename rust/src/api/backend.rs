//! Execution backends: one `run()` entry point for simulated and real
//! inference.
//!
//! The paper's runtime executes a deployment either on the cycle-accurate
//! device-model simulator (on-body timing claims) or for real through PJRT
//! (numerics). The seed exposed those as two unrelated call paths
//! (`scheduler::simulate` vs `coordinator::serve` with hand-carried
//! state); `ExecutionBackend` unifies them behind
//! [`crate::api::SynergyRuntime::run`]. A third implementation,
//! [`crate::serving::ServeBackend`], streams the deployment on real
//! worker threads (virtual-time or PJRT chunk execution).

use crate::device::Fleet;
use crate::pipeline::{PipelineId, PipelineSpec};
#[cfg(feature = "pjrt")]
use crate::runtime::Manifest;

use super::core::Deployment;
use super::error::RuntimeError;

/// Parameters for one `run()` call, backend-independent.
#[derive(Clone, Copy, Debug)]
pub struct RunConfig {
    /// Continuous-inference runs per app.
    pub runs: usize,
    /// In-flight runs per app (2 = double-buffered inter-run overlap);
    /// PJRT serving only.
    pub max_inflight: usize,
    /// Verify split outputs against whole-model execution; PJRT only.
    pub verify: bool,
    /// Seed for synthetic sensor frames / ground-truth jitter.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            runs: 24,
            max_inflight: 2,
            verify: true,
            seed: 42,
        }
    }
}

/// Per-app results of one run (populated by backends that measure
/// per-pipeline, i.e. PJRT serving).
#[derive(Clone, Debug)]
pub struct AppRunStats {
    pub app: PipelineId,
    pub name: String,
    pub completions: usize,
    pub mean_latency_s: f64,
    /// Max |split − full| output deviation (verification), PJRT only.
    pub max_split_err: Option<f64>,
}

/// Backend-independent run results.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Which backend produced this report.
    pub backend: &'static str,
    /// Completed app runs across all apps.
    pub completions: usize,
    /// Inferences per second (simulated clock or wall clock, per backend).
    pub throughput: f64,
    /// Mean end-to-end latency, seconds.
    pub avg_latency_s: f64,
    /// Mean power draw, watts (simulator and virtual-time serving — both
    /// integrate the modeled device power rails; `None` on PJRT, where a
    /// server CPU cannot impersonate a MAX78000).
    pub power_w: Option<f64>,
    /// Total energy, joules (same availability as [`Self::power_w`]).
    pub energy_j: Option<f64>,
    /// Real elapsed wall-clock seconds (PJRT only).
    pub wall_s: Option<f64>,
    /// Whether split execution matched whole-model execution (PJRT with
    /// `verify` only).
    pub verified: Option<bool>,
    /// Per-app breakdown (PJRT only; empty for the simulator).
    pub per_app: Vec<AppRunStats>,
}

/// Executes a deployment: the simulator or the real PJRT serving loop.
pub trait ExecutionBackend {
    fn name(&self) -> &'static str;

    fn run(
        &self,
        deployment: &Deployment,
        apps: &[PipelineSpec],
        fleet: &Fleet,
        cfg: &RunConfig,
    ) -> Result<RunReport, RuntimeError>;
}

/// Simulator configuration shared by [`SimBackend`] and
/// [`crate::api::RuntimeCore::simulate`]: warmup covers pipeline fill,
/// capped so short runs still measure something.
pub(crate) fn sim_config(runs: usize, policy: crate::scheduler::Policy) -> crate::scheduler::SimConfig {
    crate::scheduler::SimConfig {
        runs,
        warmup: (runs / 6).min(4),
        policy,
        record_trace: false,
    }
}

/// Cycle-accurate device-model simulation (§IV-F DES over the ground-truth
/// hardware model) — the default backend; needs no artifacts.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimBackend;

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &self,
        deployment: &Deployment,
        apps: &[PipelineSpec],
        fleet: &Fleet,
        cfg: &RunConfig,
    ) -> Result<RunReport, RuntimeError> {
        use crate::scheduler::{simulate, GroundTruth};
        let gt = GroundTruth::with_seed(cfg.seed);
        let rep = simulate(
            &deployment.plan,
            apps,
            fleet,
            &gt,
            sim_config(cfg.runs, deployment.policy),
        );
        Ok(RunReport {
            backend: self.name(),
            completions: rep.completions,
            throughput: rep.throughput,
            avg_latency_s: rep.avg_latency,
            power_w: Some(rep.power_w),
            energy_j: Some(rep.energy_j),
            wall_s: None,
            verified: None,
            per_app: Vec::new(),
        })
    }
}

/// Real inference through the PJRT serving loop (per-device worker
/// threads, mpsc radio links, AOT-compiled HLO chunks). Requires
/// `make artifacts` and the `pjrt` cargo feature.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    manifest: Manifest,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(manifest: Manifest) -> PjrtBackend {
        PjrtBackend { manifest }
    }

    /// Load the artifact manifest from a directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<PjrtBackend, RuntimeError> {
        let manifest = Manifest::load(dir).map_err(|e| RuntimeError::Backend {
            backend: "pjrt",
            message: format!("{e:#}"),
        })?;
        Ok(PjrtBackend { manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

#[cfg(feature = "pjrt")]
impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(
        &self,
        deployment: &Deployment,
        apps: &[PipelineSpec],
        fleet: &Fleet,
        cfg: &RunConfig,
    ) -> Result<RunReport, RuntimeError> {
        use crate::serving::pjrt::{serve, ServeConfig};
        let rep = serve(
            deployment,
            apps,
            fleet,
            &self.manifest,
            ServeConfig {
                runs: cfg.runs,
                max_inflight: cfg.max_inflight,
                verify: cfg.verify,
                seed: cfg.seed,
            },
        )
        .map_err(|e| RuntimeError::Backend {
            backend: "pjrt",
            message: format!("{e:#}"),
        })?;
        let per_app: Vec<AppRunStats> = rep
            .per_pipeline
            .iter()
            .zip(apps)
            .map(|(p, spec)| AppRunStats {
                app: spec.id,
                name: p.name.clone(),
                completions: p.completions,
                mean_latency_s: p.mean_latency_s,
                max_split_err: cfg.verify.then_some(p.max_split_err),
            })
            .collect();
        let total: usize = per_app.iter().map(|p| p.completions).sum();
        let avg_latency_s = if total > 0 {
            per_app
                .iter()
                .map(|p| p.mean_latency_s * p.completions as f64)
                .sum::<f64>()
                / total as f64
        } else {
            0.0
        };
        Ok(RunReport {
            backend: self.name(),
            completions: rep.completions,
            throughput: rep.throughput,
            avg_latency_s,
            power_w: None,
            energy_j: None,
            wall_s: Some(rep.wall_s),
            verified: cfg.verify.then_some(rep.verified),
            per_app,
        })
    }
}
