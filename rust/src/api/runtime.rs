//! The [`SynergyRuntime`] facade: one object owning fleet, planner, and
//! execution backend.
//!
//! Apps register through the fluent [`super::AppBuilder`]; device churn
//! goes through [`SynergyRuntime::device_joined`] /
//! [`SynergyRuntime::device_left`] / [`SynergyRuntime::set_fleet`];
//! [`SynergyRuntime::run`] executes the current deployment on whichever
//! [`ExecutionBackend`] the runtime was built with (simulator by default,
//! PJRT for real inference). Everything observable is also pushed on the
//! event channel ([`SynergyRuntime::subscribe`]).

use std::sync::{Arc, Mutex};

use crate::device::{Device, DeviceId, Fleet};
use crate::orchestrator::{Planner, Synergy};
use crate::pipeline::{PipelineSpec, SourceReq, TargetReq};
use crate::scheduler::SimReport;

use super::app::{AppBuilder, AppHandle};
use super::backend::{ExecutionBackend, RunConfig, RunReport, SimBackend};
use super::core::{Deployment, RuntimeCore};
use super::error::RuntimeError;
use super::events::EventSubscription;
use super::qos::Qos;
use super::replan::ReplanStats;
use super::scenario::Scenario;
use super::session::{Session, SessionCfg};
use super::shared_cache::GlobalPlanCache;

/// Core + planner behind one lock, shared with [`AppHandle`]s.
pub(crate) struct Shared {
    pub(crate) core: RuntimeCore,
    pub(crate) planner: Box<dyn Planner + Send>,
}

/// Non-poisoning lock over the shared core: in a population run one
/// panicking user session must not wedge its runtime's own teardown.
pub(crate) fn lock_shared(shared: &Mutex<Shared>) -> std::sync::MutexGuard<'_, Shared> {
    match shared.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// The one registration path (fluent builder and spec-based registration
/// both land here): lock, build the spec with the core visible (auto-id
/// assignment needs it), register, hand back a handle.
pub(crate) fn register_locked(
    shared: &Arc<Mutex<Shared>>,
    qos: Qos,
    make_spec: impl FnOnce(&RuntimeCore) -> PipelineSpec,
) -> Result<AppHandle, RuntimeError> {
    let mut guard = shared.lock().unwrap();
    let Shared { core, planner } = &mut *guard;
    let spec = make_spec(core);
    let id = spec.id;
    let name = spec.name.clone();
    core.register(spec, qos, planner.as_ref())?;
    drop(guard);
    Ok(AppHandle {
        shared: shared.clone(),
        id,
        name,
    })
}

/// Aggregate runtime counters (see [`SynergyRuntime::stats`]).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeStats {
    /// Holistic orchestrations performed so far.
    pub orchestrations: usize,
    /// Apps served from the plan-enumeration cache, cumulative.
    pub cache_hits: usize,
    /// Apps whose plan space was enumerated, cumulative.
    pub enumerations: usize,
    /// Enumeration bookkeeping of the most recent replan.
    pub last_replan: Option<ReplanStats>,
    /// Apps currently in the active plan.
    pub active_apps: usize,
    /// Devices currently on the body.
    pub devices: usize,
}

/// The on-body runtime: fleet + planner + execution backend behind the
/// device-agnostic app interface.
pub struct SynergyRuntime {
    shared: Arc<Mutex<Shared>>,
    backend: Box<dyn ExecutionBackend>,
}

impl SynergyRuntime {
    /// A runtime with Synergy's default planner and the simulator backend.
    pub fn new(fleet: Fleet) -> SynergyRuntime {
        SynergyRuntime::builder().fleet(fleet).build()
    }

    pub fn builder() -> RuntimeBuilder {
        RuntimeBuilder::default()
    }

    /// Start registering an app (fluent; finish with `.register()`).
    pub fn app(&self, name: impl Into<String>) -> AppBuilder {
        AppBuilder {
            shared: self.shared.clone(),
            name: name.into(),
            id: None,
            source: SourceReq::Any,
            model: None,
            target: TargetReq::Any,
            qos: Qos::default(),
        }
    }

    /// Register a pre-built pipeline spec (workload definitions, tests).
    pub fn register(&self, spec: PipelineSpec) -> Result<AppHandle, RuntimeError> {
        self.register_with_qos(spec, Qos::default())
    }

    /// Register a pre-built pipeline spec with QoS hints.
    pub fn register_with_qos(
        &self,
        spec: PipelineSpec,
        qos: Qos,
    ) -> Result<AppHandle, RuntimeError> {
        register_locked(&self.shared, qos, move |_| spec)
    }

    /// Subscribe to runtime events (device churn, replans, degradations).
    /// Events arrive stamped with a sequence number — and, inside a live
    /// [`Session`], the simulated time of the scenario event that caused
    /// them.
    pub fn subscribe(&self) -> EventSubscription {
        self.shared.lock().unwrap().core.subscribe()
    }

    /// Open a live session driving the discrete-event timeline through a
    /// [`Scenario`] of timed churn events (see [`Session`]). The session
    /// executes on the device-model simulator; the runtime's registered
    /// apps and fleet are its starting state, and scenario events mutate
    /// the same underlying core (handles observe the churn).
    pub fn session(&self, scenario: Scenario) -> Result<Session, RuntimeError> {
        Session::start(self.shared.clone(), scenario, SessionCfg::default())
    }

    /// Like [`Self::session`], with explicit session configuration
    /// (seed, trace recording, trace window).
    pub fn session_with(
        &self,
        scenario: Scenario,
        cfg: SessionCfg,
    ) -> Result<Session, RuntimeError> {
        Session::start(self.shared.clone(), scenario, cfg)
    }

    /// The current on-body fleet.
    pub fn fleet(&self) -> Fleet {
        self.shared.lock().unwrap().core.fleet().clone()
    }

    /// Specs covered by the current deployment (paused apps excluded).
    pub fn apps(&self) -> Vec<PipelineSpec> {
        self.shared.lock().unwrap().core.active_apps().to_vec()
    }

    /// The current deployment, if any app is active.
    pub fn deployment(&self) -> Option<Deployment> {
        self.shared.lock().unwrap().core.deployment().cloned()
    }

    /// Aggregate counters: orchestrations, cache effectiveness, sizes.
    pub fn stats(&self) -> RuntimeStats {
        let guard = self.shared.lock().unwrap();
        let (cache_hits, enumerations) = guard.core.cache_counters();
        RuntimeStats {
            orchestrations: guard.core.orchestrations(),
            cache_hits,
            enumerations,
            last_replan: guard.core.last_replan(),
            active_apps: guard.core.active_apps().len(),
            devices: guard.core.fleet().len(),
        }
    }

    /// A device joined the body. Its id must extend the fleet densely
    /// (`id == fleet.len()`).
    pub fn device_joined(&self, device: Device) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.device_joined(device, planner.as_ref())
    }

    /// A device left the body. Device ids are dense, so only the
    /// highest-id device can depart without renumbering; replan over an
    /// arbitrarily reshaped fleet via [`Self::set_fleet`]. Departure of a
    /// suffix device keeps the plan-enumeration cache warm — the replan is
    /// incremental.
    pub fn device_left(&self, id: DeviceId) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.device_left(id, planner.as_ref())
    }

    /// Replace the whole fleet (arbitrary churn); triggers one replan.
    pub fn set_fleet(&self, fleet: Fleet) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.set_fleet(fleet, planner.as_ref())
    }

    /// Execute the current deployment on the configured backend — the
    /// single entry point for simulated and real inference.
    ///
    /// On the simulator backend this is the one-shot wrapper over the
    /// same resumable DES a [`Session`] drives: one plan, one bounded
    /// epoch, no timeline events. Scenarios with mid-run churn go through
    /// [`Self::session`].
    pub fn run(&self, cfg: &RunConfig) -> Result<RunReport, RuntimeError> {
        // Snapshot under the lock, execute outside it (PJRT runs can take
        // a while; handles stay usable meanwhile).
        let (deployment, apps, fleet) = {
            let guard = self.shared.lock().unwrap();
            let dep = guard
                .core
                .deployment()
                .cloned()
                .ok_or(RuntimeError::NoDeployment)?;
            (
                dep,
                guard.core.active_apps().to_vec(),
                guard.core.fleet().clone(),
            )
        };
        self.backend.run(&deployment, &apps, &fleet, cfg)
    }

    /// Execute the current deployment on the device-model simulator,
    /// regardless of the configured backend (on-body timing estimates
    /// alongside a PJRT numerics run).
    pub fn simulate(&self, runs: usize, seed: u64) -> Option<SimReport> {
        self.shared.lock().unwrap().core.simulate(runs, seed)
    }
}

/// Configures and builds a [`SynergyRuntime`].
pub struct RuntimeBuilder {
    fleet: Fleet,
    planner: Box<dyn Planner + Send>,
    backend: Box<dyn ExecutionBackend>,
    shared_cache: Option<Arc<GlobalPlanCache>>,
}

impl Default for RuntimeBuilder {
    fn default() -> RuntimeBuilder {
        RuntimeBuilder {
            fleet: Fleet::default(),
            planner: Box::new(Synergy::planner()),
            backend: Box::new(SimBackend),
            shared_cache: None,
        }
    }
}

impl RuntimeBuilder {
    /// The on-body device fleet (defaults to empty; apps cannot plan until
    /// devices join).
    pub fn fleet(mut self, fleet: Fleet) -> RuntimeBuilder {
        self.fleet = fleet;
        self
    }

    /// The plan-selection method (defaults to Synergy's progressive
    /// planner, which replans incrementally; baselines replan fully).
    pub fn planner(mut self, planner: impl Planner + Send + 'static) -> RuntimeBuilder {
        self.planner = Box::new(planner);
        self
    }

    /// Like [`Self::planner`], for already-boxed planners.
    pub fn planner_boxed(mut self, planner: Box<dyn Planner + Send>) -> RuntimeBuilder {
        self.planner = planner;
        self
    }

    /// The execution backend (defaults to the device-model simulator).
    pub fn backend(mut self, backend: impl ExecutionBackend + 'static) -> RuntimeBuilder {
        self.backend = Box::new(backend);
        self
    }

    /// Join a cross-user planning service: progressive orchestrations
    /// consult the shared [`GlobalPlanCache`] before running bounded
    /// search, and feed it on a miss. Hand the same `Arc` to every
    /// runtime that should share plans (see [`crate::population`]).
    pub fn shared_plan_cache(mut self, cache: Arc<GlobalPlanCache>) -> RuntimeBuilder {
        self.shared_cache = Some(cache);
        self
    }

    pub fn build(self) -> SynergyRuntime {
        let mut core = RuntimeCore::new(self.fleet);
        if let Some(cache) = self.shared_cache {
            core.set_shared_cache(cache);
        }
        SynergyRuntime {
            shared: Arc::new(Mutex::new(Shared {
                core,
                planner: self.planner,
            })),
            backend: self.backend,
        }
    }
}
