//! Per-app quality-of-service hints.
//!
//! The device-agnostic interface (§IV-B) gives the system visibility over
//! each app's resource use; QoS hints close the loop in the other
//! direction, letting apps tell the runtime what "good enough" means.
//! Hints influence planning order (higher-priority apps pick placements
//! first, the progressive accumulation's strongest lever) and drive
//! [`crate::api::RuntimeEvent::PlanDegraded`] notifications whenever a
//! replan's estimate falls below an app's floor.

/// Planning priority class. Within the progressive accumulation, apps are
/// grouped by descending priority; the planner's data-intensity ordering
/// applies within each class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AppPriority {
    Low,
    #[default]
    Normal,
    High,
}

/// Quality-of-service hints for one app.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Qos {
    /// Minimum acceptable steady-state inference rate in Hz
    /// (0.0 = no floor).
    pub min_rate_hz: f64,
    /// End-to-end latency budget in milliseconds, sensing start to
    /// interaction end (`f64::INFINITY` = unbounded).
    pub latency_budget_ms: f64,
    /// Planning priority relative to other apps.
    pub priority: AppPriority,
}

impl Default for Qos {
    fn default() -> Qos {
        Qos {
            min_rate_hz: 0.0,
            latency_budget_ms: f64::INFINITY,
            priority: AppPriority::Normal,
        }
    }
}

/// How a deployed plan's estimate falls short of an app's QoS hints.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QosViolation {
    /// Estimated steady-state rate is below the requested floor.
    RateBelowFloor { est_hz: f64, min_hz: f64 },
    /// Estimated end-to-end latency exceeds the budget.
    LatencyOverBudget { est_ms: f64, budget_ms: f64 },
}

impl std::fmt::Display for QosViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QosViolation::RateBelowFloor { est_hz, min_hz } => {
                write!(f, "estimated {est_hz:.2} Hz < requested {min_hz:.2} Hz")
            }
            QosViolation::LatencyOverBudget { est_ms, budget_ms } => {
                write!(f, "estimated {est_ms:.1} ms > budget {budget_ms:.1} ms")
            }
        }
    }
}

impl Qos {
    /// Check an estimated (rate, latency) pair against the hints. Rate
    /// violations outrank latency violations when both hold.
    pub fn check(&self, est_rate_hz: f64, est_latency_s: f64) -> Option<QosViolation> {
        if self.min_rate_hz > 0.0 && est_rate_hz < self.min_rate_hz {
            return Some(QosViolation::RateBelowFloor {
                est_hz: est_rate_hz,
                min_hz: self.min_rate_hz,
            });
        }
        let est_ms = est_latency_s * 1e3;
        if est_ms > self.latency_budget_ms {
            return Some(QosViolation::LatencyOverBudget {
                est_ms,
                budget_ms: self.latency_budget_ms,
            });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_qos_is_never_violated() {
        let q = Qos::default();
        assert_eq!(q.check(1e-9, 1e9), None);
    }

    #[test]
    fn rate_floor_and_latency_budget() {
        let q = Qos { min_rate_hz: 10.0, latency_budget_ms: 50.0, ..Qos::default() };
        assert!(matches!(
            q.check(5.0, 0.01),
            Some(QosViolation::RateBelowFloor { .. })
        ));
        assert!(matches!(
            q.check(20.0, 0.2),
            Some(QosViolation::LatencyOverBudget { .. })
        ));
        assert_eq!(q.check(20.0, 0.01), None);
    }

    #[test]
    fn priority_orders_low_normal_high() {
        assert!(AppPriority::Low < AppPriority::Normal);
        assert!(AppPriority::Normal < AppPriority::High);
    }
}
