//! Typed runtime errors.
//!
//! Every fallible operation on [`crate::api::SynergyRuntime`] (and on the
//! [`crate::coordinator::Moderator`] shim) returns `RuntimeError` — the
//! seed's `assert!`-on-duplicate and silent no-op-on-unknown-app paths are
//! gone. `PlanError` (OOR / unsatisfiable requirements, §IV-D) converts
//! transparently so callers can still match on planning outcomes.

use crate::analysis::AnalysisError;
use crate::orchestrator::PlanError;
use crate::pipeline::PipelineId;

/// Why a runtime operation failed.
#[derive(Clone, Debug, thiserror::Error)]
pub enum RuntimeError {
    /// An app with this pipeline id is already registered.
    #[error("duplicate app id {0}: an app with this pipeline id is already registered")]
    DuplicateApp(PipelineId),

    /// No registered app has this pipeline id.
    #[error("unknown app id {0}: no such app is registered")]
    UnknownApp(PipelineId),

    /// The app specification is incomplete or inconsistent.
    #[error("invalid app {name:?}: {reason}")]
    InvalidApp { name: String, reason: String },

    /// Holistic orchestration failed (OOR or unsatisfiable requirements).
    #[error(transparent)]
    Plan(#[from] PlanError),

    /// The requested fleet change cannot be expressed on this fleet.
    #[error("unsupported fleet change: {0}")]
    FleetChange(String),

    /// No benchmark workload has this id (see `synergy list`).
    #[error("no workload {id}: valid workloads are {valid}")]
    UnknownWorkload { id: usize, valid: String },

    /// No deployment is active (no apps registered, or all paused).
    #[error("no active deployment: register (or resume) at least one app first")]
    NoDeployment,

    /// A scenario script is malformed (non-finite times, non-positive
    /// battery capacity, zero duration).
    #[error("invalid scenario: {0}")]
    InvalidScenario(String),

    /// The execution backend failed.
    #[error("backend {backend}: {message}")]
    Backend {
        backend: &'static str,
        message: String,
    },

    /// Static verification rejected a plan or scenario
    /// ([`crate::analysis::verify_deployment`] /
    /// [`crate::analysis::verify_scenario`]).
    #[error(transparent)]
    Analysis(#[from] AnalysisError),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_errors_convert_transparently() {
        let e: RuntimeError = PlanError::Oor { pipeline: "kws".into() }.into();
        assert!(matches!(e, RuntimeError::Plan(PlanError::Oor { .. })));
        assert!(format!("{e}").contains("OOR"));
    }

    #[test]
    fn display_names_the_offending_app() {
        let e = RuntimeError::DuplicateApp(PipelineId(3));
        assert!(format!("{e}").contains("p3"));
        let e = RuntimeError::UnknownApp(PipelineId(7));
        assert!(format!("{e}").contains("p7"));
    }
}
