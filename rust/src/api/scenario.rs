//! Scenario scripts: timed churn events for a live [`super::Session`].
//!
//! A [`Scenario`] is a declarative timeline of the things Synergy's
//! dynamism story is about — apps arriving and leaving, devices dropping
//! off the body and rejoining, QoS hints tightening mid-run, batteries
//! draining — expressed with a fluent builder:
//!
//! ```text
//! let scenario = Scenario::new()
//!     .at(0.0).register(kws_spec)
//!     .at(2.5).device_left(3)
//!     .at(4.0).register(activity_spec)
//!     .at(6.0).qos(PipelineId(0), Qos { min_rate_hz: 10.0, ..Qos::default() })
//!     .battery(DeviceId(2), 1.5)   // joules until depletion → departure
//!     .until(10.0);
//! ```
//!
//! The session replays the script against the discrete-event timeline,
//! replanning incrementally *inside* the run at each event. Ties are
//! applied in insertion order. Device ids are dense (see
//! [`super::SynergyRuntime::device_left`]): scripted departures and
//! battery depletions must name the current highest-id device.

use crate::device::{Device, DeviceId, Fleet};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::power::BatteryCfg;

use super::error::RuntimeError;
use super::qos::Qos;

/// One scripted (or injected) runtime mutation.
#[derive(Clone, Debug)]
pub enum ScenarioAction {
    /// The named device leaves the body (must be the current last id).
    DeviceLeft(DeviceId),
    /// A device joins the body (its id must extend the fleet densely).
    DeviceJoined(Device),
    /// Replace the whole fleet at once — the escape hatch for arbitrary
    /// reshapes (dense device ids restrict scripted departures to the
    /// highest id; a `SetFleet` can drop, renumber, or re-platform any
    /// of them). Invalidates the plan-enumeration cache unless the change
    /// is a pure suffix shrink.
    SetFleet(Fleet),
    /// Register an app with QoS hints.
    Register { spec: PipelineSpec, qos: Qos },
    /// Unregister an app.
    Unregister(PipelineId),
    /// Pause an app (drops out of the active plan).
    Pause(PipelineId),
    /// Resume a paused app.
    Resume(PipelineId),
    /// Update an app's QoS hints.
    SetQos { app: PipelineId, qos: Qos },
    /// Top up a declared battery by `joules` (clamped at its capacity) —
    /// the user docking a wearable mid-run. A no-op for devices without a
    /// declared battery; never replans, but moves the scheduled depletion
    /// instant.
    Recharge { device: DeviceId, joules: f64 },
}

impl ScenarioAction {
    /// Short label used as the plan-switch cause in session reports —
    /// deterministic, so replayed sessions compare equal.
    pub fn describe(&self) -> String {
        match self {
            ScenarioAction::DeviceLeft(d) => format!("device-left({d})"),
            ScenarioAction::DeviceJoined(dev) => format!("device-joined({})", dev.id),
            ScenarioAction::SetFleet(fleet) => format!("set-fleet({})", fleet.len()),
            ScenarioAction::Register { spec, .. } => {
                format!("register({}:{})", spec.id, spec.name)
            }
            ScenarioAction::Unregister(id) => format!("unregister({id})"),
            ScenarioAction::Pause(id) => format!("pause({id})"),
            ScenarioAction::Resume(id) => format!("resume({id})"),
            ScenarioAction::SetQos { app, .. } => format!("qos({app})"),
            ScenarioAction::Recharge { device, .. } => format!("recharge({device})"),
        }
    }
}

/// A timestamped scenario action.
#[derive(Clone, Debug)]
pub struct TimedAction {
    /// Simulated time the action fires, seconds from session start.
    pub t: f64,
    pub action: ScenarioAction,
}

/// A declarative timeline of runtime churn (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct Scenario {
    events: Vec<TimedAction>,
    /// Explicit session end; defaults to the last event time.
    until: Option<f64>,
    /// Battery declarations: (device, capacity in joules, model config).
    /// The device departs at the exact instant its modeled drain exhausts
    /// the capacity (event-driven — see [`crate::power::BatteryManager`]).
    batteries: Vec<(DeviceId, f64, BatteryCfg)>,
}

impl Scenario {
    pub fn new() -> Scenario {
        Scenario::default()
    }

    /// Start scripting an event at time `t` (seconds from session start).
    pub fn at(self, t: f64) -> ScenarioAt {
        ScenarioAt { scenario: self, t }
    }

    /// Set the session end time. Without it the session ends at the last
    /// event. Events scripted after `t` never fire.
    pub fn until(mut self, t: f64) -> Scenario {
        self.until = Some(t);
        self
    }

    /// Declare a battery for `device`: once the plan's modeled per-device
    /// drain (base draw + the deployed plan's active draws, see
    /// [`crate::power::plan_device_draw`]) exhausts `capacity_j` joules,
    /// the device leaves the body — at an *exact*, event-driven instant,
    /// recomputed at every plan switch, so busier plans deplete sooner
    /// and depletion timing is identical on the simulator and the
    /// serving engine. Device ids are dense, so depletion fires only
    /// while the device is the fleet's highest id — a depleted non-suffix
    /// device defers until scripted departures free the suffix (and a
    /// device that leaves by script takes its battery with it).
    pub fn battery(self, device: DeviceId, capacity_j: f64) -> Scenario {
        self.battery_with(device, capacity_j, BatteryCfg::default())
    }

    /// [`Self::battery`] with an explicit battery model — e.g. a Peukert
    /// exponent above 1 for load-dependent capacity derating.
    pub fn battery_with(mut self, device: DeviceId, capacity_j: f64, cfg: BatteryCfg) -> Scenario {
        self.batteries.push((device, capacity_j, cfg));
        self
    }

    /// The scripted events, in firing order (time, then insertion order).
    pub fn events(&self) -> &[TimedAction] {
        &self.events
    }

    /// Declared batteries: (device, capacity in joules, model config).
    pub fn batteries(&self) -> &[(DeviceId, f64, BatteryCfg)] {
        &self.batteries
    }

    /// The session end time: the explicit [`Self::until`] horizon, or the
    /// last event time.
    pub fn duration(&self) -> f64 {
        self.until
            .unwrap_or_else(|| self.events.iter().map(|e| e.t).fold(0.0, f64::max))
    }

    /// Events sorted by time (stable: ties keep insertion order).
    pub(crate) fn sorted_events(&self) -> Vec<TimedAction> {
        let mut evs = self.events.clone();
        evs.sort_by(|a, b| a.t.total_cmp(&b.t));
        evs
    }

    /// Validate the script: finite, non-negative times; positive battery
    /// capacities; a positive duration.
    pub(crate) fn validate(&self) -> Result<(), RuntimeError> {
        for ev in &self.events {
            if !ev.t.is_finite() || ev.t < 0.0 {
                return Err(RuntimeError::InvalidScenario(format!(
                    "event time {} is not a finite non-negative second offset ({})",
                    ev.t,
                    ev.action.describe()
                )));
            }
        }
        for (i, &(d, cap, cfg)) in self.batteries.iter().enumerate() {
            if !cap.is_finite() || cap <= 0.0 {
                return Err(RuntimeError::InvalidScenario(format!(
                    "battery capacity for {d} must be a positive joule amount, got {cap}"
                )));
            }
            if !cfg.peukert.is_finite() || cfg.peukert < 1.0 {
                return Err(RuntimeError::InvalidScenario(format!(
                    "battery Peukert exponent for {d} must be finite and ≥ 1, got {}",
                    cfg.peukert
                )));
            }
            if self.batteries[..i].iter().any(|&(prev, _, _)| prev == d) {
                return Err(RuntimeError::InvalidScenario(format!(
                    "duplicate battery declared for {d} — one battery per device"
                )));
            }
        }
        for ev in &self.events {
            if let ScenarioAction::Recharge { device, joules } = &ev.action {
                if !joules.is_finite() || *joules <= 0.0 {
                    return Err(RuntimeError::InvalidScenario(format!(
                        "recharge for {device} must add a positive joule amount, got {joules}"
                    )));
                }
            }
        }
        let dur = self.duration();
        if !dur.is_finite() || dur <= 0.0 {
            return Err(RuntimeError::InvalidScenario(format!(
                "session duration must be positive: set .until(t) or script \
                 at least one event (got {dur})"
            )));
        }
        Ok(())
    }

    fn push(mut self, t: f64, action: ScenarioAction) -> Scenario {
        self.events.push(TimedAction { t, action });
        self
    }
}

/// Builder stage returned by [`Scenario::at`]; each method scripts one
/// action at the pending time and hands the scenario back.
pub struct ScenarioAt {
    scenario: Scenario,
    t: f64,
}

impl ScenarioAt {
    /// The device with this id leaves the body.
    pub fn device_left(self, id: impl Into<DeviceId>) -> Scenario {
        let id = id.into();
        self.scenario.push(self.t, ScenarioAction::DeviceLeft(id))
    }

    /// A device joins the body.
    pub fn device_joined(self, device: Device) -> Scenario {
        self.scenario
            .push(self.t, ScenarioAction::DeviceJoined(device))
    }

    /// Replace the whole fleet (arbitrary reshape; see
    /// [`ScenarioAction::SetFleet`]).
    pub fn set_fleet(self, fleet: Fleet) -> Scenario {
        self.scenario.push(self.t, ScenarioAction::SetFleet(fleet))
    }

    /// Register an app (default QoS).
    pub fn register(self, spec: PipelineSpec) -> Scenario {
        self.scenario.push(
            self.t,
            ScenarioAction::Register { spec, qos: Qos::default() },
        )
    }

    /// Register an app with QoS hints.
    pub fn register_with_qos(self, spec: PipelineSpec, qos: Qos) -> Scenario {
        self.scenario
            .push(self.t, ScenarioAction::Register { spec, qos })
    }

    /// Unregister an app.
    pub fn unregister(self, id: PipelineId) -> Scenario {
        self.scenario.push(self.t, ScenarioAction::Unregister(id))
    }

    /// Pause an app.
    pub fn pause(self, id: PipelineId) -> Scenario {
        self.scenario.push(self.t, ScenarioAction::Pause(id))
    }

    /// Resume a paused app.
    pub fn resume(self, id: PipelineId) -> Scenario {
        self.scenario.push(self.t, ScenarioAction::Resume(id))
    }

    /// Update an app's QoS hints.
    pub fn qos(self, app: PipelineId, qos: Qos) -> Scenario {
        self.scenario
            .push(self.t, ScenarioAction::SetQos { app, qos })
    }

    /// Top up a declared battery by `joules` (clamped at capacity).
    pub fn recharge(self, device: impl Into<DeviceId>, joules: f64) -> Scenario {
        let device = device.into();
        self.scenario
            .push(self.t, ScenarioAction::Recharge { device, joules })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_scripts_sorted_timeline() {
        let s = Scenario::new()
            .at(4.0).unregister(PipelineId(1))
            .at(2.5).device_left(3)
            .at(2.5).pause(PipelineId(0))
            .until(10.0);
        assert_eq!(s.duration(), 10.0);
        let evs = s.sorted_events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].t, 2.5);
        assert!(matches!(evs[0].action, ScenarioAction::DeviceLeft(DeviceId(3))));
        // Ties keep insertion order.
        assert!(matches!(evs[1].action, ScenarioAction::Pause(PipelineId(0))));
        assert!(matches!(evs[2].action, ScenarioAction::Unregister(PipelineId(1))));
        s.validate().unwrap();
    }

    #[test]
    fn duration_defaults_to_last_event() {
        let s = Scenario::new().at(3.25).device_left(2);
        assert_eq!(s.duration(), 3.25);
    }

    #[test]
    fn invalid_scripts_are_typed_errors() {
        let s = Scenario::new().at(-1.0).device_left(0).until(5.0);
        assert!(matches!(
            s.validate().unwrap_err(),
            RuntimeError::InvalidScenario(_)
        ));
        let s = Scenario::new().at(f64::NAN).device_left(0).until(5.0);
        assert!(s.validate().is_err());
        let s = Scenario::new()
            .battery(DeviceId(1), 0.0)
            .until(5.0);
        assert!(s.validate().is_err());
        let s = Scenario::new(); // no events, no horizon
        assert!(s.validate().is_err());
        // Sub-ideal Peukert exponents and non-positive recharges are typos.
        let s = Scenario::new()
            .battery_with(DeviceId(0), 1.0, BatteryCfg { peukert: 0.5 })
            .until(5.0);
        assert!(s.validate().is_err());
        let s = Scenario::new().at(1.0).recharge(0, -2.0).until(5.0);
        assert!(s.validate().is_err());
        // Two batteries on one device would silently race — rejected.
        let s = Scenario::new()
            .battery(DeviceId(2), 10.0)
            .battery(DeviceId(2), 1.0)
            .until(5.0);
        assert!(s.validate().is_err());
    }

    #[test]
    fn recharge_scripts_a_deterministic_label() {
        let s = Scenario::new()
            .battery(DeviceId(2), 1.5)
            .at(3.0)
            .recharge(2, 1.0)
            .until(6.0);
        s.validate().unwrap();
        assert_eq!(s.events()[0].action.describe(), "recharge(d2)");
        assert_eq!(s.batteries().len(), 1);
        assert_eq!(s.batteries()[0].2, BatteryCfg::default());
    }

    #[test]
    fn set_fleet_scripts_an_arbitrary_reshape() {
        let s = Scenario::new()
            .at(1.5)
            .set_fleet(crate::workload::fleet4())
            .until(3.0);
        let evs = s.sorted_events();
        assert_eq!(evs.len(), 1);
        assert!(matches!(&evs[0].action, ScenarioAction::SetFleet(f) if f.len() == 4));
        assert_eq!(evs[0].action.describe(), "set-fleet(4)");
        s.validate().unwrap();
    }

    #[test]
    fn causes_are_deterministic_labels() {
        assert_eq!(
            ScenarioAction::DeviceLeft(DeviceId(3)).describe(),
            "device-left(d3)"
        );
        assert_eq!(
            ScenarioAction::Pause(PipelineId(2)).describe(),
            "pause(p2)"
        );
    }
}
