//! App registration and lifecycle: the fluent [`AppBuilder`] and the
//! [`AppHandle`] it returns.
//!
//! ```text
//! let kws = runtime.app("kws")
//!     .source(Sensor::Microphone)
//!     .model(ModelName::KWS)
//!     .target(Interaction::Haptic)
//!     .qos(Qos { min_rate_hz: 5.0, ..Qos::default() })
//!     .register()?;
//! kws.pause()?;   // drop out of the active plan
//! kws.resume()?;  // rejoin (one incremental replan each)
//! ```

use std::sync::{Arc, Mutex};

use crate::model::zoo::{model_by_name, ModelName};
use crate::model::ModelGraph;
use crate::pipeline::{PipelineId, PipelineSpec, SourceReq, TargetReq};

use super::core::AppStats;
use super::error::RuntimeError;
use super::qos::Qos;
use super::runtime::Shared;

/// Fluent registration of one on-body AI app (§IV-B: requirements, not
/// device bindings). Created by [`super::SynergyRuntime::app`].
pub struct AppBuilder {
    pub(crate) shared: Arc<Mutex<Shared>>,
    pub(crate) name: String,
    pub(crate) id: Option<usize>,
    pub(crate) source: SourceReq,
    pub(crate) model: Option<ModelGraph>,
    pub(crate) target: TargetReq,
    pub(crate) qos: Qos,
}

impl AppBuilder {
    /// Pin the pipeline id (defaults to a fresh, never-reused id).
    ///
    /// Pinned ids are caller-managed: re-pinning the id of a previously
    /// unregistered app is allowed (workload definitions re-register
    /// fixed ids), but stale handles of the old app will then act on the
    /// new one — the no-aliasing guarantee covers auto-assigned ids only.
    pub fn id(mut self, id: usize) -> AppBuilder {
        self.id = Some(id);
        self
    }

    /// Sensing requirement: a sensor kind, a designated `DeviceId`, or a
    /// `SourceReq` (defaults to `SourceReq::Any`).
    pub fn source(mut self, source: impl Into<SourceReq>) -> AppBuilder {
        self.source = source.into();
        self
    }

    /// The zoo model to execute.
    pub fn model(mut self, model: ModelName) -> AppBuilder {
        self.model = Some(model_by_name(model).clone());
        self
    }

    /// A custom model graph (tests, future zoo extensions).
    pub fn model_graph(mut self, model: ModelGraph) -> AppBuilder {
        self.model = Some(model);
        self
    }

    /// Interaction requirement: an interaction kind, a designated
    /// `DeviceId`, or a `TargetReq` (defaults to `TargetReq::Any`).
    pub fn target(mut self, target: impl Into<TargetReq>) -> AppBuilder {
        self.target = target.into();
        self
    }

    /// Quality-of-service hints (defaults to no floor / no budget /
    /// normal priority).
    pub fn qos(mut self, qos: Qos) -> AppBuilder {
        self.qos = qos;
        self
    }

    /// Validate, register, and orchestrate. Returns a handle on success;
    /// on failure nothing is registered and the previous deployment stays
    /// in place.
    pub fn register(self) -> Result<AppHandle, RuntimeError> {
        if self.name.trim().is_empty() {
            return Err(RuntimeError::InvalidApp {
                name: self.name,
                reason: "app name must be non-empty".into(),
            });
        }
        let model = self.model.ok_or_else(|| RuntimeError::InvalidApp {
            name: self.name.clone(),
            reason: "no model: call .model(ModelName) or .model_graph(...)".into(),
        })?;
        let (name, id, source, target) = (self.name, self.id, self.source, self.target);
        super::runtime::register_locked(&self.shared, self.qos, move |core| PipelineSpec {
            id: PipelineId(id.unwrap_or_else(|| core.next_app_id())),
            name,
            source,
            model,
            target,
        })
    }
}

/// Lifecycle handle for a registered app. Handles are cheap to clone and
/// stay valid across replans; operations on an unregistered app return
/// [`RuntimeError::UnknownApp`].
#[derive(Clone)]
pub struct AppHandle {
    pub(crate) shared: Arc<Mutex<Shared>>,
    pub(crate) id: PipelineId,
    pub(crate) name: String,
}

impl AppHandle {
    pub fn id(&self) -> PipelineId {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Exclude this app from the active plan (one replan over the rest).
    pub fn pause(&self) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.set_paused(self.id, true, planner.as_ref())
    }

    /// Rejoin the active plan (one replan).
    pub fn resume(&self) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.set_paused(self.id, false, planner.as_ref())
    }

    /// Update the app's QoS hints mid-session (one replan: priority
    /// classes reorder progressive selection, and degradation events
    /// re-check against the new floors).
    pub fn set_qos(&self, qos: Qos) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.set_qos(self.id, qos, planner.as_ref())
    }

    /// Remove the app entirely (one replan; deployment cleared when this
    /// was the last active app).
    pub fn unregister(self) -> Result<(), RuntimeError> {
        let mut guard = self.shared.lock().unwrap();
        let Shared { core, planner } = &mut *guard;
        core.remove(self.id, planner.as_ref())
    }

    /// This app's view of the current deployment: placement, estimated
    /// rate/latency, and QoS standing.
    pub fn stats(&self) -> Result<AppStats, RuntimeError> {
        self.shared.lock().unwrap().core.app_stats(self.id)
    }
}

impl std::fmt::Debug for AppHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AppHandle")
            .field("id", &self.id)
            .field("name", &self.name)
            .finish()
    }
}
