//! Runtime events: apps observe orchestration instead of polling.
//!
//! Every structural change the moderator reacts to (§III-C: app
//! registration, device churn) produces events on a broadcast channel.
//! Events arrive wrapped in a [`StampedEvent`]: a bus-wide sequence number
//! (total order across subscribers) plus, inside a live
//! [`crate::api::Session`], the simulated-timeline timestamp of the
//! scenario event that caused it — so subscribers can correlate replans
//! with the session time series.
//!
//! Subscribers get an [`EventSubscription`] (deref's to an
//! `mpsc::Receiver`); dropped subscriptions are pruned on the next emit
//! *and* on the next subscribe, so subscriptions need no explicit teardown
//! and a subscribe/drop churn loop cannot grow the sender list between
//! emits.

use std::sync::{mpsc, Arc, Weak};

use crate::device::DeviceId;
use crate::pipeline::PipelineId;

use super::qos::QosViolation;

/// What happened inside the runtime.
#[derive(Clone, Debug, PartialEq)]
pub enum RuntimeEvent {
    /// A device joined the on-body fleet.
    DeviceJoined { device: DeviceId },
    /// A device left the on-body fleet.
    DeviceLeft { device: DeviceId },
    /// An app was registered.
    AppRegistered { app: PipelineId },
    /// An app was unregistered.
    AppUnregistered { app: PipelineId },
    /// An app was paused (excluded from the active plan).
    AppPaused { app: PipelineId },
    /// A paused app was resumed.
    AppResumed { app: PipelineId },
    /// An app's QoS hints were updated.
    QosUpdated { app: PipelineId },
    /// Holistic orchestration selected a new deployment.
    Replanned {
        /// Orchestration counter (monotonically increasing).
        orchestration: usize,
        /// Apps covered by the new plan.
        apps: usize,
        /// Whether every app's plan enumeration came from the incremental
        /// cache (no re-enumeration was needed).
        incremental: bool,
        /// The new plan's estimated system throughput, inf/s.
        throughput: f64,
    },
    /// The newly selected plan's estimate violates an app's QoS hints.
    PlanDegraded {
        app: PipelineId,
        violation: QosViolation,
    },
}

/// A [`RuntimeEvent`] plus correlation metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct StampedEvent {
    /// Bus-wide sequence number, strictly increasing in emission order.
    pub seq: u64,
    /// Simulated-timeline timestamp when the event was caused by a
    /// [`crate::api::Session`] scenario; `None` for out-of-session calls.
    pub sim_time: Option<f64>,
    pub event: RuntimeEvent,
}

/// A live subscription to the event bus. Dereferences to the underlying
/// `mpsc::Receiver<StampedEvent>`, so `try_iter`/`try_recv`/`recv` work
/// directly. Dropping it unsubscribes (lazily pruned by the bus).
pub struct EventSubscription {
    rx: mpsc::Receiver<StampedEvent>,
    /// Liveness token: the bus holds the matching `Weak` and prunes
    /// senders whose token dropped.
    _alive: Arc<()>,
}

impl std::ops::Deref for EventSubscription {
    type Target = mpsc::Receiver<StampedEvent>;

    fn deref(&self) -> &Self::Target {
        &self.rx
    }
}

struct BusSender {
    tx: mpsc::Sender<StampedEvent>,
    alive: Weak<()>,
}

/// Broadcast fan-out of [`RuntimeEvent`]s to any number of subscribers.
#[derive(Default)]
pub(crate) struct EventBus {
    subscribers: Vec<BusSender>,
    next_seq: u64,
    /// Simulated clock stamped onto emitted events (sessions set this
    /// around scenario-event application).
    clock: Option<f64>,
}

impl EventBus {
    /// Open a new subscription, pruning dropped ones first.
    pub fn subscribe(&mut self) -> EventSubscription {
        self.subscribers.retain(|s| s.alive.strong_count() > 0);
        let (tx, rx) = mpsc::channel();
        let alive = Arc::new(());
        self.subscribers.push(BusSender {
            tx,
            alive: Arc::downgrade(&alive),
        });
        EventSubscription { rx, _alive: alive }
    }

    /// Set (or clear) the simulated-time stamp for subsequent emits.
    pub fn set_clock(&mut self, t: Option<f64>) {
        self.clock = t;
    }

    /// Deliver an event to all live subscribers, pruning dead ones.
    pub fn emit(&mut self, event: RuntimeEvent) {
        let stamped = StampedEvent {
            seq: self.next_seq,
            sim_time: self.clock,
            event,
        };
        self.next_seq += 1;
        self.subscribers
            .retain(|s| s.alive.strong_count() > 0 && s.tx.send(stamped.clone()).is_ok());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscribers_receive_events_in_order_with_increasing_seq() {
        let mut bus = EventBus::default();
        let rx = bus.subscribe();
        bus.emit(RuntimeEvent::DeviceJoined { device: DeviceId(2) });
        bus.emit(RuntimeEvent::AppRegistered { app: PipelineId(0) });
        bus.emit(RuntimeEvent::DeviceLeft { device: DeviceId(2) });
        let got: Vec<StampedEvent> = rx.try_iter().collect();
        assert_eq!(
            got.iter().map(|s| s.event.clone()).collect::<Vec<_>>(),
            vec![
                RuntimeEvent::DeviceJoined { device: DeviceId(2) },
                RuntimeEvent::AppRegistered { app: PipelineId(0) },
                RuntimeEvent::DeviceLeft { device: DeviceId(2) },
            ],
            "delivery must preserve emission order"
        );
        assert!(
            got.windows(2).all(|w| w[0].seq < w[1].seq),
            "sequence numbers must strictly increase: {got:?}"
        );
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn dropped_subscribers_are_pruned_on_emit() {
        let mut bus = EventBus::default();
        let rx = bus.subscribe();
        drop(rx);
        let rx2 = bus.subscribe();
        bus.emit(RuntimeEvent::AppRegistered { app: PipelineId(0) });
        assert!(rx2.try_recv().is_ok());
        assert_eq!(bus.subscribers.len(), 1);
    }

    #[test]
    fn dropped_subscribers_are_pruned_on_subscribe_too() {
        // Regression: the sender list used to grow without bound under a
        // subscribe/drop churn loop with no emits in between.
        let mut bus = EventBus::default();
        for _ in 0..64 {
            drop(bus.subscribe());
        }
        let live = bus.subscribe();
        assert_eq!(
            bus.subscribers.len(),
            1,
            "subscribe() must prune dropped subscribers"
        );
        bus.emit(RuntimeEvent::AppPaused { app: PipelineId(1) });
        assert_eq!(live.try_recv().unwrap().event, RuntimeEvent::AppPaused { app: PipelineId(1) });
    }

    #[test]
    fn session_clock_stamps_sim_time() {
        let mut bus = EventBus::default();
        let rx = bus.subscribe();
        bus.emit(RuntimeEvent::AppRegistered { app: PipelineId(0) });
        bus.set_clock(Some(2.5));
        bus.emit(RuntimeEvent::DeviceLeft { device: DeviceId(3) });
        bus.set_clock(None);
        bus.emit(RuntimeEvent::AppPaused { app: PipelineId(0) });
        let got: Vec<StampedEvent> = rx.try_iter().collect();
        assert_eq!(got[0].sim_time, None);
        assert_eq!(got[1].sim_time, Some(2.5));
        assert_eq!(got[2].sim_time, None);
    }
}
