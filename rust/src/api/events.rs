//! Runtime events: apps observe orchestration instead of polling.
//!
//! Every structural change the moderator reacts to (§III-C: app
//! registration, device churn) produces events on a broadcast channel.
//! Subscribers get an `mpsc::Receiver`; dropped receivers are pruned on the
//! next emit, so subscriptions need no explicit teardown.

use std::sync::mpsc;

use crate::device::DeviceId;
use crate::pipeline::PipelineId;

use super::qos::QosViolation;

/// What happened inside the runtime.
#[derive(Clone, Debug, PartialEq)]
pub enum RuntimeEvent {
    /// A device joined the on-body fleet.
    DeviceJoined { device: DeviceId },
    /// A device left the on-body fleet.
    DeviceLeft { device: DeviceId },
    /// An app was registered.
    AppRegistered { app: PipelineId },
    /// An app was unregistered.
    AppUnregistered { app: PipelineId },
    /// An app was paused (excluded from the active plan).
    AppPaused { app: PipelineId },
    /// A paused app was resumed.
    AppResumed { app: PipelineId },
    /// Holistic orchestration selected a new deployment.
    Replanned {
        /// Orchestration counter (monotonically increasing).
        orchestration: usize,
        /// Apps covered by the new plan.
        apps: usize,
        /// Whether every app's plan enumeration came from the incremental
        /// cache (no re-enumeration was needed).
        incremental: bool,
        /// The new plan's estimated system throughput, inf/s.
        throughput: f64,
    },
    /// The newly selected plan's estimate violates an app's QoS hints.
    PlanDegraded {
        app: PipelineId,
        violation: QosViolation,
    },
}

/// Broadcast fan-out of [`RuntimeEvent`]s to any number of subscribers.
#[derive(Default)]
pub(crate) struct EventBus {
    subscribers: Vec<mpsc::Sender<RuntimeEvent>>,
}

impl EventBus {
    /// Open a new subscription.
    pub fn subscribe(&mut self) -> mpsc::Receiver<RuntimeEvent> {
        let (tx, rx) = mpsc::channel();
        self.subscribers.push(tx);
        rx
    }

    /// Deliver an event to all live subscribers, pruning dead ones.
    pub fn emit(&mut self, event: RuntimeEvent) {
        self.subscribers.retain(|s| s.send(event.clone()).is_ok());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscribers_receive_events() {
        let mut bus = EventBus::default();
        let rx = bus.subscribe();
        bus.emit(RuntimeEvent::DeviceJoined { device: DeviceId(2) });
        assert_eq!(
            rx.try_recv().unwrap(),
            RuntimeEvent::DeviceJoined { device: DeviceId(2) }
        );
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn dropped_subscribers_are_pruned() {
        let mut bus = EventBus::default();
        let rx = bus.subscribe();
        drop(rx);
        let rx2 = bus.subscribe();
        bus.emit(RuntimeEvent::AppRegistered { app: PipelineId(0) });
        assert!(rx2.try_recv().is_ok());
    }
}
