//! Incremental re-orchestration: per-app plan-enumeration caching.
//!
//! The seed moderator re-enumerated every pipeline's execution-plan space
//! on every change. But the expensive, endpoint-independent part of that
//! space — the *split skeletons* (device permutations × split boundaries,
//! chunk-fit filtered; see [`crate::plan::enumerate_splits_with`]) —
//! depends only on the app's model and the fleet's accelerator lineup, so
//! it can be cached per app and reused:
//!
//! - **App change** (register / remove / pause / resume): the fleet is
//!   untouched, so every other app's skeletons are reused verbatim; only a
//!   newly registered app is enumerated.
//! - **Device left** (suffix shrink — surviving ids and kinds unchanged):
//!   each cached skeleton list is *filtered* to the surviving devices.
//!   Because the small fleet's permutations are a subsequence of the large
//!   fleet's, the filtered list is exactly what fresh enumeration would
//!   produce, in the same order — selection results are bit-identical.
//! - **Device joined** (or any other reshape): cached skeletons are
//!   incomplete (plans through the new device are missing), so the cache
//!   is invalidated and rebuilt on the next replan.
//!
//! Selection itself ([`select_with_cache`]) delegates to the shared
//! skeleton-selection core (`ProgressivePlanner::select_over_skeletons`) —
//! same ordering, same scoring, same first-fit-decreasing OOR retry — over
//! the cached skeletons composed with the (cheaply recomputed) endpoint
//! candidates. Cached entries carry each skeleton's chain-latency bound,
//! so replans under bounded search also reuse the pruning work, and cache
//! misses for several apps are enumerated in parallel
//! ([`crate::plan::enumerate_skeletons_for`]).

use std::collections::BTreeMap;

use crate::device::{DeviceSpec, Fleet};
use crate::orchestrator::{PlanError, Priority, ProgressivePlanner};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::{enumerate_skeletons_for, CollabPlan, PlannerCfg, SearchMode, Skeleton};

use super::qos::AppPriority;

/// Per-replan bookkeeping: how much enumeration work the cache saved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplanStats {
    /// Apps whose plan enumeration was served from the cache.
    pub reused_apps: usize,
    /// Apps whose plan space had to be (re-)enumerated.
    pub enumerated_apps: usize,
    /// Candidate plans scored during selection.
    pub candidates_scored: u64,
}

impl ReplanStats {
    /// An *incremental* replan reused every app's enumeration — typical
    /// for pause/resume and suffix device departures.
    pub fn incremental(&self) -> bool {
        self.reused_apps > 0 && self.enumerated_apps == 0
    }
}

/// The per-app skeleton cache plus the fleet signature it is valid for.
pub(crate) struct PlanCache {
    /// Full platform spec per dense id the skeletons were enumerated
    /// against. The whole spec (not just the kind) is compared: `Device`
    /// fields are public, so a caller can hand-build a device whose kind
    /// matches a stock platform but whose accelerator capacities differ —
    /// chunk-fit filtering baked into the skeletons must not survive that.
    sig: Vec<DeviceSpec>,
    /// Search configuration the skeletons were produced under (a search-
    /// mode or limit change invalidates everything: bounded and exhaustive
    /// candidate lists are not interchangeable).
    cfg: PlannerCfg,
    per_app: BTreeMap<PipelineId, Vec<Skeleton>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            sig: Vec::new(),
            cfg: PlannerCfg::default(),
            per_app: BTreeMap::new(),
        }
    }

    /// Reconcile the cache with the current fleet + search config.
    /// Suffix shrinks filter in place (cache survives); anything else
    /// invalidates.
    ///
    /// Filtering keeps each surviving skeleton's chain bound (it depends
    /// only on its own devices, whose specs are unchanged). Exhaustive
    /// lists stay an order-preserved subsequence — exactly what fresh
    /// enumeration would produce; bounded lists stay bound-sorted, they
    /// merely lose the candidates that named the departed device.
    pub fn sync_fleet(&mut self, fleet: &Fleet, cfg: PlannerCfg) {
        let sig: Vec<DeviceSpec> = fleet.devices.iter().map(|d| d.spec.clone()).collect();
        if cfg != self.cfg {
            self.per_app.clear();
            self.cfg = cfg;
        } else if sig == self.sig {
            return;
        } else if sig.len() < self.sig.len() && self.sig[..sig.len()] == sig[..] {
            // Suffix departure: drop skeletons touching departed devices.
            let n = sig.len();
            for skels in self.per_app.values_mut() {
                skels.retain(|s| s.chunks.iter().all(|a| a.device.0 < n));
            }
        } else {
            self.per_app.clear();
        }
        self.sig = sig;
    }

    pub fn contains(&self, id: PipelineId) -> bool {
        self.per_app.contains_key(&id)
    }

    pub fn insert(&mut self, id: PipelineId, skels: Vec<Skeleton>) {
        self.per_app.insert(id, skels);
    }

    /// The cached candidate lists (selection input). Call
    /// [`Self::sync_fleet`] and fill misses first.
    pub fn entries(&self) -> &BTreeMap<PipelineId, Vec<Skeleton>> {
        &self.per_app
    }

    /// Drop one app's entry (unregistration, failed registration).
    pub fn invalidate_app(&mut self, id: PipelineId) {
        self.per_app.remove(&id);
    }
}

/// Selection order: the planner's priority ordering, stably regrouped so
/// higher-QoS-priority apps pick placements first.
fn selection_order(
    priority: Priority,
    specs: &[PipelineSpec],
    prios: &[AppPriority],
) -> Vec<usize> {
    let mut order = priority.order(specs);
    order.sort_by_key(|&i| std::cmp::Reverse(prios[i]));
    order
}

/// Progressive selection over cached skeletons. Equivalent to
/// [`ProgressivePlanner::select`] (same outputs on identical inputs), but
/// the enumeration work is amortized across replans — and cache misses
/// for several apps (cold start, fleet growth) are enumerated in parallel
/// — and apps carry QoS priority classes.
pub(crate) fn select_with_cache(
    pp: &ProgressivePlanner,
    specs: &[PipelineSpec],
    prios: &[AppPriority],
    fleet: &Fleet,
    cache: &mut PlanCache,
) -> (Result<CollabPlan, PlanError>, ReplanStats) {
    let mut stats = ReplanStats::default();
    let missing: Vec<&PipelineSpec> = specs.iter().filter(|s| !cache.contains(s.id)).collect();
    stats.enumerated_apps = missing.len();
    stats.reused_apps = specs.len() - missing.len();
    for (id, skels) in enumerate_skeletons_for(&missing, fleet, pp.cfg) {
        cache.insert(id, skels);
    }

    let mut result = run_orders(pp, specs, prios, fleet, cache, &mut stats.candidates_scored);
    // A suffix shrink filters bounded-mode candidate lists down to the
    // survivors of a beam that targeted the *old* fleet — that subset can
    // dead-end (even empty out) where fresh enumeration on the shrunken
    // fleet would succeed, so an OOR from reused bounded entries is not a
    // real verdict. Rebuild every candidate list and retry once before
    // reporting it. (Exhaustive lists are immune: a filtered subsequence
    // equals fresh enumeration exactly.)
    if matches!(result, Err(PlanError::Oor { .. }))
        && matches!(pp.cfg.search, SearchMode::Bounded { .. })
        && stats.reused_apps > 0
    {
        for spec in specs {
            cache.invalidate_app(spec.id);
        }
        let all: Vec<&PipelineSpec> = specs.iter().collect();
        for (id, skels) in enumerate_skeletons_for(&all, fleet, pp.cfg) {
            cache.insert(id, skels);
        }
        stats.reused_apps = 0;
        stats.enumerated_apps = specs.len();
        result = run_orders(pp, specs, prios, fleet, cache, &mut stats.candidates_scored);
    }
    // Keep the planner's own search-effort diagnostic in sync.
    pp.candidates_scored.set(stats.candidates_scored);
    (result, stats)
}

/// Primary priority order, then the first-fit-decreasing OOR retry
/// (mirrors `ProgressivePlanner::select`).
fn run_orders(
    pp: &ProgressivePlanner,
    specs: &[PipelineSpec],
    prios: &[AppPriority],
    fleet: &Fleet,
    cache: &PlanCache,
    scored: &mut u64,
) -> Result<CollabPlan, PlanError> {
    let result = pp.select_over_skeletons(
        specs,
        fleet,
        &selection_order(pp.priority, specs, prios),
        cache.entries(),
        scored,
    );
    match result {
        Err(PlanError::Oor { .. }) if pp.priority != Priority::ModelSizeDesc => pp
            .select_over_skeletons(
                specs,
                fleet,
                &selection_order(Priority::ModelSizeDesc, specs, prios),
                cache.entries(),
                scored,
            ),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::orchestrator::Synergy;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::workload::{fleet_n, workload};

    fn any_pipes(models: &[ModelName]) -> Vec<PipelineSpec> {
        models
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect()
    }

    #[test]
    fn cached_selection_matches_streaming_selection() {
        let pp = Synergy::planner();
        for fleet in [fleet_n(2), fleet_n(3)] {
            let ps = any_pipes(&[ModelName::KWS, ModelName::SimpleNet, ModelName::UNet]);
            let prios = vec![AppPriority::Normal; ps.len()];
            let mut cache = PlanCache::new();
            cache.sync_fleet(&fleet, pp.cfg);
            let (res, stats) = select_with_cache(&pp, &ps, &prios, &fleet, &mut cache);
            let cached = res.unwrap();
            let streamed = pp.select(&ps, &fleet).unwrap();
            assert_eq!(cached, streamed);
            assert_eq!(stats.enumerated_apps, 3);
            assert_eq!(stats.reused_apps, 0);
        }
    }

    #[test]
    fn cached_bounded_selection_matches_direct_bounded_selection() {
        // The replan cache and the planner's own bounded path must agree:
        // both run select_over_skeletons on identical candidate lists.
        let pp = Synergy::planner_bounded(8);
        let fleet = fleet_n(3);
        let ps = any_pipes(&[ModelName::KWS, ModelName::SimpleNet]);
        let prios = vec![AppPriority::Normal; ps.len()];
        let mut cache = PlanCache::new();
        cache.sync_fleet(&fleet, pp.cfg);
        let (res, stats) = select_with_cache(&pp, &ps, &prios, &fleet, &mut cache);
        assert_eq!(res.unwrap(), pp.select(&ps, &fleet).unwrap());
        assert_eq!(stats.enumerated_apps, 2);
    }

    #[test]
    fn suffix_shrink_keeps_cache_and_matches_fresh_enumeration() {
        let pp = Synergy::planner();
        let w = workload(1).unwrap();
        let prios = vec![AppPriority::Normal; w.pipelines.len()];
        let mut cache = PlanCache::new();

        let big = fleet_n(5);
        cache.sync_fleet(&big, pp.cfg);
        let (res, _) = select_with_cache(&pp, &w.pipelines, &prios, &big, &mut cache);
        res.unwrap();

        // Device 4 leaves: the cache filters in place, no re-enumeration…
        let small = fleet_n(4);
        cache.sync_fleet(&small, pp.cfg);
        let (res, stats) = select_with_cache(&pp, &w.pipelines, &prios, &small, &mut cache);
        let incremental = res.unwrap();
        assert!(stats.incremental(), "{stats:?}");
        // …and the selected plan is identical to planning from scratch.
        assert_eq!(incremental, pp.select(&w.pipelines, &small).unwrap());
    }

    #[test]
    fn bounded_suffix_shrink_reenumerates_when_filtered_candidates_dead_end() {
        // beam_width = 1 makes every UNet candidate's first chunk land on
        // the fastest device (the MAX78002 at d4); when that device
        // departs, the suffix filter empties the cached list. The replan
        // must rebuild candidates on the shrunken fleet instead of
        // reporting a spurious OOR.
        use crate::device::DeviceKind;
        use crate::workload::fleet_of;
        let pp = Synergy::planner_bounded(1);
        let big = fleet_of(&[
            DeviceKind::Max78000,
            DeviceKind::Max78000,
            DeviceKind::Max78000,
            DeviceKind::Max78000,
            DeviceKind::Max78002,
        ]);
        let ps = any_pipes(&[ModelName::UNet]);
        let prios = vec![AppPriority::Normal];
        let mut cache = PlanCache::new();
        cache.sync_fleet(&big, pp.cfg);
        let (res, _) = select_with_cache(&pp, &ps, &prios, &big, &mut cache);
        res.unwrap();

        let small = fleet_of(&[DeviceKind::Max78000; 4]);
        cache.sync_fleet(&small, pp.cfg);
        let (res, stats) = select_with_cache(&pp, &ps, &prios, &small, &mut cache);
        let plan = res.unwrap();
        plan.check_runnable(&ps, &small).unwrap();
        assert_eq!(
            stats.enumerated_apps, 1,
            "dead-ended filtered cache must be rebuilt: {stats:?}"
        );
    }

    #[test]
    fn fleet_growth_invalidates_cache() {
        let pp = Synergy::planner();
        let ps = any_pipes(&[ModelName::KWS]);
        let prios = vec![AppPriority::Normal];
        let mut cache = PlanCache::new();
        cache.sync_fleet(&fleet_n(2), pp.cfg);
        select_with_cache(&pp, &ps, &prios, &fleet_n(2), &mut cache).0.unwrap();
        cache.sync_fleet(&fleet_n(3), pp.cfg);
        let (res, stats) = select_with_cache(&pp, &ps, &prios, &fleet_n(3), &mut cache);
        res.unwrap();
        assert_eq!(stats.enumerated_apps, 1, "growth must re-enumerate");
    }

    #[test]
    fn high_priority_app_plans_first() {
        // KWS (low data intensity) normally plans after UNet; High priority
        // regroups it to the front of the selection order.
        let ps = any_pipes(&[ModelName::KWS, ModelName::UNet]);
        let normal = selection_order(
            Priority::DataIntensityDesc,
            &ps,
            &[AppPriority::Normal, AppPriority::Normal],
        );
        assert_eq!(normal, vec![1, 0]);
        let boosted = selection_order(
            Priority::DataIntensityDesc,
            &ps,
            &[AppPriority::High, AppPriority::Normal],
        );
        assert_eq!(boosted, vec![0, 1]);
    }
}
