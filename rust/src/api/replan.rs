//! Incremental re-orchestration: per-app plan-enumeration caching.
//!
//! The seed moderator re-enumerated every pipeline's execution-plan space
//! on every change. But the expensive, endpoint-independent part of that
//! space — the *split skeletons* (device permutations × split boundaries,
//! chunk-fit filtered; see [`crate::plan::enumerate_splits_with`]) —
//! depends only on the app's model and the fleet's accelerator lineup, so
//! it can be cached per app and reused:
//!
//! - **App change** (register / remove / pause / resume): the fleet is
//!   untouched, so every other app's skeletons are reused verbatim; only a
//!   newly registered app is enumerated.
//! - **Device left** (suffix shrink — surviving ids and kinds unchanged):
//!   each cached skeleton list is *filtered* to the surviving devices.
//!   Because the small fleet's permutations are a subsequence of the large
//!   fleet's, the filtered list is exactly what fresh enumeration would
//!   produce, in the same order — selection results are bit-identical.
//! - **Device joined** (or any other reshape): cached skeletons are
//!   incomplete (plans through the new device are missing), so the cache
//!   is invalidated and rebuilt on the next replan.
//!
//! Selection itself ([`select_with_cache`]) mirrors the progressive
//! accumulation of [`ProgressivePlanner::select`] — same ordering, same
//! scoring, same first-fit-decreasing OOR retry — over the cached
//! skeletons composed with the (cheaply recomputed) endpoint candidates.

use std::collections::BTreeMap;

use crate::device::{DeviceSpec, Fleet};
use crate::estimator::{EstimateAccum, LatencyModel};
use crate::orchestrator::{PlanError, Priority, ProgressivePlanner};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::collab::MemoryLedger;
use crate::plan::{enumerate_splits_with, Assignment, CollabPlan, EnumerateCfg, ExecutionPlan};

use super::qos::AppPriority;

/// Per-replan bookkeeping: how much enumeration work the cache saved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplanStats {
    /// Apps whose plan enumeration was served from the cache.
    pub reused_apps: usize,
    /// Apps whose plan space had to be (re-)enumerated.
    pub enumerated_apps: usize,
    /// Candidate plans scored during selection.
    pub candidates_scored: u64,
}

impl ReplanStats {
    /// An *incremental* replan reused every app's enumeration — typical
    /// for pause/resume and suffix device departures.
    pub fn incremental(&self) -> bool {
        self.reused_apps > 0 && self.enumerated_apps == 0
    }
}

/// The per-app skeleton cache plus the fleet signature it is valid for.
pub(crate) struct PlanCache {
    /// Full platform spec per dense id the skeletons were enumerated
    /// against. The whole spec (not just the kind) is compared: `Device`
    /// fields are public, so a caller can hand-build a device whose kind
    /// matches a stock platform but whose accelerator capacities differ —
    /// chunk-fit filtering baked into the skeletons must not survive that.
    sig: Vec<DeviceSpec>,
    /// Enumeration limits the skeletons were produced under.
    cfg: EnumerateCfg,
    per_app: BTreeMap<PipelineId, Vec<Vec<Assignment>>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            sig: Vec::new(),
            cfg: EnumerateCfg::default(),
            per_app: BTreeMap::new(),
        }
    }

    /// Reconcile the cache with the current fleet + enumeration config.
    /// Suffix shrinks filter in place (cache survives); anything else
    /// invalidates.
    pub fn sync_fleet(&mut self, fleet: &Fleet, cfg: EnumerateCfg) {
        let sig: Vec<DeviceSpec> = fleet.devices.iter().map(|d| d.spec.clone()).collect();
        if cfg != self.cfg {
            self.per_app.clear();
            self.cfg = cfg;
        } else if sig == self.sig {
            return;
        } else if sig.len() < self.sig.len() && self.sig[..sig.len()] == sig[..] {
            // Suffix departure: drop skeletons touching departed devices.
            let n = sig.len();
            for skels in self.per_app.values_mut() {
                skels.retain(|s| s.iter().all(|a| a.device.0 < n));
            }
        } else {
            self.per_app.clear();
        }
        self.sig = sig;
    }

    /// Ensure an entry exists for `spec`; returns whether it was a cache
    /// hit. Call [`Self::sync_fleet`] first.
    pub fn ensure(&mut self, spec: &PipelineSpec, fleet: &Fleet) -> bool {
        if self.per_app.contains_key(&spec.id) {
            return true;
        }
        let mut skels = Vec::new();
        enumerate_splits_with(spec, fleet, self.cfg, |chunks| skels.push(chunks.to_vec()));
        self.per_app.insert(spec.id, skels);
        false
    }

    pub fn get(&self, id: PipelineId) -> Option<&[Vec<Assignment>]> {
        self.per_app.get(&id).map(Vec::as_slice)
    }

    /// Drop one app's entry (unregistration, failed registration).
    pub fn invalidate_app(&mut self, id: PipelineId) {
        self.per_app.remove(&id);
    }
}

/// Selection order: the planner's priority ordering, stably regrouped so
/// higher-QoS-priority apps pick placements first.
fn selection_order(
    priority: Priority,
    specs: &[PipelineSpec],
    prios: &[AppPriority],
) -> Vec<usize> {
    let mut order = priority.order(specs);
    order.sort_by_key(|&i| std::cmp::Reverse(prios[i]));
    order
}

/// Progressive selection over cached skeletons. Equivalent to
/// [`ProgressivePlanner::select`] (same outputs on identical inputs), but
/// the enumeration work is amortized across replans, and apps carry QoS
/// priority classes.
pub(crate) fn select_with_cache(
    pp: &ProgressivePlanner,
    specs: &[PipelineSpec],
    prios: &[AppPriority],
    fleet: &Fleet,
    cache: &mut PlanCache,
) -> (Result<CollabPlan, PlanError>, ReplanStats) {
    let mut stats = ReplanStats::default();
    for spec in specs {
        if cache.ensure(spec, fleet) {
            stats.reused_apps += 1;
        } else {
            stats.enumerated_apps += 1;
        }
    }

    let mut result = select_ordered(pp, specs, fleet, cache, &mut stats, {
        selection_order(pp.priority, specs, prios)
    });
    // Greedy accumulation can dead-end; retry once first-fit-decreasing
    // (mirrors ProgressivePlanner::select).
    if matches!(result, Err(PlanError::Oor { .. })) && pp.priority != Priority::ModelSizeDesc {
        result = select_ordered(pp, specs, fleet, cache, &mut stats, {
            selection_order(Priority::ModelSizeDesc, specs, prios)
        });
    }
    // Keep the planner's own search-effort diagnostic in sync.
    pp.candidates_scored.set(stats.candidates_scored);
    (result, stats)
}

// KEEP IN SYNC with `ProgressivePlanner::select_with_order`
// (orchestrator/progressive.rs): same Unsatisfiable check, same ledger/
// accumulator updates, same objective scoring with strict-`>` tie-break.
// The streaming path must stay allocation-free, so the loop exists twice;
// `tests::cached_selection_matches_streaming_selection` pins the parity —
// extend that test when touching either copy.
fn select_ordered(
    pp: &ProgressivePlanner,
    specs: &[PipelineSpec],
    fleet: &Fleet,
    cache: &PlanCache,
    stats: &mut ReplanStats,
    order: Vec<usize>,
) -> Result<CollabPlan, PlanError> {
    let lm = LatencyModel::new(fleet);
    let mut ledger = MemoryLedger::default();
    let mut accum = EstimateAccum::new(fleet);
    let mut selected: Vec<Option<ExecutionPlan>> = vec![None; specs.len()];
    // Scratch buffers reused across all candidate evaluations.
    let mut unit_scratch = Vec::with_capacity(16);

    for &i in &order {
        let spec = &specs[i];
        let sources = spec.source_candidates(fleet);
        let targets = spec.target_candidates(fleet);
        if sources.is_empty() || targets.is_empty() {
            return Err(PlanError::Unsatisfiable {
                pipeline: spec.name.clone(),
            });
        }
        let skeletons = cache.get(spec.id).expect("cache entry ensured above");
        let mut cand = ExecutionPlan {
            pipeline: spec.id,
            source_dev: sources[0],
            target_dev: targets[0],
            chunks: Vec::new(),
        };
        let mut best: Option<(f64, ExecutionPlan)> = None;
        for skel in skeletons {
            cand.chunks.clear();
            cand.chunks.extend_from_slice(skel);
            // Joint-memory fit is endpoint-independent: check once per
            // skeleton instead of once per enumerated plan.
            if !ledger.fits(&cand, &spec.model, fleet) {
                continue;
            }
            for &s in &sources {
                for &t in &targets {
                    cand.source_dev = s;
                    cand.target_dev = t;
                    stats.candidates_scored += 1;
                    let est = accum.peek_fast(&cand, spec, fleet, &lm, &mut unit_scratch);
                    let score = pp.objective.score(&est);
                    if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
                        best = Some((score, cand.clone()));
                    }
                }
            }
        }
        let (_, chosen) = best.ok_or_else(|| PlanError::Oor {
            pipeline: spec.name.clone(),
        })?;
        ledger.commit(&chosen, &spec.model);
        accum.add_plan(&chosen, spec, fleet, &lm);
        selected[i] = Some(chosen);
    }

    Ok(CollabPlan::new(
        selected.into_iter().map(Option::unwrap).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::orchestrator::Synergy;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::workload::{fleet_n, workload};

    fn any_pipes(models: &[ModelName]) -> Vec<PipelineSpec> {
        models
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                PipelineSpec::new(
                    i,
                    m.as_str(),
                    SourceReq::Any,
                    model_by_name(m).clone(),
                    TargetReq::Any,
                )
            })
            .collect()
    }

    #[test]
    fn cached_selection_matches_streaming_selection() {
        let pp = Synergy::planner();
        for fleet in [fleet_n(2), fleet_n(3)] {
            let ps = any_pipes(&[ModelName::KWS, ModelName::SimpleNet, ModelName::UNet]);
            let prios = vec![AppPriority::Normal; ps.len()];
            let mut cache = PlanCache::new();
            cache.sync_fleet(&fleet, pp.cfg);
            let (res, stats) = select_with_cache(&pp, &ps, &prios, &fleet, &mut cache);
            let cached = res.unwrap();
            let streamed = pp.select(&ps, &fleet).unwrap();
            assert_eq!(cached, streamed);
            assert_eq!(stats.enumerated_apps, 3);
            assert_eq!(stats.reused_apps, 0);
        }
    }

    #[test]
    fn suffix_shrink_keeps_cache_and_matches_fresh_enumeration() {
        let pp = Synergy::planner();
        let w = workload(1);
        let prios = vec![AppPriority::Normal; w.pipelines.len()];
        let mut cache = PlanCache::new();

        let big = fleet_n(5);
        cache.sync_fleet(&big, pp.cfg);
        let (res, _) = select_with_cache(&pp, &w.pipelines, &prios, &big, &mut cache);
        res.unwrap();

        // Device 4 leaves: the cache filters in place, no re-enumeration…
        let small = fleet_n(4);
        cache.sync_fleet(&small, pp.cfg);
        let (res, stats) = select_with_cache(&pp, &w.pipelines, &prios, &small, &mut cache);
        let incremental = res.unwrap();
        assert!(stats.incremental(), "{stats:?}");
        // …and the selected plan is identical to planning from scratch.
        assert_eq!(incremental, pp.select(&w.pipelines, &small).unwrap());
    }

    #[test]
    fn fleet_growth_invalidates_cache() {
        let pp = Synergy::planner();
        let ps = any_pipes(&[ModelName::KWS]);
        let prios = vec![AppPriority::Normal];
        let mut cache = PlanCache::new();
        cache.sync_fleet(&fleet_n(2), pp.cfg);
        select_with_cache(&pp, &ps, &prios, &fleet_n(2), &mut cache).0.unwrap();
        cache.sync_fleet(&fleet_n(3), pp.cfg);
        let (res, stats) = select_with_cache(&pp, &ps, &prios, &fleet_n(3), &mut cache);
        res.unwrap();
        assert_eq!(stats.enumerated_apps, 1, "growth must re-enumerate");
    }

    #[test]
    fn high_priority_app_plans_first() {
        // KWS (low data intensity) normally plans after UNet; High priority
        // regroups it to the front of the selection order.
        let ps = any_pipes(&[ModelName::KWS, ModelName::UNet]);
        let normal = selection_order(
            Priority::DataIntensityDesc,
            &ps,
            &[AppPriority::Normal, AppPriority::Normal],
        );
        assert_eq!(normal, vec![1, 0]);
        let boosted = selection_order(
            Priority::DataIntensityDesc,
            &ps,
            &[AppPriority::High, AppPriority::Normal],
        );
        assert_eq!(boosted, vec![0, 1]);
    }
}
