//! The unified runtime API: a [`SynergyRuntime`] session facade over the
//! device-agnostic programming interface (§IV-B).
//!
//! The paper's core interface promise is that apps describe *what* they
//! need (a sensor, a model, an interaction, a quality floor) and the
//! system decides *where* everything runs. This module is that surface:
//!
//! - [`SynergyRuntime`] owns the fleet, the planner, and the execution
//!   backend; [`RuntimeBuilder`] configures all three.
//! - [`AppBuilder`] registers apps fluently
//!   (`runtime.app("kws").source(Sensor::Microphone).model(ModelName::KWS)
//!   .target(Interaction::Haptic).qos(...).register()?`) and returns an
//!   [`AppHandle`] with lifecycle methods (`pause`, `resume`,
//!   `unregister`, `stats`).
//! - [`RuntimeError`] types every failure (no panics, no silent no-ops).
//! - [`RuntimeEvent`] streams orchestration to subscribers — device churn,
//!   replans, QoS degradations — instead of making apps poll. Events are
//!   [`StampedEvent`]s: sequence-numbered, and timestamped on the
//!   simulated timeline inside a session.
//! - Re-orchestration is *incremental*: per-app plan enumerations are
//!   cached and reused across app and fleet changes ([`replan`]).
//! - [`ExecutionBackend`] unifies simulated ([`SimBackend`]) and real
//!   PJRT ([`PjrtBackend`]) inference behind [`SynergyRuntime::run`].
//! - **Live sessions** ([`session`], [`scenario`]): a [`Scenario`] scripts
//!   timed churn (device departures, app arrivals, fleet reshapes, QoS
//!   changes, battery drains); [`SynergyRuntime::session`] replays it on
//!   the resumable DES with mid-run incremental replanning —
//!   [`Session::run_until`] / [`Session::inject`] / [`Session::finish`] —
//!   and reports a time series ([`SessionReport`]): per-interval
//!   throughput/latency/power per app, a plan-switch timeline, and
//!   QoS-violation spans.
//! - **Streaming serving** ([`Session::serve`], [`crate::serving`]): the
//!   same session re-seated on the multi-threaded streaming engine —
//!   worker threads rebind live at every plan switch, with the measured
//!   pause in the switch timeline and a conservation summary
//!   ([`ServeSummary`]) in the report.
//! - **Cross-user planning service** ([`shared_cache`]): many runtimes
//!   share one [`GlobalPlanCache`] — signature-equal planning problems
//!   reuse one bounded search across users
//!   ([`RuntimeBuilder::shared_plan_cache`], [`crate::population`]).

pub mod app;
pub mod backend;
pub mod core;
pub mod error;
pub mod events;
pub mod qos;
pub mod replan;
pub mod scenario;
pub mod session;
pub mod shared_cache;

mod runtime;

pub use self::app::{AppBuilder, AppHandle};
#[cfg(feature = "pjrt")]
pub use self::backend::PjrtBackend;
pub use self::backend::{AppRunStats, ExecutionBackend, RunConfig, RunReport, SimBackend};
pub use self::core::{AppStats, Deployment, RuntimeCore};
pub use self::error::RuntimeError;
pub use self::events::{EventSubscription, RuntimeEvent, StampedEvent};
pub use self::qos::{AppPriority, Qos, QosViolation};
pub use self::replan::ReplanStats;
pub use self::runtime::{RuntimeBuilder, RuntimeStats, SynergyRuntime};
pub use self::scenario::{Scenario, ScenarioAction, TimedAction};
pub use self::session::{
    AppInterval, Interval, PlanSwitch, QosSpan, ServeSummary, Session, SessionCfg, SessionReport,
    TracedReport,
};
pub use self::shared_cache::{GlobalPlanCache, PlanCacheStats};

// Capability vocabulary under the names the app interface reads best with:
// `.source(Sensor::Microphone)`, `.target(Interaction::Haptic)`.
pub use crate::device::{InteractionKind as Interaction, SensorKind as Sensor};

// Battery model config for `Scenario::battery_with` (the full subsystem
// lives in [`crate::power`]).
pub use crate::power::BatteryCfg;
