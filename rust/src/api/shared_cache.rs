//! The cross-user plan cache: one planning service shared by many
//! runtimes (see [`crate::population`]).
//!
//! A single [`super::SynergyRuntime`] owns exactly one fleet, so serving
//! N users naively costs N bounded plan searches — even when thousands of
//! bodies wear the same device shapes and run the same workloads. The
//! [`GlobalPlanCache`] memoizes selected [`CollabPlan`]s under a
//! *canonical signature* of the planning problem; signature-equal users
//! get the cached plan re-endpointed onto their concrete
//! [`crate::pipeline::PipelineId`]s ([`crate::plan::rebind_pipelines`])
//! instead of re-running the search. This is the PR-2 per-app skeleton
//! cache ([`super::replan::PlanCache`], private to one runtime)
//! generalized into a keyed global cache shared *across* runtimes.
//!
//! **Why a hit is exact, not approximate.** The signature covers
//! everything selection reads: the planner configuration (priority,
//! objective, search config, execution policy), each device's spec and
//! capability lists in fleet order (names excluded — planning never
//! reads them), and each active app's model, endpoint requirements, and
//! full QoS (including [`super::AppPriority`], which reorders the
//! greedy accumulation) in registration order. Selection itself is a
//! pure function of exactly those inputs, and its index-based orderings
//! make the result invariant to pipeline-id labels — so a rebound hit is
//! bit-equal to the fresh search it replaces (`tests/population.rs`
//! pins this plan-for-plan).
//!
//! **Concurrency.** Lookups and inserts take one non-poisoning mutex;
//! concurrent first lookups of the same signature may each miss and then
//! insert the identical plan (first insert wins — idempotent by the
//! purity above). That makes the raw hit *count* scheduling-dependent —
//! it lives in the cache's [`MetricsRegistry`] as the annex counter
//! `annex.plan_cache.raw_hits`, not in the deterministic inner state —
//! which is why [`PlanCacheStats::hit_rate`] is derived from the number
//! of *distinct signatures seen* instead: deterministic for a fixed user
//! set regardless of worker count.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::device::Fleet;
use crate::obs::{Counter, MetricsRegistry};
use crate::orchestrator::ProgressivePlanner;
use crate::pipeline::PipelineSpec;
use crate::plan::{digest_debug, CollabPlan};

use super::qos::Qos;

/// Deterministic cache counters (see [`GlobalPlanCache::stats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Total lookups — one per progressive orchestration that consulted
    /// the cache. Deterministic for a fixed user set.
    pub lookups: u64,
    /// Raw hits. Scheduling-dependent under a worker pool (racing first
    /// lookups of one signature may all miss); use [`Self::hit_rate`]
    /// for a deterministic figure.
    pub hits: u64,
    /// Distinct signatures ever looked up. Deterministic: a fixed user
    /// set produces a fixed signature set, whatever the interleaving.
    pub unique_signatures: usize,
    /// Plans resident in the cache (successful selections only).
    pub unique_plans: usize,
}

impl PlanCacheStats {
    /// Deterministic hit rate: every distinct signature is charged
    /// exactly one miss (its first search), every other lookup of it is
    /// a hit — `1 − unique_signatures / lookups`. Equals the raw
    /// `hits / lookups` on a single worker; unlike it, identical across
    /// worker-pool sizes.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        1.0 - (self.unique_signatures.min(self.lookups as usize) as f64 / self.lookups as f64)
    }
}

struct CacheInner {
    plans: BTreeMap<String, CollabPlan>,
    seen: BTreeSet<String>,
    lookups: u64,
}

/// The shared, keyed plan store (see the module docs). Construct one,
/// wrap it in an `Arc`, and hand clones to
/// [`super::RuntimeBuilder::shared_plan_cache`].
///
/// Deterministic counters (lookups, distinct signatures) live in the
/// locked inner state; the scheduling-dependent raw hit count is an
/// atomic [`Counter`] in the cache's [`MetricsRegistry`], under the
/// annex prefix so determinism comparisons scrub it.
pub struct GlobalPlanCache {
    inner: Mutex<CacheInner>,
    metrics: MetricsRegistry,
    raw_hits: Arc<Counter>,
}

impl GlobalPlanCache {
    pub fn new() -> GlobalPlanCache {
        let metrics = MetricsRegistry::new();
        let raw_hits = metrics.counter("annex.plan_cache.raw_hits");
        GlobalPlanCache {
            inner: Mutex::new(CacheInner {
                plans: BTreeMap::new(),
                seen: BTreeSet::new(),
                lookups: 0,
            }),
            metrics,
            raw_hits,
        }
    }

    /// Non-poisoning lock: a panicking user session must not wedge every
    /// other user of the service.
    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        }
    }

    /// Look up a signature, counting the lookup. Returns a clone of the
    /// cached plan (callers rebind it onto their own pipeline ids).
    pub(crate) fn lookup(&self, key: &str) -> Option<CollabPlan> {
        let mut g = self.lock();
        g.lookups += 1;
        if !g.seen.contains(key) {
            g.seen.insert(key.to_string());
        }
        let hit = g.plans.get(key).cloned();
        if hit.is_some() {
            self.raw_hits.inc();
        }
        hit
    }

    /// Insert a freshly selected plan. First insert wins — concurrent
    /// duplicate misses insert the identical plan (selection is pure),
    /// so the stored value is the same either way.
    pub(crate) fn insert(&self, key: String, plan: CollabPlan) {
        let mut g = self.lock();
        g.plans.entry(key).or_insert(plan);
    }

    /// Counter snapshot. `hits` is read back from the annex metrics
    /// counter — racy under a worker pool, deterministic single-threaded.
    pub fn stats(&self) -> PlanCacheStats {
        let g = self.lock();
        PlanCacheStats {
            lookups: g.lookups,
            hits: self.raw_hits.get(),
            unique_signatures: g.seen.len(),
            unique_plans: g.plans.len(),
        }
    }

    /// The cache's metrics registry (holds `annex.plan_cache.raw_hits`).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }
}

impl Default for GlobalPlanCache {
    fn default() -> GlobalPlanCache {
        GlobalPlanCache::new()
    }
}

/// Canonical signature of one planning problem: planner configuration,
/// fleet shape/capabilities, and the active apps' models + endpoint
/// requirements + QoS in registration order (see the module docs for the
/// exactness argument). Per-model and per-device `Debug` renderings are
/// collapsed to streamed FNV-1a digests so keys stay small (~100 bytes)
/// even for deep model graphs.
pub(crate) fn plan_signature(
    pp: &ProgressivePlanner,
    active: &[PipelineSpec],
    qos: &[Qos],
    fleet: &Fleet,
) -> String {
    debug_assert_eq!(active.len(), qos.len(), "one QoS per active app");
    let mut key = String::with_capacity(128 + 24 * (fleet.len() + active.len()));
    pp.signature_token(&mut key);
    let _ = write!(key, "|fleet{}[", fleet.len());
    for d in &fleet.devices {
        let _ = write!(
            key,
            "{:016x};",
            digest_debug(&(&d.spec, &d.sensors, &d.interactions))
        );
    }
    key.push(']');
    let _ = write!(key, "|apps{}[", active.len());
    for (spec, q) in active.iter().zip(qos) {
        let _ = write!(
            key,
            "{:016x}:{:?}:{:?}:{:?};",
            digest_debug(&spec.model),
            spec.source,
            spec.target,
            q
        );
    }
    key.push(']');
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::AppPriority;
    use crate::device::DeviceId;
    use crate::orchestrator::Synergy;
    use crate::plan::ExecutionPlan;
    use crate::workload::{fleet4, fleet8, workload};

    fn sig(pp: &ProgressivePlanner, fleet: &Fleet) -> String {
        let w = workload(1).unwrap();
        let qos: Vec<Qos> = w.pipelines.iter().map(|_| Qos::default()).collect();
        plan_signature(pp, &w.pipelines, &qos, fleet)
    }

    #[test]
    fn signature_is_stable_and_separates_planning_inputs() {
        let pp = Synergy::planner_bounded(8);
        let base = sig(&pp, &fleet4());
        assert_eq!(base, sig(&pp, &fleet4()), "same inputs, same key");
        assert_ne!(base, sig(&pp, &fleet8()), "fleet shape is in the key");
        assert_ne!(base, sig(&Synergy::planner_bounded(4), &fleet4()), "beam is in the key");
        assert_ne!(base, sig(&Synergy::planner(), &fleet4()), "search mode is in the key");
    }

    #[test]
    fn qos_and_app_order_are_in_the_key() {
        let pp = Synergy::planner_bounded(8);
        let w = workload(1).unwrap();
        let f = fleet4();
        let default_qos: Vec<Qos> = w.pipelines.iter().map(|_| Qos::default()).collect();
        let base = plan_signature(&pp, &w.pipelines, &default_qos, &f);

        // Priority reorders the greedy accumulation, so it must miss.
        let mut hot = default_qos.clone();
        hot[0].priority = AppPriority::High;
        assert_ne!(base, plan_signature(&pp, &w.pipelines, &hot, &f));

        // Registration order is part of the problem, not a label.
        if w.pipelines.len() >= 2 {
            let mut swapped = w.pipelines.clone();
            swapped.swap(0, 1);
            assert_ne!(base, plan_signature(&pp, &swapped, &default_qos, &f));
        }
    }

    #[test]
    fn device_names_and_pipeline_ids_are_labels_not_inputs() {
        let pp = Synergy::planner_bounded(8);
        let w = workload(1).unwrap();
        let qos: Vec<Qos> = w.pipelines.iter().map(|_| Qos::default()).collect();
        let f = fleet4();
        let mut renamed = f.clone();
        for d in &mut renamed.devices {
            d.name = format!("user7-{}", d.name);
        }
        let base = plan_signature(&pp, &w.pipelines, &qos, &f);
        assert_eq!(base, plan_signature(&pp, &w.pipelines, &qos, &renamed));

        let mut relabeled = w.pipelines.clone();
        for (i, p) in relabeled.iter_mut().enumerate() {
            p.id = crate::pipeline::PipelineId(100 + i);
        }
        assert_eq!(base, plan_signature(&pp, &relabeled, &qos, &f));
    }

    #[test]
    fn cache_counts_deterministic_signatures_not_racy_hits() {
        let cache = GlobalPlanCache::new();
        let plan = CollabPlan::new(vec![ExecutionPlan::monolithic(
            &workload(1).unwrap().pipelines[0],
            DeviceId(0),
            DeviceId(0),
            DeviceId(0),
        )]);
        assert!(cache.lookup("k1").is_none());
        cache.insert("k1".into(), plan.clone());
        assert_eq!(cache.lookup("k1").as_ref(), Some(&plan));
        assert!(cache.lookup("k2").is_none());
        // Duplicate insert keeps the first value (idempotent).
        cache.insert("k1".into(), plan.clone());
        let s = cache.stats();
        assert_eq!((s.lookups, s.hits), (3, 1));
        assert_eq!((s.unique_signatures, s.unique_plans), (2, 1));
        // 3 lookups over 2 distinct signatures: 1/3 deterministic rate.
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn raw_hits_live_in_the_annex_metrics_counter() {
        let cache = GlobalPlanCache::new();
        let plan = CollabPlan::new(vec![ExecutionPlan::monolithic(
            &workload(1).unwrap().pipelines[0],
            DeviceId(0),
            DeviceId(0),
            DeviceId(0),
        )]);
        cache.insert("k".into(), plan);
        cache.lookup("k");
        cache.lookup("k");
        let snap = cache.metrics().snapshot();
        assert_eq!(snap.counter("annex.plan_cache.raw_hits"), Some(2));
        // Scrubbing the annex removes the racy figure entirely.
        let mut scrubbed = snap.clone();
        scrubbed.scrub_annex();
        assert_eq!(scrubbed.counter("annex.plan_cache.raw_hits"), None);
    }
}
