//! The runtime core: app registry, fleet, deployment, and event-driven
//! re-orchestration.
//!
//! `RuntimeCore` is the planner-agnostic heart shared by the public
//! [`crate::api::SynergyRuntime`] facade and the
//! [`crate::coordinator::Moderator`] compatibility shim. It owns the app
//! entries (spec + QoS + paused flag), the fleet, the current
//! [`Deployment`], the incremental plan cache, and the event bus; every
//! mutation that changes the set of active apps or the fleet triggers
//! exactly one re-orchestration (§III-C).

use std::sync::Arc;

use crate::device::{Device, DeviceId, Fleet};
use crate::estimator::{estimate_plan, LatencyModel, PlanEstimate};
use crate::orchestrator::Planner;
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::{rebind_pipelines, CollabPlan, ExecutionPlan};
use crate::scheduler::{simulate, GroundTruth, Policy, SimReport};

use super::error::RuntimeError;
use super::events::{EventBus, EventSubscription, RuntimeEvent};
use super::qos::{Qos, QosViolation};
use super::replan::{select_with_cache, PlanCache, ReplanStats};
use super::shared_cache::{plan_signature, GlobalPlanCache};

/// A selected + checked holistic collaboration plan, ready to deploy.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub plan: CollabPlan,
    pub policy: Policy,
    pub estimate: PlanEstimate,
}

/// Per-app view of the current deployment (see [`super::AppHandle::stats`]).
#[derive(Clone, Debug)]
pub struct AppStats {
    pub app: PipelineId,
    pub name: String,
    pub paused: bool,
    pub qos: Qos,
    /// The app's execution plan within the active deployment.
    pub plan: Option<ExecutionPlan>,
    /// Estimated steady-state per-app inference rate, Hz.
    pub est_rate_hz: Option<f64>,
    /// Estimated end-to-end latency (sense start → interact end), seconds.
    pub est_latency_s: Option<f64>,
    /// How the current estimate falls short of the QoS hints, if it does.
    pub qos_violation: Option<QosViolation>,
}

struct AppEntry {
    spec: PipelineSpec,
    qos: Qos,
    paused: bool,
}

/// The planner-agnostic runtime core.
pub struct RuntimeCore {
    fleet: Fleet,
    apps: Vec<AppEntry>,
    /// Specs covered by the current deployment (registration order,
    /// paused apps excluded); index-aligned with `deployment.plan.plans`.
    active: Vec<PipelineSpec>,
    /// High-water mark for auto-assigned ids (never reused, so stale
    /// cloned handles of unregistered apps cannot alias a new app; a
    /// caller who pins ids explicitly manages that aliasing themselves).
    next_id: usize,
    deployment: Option<Deployment>,
    cache: PlanCache,
    events: EventBus,
    orchestrations: usize,
    last_replan: Option<ReplanStats>,
    cache_hits: usize,
    enumerations: usize,
    /// Cross-user planning service, if this runtime participates in one
    /// (see [`super::shared_cache`]).
    shared_cache: Option<Arc<GlobalPlanCache>>,
}

impl RuntimeCore {
    pub fn new(fleet: Fleet) -> RuntimeCore {
        RuntimeCore {
            fleet,
            apps: Vec::new(),
            active: Vec::new(),
            next_id: 0,
            deployment: None,
            cache: PlanCache::new(),
            events: EventBus::default(),
            orchestrations: 0,
            last_replan: None,
            cache_hits: 0,
            enumerations: 0,
            shared_cache: None,
        }
    }

    /// Join a cross-user planning service: progressive orchestrations
    /// consult (and feed) the shared cache before running bounded search.
    pub(crate) fn set_shared_cache(&mut self, cache: Arc<GlobalPlanCache>) {
        self.shared_cache = Some(cache);
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Specs in the current deployment (paused apps excluded).
    pub fn active_apps(&self) -> &[PipelineSpec] {
        &self.active
    }

    /// QoS hints index-aligned with [`Self::active_apps`] (session QoS
    /// span tracking).
    pub(crate) fn active_qos(&self) -> Vec<Qos> {
        self.active
            .iter()
            .map(|spec| {
                self.apps
                    .iter()
                    .find(|a| a.spec.id == spec.id)
                    .map(|a| a.qos)
                    .unwrap_or_default()
            })
            .collect()
    }

    pub fn deployment(&self) -> Option<&Deployment> {
        self.deployment.as_ref()
    }

    /// Orchestrations performed (every app/fleet change triggers exactly
    /// one).
    pub fn orchestrations(&self) -> usize {
        self.orchestrations
    }

    /// Enumeration bookkeeping of the most recent replan.
    pub fn last_replan(&self) -> Option<ReplanStats> {
        self.last_replan
    }

    /// Cumulative (cache-hit, enumeration) app counts across all replans.
    pub fn cache_counters(&self) -> (usize, usize) {
        (self.cache_hits, self.enumerations)
    }

    pub fn subscribe(&mut self) -> EventSubscription {
        self.events.subscribe()
    }

    /// Stamp subsequent events with a simulated-timeline time (sessions
    /// set this around scenario-event application, and clear it after).
    pub(crate) fn set_event_clock(&mut self, t: Option<f64>) {
        self.events.set_clock(t);
    }

    /// One past the largest pipeline id ever registered (for builder
    /// auto-assignment). Auto-assigned ids are never reused, so a stale
    /// handle of an unregistered auto-id app can never act on a later
    /// app; explicitly pinned ids ([`super::AppBuilder::id`]) opt out of
    /// that guarantee.
    pub fn next_app_id(&self) -> usize {
        self.next_id
    }

    fn entry(&self, id: PipelineId) -> Result<usize, RuntimeError> {
        self.apps
            .iter()
            .position(|a| a.spec.id == id)
            .ok_or(RuntimeError::UnknownApp(id))
    }

    /// Register an app; triggers one re-orchestration. Registration is
    /// atomic: on planning failure the app is rolled back and the previous
    /// deployment stays in place.
    pub fn register(
        &mut self,
        spec: PipelineSpec,
        qos: Qos,
        planner: &dyn Planner,
    ) -> Result<(), RuntimeError> {
        if self.apps.iter().any(|a| a.spec.id == spec.id) {
            return Err(RuntimeError::DuplicateApp(spec.id));
        }
        let id = spec.id;
        self.apps.push(AppEntry { spec, qos, paused: false });
        if let Err(e) = self.orchestrate(planner) {
            self.apps.pop();
            self.cache.invalidate_app(id);
            // `active` still lists the failed app; rebuild it.
            self.rebuild_active();
            return Err(e);
        }
        self.next_id = self.next_id.max(id.0 + 1);
        self.events.emit(RuntimeEvent::AppRegistered { app: id });
        Ok(())
    }

    /// Remove an app; triggers one re-orchestration (deployment cleared
    /// when no active apps remain). Unknown ids are a typed error, not a
    /// silent no-op.
    pub fn remove(&mut self, id: PipelineId, planner: &dyn Planner) -> Result<(), RuntimeError> {
        let idx = self.entry(id)?;
        self.apps.remove(idx);
        self.cache.invalidate_app(id);
        self.events.emit(RuntimeEvent::AppUnregistered { app: id });
        if let Err(e) = self.orchestrate(planner) {
            // The stale deployment still covers the removed app — drop it.
            self.deployment = None;
            return Err(e);
        }
        Ok(())
    }

    /// Pause or resume an app; triggers one re-orchestration over the new
    /// active set. Reverted on planning failure.
    pub fn set_paused(
        &mut self,
        id: PipelineId,
        paused: bool,
        planner: &dyn Planner,
    ) -> Result<(), RuntimeError> {
        let idx = self.entry(id)?;
        if self.apps[idx].paused == paused {
            return Ok(());
        }
        self.apps[idx].paused = paused;
        if let Err(e) = self.orchestrate(planner) {
            self.apps[idx].paused = !paused;
            self.rebuild_active();
            return Err(e);
        }
        self.events.emit(if paused {
            RuntimeEvent::AppPaused { app: id }
        } else {
            RuntimeEvent::AppResumed { app: id }
        });
        Ok(())
    }

    /// Update an app's QoS hints; triggers one re-orchestration (priority
    /// classes reorder progressive selection). Reverted on planning
    /// failure.
    pub fn set_qos(
        &mut self,
        id: PipelineId,
        qos: Qos,
        planner: &dyn Planner,
    ) -> Result<(), RuntimeError> {
        let idx = self.entry(id)?;
        let old = self.apps[idx].qos;
        if old == qos {
            return Ok(());
        }
        self.apps[idx].qos = qos;
        if let Err(e) = self.orchestrate(planner) {
            self.apps[idx].qos = old;
            return Err(e);
        }
        self.events.emit(RuntimeEvent::QosUpdated { app: id });
        Ok(())
    }

    /// A device joined the body. Its id must extend the fleet densely
    /// (`id == fleet.len()`); triggers one re-orchestration.
    pub fn device_joined(
        &mut self,
        device: Device,
        planner: &dyn Planner,
    ) -> Result<(), RuntimeError> {
        if device.id.0 != self.fleet.len() {
            return Err(RuntimeError::FleetChange(format!(
                "joined device id {} must extend the dense fleet (expected d{})",
                device.id,
                self.fleet.len()
            )));
        }
        let mut devices = self.fleet.devices.clone();
        devices.push(device);
        self.set_fleet(Fleet::new(devices), planner)
    }

    /// A device left the body. Device ids are dense, so only the
    /// highest-id device can depart without renumbering; replan over an
    /// arbitrarily reshaped fleet via [`Self::set_fleet`]. Departure of a
    /// suffix device keeps the plan-enumeration cache warm — the replan is
    /// incremental.
    pub fn device_left(
        &mut self,
        id: DeviceId,
        planner: &dyn Planner,
    ) -> Result<(), RuntimeError> {
        let n = self.fleet.len();
        if n == 0 || id.0 != n - 1 {
            return Err(RuntimeError::FleetChange(format!(
                "device ids are dense: only the last device (d{}) can leave; \
                 use set_fleet for arbitrary reshapes",
                n.saturating_sub(1)
            )));
        }
        let mut devices = self.fleet.devices.clone();
        devices.pop();
        self.set_fleet(Fleet::new(devices), planner)
    }

    /// Replace the fleet (device churn); emits join/leave events and
    /// triggers one re-orchestration. On planning failure the stale
    /// deployment is cleared (it may reference departed devices). An id
    /// whose platform changed in place (e.g. a MAX78002 upgrade) emits a
    /// leave followed by a join for that id.
    pub fn set_fleet(&mut self, fleet: Fleet, planner: &dyn Planner) -> Result<(), RuntimeError> {
        let (old, new) = (self.fleet.len(), fleet.len());
        for i in new..old {
            self.events.emit(RuntimeEvent::DeviceLeft {
                device: crate::device::DeviceId(i),
            });
        }
        for i in 0..old.min(new) {
            let (a, b) = (&self.fleet.devices[i], &fleet.devices[i]);
            if a.spec != b.spec || a.sensors != b.sensors || a.interactions != b.interactions {
                self.events.emit(RuntimeEvent::DeviceLeft {
                    device: crate::device::DeviceId(i),
                });
                self.events.emit(RuntimeEvent::DeviceJoined {
                    device: crate::device::DeviceId(i),
                });
            }
        }
        for i in old..new {
            self.events.emit(RuntimeEvent::DeviceJoined {
                device: crate::device::DeviceId(i),
            });
        }
        self.fleet = fleet;
        if let Err(e) = self.orchestrate(planner) {
            self.deployment = None;
            return Err(e);
        }
        Ok(())
    }

    fn rebuild_active(&mut self) {
        self.active = self
            .apps
            .iter()
            .filter(|a| !a.paused)
            .map(|a| a.spec.clone())
            .collect();
    }

    /// Run holistic orchestration over the active apps + fleet. Uses the
    /// incremental path when the planner exposes a progressive
    /// configuration; leaves the previous deployment untouched on failure.
    pub fn orchestrate(&mut self, planner: &dyn Planner) -> Result<(), RuntimeError> {
        self.rebuild_active();
        if self.active.is_empty() {
            self.deployment = None;
            return Ok(());
        }
        self.orchestrations += 1;
        let qos_list = self.active_qos();

        let (plan, stats) = if let Some(pp) = planner.as_progressive() {
            // Cross-user service: signature-equal planning problems share
            // one bounded search (see [`super::shared_cache`] for why a
            // rebound hit is bit-equal to the search it replaces).
            let key = self
                .shared_cache
                .as_ref()
                .map(|_| plan_signature(pp, &self.active, &qos_list, &self.fleet));
            let hit = match (&self.shared_cache, &key) {
                (Some(cache), Some(key)) => cache.lookup(key),
                _ => None,
            };
            if let Some(cached) = hit {
                let ids: Vec<PipelineId> = self.active.iter().map(|s| s.id).collect();
                let plan = rebind_pipelines(&cached, &ids);
                // The per-runtime skeleton cache is left stale on a hit; it
                // re-syncs at the next shared miss. Every app rode the
                // shared plan, so all count as reused.
                let stats = ReplanStats {
                    reused_apps: self.active.len(),
                    ..ReplanStats::default()
                };
                (plan, stats)
            } else {
                self.cache.sync_fleet(&self.fleet, pp.cfg);
                let prios: Vec<_> = self
                    .apps
                    .iter()
                    .filter(|a| !a.paused)
                    .map(|a| a.qos.priority)
                    .collect();
                let (res, stats) =
                    select_with_cache(pp, &self.active, &prios, &self.fleet, &mut self.cache);
                let plan = res?;
                if let (Some(cache), Some(key)) = (&self.shared_cache, key) {
                    cache.insert(key, plan.clone());
                }
                (plan, stats)
            }
        } else {
            let plan = planner.plan(&self.active, &self.fleet)?;
            let stats = ReplanStats {
                enumerated_apps: self.active.len(),
                ..ReplanStats::default()
            };
            (plan, stats)
        };
        // Every plan the orchestrator commits must pass full static
        // verification (shape connectivity, ghost devices, double-booking,
        // joint memory fit) — a failure here is a planner bug. Debug-only;
        // compiles out of release builds.
        crate::analysis::debug_verify_deployment(&plan, &self.active, &self.fleet);

        let lm = LatencyModel::new(&self.fleet);
        let estimate = estimate_plan(&plan, &self.active, &self.fleet, &lm);
        self.cache_hits += stats.reused_apps;
        self.enumerations += stats.enumerated_apps;
        self.last_replan = Some(stats);

        // QoS degradation notifications: each app completes once per
        // unified round, so per-app rate = system throughput / #apps.
        let per_app_rate = estimate.throughput / self.active.len() as f64;
        for (i, spec) in self.active.iter().enumerate() {
            if let Some(violation) = qos_list[i].check(per_app_rate, estimate.chain_latency[i]) {
                self.events.emit(RuntimeEvent::PlanDegraded {
                    app: spec.id,
                    violation,
                });
            }
        }

        self.events.emit(RuntimeEvent::Replanned {
            orchestration: self.orchestrations,
            apps: self.active.len(),
            incremental: stats.incremental(),
            throughput: estimate.throughput,
        });
        self.deployment = Some(Deployment {
            plan,
            policy: planner.exec_policy(),
            estimate,
        });
        Ok(())
    }

    /// Per-app deployment view.
    pub fn app_stats(&self, id: PipelineId) -> Result<AppStats, RuntimeError> {
        let entry = &self.apps[self.entry(id)?];
        let active_idx = self.active.iter().position(|s| s.id == id);
        let (plan, est_rate, est_latency) = match (&self.deployment, active_idx) {
            (Some(dep), Some(i)) => (
                dep.plan.plans.iter().find(|p| p.pipeline == id).cloned(),
                Some(dep.estimate.throughput / self.active.len() as f64),
                Some(dep.estimate.chain_latency[i]),
            ),
            _ => (None, None, None),
        };
        let qos_violation = match (est_rate, est_latency) {
            (Some(r), Some(l)) => entry.qos.check(r, l),
            _ => None,
        };
        Ok(AppStats {
            app: id,
            name: entry.spec.name.clone(),
            paused: entry.paused,
            qos: entry.qos,
            plan,
            est_rate_hz: est_rate,
            est_latency_s: est_latency,
            qos_violation,
        })
    }

    /// Execute the current deployment on the simulated hardware.
    pub fn simulate(&self, runs: usize, seed: u64) -> Option<SimReport> {
        let dep = self.deployment.as_ref()?;
        let gt = GroundTruth::with_seed(seed);
        Some(simulate(
            &dep.plan,
            &self.active,
            &self.fleet,
            &gt,
            super::backend::sim_config(runs, dep.policy),
        ))
    }
}
