//! Live sessions: scenario-driven execution with mid-run replanning and
//! time-series reports.
//!
//! A [`Session`] drives an execution engine through a [`super::Scenario`]
//! of timed churn events. At each event the session mutates the shared
//! runtime core (the same registry/fleet/deployment the
//! [`super::SynergyRuntime`] handles see), replans incrementally using the
//! cached per-app enumerations, and swaps the new plan into the engine —
//! *inside* the timeline, carrying the clock, in-flight work, and energy
//! accounting across the switch. The one-shot
//! [`super::SynergyRuntime::run`] is the degenerate case: one plan, no
//! events.
//!
//! Two engines can sit under a session:
//!
//! - the resumable discrete-event simulator
//!   ([`crate::scheduler::SimEngine`]) — the default; and
//! - the multi-threaded streaming engine
//!   ([`crate::serving::ServeEngine`]) via [`Session::serve`] — real
//!   worker threads, deterministic per-unit merges, and live plan
//!   rebinding with a measured switch pause. On the virtual-time
//!   executor its per-app throughput tracks the simulator within a few
//!   percent on the same plans, which is what makes the two paths
//!   directly comparable.
//!
//! ```text
//! let scenario = Scenario::new().at(3.0).device_left(4).until(8.0);
//! let mut session = runtime.session(scenario)?;      // DES…
//! // …or: let mut session = runtime.session(scenario)?.serve(ServeCfg::default())?;
//! session.run_until(5.0)?;                 // drive in segments…
//! session.inject(ScenarioAction::Pause(app))?;  // …or improvise
//! let report = session.finish()?;          // time-series report
//! ```
//!
//! **Energy and batteries** ride the shared [`crate::power`] subsystem on
//! *both* engines: the simulator integrates as it executes; the streaming
//! engine replays its workers' busy spans at finish — so served sessions
//! report real `power_w`/`energy_j`, and sim-vs-serve energy agrees on
//! identical plans. Battery ramps ([`super::Scenario::battery`]) are
//! *event-driven*: each battery drains at the deployed plan's modeled
//! per-device draw, the exact depletion instant is scheduled as a
//! timeline event (recomputed at every switch, churn, or
//! [`super::ScenarioAction::Recharge`]), and depletion triggers a
//! `battery-depleted(dN)` plan switch — with instants independent of any
//! poll granularity and identical across the two engines.
//!
//! Reports are time series: one [`Interval`] per inter-event segment with
//! per-app throughput/latency and power, a [`PlanSwitch`] timeline with
//! measured replan latencies (plus worker rebind pauses when serving),
//! and [`QosSpan`]s marking when an app's deployed estimate violated its
//! hints. Interval statistics aggregate *streamingly* as rounds complete,
//! so [`SessionCfg::trace_window`] bounds retained memory without
//! corrupting intervals older than the window. Replayed scenarios are
//! deterministic on both engines: everything except the wall-clock
//! `replan_wall_s`/`rebind_wall_s` compares equal.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::analysis::{debug_verify_deployment, SameTimePolicy};
use crate::device::{DeviceId, Fleet};
use crate::obs::{self, FlightRecording, MetricsRegistry, MetricsSnapshot};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::CollabPlan;
use crate::power::{plan_device_draw, BatteryManager, BusySpan, EnergyReplay};
use crate::scheduler::{GroundTruth, RoundRecord, SimEngine, Trace};
use crate::serving::{ChunkExecutor, ServeCfg, ServeEngine, VirtualExecutor};

use super::error::RuntimeError;
use super::qos::{Qos, QosViolation};
use super::replan::ReplanStats;
use super::runtime::{lock_shared, Shared};
use super::scenario::{Scenario, ScenarioAction, TimedAction};

/// Session configuration (see [`super::SynergyRuntime::session_with`]).
#[derive(Clone, Copy, Debug)]
pub struct SessionCfg {
    /// Seed for the ground-truth jitter stream.
    pub seed: u64,
    /// Record a full task trace into the report — on both engines: the
    /// DES keeps its execution trace, a served session reconstructs the
    /// identical-schema trace from the engine's post-hoc task spans.
    pub record_trace: bool,
    /// Ring window over retained trace spans: keep only the most recent
    /// `n`, so hour-scale traced sessions stay bounded in memory.
    /// Interval statistics aggregate streamingly and are *not* affected
    /// by the window; totals ([`SessionReport::completions`]) keep
    /// counting too. `None` (default) retains everything.
    pub trace_window: Option<usize>,
    /// How the DES orders simultaneously-ready events
    /// ([`crate::analysis::SameTimePolicy`]) — the race-exploration knob.
    /// Served sessions take theirs from [`ServeCfg::same_time`].
    pub same_time: SameTimePolicy,
}

impl Default for SessionCfg {
    fn default() -> SessionCfg {
        SessionCfg {
            seed: 42,
            record_trace: false,
            trace_window: None,
            same_time: SameTimePolicy::Deterministic,
        }
    }
}

/// One plan switch on the session timeline.
#[derive(Clone, Debug)]
pub struct PlanSwitch {
    /// Simulated time the causing event fired.
    pub t: f64,
    /// Deterministic cause label (see
    /// [`super::ScenarioAction::describe`]); battery depletions report
    /// `battery-depleted(dN)`.
    pub cause: String,
    /// Apps in the new active plan (0 = deployment cleared).
    pub apps: usize,
    /// Whether the replan was served entirely from the enumeration cache.
    pub incremental: bool,
    /// Apps served from the cache / re-enumerated by this replan.
    pub reused_apps: usize,
    pub enumerated_apps: usize,
    /// The new plan's estimated system throughput, inf/s (0 when the
    /// deployment cleared).
    pub est_throughput: f64,
    /// Measured wall-clock replan latency, seconds. Wall clock — excluded
    /// from replay comparisons.
    pub replan_wall_s: f64,
    /// Measured wall-clock pause to rebind the streaming engine's workers
    /// to the new deployment (0 on simulator sessions). Wall clock —
    /// excluded from replay comparisons.
    pub rebind_wall_s: f64,
}

/// A span of the timeline during which an app's deployed estimate
/// violated its QoS hints.
#[derive(Clone, Debug)]
pub struct QosSpan {
    pub app: PipelineId,
    pub name: String,
    pub violation: QosViolation,
    pub start: f64,
    /// Span end (the session end if still violating at finish).
    pub end: f64,
}

/// Per-app slice of one report interval.
#[derive(Clone, Debug)]
pub struct AppInterval {
    pub app: PipelineId,
    pub name: String,
    /// Rounds completed within the interval.
    pub completions: usize,
    /// Completions per second of interval time.
    pub throughput: f64,
    /// Mean end-to-end latency of the interval's rounds, seconds.
    pub mean_latency_s: f64,
}

/// Measured behavior between two timeline boundaries (session start,
/// scenario events, session end).
#[derive(Clone, Debug)]
pub struct Interval {
    pub start: f64,
    pub end: f64,
    /// Rounds completed in the interval, all apps.
    pub completions: usize,
    /// System throughput over the interval, inf/s.
    pub throughput: f64,
    /// Mean end-to-end latency over the interval's rounds, seconds
    /// (0 when nothing completed).
    pub avg_latency_s: f64,
    /// Mean power draw over the interval, watts — on both engines (the
    /// streaming engine integrates its workers' busy spans through the
    /// same accountant the DES uses).
    pub power_w: f64,
    /// State of charge of every armed battery at the interval's *end*
    /// boundary, `(device, remaining joules)` sorted by device id —
    /// engine-independent (the drain model is closed-form), so cascade
    /// scenarios plot directly from the report without hand-sampling
    /// [`Session::battery_remaining_j`]. Empty when the scenario
    /// declares no batteries.
    pub battery_j: Vec<(DeviceId, f64)>,
    pub per_app: Vec<AppInterval>,
}

/// Streaming-engine summary attached to served sessions
/// ([`Session::serve`]); `None` on simulator sessions.
#[derive(Clone, Copy, Debug)]
pub struct ServeSummary {
    /// Executor that ran the chunks (`"virtual"`, `"pjrt"`).
    pub executor: &'static str,
    /// Rounds admitted by the per-app tickers across all epochs.
    pub admitted_rounds: usize,
    /// Rounds completed, including those that drained past the session
    /// horizon. Equal to `admitted_rounds` — the conservation invariant
    /// across plan switches — unless an executor fault cut the run short.
    pub completed_rounds: usize,
    /// Plan rebinds performed (including the initial binding).
    pub rebinds: usize,
    /// Worker threads the engine ran.
    pub workers: usize,
}

/// The session's time-series report.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Session horizon, simulated seconds.
    pub duration: f64,
    /// Rounds completed across the whole session (within the horizon).
    pub completions: usize,
    /// Whole-session throughput, inf/s.
    pub throughput: f64,
    /// Total energy over the horizon, joules (simulated and served
    /// sessions alike).
    pub energy_j: f64,
    /// Mean power over the horizon, watts.
    pub power_w: f64,
    /// Per-segment time series (one entry per inter-event interval).
    pub intervals: Vec<Interval>,
    /// Plan-switch timeline with replan latencies.
    pub switches: Vec<PlanSwitch>,
    /// QoS-violation spans.
    pub qos_spans: Vec<QosSpan>,
    /// Full task trace when requested via [`SessionCfg::record_trace`].
    /// Both engines fill it with the same schema: the DES records spans
    /// as it executes, a served session sorts the workers' post-hoc task
    /// spans into chronological order at [`Session::finish`].
    pub trace: Option<Trace>,
    /// Streaming-engine summary when the session ran on
    /// [`Session::serve`].
    pub served: Option<ServeSummary>,
}

impl SessionReport {
    /// One device's state-of-charge series over the interval end
    /// boundaries, `(t, remaining joules)` — the plottable per-battery
    /// view of [`Interval::battery_j`]. Entries stop once the device's
    /// battery departs (depletion or scripted departure).
    pub fn battery_series(&self, device: DeviceId) -> Vec<(f64, f64)> {
        self.intervals
            .iter()
            .filter_map(|iv| {
                iv.battery_j
                    .iter()
                    .find(|&&(d, _)| d == device)
                    .map(|&(_, j)| (iv.end, j))
            })
            .collect()
    }
}

/// A finished session with its flight recording and metrics snapshot —
/// what [`Session::finish_traced`] returns. Export the recording with
/// [`crate::obs::to_chrome_json`] (Perfetto / `chrome://tracing`) and
/// the metrics with [`MetricsSnapshot::to_json`].
#[derive(Clone, Debug)]
pub struct TracedReport {
    /// The ordinary time-series report ([`Session::finish`]).
    pub report: SessionReport,
    /// The session timeline as trace events: switch/depletion instants,
    /// QoS spans, power/battery counter tracks, per-(device, unit) task
    /// or busy spans.
    pub recording: FlightRecording,
    /// Session aggregates + planner/replan counters. Wall-clock figures
    /// sit under `annex.` — scrub before determinism comparisons.
    pub metrics: MetricsSnapshot,
}

/// Core state cloned out of the lock after applying a scenario event —
/// the session does its engine/bookkeeping work outside the mutex.
struct CoreSnapshot {
    fleet: Fleet,
    active: Vec<PipelineSpec>,
    qos: Vec<Qos>,
    deployment_plan: Option<(CollabPlan, f64, Vec<f64>)>,
    /// Replan stats for THIS event — `None` when the event cleared the
    /// deployment without orchestrating (pausing/unregistering the last
    /// app), where `core.last_replan()` would be a stale earlier replan.
    replan: Option<ReplanStats>,
}

/// Running aggregates of one report interval (streaming — rounds are
/// folded in as they complete, so retention windows never corrupt them).
#[derive(Clone, Debug, Default)]
struct IntervalScratch {
    completions: usize,
    lat_sum: f64,
    per_app: BTreeMap<PipelineId, (usize, f64)>,
}

impl IntervalScratch {
    fn add(&mut self, rec: &RoundRecord) {
        let lat = rec.end - rec.start;
        self.completions += 1;
        self.lat_sum += lat;
        let e = self.per_app.entry(rec.pipeline).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += lat;
    }

    fn merge(&mut self, other: IntervalScratch) {
        self.completions += other.completions;
        self.lat_sum += other.lat_sum;
        for (app, (c, lat)) in other.per_app {
            let e = self.per_app.entry(app).or_insert((0, 0.0));
            e.0 += c;
            e.1 += lat;
        }
    }
}

/// The engine a session drives: the resumable DES, or the streaming
/// serving engine after [`Session::serve`].
enum SessionEngine {
    Sim(SimEngine),
    Serve(ServeEngine),
}

impl SessionEngine {
    fn now(&self) -> f64 {
        match self {
            SessionEngine::Sim(e) => e.now(),
            SessionEngine::Serve(e) => e.now(),
        }
    }

    fn run_until(&mut self, t: f64) {
        match self {
            SessionEngine::Sim(e) => e.run_until(t),
            SessionEngine::Serve(e) => e.run_until(t),
        }
    }

    fn set_fleet(&mut self, fleet: Fleet) {
        match self {
            SessionEngine::Sim(e) => e.set_fleet(fleet),
            SessionEngine::Serve(e) => e.set_fleet(fleet),
        }
    }

    fn set_plan(
        &mut self,
        plan: &CollabPlan,
        pipelines: &[PipelineSpec],
    ) -> Result<(), RuntimeError> {
        match self {
            SessionEngine::Sim(e) => e.set_plan(plan, pipelines, None).map_err(RuntimeError::from),
            SessionEngine::Serve(e) => e.set_plan(plan, pipelines, None),
        }
    }

    fn clear_plan(&mut self) {
        match self {
            SessionEngine::Sim(e) => e.clear_plan(),
            SessionEngine::Serve(e) => e.clear_plan(),
        }
    }

    /// Live total energy at `horizon`. The streaming engine integrates
    /// post-hoc (busy spans drain asynchronously), so its mid-run probe
    /// is a placeholder; the session recomputes served energy marks at
    /// finish.
    fn energy_probe_j(&self, horizon: f64) -> f64 {
        match self {
            SessionEngine::Sim(e) => e.energy_total_j(horizon),
            SessionEngine::Serve(_) => 0.0,
        }
    }

    /// Wall pause of the most recent worker rebind (0 on the DES).
    fn last_rebind_wall_s(&self) -> f64 {
        match self {
            SessionEngine::Sim(_) => 0.0,
            SessionEngine::Serve(e) => e.last_rebind_wall_s(),
        }
    }

    /// Rebinds performed so far (0 on the DES) — lets a switch attribute
    /// a rebind pause only when this event actually rebound workers.
    fn rebind_count(&self) -> usize {
        match self {
            SessionEngine::Sim(_) => 0,
            SessionEngine::Serve(e) => e.rebind_count(),
        }
    }
}

/// A live, scenario-driven execution session (see the module docs).
pub struct Session {
    shared: Arc<Mutex<Shared>>,
    engine: SessionEngine,
    queue: VecDeque<TimedAction>,
    duration: f64,
    seed: u64,
    record_trace: bool,
    trace_window: Option<usize>,
    /// The event-driven battery timeline (empty manager when the scenario
    /// declares none).
    batteries: BatteryManager,
    /// Mirrored deterministic DES used as the measured-energy probe for
    /// battery re-anchoring when the main engine cannot serve as one
    /// (streaming engine, or a DES running a non-default
    /// [`SameTimePolicy`]): same fleet, seed, and plan sequence, always
    /// default tie-breaking — so anchors are policy-invariant and
    /// depletion instants stay bit-identical across engines and
    /// same-time policies.
    shadow: Option<Box<SimEngine>>,
    /// Cumulative measured device energy at each battery's last
    /// re-anchor — the window baseline for
    /// [`BatteryManager::reanchor`]. Entries are dropped when the device
    /// leaves and re-seeded when it joins.
    anchor_cum: BTreeMap<DeviceId, f64>,
    /// Current fleet size (dense ids) — battery suffix eligibility.
    fleet_len: usize,
    /// Interval boundaries, ascending, starting at 0.0. While running,
    /// `scratch` has one more entry than closed boundaries: the open
    /// interval.
    bounds: Vec<f64>,
    /// Cumulative energy at each boundary (simulator sessions; served
    /// sessions rebuild the marks at finish from the busy-span replay).
    energy_marks: Vec<f64>,
    /// Battery state-of-charge snapshot at each boundary (parallel to
    /// `bounds`; engine-independent — the closed-form drain model is
    /// shared, so no serve-side rebuild is needed). Boundary snapshots
    /// are taken *before* the switch's measured re-anchor, so a series
    /// shows the modeled drain up to each switch and the anchored
    /// correction from the next interval on.
    soc_marks: Vec<Vec<(DeviceId, f64)>>,
    /// Streaming per-interval aggregates; `scratch[i]` covers
    /// `(bounds[i], bounds[i+1]]` — a round completing exactly at a plan
    /// switch ran under the *old* plan, so it belongs to the interval
    /// that ends there (identical on both engines).
    scratch: Vec<IntervalScratch>,
    switches: Vec<PlanSwitch>,
    open_qos: BTreeMap<PipelineId, (QosViolation, f64)>,
    qos_spans: Vec<QosSpan>,
    /// App names seen so far (kept after unregistration for spans).
    names: BTreeMap<PipelineId, String>,
}

impl Session {
    /// Open a session: snapshot the runtime's fleet/deployment as the
    /// starting state and queue the scenario script.
    pub(crate) fn start(
        shared: Arc<Mutex<Shared>>,
        scenario: Scenario,
        cfg: SessionCfg,
    ) -> Result<Session, RuntimeError> {
        scenario.validate()?;
        let duration = scenario.duration();
        let queue: VecDeque<TimedAction> = scenario.sorted_events().into();
        let declared = scenario.batteries().to_vec();

        // A battery for a device that never exists would silently never
        // deplete — reject the typo up front.
        let fleet_len = lock_shared(&shared).core.fleet().len();
        for &(d, _, _) in &declared {
            let joins_later = scenario.events().iter().any(|e| match &e.action {
                ScenarioAction::DeviceJoined(dev) => dev.id == d,
                // A scripted reshape that grows past the id also arms it.
                ScenarioAction::SetFleet(f) => d.0 < f.len(),
                _ => false,
            });
            if d.0 >= fleet_len && !joins_later {
                return Err(RuntimeError::InvalidScenario(format!(
                    "battery declared for {d}, which is neither in the \
                     {fleet_len}-device starting fleet nor scripted to join"
                )));
            }
        }

        let (engine, names, active, qos, est, plan, fleet, policy) = {
            let guard = lock_shared(&shared);
            let core = &guard.core;
            let policy = guard.planner.exec_policy();
            let mut engine = SimEngine::new(
                core.fleet().clone(),
                GroundTruth::with_seed(cfg.seed),
                policy,
                cfg.record_trace,
            );
            engine.set_span_cap(cfg.trace_window);
            engine.set_same_time(cfg.same_time);
            let mut est = None;
            let mut plan = None;
            if let Some(dep) = core.deployment() {
                debug_verify_deployment(&dep.plan, core.active_apps(), core.fleet());
                engine.set_plan(&dep.plan, core.active_apps(), None)?;
                est = Some((dep.estimate.throughput, dep.estimate.chain_latency.clone()));
                plan = Some(dep.plan.clone());
            }
            let names: BTreeMap<PipelineId, String> = core
                .active_apps()
                .iter()
                .map(|s| (s.id, s.name.clone()))
                .collect();
            (
                engine,
                names,
                core.active_apps().to_vec(),
                core.active_qos(),
                est,
                plan,
                core.fleet().clone(),
                policy,
            )
        };

        let mut batteries = BatteryManager::new(&declared);
        batteries.sync_presence(fleet.len());
        let draws = plan_device_draw(plan.as_ref(), &active, &fleet);
        batteries.set_loads(
            |d| draws.get(d.0).copied().unwrap_or(0.0),
            |d| fleet.devices.get(d.0).map_or(0.0, |dev| dev.spec.power.base_w),
        );

        // A perturbed same-time policy reshuffles the main DES, but
        // battery re-anchoring must stay policy-invariant (depletion
        // instants are part of the switch timeline that the race sweep
        // compares across policies) — so anchor against a mirrored
        // default-policy DES instead of the perturbed main engine.
        let shadow = if !batteries.is_empty() && cfg.same_time != SameTimePolicy::Deterministic {
            let mut sh = SimEngine::new(
                fleet.clone(),
                GroundTruth::with_seed(cfg.seed),
                policy,
                false,
            );
            if let Some(p) = plan.as_ref() {
                sh.set_plan(p, &active, None)?;
            }
            Some(Box::new(sh))
        } else {
            None
        };

        let soc0 = batteries.snapshot();
        let mut session = Session {
            shared,
            engine: SessionEngine::Sim(engine),
            queue,
            duration,
            seed: cfg.seed,
            record_trace: cfg.record_trace,
            trace_window: cfg.trace_window,
            batteries,
            shadow,
            anchor_cum: BTreeMap::new(),
            fleet_len: fleet.len(),
            bounds: vec![0.0],
            energy_marks: vec![0.0],
            soc_marks: vec![soc0],
            scratch: vec![IntervalScratch::default()],
            switches: Vec::new(),
            open_qos: BTreeMap::new(),
            qos_spans: Vec::new(),
            names,
        };
        // QoS standing of the pre-registered deployment opens at t=0.
        if let Some((throughput, chain_latency)) = est {
            session.refresh_qos(0.0, &active, &qos, Some((throughput, chain_latency.as_slice())));
        }
        Ok(session)
    }

    /// Re-seat this session on the streaming serving engine with the
    /// deterministic virtual-time executor (same jitter seed as the
    /// session, so it is directly comparable to the simulator path). Must
    /// be called before any time elapses. Battery ramps ride along: the
    /// drain model is engine-independent, so depletion instants match the
    /// simulator session exactly.
    pub fn serve(self, cfg: ServeCfg) -> Result<Session, RuntimeError> {
        let seed = self.seed;
        self.serve_with(Arc::new(VirtualExecutor::with_seed(seed)), cfg)
    }

    /// Like [`Self::serve`], streaming through a caller-provided executor
    /// (e.g. the PJRT chunk executor behind the `pjrt` feature).
    pub fn serve_with(
        mut self,
        executor: Arc<dyn ChunkExecutor>,
        cfg: ServeCfg,
    ) -> Result<Session, RuntimeError> {
        if matches!(self.engine, SessionEngine::Serve(_)) {
            return Err(RuntimeError::InvalidScenario(
                "session is already serving".into(),
            ));
        }
        if self.engine.now() > 0.0 || !self.switches.is_empty() {
            return Err(RuntimeError::InvalidScenario(
                "serve() must re-seat the session before its timeline starts \
                 (call it right after runtime.session(..))"
                    .into(),
            ));
        }
        let (fleet, active, dep_plan, policy) = {
            let guard = lock_shared(&self.shared);
            let core = &guard.core;
            (
                core.fleet().clone(),
                core.active_apps().to_vec(),
                core.deployment().map(|d| d.plan.clone()),
                guard.planner.exec_policy(),
            )
        };
        let mut engine = ServeEngine::new(executor, cfg, fleet.clone());
        if let Some(plan) = &dep_plan {
            debug_verify_deployment(plan, &active, &fleet);
            engine.set_plan(plan, &active, None)?;
        }
        // The streaming engine has no DES energy integral to anchor
        // batteries against — mirror a default-policy simulator alongside
        // it as the measured-energy probe (same seed/fleet/plan sequence
        // as the comparable simulator session, so anchored depletion
        // instants still match that session bit-for-bit).
        if !self.batteries.is_empty() && self.shadow.is_none() {
            let mut sh = SimEngine::new(
                fleet.clone(),
                GroundTruth::with_seed(self.seed),
                policy,
                false,
            );
            if let Some(p) = &dep_plan {
                sh.set_plan(p, &active, None)?;
            }
            self.shadow = Some(Box::new(sh));
        }
        self.engine = SessionEngine::Serve(engine);
        Ok(self)
    }

    /// The current simulated time.
    pub fn now(&self) -> f64 {
        self.engine.now()
    }

    /// Plan switches so far (mid-run observability).
    pub fn switches(&self) -> &[PlanSwitch] {
        &self.switches
    }

    /// Remaining charge of a device's declared battery, if one is armed
    /// (mid-run observability for battery scenarios).
    pub fn battery_remaining_j(&self, device: DeviceId) -> Option<f64> {
        self.batteries.remaining_j(device)
    }

    /// Advance the timeline to `t` (clamped to the scenario horizon),
    /// applying every scripted event — and every exact battery-depletion
    /// instant — on the way.
    pub fn run_until(&mut self, t: f64) -> Result<(), RuntimeError> {
        let target = t.min(self.duration);
        loop {
            let next = self
                .queue
                .front()
                .map(|e| e.t)
                .filter(|&et| et <= target);
            match next {
                Some(et) => {
                    self.advance(et)?;
                    let ev = self.queue.pop_front().expect("peeked event");
                    let cause = ev.action.describe();
                    self.apply(ev.t.max(self.engine.now()), cause, ev.action)?;
                }
                None => {
                    self.advance(target)?;
                    return Ok(());
                }
            }
        }
    }

    /// Apply an unscripted action at the current simulated time — the
    /// imperative escape hatch for driving a session interactively.
    pub fn inject(&mut self, action: ScenarioAction) -> Result<(), RuntimeError> {
        let t = self.engine.now();
        let cause = action.describe();
        self.apply(t, cause, action)
    }

    /// Run the remaining scenario to its horizon and produce the
    /// time-series report.
    pub fn finish(self) -> Result<SessionReport, RuntimeError> {
        self.finish_inner().map(|(report, _)| report)
    }

    /// [`Self::finish`], additionally producing a flight recording of
    /// the session timeline and a metrics snapshot (session aggregates,
    /// planner search counters, replan cache counters; wall-clock
    /// figures under the scrub-able `annex.` prefix).
    ///
    /// The recording is emitted *post-hoc* from the finished report's
    /// deterministic artifacts — never live from engine hot paths — so
    /// it is bit-identical across reruns and, for served sessions,
    /// across worker counts. Set [`SessionCfg::record_trace`] to include
    /// per-(device, unit) task spans on either engine — the serve path
    /// collects them post-hoc, never live from worker threads.
    pub fn finish_traced(self) -> Result<TracedReport, RuntimeError> {
        let shared = Arc::clone(&self.shared);
        let (report, serve_busy) = self.finish_inner()?;

        let mut recording = FlightRecording::new();
        obs::record_session(&report, &serve_busy, &mut recording);

        let registry = MetricsRegistry::new();
        obs::session_metrics(&report, &registry);
        {
            let guard = lock_shared(&shared);
            if let Some(pp) = guard.planner.as_progressive() {
                registry
                    .counter("planner.candidates_scored")
                    .add(pp.candidates_scored.get());
                registry
                    .counter("planner.skeletons_considered")
                    .add(pp.counters.skeletons_considered.get());
                registry
                    .counter("planner.admission_pruned")
                    .add(pp.counters.admission_pruned.get());
                registry.counter("planner.bound_cutoffs").add(pp.counters.bound_cutoffs.get());
            }
            let (cache_hits, enumerations) = guard.core.cache_counters();
            registry.counter("replan.cache_hits").add(cache_hits as u64);
            registry.counter("replan.enumerations").add(enumerations as u64);
        }

        Ok(TracedReport { report, recording, metrics: registry.snapshot() })
    }

    fn finish_inner(mut self) -> Result<(SessionReport, Vec<BusySpan>), RuntimeError> {
        self.run_until(self.duration)?;
        self.close_final(self.duration);
        // Close still-open QoS spans at the horizon.
        let open: Vec<(PipelineId, (QosViolation, f64))> =
            std::mem::take(&mut self.open_qos).into_iter().collect();
        for (app, (violation, start)) in open {
            self.push_qos_span(app, violation, start, self.duration);
        }

        let duration = self.duration;
        let record_trace = self.record_trace;
        let trace_window = self.trace_window;
        let bounds = std::mem::take(&mut self.bounds);
        let mut scratch = std::mem::take(&mut self.scratch);
        let sim_marks = std::mem::take(&mut self.energy_marks);
        let soc_marks = std::mem::take(&mut self.soc_marks);
        let names = std::mem::take(&mut self.names);

        let (completions, energy_j, trace, served, marks, serve_busy) = match self.engine {
            SessionEngine::Sim(engine) => {
                let completions = engine.completions();
                let energy_j = engine.energy_total_j(duration);
                (completions, energy_j, engine.into_trace(), None, sim_marks, Vec::new())
            }
            SessionEngine::Serve(engine) => {
                let outcome = engine.finish()?;
                let served = ServeSummary {
                    executor: outcome.executor,
                    admitted_rounds: outcome.admitted,
                    completed_rounds: outcome.completed,
                    rebinds: outcome.rebinds.len(),
                    workers: outcome.workers,
                };
                // Rounds that drained past the horizon stay in the
                // conservation totals but out of the report window — the
                // same cut the DES makes by never processing events past
                // the horizon.
                let mut past_horizon = 0usize;
                for rec in &outcome.records {
                    if rec.end > duration + 1e-9 {
                        past_horizon += 1;
                    } else {
                        scratch[Self::interval_index(&bounds, rec.end)].add(rec);
                    }
                }
                let completions = outcome.completed - past_horizon;
                // Energy marks: chronological replay of the workers' busy
                // spans interleaved with the fleet-change history —
                // completions before churn at equal instants, exactly the
                // DES event order.
                let mut replay = EnergyReplay::new(
                    outcome
                        .fleet_history
                        .first()
                        .map(|(_, f)| f.clone())
                        .unwrap_or_else(|| Fleet::new(Vec::new())),
                );
                let mut spans = outcome.busy.iter().peekable();
                let mut changes = outcome.fleet_history.iter().skip(1).peekable();
                let mut marks = Vec::with_capacity(bounds.len());
                for &b in &bounds {
                    loop {
                        let next_span = spans.peek().map(|s| s.end);
                        let next_change = changes.peek().map(|(t, _)| *t);
                        match (next_span, next_change) {
                            (Some(e), c) if e <= b && !c.is_some_and(|t| e > t) => {
                                replay.record(spans.next().expect("peeked span"));
                            }
                            (_, Some(t)) if t <= b => {
                                let (tc, f) = changes.next().expect("peeked change");
                                replay.set_fleet(f.clone(), *tc);
                            }
                            _ => break,
                        }
                    }
                    marks.push(replay.energy_at(b));
                }
                let energy_j = marks.last().copied().unwrap_or(0.0);
                let trace = if record_trace {
                    // Same schema as the DES trace: chronological span
                    // order, ties broken by the canonical task identity,
                    // ring-windowed to the most recent `n` when capped.
                    let mut task_spans = outcome.tasks.clone();
                    task_spans.sort_by(|a, b| {
                        a.start.total_cmp(&b.start).then_with(|| {
                            (a.pipeline, a.run, a.seq).cmp(&(b.pipeline, b.run, b.seq))
                        })
                    });
                    if let Some(cap) = trace_window {
                        if task_spans.len() > cap {
                            let overflow = task_spans.len() - cap;
                            task_spans.drain(..overflow);
                        }
                    }
                    Some(Trace { spans: task_spans })
                } else {
                    None
                };
                (completions, energy_j, trace, Some(served), marks, outcome.busy)
            }
        };

        let mut intervals = Vec::with_capacity(scratch.len());
        for (i, s) in scratch.iter().enumerate() {
            let (a, b) = (bounds[i], bounds[i + 1]);
            let span = (b - a).max(1e-12);
            let per_app: Vec<AppInterval> = s
                .per_app
                .iter()
                .map(|(&app, &(c, lat_sum))| AppInterval {
                    app,
                    name: names.get(&app).cloned().unwrap_or_default(),
                    completions: c,
                    throughput: c as f64 / span,
                    mean_latency_s: lat_sum / c as f64,
                })
                .collect();
            intervals.push(Interval {
                start: a,
                end: b,
                completions: s.completions,
                throughput: s.completions as f64 / span,
                avg_latency_s: if s.completions > 0 {
                    s.lat_sum / s.completions as f64
                } else {
                    0.0
                },
                power_w: (marks[i + 1] - marks[i]) / span,
                battery_j: soc_marks.get(i + 1).cloned().unwrap_or_default(),
                per_app,
            });
        }

        let report = SessionReport {
            duration,
            completions,
            throughput: completions as f64 / duration.max(1e-12),
            energy_j,
            power_w: energy_j / duration.max(1e-12),
            intervals,
            switches: self.switches,
            qos_spans: self.qos_spans,
            trace,
            served,
        };
        Ok((report, serve_busy))
    }

    /// The interval a completed round belongs to, given the final
    /// boundary list: `(bounds[i], bounds[i+1]]` — a round ending exactly
    /// at a boundary completed under the plan that was retiring there, so
    /// it counts toward the interval that *ends* at the boundary (the
    /// same attribution the simulator path applies while draining).
    fn interval_index(bounds: &[f64], end: f64) -> usize {
        let m = bounds.len() - 1;
        let i = bounds.partition_point(|&x| x < end);
        i.clamp(1, m) - 1
    }

    /// Advance the engine to `to`, firing exact battery-depletion events
    /// on the way. Same-instant targets are a no-op, so a burst of events
    /// sharing one timestamp applies atomically — the intermediate plans
    /// never start tasks (their seeds are dropped on retirement).
    fn advance(&mut self, to: f64) -> Result<(), RuntimeError> {
        while self.engine.now() < to {
            match self.batteries.next_depletion(self.fleet_len) {
                Some((d, t_dep)) if t_dep <= to => {
                    let t_dep = t_dep.max(self.engine.now());
                    self.step_engine(t_dep);
                    self.batteries.advance(t_dep);
                    self.apply(
                        t_dep,
                        format!("battery-depleted({d})"),
                        ScenarioAction::DeviceLeft(d),
                    )?;
                }
                _ => {
                    self.step_engine(to);
                    self.batteries.advance(to);
                }
            }
        }
        Ok(())
    }

    /// Run the engine to `to`, draining completed rounds into the open
    /// interval. With a trace window set, the DES is stepped in short
    /// chunks so the drain keeps retained records bounded even across
    /// long uneventful stretches.
    fn step_engine(&mut self, to: f64) {
        let chunked = self.trace_window.is_some() && matches!(self.engine, SessionEngine::Sim(_));
        if chunked {
            let mut t = self.engine.now();
            while t < to {
                t = (t + 1.0).min(to);
                self.engine.run_until(t);
                self.drain_records();
            }
        } else {
            self.engine.run_until(to);
            self.drain_records();
        }
        // The probe mirror tracks the main engine's clock; its records
        // are dropped — only its energy integral is ever read.
        if let Some(sh) = &mut self.shadow {
            sh.run_until(to);
            let _ = sh.take_records();
        }
    }

    /// Cumulative measured energy for `device` at time `t` on the
    /// deterministic reference timeline: the main DES when it *is* that
    /// timeline, otherwise the mirrored shadow probe (already stepped to
    /// `t` alongside the main engine).
    fn measured_energy_j(&self, device: DeviceId, t: f64) -> f64 {
        if let Some(sh) = &self.shadow {
            return sh.device_energy_j(device, t);
        }
        match &self.engine {
            SessionEngine::Sim(e) => e.device_energy_j(device, t),
            // Unreachable in practice: serving sessions with batteries
            // always carry a shadow probe.
            SessionEngine::Serve(_) => 0.0,
        }
    }

    /// Fold newly completed rounds into the open interval (simulator
    /// engines; the streaming engine's records are collected at finish).
    /// Every drained round completed at or before the drain horizon, so
    /// it belongs to the interval that is open *up to* that horizon —
    /// including rounds ending exactly on an interval boundary, which ran
    /// under the plan that retires there.
    fn drain_records(&mut self) {
        let recs = match &mut self.engine {
            SessionEngine::Sim(e) => e.take_records(),
            SessionEngine::Serve(_) => return,
        };
        if recs.is_empty() {
            return;
        }
        let open = self.scratch.last_mut().expect("open interval");
        for rec in recs {
            open.add(&rec);
        }
    }

    /// Apply one action at time `t`: mutate the core (one incremental
    /// replan), swap the new deployment into the engine, and record the
    /// interval boundary, plan switch, battery loads, and QoS standing.
    fn apply(&mut self, t: f64, cause: String, action: ScenarioAction) -> Result<(), RuntimeError> {
        self.batteries.advance(t);
        if let ScenarioAction::Recharge { device, joules } = &action {
            // A recharge never replans — it only moves the scheduled
            // depletion instant.
            self.batteries.recharge(*device, *joules);
            return Ok(());
        }
        let fleet_changes = matches!(
            action,
            ScenarioAction::DeviceLeft(_)
                | ScenarioAction::DeviceJoined(_)
                | ScenarioAction::SetFleet(_)
        );
        let (snapshot, wall) = {
            let mut guard = lock_shared(&self.shared);
            let Shared { core, planner } = &mut *guard;
            let orchestrations_before = core.orchestrations();
            let had_deployment = core.deployment().is_some();
            let fleet_before = core.fleet().clone();
            core.set_event_clock(Some(t));
            let t0 = Instant::now();
            let result = match action {
                ScenarioAction::DeviceLeft(d) => core.device_left(d, planner.as_ref()),
                ScenarioAction::DeviceJoined(dev) => core.device_joined(dev, planner.as_ref()),
                ScenarioAction::SetFleet(fleet) => core.set_fleet(fleet, planner.as_ref()),
                ScenarioAction::Register { spec, qos } => {
                    core.register(spec, qos, planner.as_ref())
                }
                ScenarioAction::Unregister(id) => core.remove(id, planner.as_ref()),
                ScenarioAction::Pause(id) => core.set_paused(id, true, planner.as_ref()),
                ScenarioAction::Resume(id) => core.set_paused(id, false, planner.as_ref()),
                ScenarioAction::SetQos { app, qos } => core.set_qos(app, qos, planner.as_ref()),
                ScenarioAction::Recharge { .. } => unreachable!("handled above"),
            };
            let wall = t0.elapsed().as_secs_f64();
            core.set_event_clock(None);
            if let Err(e) = result {
                // Keep the engine — and the report — consistent with
                // however the core failed: a fleet change lands even when
                // the replan errors, and a failed replan clears the
                // deployment. Otherwise a caller that catches the error
                // and keeps driving the session would run the old plan on
                // devices the core no longer has, with the transition
                // missing from the timeline.
                let fleet_changed = core.fleet().devices.len() != fleet_before.devices.len()
                    || core
                        .fleet()
                        .devices
                        .iter()
                        .zip(&fleet_before.devices)
                        .any(|(a, b)| a.spec != b.spec);
                let cleared = had_deployment && core.deployment().is_none();
                let fleet = core.fleet().clone();
                let active = core.active_apps().to_vec();
                let plan = core.deployment().map(|d| d.plan.clone());
                drop(guard);
                if fleet_changed || cleared {
                    let rebinds_before = self.engine.rebind_count();
                    self.close_interval(t);
                    if fleet_changed {
                        self.engine.set_fleet(fleet.clone());
                        if let Some(sh) = &mut self.shadow {
                            sh.set_fleet(fleet.clone());
                        }
                    }
                    if cleared {
                        self.engine.clear_plan();
                        if let Some(sh) = &mut self.shadow {
                            sh.clear_plan();
                        }
                    }
                    self.switches.push(PlanSwitch {
                        t,
                        cause: format!("{cause} (replan failed)"),
                        apps: 0,
                        incremental: false,
                        reused_apps: 0,
                        enumerated_apps: 0,
                        est_throughput: 0.0,
                        replan_wall_s: wall,
                        rebind_wall_s: if self.engine.rebind_count() > rebinds_before {
                            self.engine.last_rebind_wall_s()
                        } else {
                            0.0
                        },
                    });
                    self.sync_batteries(t, &fleet, &active, plan.as_ref());
                    self.refresh_qos(t, &[], &[], None);
                }
                return Err(e);
            }
            if !fleet_changes
                && core.orchestrations() == orchestrations_before
                && core.deployment().is_some() == had_deployment
            {
                // The event was a no-op (e.g. identical QoS hints): no
                // replan happened, so the running epoch stays untouched.
                return Ok(());
            }
            let snapshot = CoreSnapshot {
                fleet: core.fleet().clone(),
                active: core.active_apps().to_vec(),
                qos: core.active_qos(),
                deployment_plan: core.deployment().map(|d| {
                    (
                        d.plan.clone(),
                        d.estimate.throughput,
                        d.estimate.chain_latency.clone(),
                    )
                }),
                replan: if core.orchestrations() != orchestrations_before {
                    core.last_replan()
                } else {
                    None
                },
            };
            (snapshot, wall)
        };

        // The event replanned: close the interval at the pre-switch
        // energy state (the core mutation above did not touch the
        // engine), then sync the engine — fleet first (presence/energy),
        // then the plan.
        let rebinds_before = self.engine.rebind_count();
        self.close_interval(t);
        if fleet_changes {
            self.engine.set_fleet(snapshot.fleet.clone());
            if let Some(sh) = &mut self.shadow {
                sh.set_fleet(snapshot.fleet.clone());
            }
        }
        let est_throughput = match &snapshot.deployment_plan {
            Some((plan, throughput, _)) => {
                // Every mid-timeline replan recommits through the static
                // verifier — a failure here is a planner bug (debug
                // builds; free in release).
                debug_verify_deployment(plan, &snapshot.active, &snapshot.fleet);
                self.engine.set_plan(plan, &snapshot.active)?;
                if let Some(sh) = &mut self.shadow {
                    sh.set_plan(plan, &snapshot.active, None)?;
                }
                *throughput
            }
            None => {
                self.engine.clear_plan();
                if let Some(sh) = &mut self.shadow {
                    sh.clear_plan();
                }
                0.0
            }
        };
        for spec in &snapshot.active {
            self.names.insert(spec.id, spec.name.clone());
        }
        self.sync_batteries(
            t,
            &snapshot.fleet,
            &snapshot.active,
            snapshot.deployment_plan.as_ref().map(|(p, _, _)| p),
        );

        let stats = snapshot.replan.unwrap_or_default();
        self.switches.push(PlanSwitch {
            t,
            cause,
            apps: snapshot.active.len(),
            incremental: stats.incremental(),
            reused_apps: stats.reused_apps,
            enumerated_apps: stats.enumerated_apps,
            est_throughput,
            replan_wall_s: wall,
            rebind_wall_s: if self.engine.rebind_count() > rebinds_before {
                self.engine.last_rebind_wall_s()
            } else {
                0.0
            },
        });

        let est = snapshot
            .deployment_plan
            .as_ref()
            .map(|(_, tp, lat)| (*tp, lat.as_slice()));
        self.refresh_qos(t, &snapshot.active, &snapshot.qos, est);
        Ok(())
    }

    /// Reconcile batteries with the post-event world: re-anchor each
    /// draining battery's remaining charge to the *measured* energy
    /// integral since its last anchor (the modeled draw only schedules
    /// depletion *between* switches; the accountant's integral corrects
    /// the drift at every switch), then presence (dense ids), then the
    /// new plan's modeled per-device draws.
    fn sync_batteries(
        &mut self,
        t: f64,
        fleet: &Fleet,
        active: &[PipelineSpec],
        plan: Option<&CollabPlan>,
    ) {
        self.fleet_len = fleet.len();
        if self.batteries.is_empty() {
            return;
        }
        for d in self.batteries.active_devices() {
            if d.0 >= fleet.len() {
                // The device is leaving this instant (departure or
                // depletion): its window is moot, and dropping the
                // baseline re-seeds it cleanly on a later rejoin.
                self.anchor_cum.remove(&d);
                continue;
            }
            let cum = self.measured_energy_j(d, t);
            let prev = self.anchor_cum.insert(d, cum).unwrap_or(0.0);
            self.batteries.reanchor(d, (cum - prev).max(0.0));
        }
        self.batteries.sync_presence(fleet.len());
        // A battery that just started draining (its device joined, or a
        // scripted reshape grew past it) anchors forward from here: seed
        // its baseline so the first window excludes pre-presence energy.
        for d in self.batteries.active_devices() {
            if !self.anchor_cum.contains_key(&d) {
                let cum = self.measured_energy_j(d, t);
                self.anchor_cum.insert(d, cum);
            }
        }
        let draws = plan_device_draw(plan, active, fleet);
        self.batteries.set_loads(
            |d| draws.get(d.0).copied().unwrap_or(0.0),
            |d| fleet.devices.get(d.0).map_or(0.0, |dev| dev.spec.power.base_w),
        );
    }

    /// Reconcile open QoS-violation spans against the new deployment's
    /// estimate (the same per-app rate model the core's `PlanDegraded`
    /// events use).
    fn refresh_qos(
        &mut self,
        t: f64,
        active: &[PipelineSpec],
        qos: &[Qos],
        est: Option<(f64, &[f64])>,
    ) {
        let mut current: BTreeMap<PipelineId, QosViolation> = BTreeMap::new();
        if let Some((throughput, chain_latency)) = est {
            if !active.is_empty() {
                let per_app_rate = throughput / active.len() as f64;
                for (i, spec) in active.iter().enumerate() {
                    if let Some(v) = qos[i].check(per_app_rate, chain_latency[i]) {
                        current.insert(spec.id, v);
                    }
                }
            }
        }
        // Close spans that ended or changed shape.
        let open_apps: Vec<PipelineId> = self.open_qos.keys().copied().collect();
        for app in open_apps {
            let still = current.get(&app);
            let (violation, start) = self.open_qos[&app];
            if still != Some(&violation) {
                self.open_qos.remove(&app);
                self.push_qos_span(app, violation, start, t);
            }
        }
        // Open new spans.
        for (app, violation) in current {
            self.open_qos.entry(app).or_insert((violation, t));
        }
    }

    fn push_qos_span(&mut self, app: PipelineId, violation: QosViolation, start: f64, end: f64) {
        let name = self.names.get(&app).cloned().unwrap_or_default();
        self.qos_spans.push(QosSpan {
            app,
            name,
            violation,
            start,
            end,
        });
    }

    /// Record an interval boundary at time `t`: drain the completed
    /// rounds into the ending interval (boundary rounds included — they
    /// ran under the retiring plan), snapshot the energy state, open the
    /// next interval.
    fn close_interval(&mut self, t: f64) {
        let last = *self.bounds.last().expect("initial boundary");
        if t <= last {
            // Same-instant event bursts share one boundary.
            return;
        }
        self.drain_records();
        self.bounds.push(t);
        self.energy_marks.push(self.engine.energy_probe_j(t));
        // `apply`/`advance` always advance the batteries to `t` before
        // closing an interval, so this snapshot is boundary-exact.
        self.soc_marks.push(self.batteries.snapshot());
        self.scratch.push(IntervalScratch::default());
    }

    /// Close the report at the horizon: the final interval takes every
    /// remaining round, horizon-exact completions included.
    fn close_final(&mut self, duration: f64) {
        self.drain_records();
        let last = *self.bounds.last().expect("initial boundary");
        if last < duration {
            self.bounds.push(duration);
            self.energy_marks.push(self.engine.energy_probe_j(duration));
            self.soc_marks.push(self.batteries.snapshot());
        } else if self.scratch.len() == self.bounds.len() && self.scratch.len() >= 2 {
            // A terminal event landed exactly on the horizon: fold its
            // empty trailing interval into the final one.
            let extra = self.scratch.pop().expect("trailing interval");
            self.scratch.last_mut().expect("final interval").merge(extra);
        }
    }
}
