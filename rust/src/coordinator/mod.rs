//! §V — the moderator shim and the real serving loop.
//!
//! Orchestration state (apps, fleet, deployment, incremental replanning)
//! lives in [`crate::api::RuntimeCore`]; the [`moderator`] here is a thin
//! direct-ownership shim over it, kept for callers that don't need
//! handles, events, or backends. [`serve`] executes a deployment for
//! real: per-device threads with per-unit work queues, mpsc channels as
//! radio links, and PJRT inference through the runtime service — the
//! paper's runtime made concrete on this testbed. New code reaches both
//! through [`crate::api::SynergyRuntime`] (`run()` with a
//! [`crate::api::PjrtBackend`]) rather than calling `serve` directly.

pub mod moderator;
#[cfg(feature = "pjrt")]
pub mod serve;

pub use moderator::{Deployment, Moderator};
#[cfg(feature = "pjrt")]
pub use serve::{serve, ServeConfig, ServeReport};
