//! §V — the moderator compatibility shim.
//!
//! Orchestration state (apps, fleet, deployment, incremental replanning)
//! lives in [`crate::api::RuntimeCore`]; the [`moderator`] here is a thin
//! direct-ownership shim over it, kept for callers that don't need
//! handles, events, or backends. The threaded serving loop that used to
//! live here was absorbed into the [`crate::serving`] subsystem — the
//! streaming engine with live plan rebinding; the one-shot PJRT loop is
//! `crate::serving::pjrt::serve` behind the `pjrt` feature. New code
//! reaches execution through [`crate::api::SynergyRuntime`] backends
//! rather than calling serving loops directly.

pub mod moderator;

pub use moderator::{Deployment, Moderator};
