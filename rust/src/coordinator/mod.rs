//! §V — the moderator and the real serving loop.
//!
//! The [`moderator`] owns the device registry and the registered apps,
//! re-orchestrates whenever either changes (the only time Python-side work
//! would ever matter is `make artifacts`, long before this), and records
//! the deployment. [`serve`] executes a deployment for real: per-device
//! threads with per-unit work queues, mpsc channels as radio links, and
//! PJRT inference through the runtime service — the paper's runtime made
//! concrete on this testbed.

pub mod moderator;
pub mod serve;

pub use moderator::{Deployment, Moderator};
pub use serve::{serve, ServeConfig, ServeReport};
