//! The moderator (§III-C "moderator-initiated orchestration") — now a thin
//! compatibility shim over [`crate::api::RuntimeCore`].
//!
//! The moderator predates the [`crate::api::SynergyRuntime`] facade; it
//! remains for callers that want a single-owner, generic-planner view
//! without handles, events, or backends. All orchestration behavior
//! (incremental re-orchestration included, when the planner is
//! progressive) lives in the core; the shim adds nothing but the borrow
//! discipline of `&mut self`. New code should prefer the runtime facade.

use crate::api::{RuntimeCore, RuntimeError};
use crate::device::Fleet;
use crate::orchestrator::Planner;
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::scheduler::SimReport;

pub use crate::api::Deployment;

/// The orchestration moderator: a direct-ownership shim over the runtime
/// core.
pub struct Moderator<P: Planner> {
    core: RuntimeCore,
    planner: P,
}

impl<P: Planner> Moderator<P> {
    pub fn new(fleet: Fleet, planner: P) -> Moderator<P> {
        Moderator {
            core: RuntimeCore::new(fleet),
            planner,
        }
    }

    pub fn fleet(&self) -> &Fleet {
        self.core.fleet()
    }

    pub fn apps(&self) -> &[PipelineSpec] {
        self.core.active_apps()
    }

    pub fn deployment(&self) -> Option<&Deployment> {
        self.core.deployment()
    }

    /// Orchestrations performed (diagnostics; †every app/fleet change
    /// triggers exactly one).
    pub fn orchestrations(&self) -> usize {
        self.core.orchestrations()
    }

    /// Register an app pipeline; triggers re-orchestration. Duplicate ids
    /// are a typed error ([`RuntimeError::DuplicateApp`]), not a panic —
    /// and a registration that somehow leaves no deployment is a typed
    /// [`RuntimeError::NoDeployment`], not an `expect` crash (the shim
    /// must never take down a live session).
    pub fn register_app(&mut self, spec: PipelineSpec) -> Result<&Deployment, RuntimeError> {
        self.core
            .register(spec, crate::api::Qos::default(), &self.planner)?;
        self.core.deployment().ok_or(RuntimeError::NoDeployment)
    }

    /// Remove an app; triggers re-orchestration (no-op plan when empty).
    /// Unknown ids are a typed error ([`RuntimeError::UnknownApp`]), not a
    /// silent no-op.
    pub fn remove_app(&mut self, id: PipelineId) -> Result<Option<&Deployment>, RuntimeError> {
        self.core.remove(id, &self.planner)?;
        Ok(self.core.deployment())
    }

    /// Replace the fleet (device joined/left); triggers re-orchestration.
    pub fn set_fleet(&mut self, fleet: Fleet) -> Result<Option<&Deployment>, RuntimeError> {
        self.core.set_fleet(fleet, &self.planner)?;
        Ok(self.core.deployment())
    }

    /// Run holistic orchestration over the current apps + fleet.
    pub fn orchestrate(&mut self) -> Result<&Deployment, RuntimeError> {
        self.core.orchestrate(&self.planner)?;
        self.core.deployment().ok_or(RuntimeError::NoDeployment)
    }

    /// Execute the current deployment on the simulated hardware.
    pub fn simulate(&self, runs: usize, seed: u64) -> Option<SimReport> {
        self.core.simulate(runs, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceId;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::orchestrator::Synergy;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::workload::{fleet4, fleet_n};

    fn app(id: usize, m: ModelName) -> PipelineSpec {
        PipelineSpec::new(
            id,
            m.as_str(),
            SourceReq::Device(DeviceId(0)),
            model_by_name(m).clone(),
            TargetReq::Device(DeviceId(1)),
        )
    }

    #[test]
    fn registration_triggers_orchestration() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS))?;
        assert_eq!(m.orchestrations(), 1);
        assert_eq!(m.deployment().ok_or(RuntimeError::NoDeployment)?.plan.plans.len(), 1);
        m.register_app(app(1, ModelName::SimpleNet))?;
        assert_eq!(m.orchestrations(), 2);
        assert_eq!(m.deployment().ok_or(RuntimeError::NoDeployment)?.plan.plans.len(), 2);
        Ok(())
    }

    #[test]
    fn device_change_reorchestrates() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        let before = m.register_app(app(0, ModelName::UNet))?.estimate.throughput;
        let after = m
            .set_fleet(fleet_n(2))?
            .ok_or(RuntimeError::NoDeployment)?
            .estimate
            .throughput;
        assert_eq!(m.orchestrations(), 2);
        assert!(before > 0.0 && after > 0.0);
        Ok(())
    }

    #[test]
    fn removal_clears_deployment_when_empty() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS))?;
        m.remove_app(PipelineId(0))?;
        assert!(m.deployment().is_none());
        Ok(())
    }

    #[test]
    fn simulate_executes_deployment() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS))?;
        let rep = m.simulate(12, 7).ok_or(RuntimeError::NoDeployment)?;
        assert_eq!(rep.completions, 12);
        assert!(rep.throughput > 0.0);
        Ok(())
    }

    #[test]
    fn duplicate_ids_are_typed_errors() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS))?;
        let err = m.register_app(app(0, ModelName::SimpleNet)).unwrap_err();
        assert!(matches!(err, RuntimeError::DuplicateApp(PipelineId(0))));
        // The failed registration did not disturb the deployment.
        assert_eq!(m.deployment().ok_or(RuntimeError::NoDeployment)?.plan.plans.len(), 1);
        assert_eq!(m.apps().len(), 1);
        Ok(())
    }

    #[test]
    fn removing_unknown_app_is_typed_error() -> Result<(), RuntimeError> {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS))?;
        let err = m.remove_app(PipelineId(9)).unwrap_err();
        assert!(matches!(err, RuntimeError::UnknownApp(PipelineId(9))));
        // Still registered, still deployed.
        assert_eq!(m.apps().len(), 1);
        assert!(m.deployment().is_some());
        Ok(())
    }

    #[test]
    fn unplannable_registration_is_a_typed_error_not_a_crash() {
        // Regression for the legacy shim's `expect` path: a registration
        // the planner cannot satisfy (source pinned beyond the fleet)
        // must come back as a typed RuntimeError and leave the moderator
        // usable — a panic here would take down a live session driving
        // the shim.
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        let bad = PipelineSpec::new(
            0,
            "bad",
            SourceReq::Device(DeviceId(17)),
            model_by_name(ModelName::KWS).clone(),
            TargetReq::Any,
        );
        let err = m.register_app(bad).unwrap_err();
        assert!(matches!(err, RuntimeError::Plan(_)), "{err:?}");
        assert!(m.deployment().is_none());
        assert!(m.apps().is_empty());
        // Recovery: the same moderator still accepts a plannable app.
        m.register_app(app(0, ModelName::KWS)).unwrap();
        assert!(m.deployment().is_some());
    }
}
