//! The moderator (§III-C "moderator-initiated orchestration"): discovers
//! and manages devices, accepts app registrations through the
//! device-agnostic interface, and triggers holistic orchestration whenever
//! apps or device availability change. Once deployed, runtime inference
//! proceeds without it.

use crate::device::Fleet;
use crate::estimator::{estimate_plan, LatencyModel, PlanEstimate};
use crate::orchestrator::{PlanError, Planner};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::CollabPlan;
use crate::scheduler::{simulate, GroundTruth, Policy, SimConfig, SimReport};

/// A selected + checked holistic collaboration plan, ready to deploy.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub plan: CollabPlan,
    pub policy: Policy,
    pub estimate: PlanEstimate,
}

/// The orchestration moderator.
pub struct Moderator<P: Planner> {
    fleet: Fleet,
    planner: P,
    apps: Vec<PipelineSpec>,
    deployment: Option<Deployment>,
    /// Orchestrations performed (diagnostics; †every app/fleet change
    /// triggers exactly one).
    pub orchestrations: usize,
}

impl<P: Planner> Moderator<P> {
    pub fn new(fleet: Fleet, planner: P) -> Moderator<P> {
        Moderator {
            fleet,
            planner,
            apps: Vec::new(),
            deployment: None,
            orchestrations: 0,
        }
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    pub fn apps(&self) -> &[PipelineSpec] {
        &self.apps
    }

    pub fn deployment(&self) -> Option<&Deployment> {
        self.deployment.as_ref()
    }

    /// Register an app pipeline; triggers re-orchestration.
    pub fn register_app(&mut self, spec: PipelineSpec) -> Result<&Deployment, PlanError> {
        assert!(
            self.apps.iter().all(|a| a.id != spec.id),
            "duplicate pipeline id {:?}",
            spec.id
        );
        self.apps.push(spec);
        self.orchestrate()
    }

    /// Remove an app; triggers re-orchestration (no-op plan when empty).
    pub fn remove_app(&mut self, id: PipelineId) -> Result<Option<&Deployment>, PlanError> {
        self.apps.retain(|a| a.id != id);
        if self.apps.is_empty() {
            self.deployment = None;
            return Ok(None);
        }
        self.orchestrate().map(Some)
    }

    /// Replace the fleet (device joined/left); triggers re-orchestration.
    pub fn set_fleet(&mut self, fleet: Fleet) -> Result<Option<&Deployment>, PlanError> {
        self.fleet = fleet;
        if self.apps.is_empty() {
            return Ok(None);
        }
        self.orchestrate().map(Some)
    }

    /// Run holistic orchestration over the current apps + fleet.
    pub fn orchestrate(&mut self) -> Result<&Deployment, PlanError> {
        self.orchestrations += 1;
        let plan = self.planner.plan(&self.apps, &self.fleet)?;
        debug_assert!(plan.check_runnable(&self.apps, &self.fleet).is_ok());
        let lm = LatencyModel::new(&self.fleet);
        let estimate = estimate_plan(&plan, &self.apps, &self.fleet, &lm);
        self.deployment = Some(Deployment {
            plan,
            policy: self.planner.exec_policy(),
            estimate,
        });
        Ok(self.deployment.as_ref().unwrap())
    }

    /// Execute the current deployment on the simulated hardware.
    pub fn simulate(&self, runs: usize, seed: u64) -> Option<SimReport> {
        let dep = self.deployment.as_ref()?;
        let gt = GroundTruth::with_seed(seed);
        Some(simulate(
            &dep.plan,
            &self.apps,
            &self.fleet,
            &gt,
            SimConfig {
                runs,
                warmup: (runs / 6).min(4),
                policy: dep.policy,
                record_trace: false,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceId;
    use crate::model::zoo::{model_by_name, ModelName};
    use crate::orchestrator::Synergy;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::workload::{fleet4, fleet_n};

    fn app(id: usize, m: ModelName) -> PipelineSpec {
        PipelineSpec::new(
            id,
            m.as_str(),
            SourceReq::Device(DeviceId(0)),
            model_by_name(m).clone(),
            TargetReq::Device(DeviceId(1)),
        )
    }

    #[test]
    fn registration_triggers_orchestration() {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS)).unwrap();
        assert_eq!(m.orchestrations, 1);
        assert_eq!(m.deployment().unwrap().plan.plans.len(), 1);
        m.register_app(app(1, ModelName::SimpleNet)).unwrap();
        assert_eq!(m.orchestrations, 2);
        assert_eq!(m.deployment().unwrap().plan.plans.len(), 2);
    }

    #[test]
    fn device_change_reorchestrates() {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::UNet)).unwrap();
        let before = m.deployment().unwrap().estimate.throughput;
        m.set_fleet(fleet_n(2)).unwrap();
        assert_eq!(m.orchestrations, 2);
        let after = m.deployment().unwrap().estimate.throughput;
        assert!(before > 0.0 && after > 0.0);
    }

    #[test]
    fn removal_clears_deployment_when_empty() {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS)).unwrap();
        m.remove_app(PipelineId(0)).unwrap();
        assert!(m.deployment().is_none());
    }

    #[test]
    fn simulate_executes_deployment() {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS)).unwrap();
        let rep = m.simulate(12, 7).unwrap();
        assert_eq!(rep.completions, 12);
        assert!(rep.throughput > 0.0);
    }

    #[test]
    #[should_panic(expected = "duplicate pipeline id")]
    fn duplicate_ids_rejected() {
        let mut m = Moderator::new(fleet4(), Synergy::planner());
        m.register_app(app(0, ModelName::KWS)).unwrap();
        let _ = m.register_app(app(0, ModelName::SimpleNet));
    }
}
