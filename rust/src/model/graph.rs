//! Sequential model graphs and split ranges.

use super::layer::{Layer, Shape};

/// A contiguous layer range `[start, end)` — the unit of model splitting
/// (`Model^{i:j}` in the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SplitRange {
    pub start: usize,
    pub end: usize,
}

impl SplitRange {
    pub fn new(start: usize, end: usize) -> SplitRange {
        assert!(start < end, "empty split range {start}..{end}");
        SplitRange { start, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        false // construction forbids empty ranges
    }
}

impl std::fmt::Display for SplitRange {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.start, self.end)
    }
}

/// A model as a sequence of layer units with a fixed input shape.
///
/// Treat a constructed `ModelGraph` as immutable: the shape cache, the
/// prefix sums, and the `uid` that keys the estimator's latency memo are
/// all computed once in [`ModelGraph::new`]. Mutating the public fields of
/// an existing instance (rather than building a new one) leaves every one
/// of those derived values stale. Build variants with `ModelGraph::new`.
#[derive(Clone, Debug)]
pub struct ModelGraph {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
    /// Process-unique id assigned at construction, used as a memoization
    /// key by the estimator (clones keep the id: a clone's content — and
    /// therefore every latency derived from it — is identical, so sharing
    /// cache entries is sound; two *independently built* models never
    /// collide, even when they share a name).
    uid: u64,
    /// Cached per-layer input shapes: `shapes[l]` is the input of layer `l`,
    /// `shapes[L]` is the final output.
    shapes: Vec<Shape>,
    /// Prefix sums for O(1) range queries (the planner evaluates tens of
    /// thousands of candidate ranges per orchestration — §Perf).
    prefix_w: Vec<u64>,
    prefix_b: Vec<u64>,
    /// Accelerator cycles at P = 64 (the MAX78000/78002 lane count).
    prefix_cycles_p64: Vec<u64>,
}

static NEXT_MODEL_UID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl ModelGraph {
    pub fn new(name: impl Into<String>, input: Shape, layers: Vec<Layer>) -> ModelGraph {
        assert!(!layers.is_empty(), "model must have at least one layer");
        let mut shapes = Vec::with_capacity(layers.len() + 1);
        shapes.push(input);
        let mut prev = input;
        for l in &layers {
            prev = l.out_shape(prev);
            shapes.push(prev);
        }
        let mut prefix_w = Vec::with_capacity(layers.len() + 1);
        let mut prefix_b = Vec::with_capacity(layers.len() + 1);
        let mut prefix_cycles_p64 = Vec::with_capacity(layers.len() + 1);
        prefix_w.push(0);
        prefix_b.push(0);
        prefix_cycles_p64.push(0);
        for (i, l) in layers.iter().enumerate() {
            prefix_w.push(prefix_w[i] + l.weight_bytes(shapes[i]));
            prefix_b.push(prefix_b[i] + l.bias_bytes(shapes[i]));
            prefix_cycles_p64
                .push(prefix_cycles_p64[i] + crate::estimator::clock::layer_cycles_accel(l, shapes[i], 64));
        }
        ModelGraph {
            name: name.into(),
            input,
            layers,
            uid: NEXT_MODEL_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            shapes,
            prefix_w,
            prefix_b,
            prefix_cycles_p64,
        }
    }

    /// Process-unique id for estimator memoization (see the field docs).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Input shape of layer `l` (0-based).
    pub fn in_shape(&self, l: usize) -> Shape {
        self.shapes[l]
    }

    /// Output shape of layer `l`.
    pub fn out_shape(&self, l: usize) -> Shape {
        self.shapes[l + 1]
    }

    /// Final output shape of the whole model.
    pub fn output(&self) -> Shape {
        // `shapes` holds layers.len() + 1 entries by construction.
        self.shapes[self.layers.len()]
    }

    /// Output bytes of layer `l` (8-bit activations).
    pub fn out_bytes(&self, l: usize) -> u64 {
        self.out_shape(l).bytes()
    }

    /// Input bytes of the model.
    pub fn in_bytes(&self) -> u64 {
        self.input.bytes()
    }

    /// Total weight bytes of a layer range — O(1) via prefix sums.
    pub fn weight_bytes(&self, r: SplitRange) -> u64 {
        self.prefix_w[r.end] - self.prefix_w[r.start]
    }

    /// Total bias bytes of a layer range — O(1) via prefix sums.
    pub fn bias_bytes(&self, r: SplitRange) -> u64 {
        self.prefix_b[r.end] - self.prefix_b[r.start]
    }

    /// Accelerator cycles of a layer range at P = 64 — O(1) (the hot case;
    /// other lane counts go through `estimator::clock`).
    pub fn cycles_p64(&self, r: SplitRange) -> u64 {
        self.prefix_cycles_p64[r.end] - self.prefix_cycles_p64[r.start]
    }

    /// Full-model range.
    pub fn full(&self) -> SplitRange {
        SplitRange::new(0, self.num_layers())
    }

    /// Total model size (weights + biases), the "Model Size" of Table I.
    pub fn size_bytes(&self) -> u64 {
        self.weight_bytes(self.full()) + self.bias_bytes(self.full())
    }

    /// Total MACs of a layer range.
    pub fn macs(&self, r: SplitRange) -> u64 {
        (r.start..r.end)
            .map(|l| self.layers[l].macs(self.in_shape(l)))
            .sum()
    }

    /// Bytes crossing the boundary *after* layer `l` (what a split at `l+1`
    /// would transmit). `boundary_bytes(L-1)` is the final output size.
    pub fn boundary_bytes(&self, l: usize) -> u64 {
        self.out_bytes(l)
    }

    /// The paper's data intensity metric (§IV-D):
    /// `(In_size + Σ_l Out_size_l) / (L + 1)` — the average data size a
    /// transmission would carry across all split positions.
    pub fn data_intensity(&self) -> f64 {
        let total: u64 = self.in_bytes() + (0..self.num_layers()).map(|l| self.out_bytes(l)).sum::<u64>();
        total as f64 / (self.num_layers() + 1) as f64
    }

    /// Average output size, the "Avg. Out Size" column of Table I:
    /// mean over layer outputs only.
    pub fn avg_out_bytes(&self) -> f64 {
        let total: u64 = (0..self.num_layers()).map(|l| self.out_bytes(l)).sum();
        total as f64 / self.num_layers() as f64
    }

    /// All contiguous split points: a d-way split is described by d-1
    /// boundaries; this returns the valid single boundaries 1..L.
    pub fn split_points(&self) -> impl Iterator<Item = usize> + '_ {
        1..self.num_layers()
    }

    /// Partition the model into `parts` contiguous chunks at the given
    /// ascending boundaries (each in `1..L`).
    pub fn split_at(&self, boundaries: &[usize]) -> Vec<SplitRange> {
        let mut prev = 0;
        let mut out = Vec::with_capacity(boundaries.len() + 1);
        for &b in boundaries {
            assert!(b > prev && b < self.num_layers(), "bad boundary {b}");
            out.push(SplitRange::new(prev, b));
            prev = b;
        }
        out.push(SplitRange::new(prev, self.num_layers()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::LayerKind;

    fn toy() -> ModelGraph {
        // 3-layer toy: conv(1→8) @8×8, conv pool2 (8→16) @4×4, linear → 10.
        ModelGraph::new(
            "toy",
            Shape::new(8, 8, 1),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 2, cout: 16, residual: false, has_bias: true },
                Layer { kind: LayerKind::Linear, pool: 1, cout: 10, residual: false, has_bias: true },
            ],
        )
    }

    #[test]
    fn shapes_propagate() {
        let m = toy();
        assert_eq!(m.in_shape(0), Shape::new(8, 8, 1));
        assert_eq!(m.out_shape(0), Shape::new(8, 8, 8));
        assert_eq!(m.out_shape(1), Shape::new(4, 4, 16));
        assert_eq!(m.output(), Shape::new(1, 1, 10));
    }

    #[test]
    fn sizes_accumulate() {
        let m = toy();
        let w0 = 3 * 3 * 1 * 8;
        let w1 = 3 * 3 * 8 * 16;
        let w2 = 4 * 4 * 16 * 10;
        assert_eq!(m.weight_bytes(m.full()), (w0 + w1 + w2) as u64);
        assert_eq!(m.bias_bytes(m.full()), 8 + 16 + 10);
        assert_eq!(m.size_bytes(), (w0 + w1 + w2 + 34) as u64);
        assert_eq!(
            m.weight_bytes(SplitRange::new(1, 3)),
            (w1 + w2) as u64
        );
    }

    #[test]
    fn data_intensity_matches_formula() {
        let m = toy();
        let expected =
            (64.0 + (8 * 8 * 8) as f64 + (4 * 4 * 16) as f64 + 10.0) / 4.0;
        assert!((m.data_intensity() - expected).abs() < 1e-9);
    }

    #[test]
    fn split_partitions_cover() {
        let m = toy();
        let parts = m.split_at(&[1, 2]);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], SplitRange::new(0, 1));
        assert_eq!(parts[1], SplitRange::new(1, 2));
        assert_eq!(parts[2], SplitRange::new(2, 3));
        // Chunk sizes sum to the full model.
        let total: u64 = parts.iter().map(|&r| m.weight_bytes(r)).sum();
        assert_eq!(total, m.weight_bytes(m.full()));
    }

    #[test]
    #[should_panic(expected = "bad boundary")]
    fn split_rejects_out_of_range() {
        toy().split_at(&[3]);
    }

    #[test]
    fn uids_distinguish_instances_but_not_clones() {
        let a = toy();
        let b = toy(); // same name + content, independently built
        assert_ne!(a.uid(), b.uid(), "independent builds must not collide");
        assert_eq!(a.uid(), a.clone().uid(), "clones share content and uid");
    }
}
