//! Model descriptions: layer shape algebra and the paper's 8-model zoo
//! (Table I) plus the FaceID model used by the Fig. 2 microbenchmark.
//!
//! A model is a *sequence of layer units* (the paper's splittable unit:
//! `EfficientNet^{i:j}` means units i..j). A unit may internally carry a
//! residual connection, but externally has one input and one output tensor,
//! which keeps layer-wise splitting linear exactly as in §IV-C.

pub mod layer;
pub mod graph;
pub mod zoo;

pub use graph::{ModelGraph, SplitRange};
pub use layer::{Layer, LayerKind, Shape};
pub use zoo::{model_by_name, zoo, ModelName};
