//! Layer unit shape algebra.
//!
//! Semantics follow the MAX78000's CNN accelerator conventions:
//! - pooling (when present) runs *before* the convolution in the same layer
//!   unit (that is how ai8x layers are synthesized);
//! - convolutions are 'same'-padded (pad = k/2) with stride 1;
//! - transpose convolutions upsample 2×;
//! - weights/activations are 8-bit, so weight bytes = parameter count and
//!   activation bytes = element count (Table I sizes are byte counts).

use std::fmt;

/// A (height, width, channels) activation shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn new(h: usize, w: usize, c: usize) -> Shape {
        Shape { h, w, c }
    }

    /// Number of elements in the activation tensor, independent of dtype
    /// width. Use this to size element buffers (e.g. the f32 tensors the
    /// PJRT path moves around); use [`Shape::bytes`] for on-accelerator
    /// memory accounting.
    pub fn elements(&self) -> u64 {
        (self.h * self.w * self.c) as u64
    }

    /// On-accelerator byte count. Weights/activations are 8-bit on the
    /// MAX78000 class, so this equals [`Shape::elements`] — but the two are
    /// distinct quantities and must not be interchanged (an f32 buffer has
    /// `elements()` entries and `4 × elements()` bytes).
    pub fn bytes(&self) -> u64 {
        self.elements()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}×{}×{}", self.h, self.w, self.c)
    }
}

/// The kinds of layer units the zoo uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution, kernel `k`, 'same' padding, stride 1.
    Conv2d { k: usize },
    /// Depthwise convolution (cout == cin), kernel `k`.
    DepthwiseConv2d { k: usize },
    /// Transpose convolution upsampling 2× (UNet decoder).
    ConvTranspose2d { k: usize },
    /// Fully connected over the flattened input.
    Linear,
}

/// One splittable layer unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layer {
    pub kind: LayerKind,
    /// Max-pool factor applied before the op (1 = none).
    pub pool: usize,
    /// Output channels (== input channels for depthwise).
    pub cout: usize,
    /// Residual add across this unit (documentation/MAC bookkeeping only;
    /// does not change shapes or split semantics).
    pub residual: bool,
    /// Whether the layer carries a bias vector. BN-folded expansion and
    /// depthwise convs are synthesized without bias (ai8x option) — bias
    /// memory (2 KB on MAX78000) is the scarcest accelerator resource.
    pub has_bias: bool,
}

impl Layer {
    /// Shape after the pre-op pooling step.
    pub fn pooled(&self, input: Shape) -> Shape {
        Shape::new(input.h / self.pool, input.w / self.pool, input.c)
    }

    /// Output shape given the unit's input shape.
    pub fn out_shape(&self, input: Shape) -> Shape {
        let p = self.pooled(input);
        match self.kind {
            // 'same' padding, stride 1: spatial dims preserved.
            LayerKind::Conv2d { .. } => Shape::new(p.h, p.w, self.cout),
            LayerKind::DepthwiseConv2d { .. } => Shape::new(p.h, p.w, p.c),
            LayerKind::ConvTranspose2d { .. } => Shape::new(p.h * 2, p.w * 2, self.cout),
            LayerKind::Linear => Shape::new(1, 1, self.cout),
        }
    }

    /// Weight bytes (8-bit): parameter count of the op.
    pub fn weight_bytes(&self, input: Shape) -> u64 {
        let p = self.pooled(input);
        match self.kind {
            LayerKind::Conv2d { k } => (k * k * p.c * self.cout) as u64,
            LayerKind::DepthwiseConv2d { k } => (k * k * p.c) as u64,
            LayerKind::ConvTranspose2d { k } => (k * k * p.c * self.cout) as u64,
            LayerKind::Linear => (p.h * p.w * p.c * self.cout) as u64,
        }
    }

    /// Bias bytes: one per output channel (MAX78000 bias memory is per
    /// output channel); zero for bias-free layers.
    pub fn bias_bytes(&self, input: Shape) -> u64 {
        if self.has_bias {
            self.out_shape(input).c as u64
        } else {
            0
        }
    }

    /// Multiply-accumulate count (for roofline/diagnostics; the latency
    /// model uses clock cycles, not MACs — see `estimator::clock`).
    pub fn macs(&self, input: Shape) -> u64 {
        let p = self.pooled(input);
        let o = self.out_shape(input);
        match self.kind {
            LayerKind::Conv2d { k } => (k * k * o.h * o.w * p.c * o.c) as u64,
            LayerKind::DepthwiseConv2d { k } => (k * k * o.h * o.w * o.c) as u64,
            LayerKind::ConvTranspose2d { k } => (k * k * o.h * o.w * p.c * o.c) as u64,
            LayerKind::Linear => (p.h * p.w * p.c * o.c) as u64,
        }
    }

    /// Kernel size (1 for Linear).
    pub fn kernel(&self) -> usize {
        match self.kind {
            LayerKind::Conv2d { k }
            | LayerKind::DepthwiseConv2d { k }
            | LayerKind::ConvTranspose2d { k } => k,
            LayerKind::Linear => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const IN: Shape = Shape { h: 28, w: 28, c: 16 };

    #[test]
    fn conv_same_preserves_spatial() {
        let l = Layer {
            kind: LayerKind::Conv2d { k: 3 },
            pool: 1,
            cout: 32,
            residual: false,
            has_bias: true,
        };
        assert_eq!(l.out_shape(IN), Shape::new(28, 28, 32));
        assert_eq!(l.weight_bytes(IN), 3 * 3 * 16 * 32);
        assert_eq!(l.bias_bytes(IN), 32);
        assert_eq!(l.macs(IN), 9 * 28 * 28 * 16 * 32);
    }

    #[test]
    fn pool_halves_before_conv() {
        let l = Layer {
            kind: LayerKind::Conv2d { k: 3 },
            pool: 2,
            cout: 8,
            residual: false,
            has_bias: true,
        };
        assert_eq!(l.out_shape(IN), Shape::new(14, 14, 8));
        // Weight count is unaffected by pooling.
        assert_eq!(l.weight_bytes(IN), 3 * 3 * 16 * 8);
    }

    #[test]
    fn depthwise_keeps_channels() {
        let l = Layer {
            kind: LayerKind::DepthwiseConv2d { k: 3 },
            pool: 1,
            cout: 16, // ignored: depthwise keeps cin
            residual: false,
            has_bias: true,
        };
        assert_eq!(l.out_shape(IN), Shape::new(28, 28, 16));
        assert_eq!(l.weight_bytes(IN), 9 * 16);
        assert_eq!(l.macs(IN), 9 * 28 * 28 * 16);
    }

    #[test]
    fn transpose_doubles_spatial() {
        let l = Layer {
            kind: LayerKind::ConvTranspose2d { k: 3 },
            pool: 1,
            cout: 4,
            residual: false,
            has_bias: true,
        };
        assert_eq!(l.out_shape(IN), Shape::new(56, 56, 4));
    }

    #[test]
    fn linear_flattens() {
        let l = Layer {
            kind: LayerKind::Linear,
            pool: 1,
            cout: 10,
            residual: false,
            has_bias: true,
        };
        assert_eq!(l.out_shape(IN), Shape::new(1, 1, 10));
        assert_eq!(l.weight_bytes(IN), 28 * 28 * 16 * 10);
        assert_eq!(l.bias_bytes(IN), 10);
    }

    #[test]
    fn shape_bytes_are_elements() {
        assert_eq!(Shape::new(48, 48, 48).bytes(), 110_592);
    }

    #[test]
    fn elements_count_entries_not_f32_bytes() {
        // Regression for the serve/executor input-sizing audit: element
        // buffers (f32 tensors on the PJRT path) are sized with
        // `elements()`, which must equal h·w·c — never the 4× figure an
        // f32 *byte* count would give.
        let s = Shape::new(64, 64, 3);
        assert_eq!(s.elements(), 64 * 64 * 3);
        assert_eq!(s.elements(), s.bytes(), "8-bit accounting coincides");
        assert_ne!(s.elements(), 4 * 64 * 64 * 3);
    }
}
