//! The model zoo: the paper's 8 Table I models plus FaceID (Fig. 2).
//!
//! Architectures live in `python/compile/archs.json` — the single source of
//! truth shared with the Python/JAX build path (`python/compile/archs.py`),
//! fitted at design time by `design_zoo.py` so that every model matches
//! Table I's layer count, total size, input shape, and average output size
//! to within 0.1%. The JSON is compiled into the binary via `include_str!`.

use std::collections::BTreeMap;

use once_cell::sync::Lazy;

use super::graph::ModelGraph;
use super::layer::{Layer, LayerKind, Shape};
use crate::util::json::Json;

/// The canonical arch spec, shared with Python.
pub const ARCHS_JSON: &str = include_str!("../../../python/compile/archs.json");

/// Names of the Table I models, in pipeline order (1..=8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelName {
    ConvNet5,
    ResSimpleNet,
    UNet,
    KWS,
    SimpleNet,
    WideNet,
    EfficientNetV2,
    MobileNetV2,
    /// Not in Table I; used by the Fig. 2 microbenchmark.
    FaceID,
}

impl ModelName {
    pub const TABLE1: [ModelName; 8] = [
        ModelName::ConvNet5,
        ModelName::ResSimpleNet,
        ModelName::UNet,
        ModelName::KWS,
        ModelName::SimpleNet,
        ModelName::WideNet,
        ModelName::EfficientNetV2,
        ModelName::MobileNetV2,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelName::ConvNet5 => "ConvNet5",
            ModelName::ResSimpleNet => "ResSimpleNet",
            ModelName::UNet => "UNet",
            ModelName::KWS => "KWS",
            ModelName::SimpleNet => "SimpleNet",
            ModelName::WideNet => "WideNet",
            ModelName::EfficientNetV2 => "EfficientNetV2",
            ModelName::MobileNetV2 => "MobileNetV2",
            ModelName::FaceID => "FaceID",
        }
    }

    pub fn parse(s: &str) -> Option<ModelName> {
        Self::TABLE1
            .iter()
            .chain([&ModelName::FaceID])
            .copied()
            .find(|m| m.as_str().eq_ignore_ascii_case(s))
    }
}

impl std::fmt::Display for ModelName {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

fn parse_layer(j: &Json) -> Layer {
    let kind_s = j.get("kind").and_then(Json::as_str).expect("layer.kind");
    let k = j.get("k").and_then(Json::as_usize).expect("layer.k");
    let pool = j.get("pool").and_then(Json::as_usize).expect("layer.pool");
    let cout = j.get("cout").and_then(Json::as_usize).expect("layer.cout");
    let residual = j
        .get("residual")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    let has_bias = j.get("bias").and_then(Json::as_bool).unwrap_or(true);
    let kind = match kind_s {
        "conv" => LayerKind::Conv2d { k },
        "dw" => LayerKind::DepthwiseConv2d { k },
        "convt" => LayerKind::ConvTranspose2d { k },
        "linear" => LayerKind::Linear,
        other => panic!("unknown layer kind {other:?} in archs.json"),
    };
    Layer {
        kind,
        pool,
        cout,
        residual,
        has_bias,
    }
}

fn parse_archs() -> BTreeMap<String, ModelGraph> {
    let root = Json::parse(ARCHS_JSON).expect("archs.json must parse");
    let obj = root.as_obj().expect("archs.json must be an object");
    obj.iter()
        .map(|(name, spec)| {
            let input = spec.get("input").and_then(Json::as_arr).expect("input");
            let shape = Shape::new(
                input[0].as_usize().unwrap(),
                input[1].as_usize().unwrap(),
                input[2].as_usize().unwrap(),
            );
            let layers: Vec<Layer> = spec
                .get("layers")
                .and_then(Json::as_arr)
                .expect("layers")
                .iter()
                .map(parse_layer)
                .collect();
            (name.clone(), ModelGraph::new(name.clone(), shape, layers))
        })
        .collect()
}

static ZOO: Lazy<BTreeMap<String, ModelGraph>> = Lazy::new(parse_archs);

/// All models in the zoo, keyed by name.
pub fn zoo() -> &'static BTreeMap<String, ModelGraph> {
    &ZOO
}

/// Look up a model by enum name.
pub fn model_by_name(name: ModelName) -> &'static ModelGraph {
    ZOO.get(name.as_str())
        .unwrap_or_else(|| panic!("{name} missing from archs.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I ground truth: (model, layers, size bytes, input, avg out).
    const TABLE1: [(ModelName, usize, u64, (usize, usize, usize), f64); 8] = [
        (ModelName::ConvNet5, 5, 71158, (28, 28, 1), 14031.0),
        (ModelName::ResSimpleNet, 14, 381792, (32, 32, 3), 11217.0),
        (ModelName::UNet, 19, 279084, (48, 48, 48), 74547.0),
        (ModelName::KWS, 9, 169472, (128, 128, 1), 7976.0),
        (ModelName::SimpleNet, 14, 166448, (32, 32, 3), 9237.0),
        (ModelName::WideNet, 14, 313700, (32, 32, 3), 10091.0),
        (ModelName::EfficientNetV2, 29, 627220, (32, 32, 3), 66468.0),
        (ModelName::MobileNetV2, 28, 821164, (32, 32, 3), 296318.0),
    ];

    #[test]
    fn zoo_has_all_models() {
        assert_eq!(zoo().len(), 9); // 8 Table I + FaceID
        for (name, ..) in TABLE1 {
            assert!(zoo().contains_key(name.as_str()), "{name} missing");
        }
    }

    #[test]
    fn matches_table1_within_half_percent() {
        for (name, layers, size, input, avg_out) in TABLE1 {
            let m = model_by_name(name);
            assert_eq!(m.num_layers(), layers, "{name} layer count");
            assert_eq!(
                (m.input.h, m.input.w, m.input.c),
                input,
                "{name} input shape"
            );
            let size_err = (m.size_bytes() as f64 - size as f64).abs() / size as f64;
            assert!(
                size_err < 0.005,
                "{name} size {} vs Table I {size} ({:.2}% off)",
                m.size_bytes(),
                size_err * 100.0
            );
            let out_err = (m.avg_out_bytes() - avg_out).abs() / avg_out;
            assert!(
                out_err < 0.005,
                "{name} avg out {:.0} vs Table I {avg_out} ({:.2}% off)",
                m.avg_out_bytes(),
                out_err * 100.0
            );
        }
    }

    #[test]
    fn paper_layer_counts_for_named_models() {
        // §IV-D quotes "a 9-layer KWS, a 14-layer SimpleNet, and a 19-layer
        // UNet"; §IV-C says EfficientNet has 29 layers.
        assert_eq!(model_by_name(ModelName::KWS).num_layers(), 9);
        assert_eq!(model_by_name(ModelName::SimpleNet).num_layers(), 14);
        assert_eq!(model_by_name(ModelName::UNet).num_layers(), 19);
        assert_eq!(model_by_name(ModelName::EfficientNetV2).num_layers(), 29);
    }

    #[test]
    fn unet_fits_max78000_weight_memory_only_when_split() {
        // UNet (279 KB) exceeds nothing alone, but MobileNetV2 (821 KB)
        // exceeds the MAX78000's 442 KB weight memory — the motivating case
        // for splitting large models (§II-B).
        let mobilenet = model_by_name(ModelName::MobileNetV2);
        assert!(mobilenet.weight_bytes(mobilenet.full()) > 442 * 1024);
        let unet = model_by_name(ModelName::UNet);
        assert!(unet.weight_bytes(unet.full()) < 442 * 1024);
    }

    #[test]
    fn model_name_parse_roundtrip() {
        for m in ModelName::TABLE1 {
            assert_eq!(ModelName::parse(m.as_str()), Some(m));
        }
        assert_eq!(ModelName::parse("kws"), Some(ModelName::KWS));
        assert_eq!(ModelName::parse("nope"), None);
    }

    #[test]
    fn data_intensity_ordering_unet_highest_of_small() {
        // UNet moves far more data per boundary than KWS/SimpleNet —
        // the premise behind data-intensity prioritization (§IV-D).
        let unet = model_by_name(ModelName::UNet).data_intensity();
        let kws = model_by_name(ModelName::KWS).data_intensity();
        let simple = model_by_name(ModelName::SimpleNet).data_intensity();
        assert!(unet > kws && unet > simple);
    }
}
