//! Minimal property-based testing harness (proptest is not vendored).
//!
//! `check(seed-cases, generator, property)` runs a property over many random
//! inputs from a deterministic PRNG; on failure it reports the failing case's
//! seed and `Debug` form so the case can be replayed with `check_one`.
//! No shrinking — generators are encouraged to produce small cases directly
//! (sizes are drawn log-uniformly towards small values).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn from `gen`. Panics (with the
/// case seed and value) on the first failing case or property panic.
pub fn check<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property failed at case {case_idx} (seed {case_seed:#x}):\n  {msg}\n  input: {value:#?}"
            );
        }
    }
}

/// Replay a single case by its reported seed.
pub fn check_one<T, G, P>(case_seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    let value = gen(&mut rng);
    if let Err(msg) = prop(&value) {
        panic!("replayed property failed (seed {case_seed:#x}):\n  {msg}\n  input: {value:#?}");
    }
}

/// Draw a size biased towards small values: log-uniform over [lo, hi].
pub fn small_size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo >= 1 && hi >= lo);
    let llo = (lo as f64).ln();
    let lhi = (hi as f64 + 1.0).ln();
    let v = rng.range_f64(llo, lhi).exp() as usize;
    v.clamp(lo, hi)
}

/// Assert helper returning `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            Config { cases: 50, seed: 1 },
            |rng| rng.range(0, 100),
            |&x| {
                n += 1;
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 50, seed: 2 },
            |rng| rng.range(0, 10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 5"))
                }
            },
        );
    }

    #[test]
    fn small_size_respects_bounds_and_skews_small() {
        let mut rng = Rng::new(5);
        let mut small = 0;
        for _ in 0..1000 {
            let s = small_size(&mut rng, 1, 100);
            assert!((1..=100).contains(&s));
            if s <= 10 {
                small += 1;
            }
        }
        // log-uniform: ~half the draws land in [1, 10].
        assert!(small > 350, "only {small} small draws");
    }

    #[test]
    fn prop_assert_macro() {
        fn inner(x: i32) -> Result<(), String> {
            prop_assert!(x > 0, "x must be positive, got {x}");
            Ok(())
        }
        assert!(inner(1).is_ok());
        assert_eq!(inner(-1).unwrap_err(), "x must be positive, got -1");
    }
}
