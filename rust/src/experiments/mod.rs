//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! Every experiment regenerates its table/figure's rows on the simulated
//! hardware substrate and prints them next to the paper's reported values
//! where the paper gives numbers. Run via `synergy exp <id>` or
//! `synergy exp all`; results are recorded in EXPERIMENTS.md.

pub mod common;
pub mod fig2;
pub mod fig4;
pub mod fig8;
pub mod fig9;
pub mod fig11;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod fig18;
pub mod fig19;
pub mod table2;
pub mod table3;

use crate::util::cli::Args;

/// An experiment: id, one-line description, and the runner.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub runner: fn(&Args) -> String,
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig2", paper_ref: "Fig. 2 — accelerator vs MCU latency/energy", runner: fig2::run },
        Experiment { id: "fig4", paper_ref: "Fig. 4 — Synergy vs phone offloading", runner: fig4::run },
        Experiment { id: "fig8", paper_ref: "Fig. 8 — UNet layer-wise latency decomposition", runner: fig8::run },
        Experiment { id: "fig9", paper_ref: "Fig. 9 — prioritization strategies vs Oracle", runner: fig9::run },
        Experiment { id: "fig11", paper_ref: "Fig. 11 — params vs clock-cycle latency correlation", runner: fig11::run },
        Experiment { id: "fig15", paper_ref: "Fig. 15 — overall performance, 4 workloads × 8 methods", runner: fig15::run },
        Experiment { id: "table2", paper_ref: "Table II — ablation (JRC/STT/PSR/ATP)", runner: table2::run },
        Experiment { id: "fig16a", paper_ref: "Fig. 16a — number of devices", runner: fig16::run_a },
        Experiment { id: "fig16b", paper_ref: "Fig. 16b — number of pipelines", runner: fig16::run_b },
        Experiment { id: "fig17", paper_ref: "Fig. 17 — heterogeneous accelerator composition", runner: fig17::run },
        Experiment { id: "fig18", paper_ref: "Fig. 18 — source/target mappings", runner: fig18::run },
        Experiment { id: "table3", paper_ref: "Table III — objectives (TPUT/Latency/Power)", runner: table3::run },
        Experiment { id: "fig19", paper_ref: "Fig. 19 — Power-min objective across methods", runner: fig19::run },
    ]
}

/// Run one experiment by id (or `all`), returning the rendered report.
pub fn run(id: &str, args: &Args) -> Option<String> {
    if id == "all" {
        let mut out = String::new();
        for e in registry() {
            out.push_str(&format!("\n===== {} ({}) =====\n", e.id, e.paper_ref));
            out.push_str(&(e.runner)(args));
        }
        return Some(out);
    }
    registry()
        .into_iter()
        .find(|e| e.id == id)
        .map(|e| (e.runner)(args))
}
