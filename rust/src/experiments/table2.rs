//! Table II — ablation of Synergy's components on Workloads 1–2:
//!
//! | row            | planner                                   | execution  |
//! |----------------|-------------------------------------------|------------|
//! | (none)         | IndModel                                  | sequential |
//! | JRC            | JointModel (joint resource consideration) | sequential |
//! | JRC+STT        | JointE2E (adds source/target awareness)   | sequential |
//! | JRC+STT+PSR    | progressive accumulation (holistic score) | sequential |
//! | +ATP (Synergy) | progressive accumulation                  | ATP        |
//!
//! Paper: W1 OOR → 0.06 → 0.92 → 2.72 → 4.20 inf/s; W2 OOR → 2.30 → 15.28
//! → 15.28 → 29.67, with latency falling and power roughly flat.

use crate::baselines::{IndModel, JointE2E, JointModel};
use crate::experiments::common::{evaluate, Cell};
use crate::orchestrator::{Objective, Priority, ProgressivePlanner};
use crate::scheduler::Policy;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet4, workload};

fn psr_planner(policy: Policy) -> ProgressivePlanner {
    let mut p = ProgressivePlanner::new(Priority::DataIntensityDesc, Objective::TputMax);
    p.policy = policy;
    p
}

pub fn rows(args: &Args, wid: usize) -> Vec<(&'static str, Cell)> {
    let w = workload(wid).expect("Table I workload");
    let f = fleet4();
    vec![
        (
            "IndModel (none)",
            evaluate(&IndModel::default(), "IndModel", &w.pipelines, &f, args),
        ),
        (
            "JRC",
            evaluate(&JointModel::default(), "JointModel", &w.pipelines, &f, args),
        ),
        (
            "JRC+STT",
            evaluate(&JointE2E::default(), "JointE2E", &w.pipelines, &f, args),
        ),
        (
            "JRC+STT+PSR",
            evaluate(&psr_planner(Policy::Sequential), "PSR", &w.pipelines, &f, args),
        ),
        (
            "JRC+STT+PSR+ATP",
            evaluate(&psr_planner(Policy::atp()), "Synergy", &w.pipelines, &f, args),
        ),
    ]
}

pub fn run(args: &Args) -> String {
    let mut out = String::new();
    for wid in [1usize, 2] {
        let mut t = Table::new(["components", "TPUT (inf/s)", "latency (s)", "power (J/s)"]);
        for (label, cell) in rows(args, wid) {
            t.row([
                label.to_string(),
                cell.fmt_tput(),
                cell.fmt_latency(),
                cell.fmt_power(),
            ]);
        }
        out.push_str(&format!("\n--- Workload {wid} ---\n{}", t.render()));
    }
    out.push_str(
        "\npaper W1: OOR → 0.06 → 0.92 → 2.72 → 4.20 inf/s; \
         W2: OOR → 2.30 → 15.28 → 15.28 → 29.67 inf/s\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_component_is_monotone_on_workload1() {
        let args = Args::parse(["--runs".to_string(), "12".to_string()], &["runs"]);
        let r = rows(&args, 1);
        // IndModel OORs; after that throughput must be non-decreasing.
        let tputs: Vec<Option<f64>> = r.iter().map(|(_, c)| c.tput()).collect();
        let mut prev = 0.0;
        for (i, t) in tputs.iter().enumerate().skip(1) {
            let t = t.unwrap_or_else(|| panic!("row {i} OOR"));
            assert!(
                t >= prev * 0.9,
                "row {i} ({}) regressed: {t} < {prev}",
                r[i].0
            );
            prev = prev.max(t);
        }
        // ATP must beat the sequential PSR row.
        let psr = tputs[3].unwrap();
        let atp = tputs[4].unwrap();
        assert!(atp > psr, "ATP {atp} vs PSR {psr}");
    }
}
