//! Shared experiment infrastructure: the method roster (Synergy + the 7
//! baselines), and plan-then-simulate evaluation on the DES ground truth.

use crate::baselines::{Cost, IndE2E, IndModel, JointModel, MaxDev, MinDev, PriMaxDev, PriMinDev};
use crate::device::Fleet;
use crate::orchestrator::{Objective, PlanError, Planner, Synergy};
use crate::pipeline::PipelineSpec;
use crate::scheduler::{simulate, GroundTruth, SimConfig, SimReport};
use crate::util::cli::Args;

/// Measured metrics of one (method, workload) cell; `None` means OOR.
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: &'static str,
    pub result: Option<SimReport>,
    pub error: Option<PlanError>,
}

impl Cell {
    pub fn tput(&self) -> Option<f64> {
        self.result.as_ref().map(|r| r.throughput)
    }

    pub fn latency(&self) -> Option<f64> {
        self.result.as_ref().map(|r| r.avg_latency)
    }

    pub fn power(&self) -> Option<f64> {
        self.result.as_ref().map(|r| r.power_w)
    }

    pub fn fmt_tput(&self) -> String {
        crate::util::table::fmt_or_oor(self.tput(), "")
    }

    pub fn fmt_latency(&self) -> String {
        crate::util::table::fmt_or_oor(self.latency(), "")
    }

    pub fn fmt_power(&self) -> String {
        crate::util::table::fmt_or_oor(self.power(), "")
    }
}

/// The Fig. 15 method roster: Synergy + 7 baselines, in paper order.
pub fn method_roster(objective: Objective, cost: Cost) -> Vec<(&'static str, Box<dyn Planner>)> {
    vec![
        ("Synergy", Box::new(Synergy::with_objective(objective))),
        ("MinDev", Box::new(MinDev)),
        ("MaxDev", Box::new(MaxDev)),
        ("PriMinDev", Box::new(PriMinDev)),
        ("PriMaxDev", Box::new(PriMaxDev)),
        ("IndModel", Box::new(IndModel { cost })),
        ("JointModel", Box::new(JointModel { cost })),
        ("IndE2E", Box::new(IndE2E { cost })),
    ]
}

/// Simulation length from CLI (`--runs`, `--seed`).
pub fn sim_cfg_from(args: &Args, policy: crate::scheduler::Policy) -> (SimConfig, u64) {
    let runs = args.opt_parse("runs", 24usize).max(6);
    let seed = args.opt_parse("seed", 7u64);
    (
        SimConfig {
            runs,
            warmup: (runs / 6).min(4),
            policy,
            record_trace: false,
        },
        seed,
    )
}

/// Plan with `planner`, then execute on the DES with the planner's policy.
pub fn evaluate(
    planner: &dyn Planner,
    method: &'static str,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    args: &Args,
) -> Cell {
    match planner.plan(pipelines, fleet) {
        Ok(plan) => {
            debug_assert!(plan.check_runnable(pipelines, fleet).is_ok());
            let (cfg, seed) = sim_cfg_from(args, planner.exec_policy());
            let gt = GroundTruth::with_seed(seed);
            let report = simulate(&plan, pipelines, fleet, &gt, cfg);
            Cell {
                method,
                result: Some(report),
                error: None,
            }
        }
        Err(e) => Cell {
            method,
            result: None,
            error: Some(e),
        },
    }
}

/// Evaluate the whole roster on one workload.
pub fn evaluate_roster(
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    objective: Objective,
    cost: Cost,
    args: &Args,
) -> Vec<Cell> {
    method_roster(objective, cost)
        .iter()
        .map(|(name, planner)| evaluate(planner.as_ref(), name, pipelines, fleet, args))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{fleet4, workload};

    #[test]
    fn roster_has_eight_methods() {
        assert_eq!(method_roster(Objective::TputMax, Cost::Latency).len(), 8);
    }

    #[test]
    fn evaluate_roster_on_workload1() {
        let args = Args::default();
        let w = workload(1).unwrap();
        let f = fleet4();
        let cells = evaluate_roster(&w.pipelines, &f, Objective::TputMax, Cost::Latency, &args);
        assert_eq!(cells.len(), 8);
        // Synergy must succeed on its own headline workload.
        assert!(cells[0].result.is_some(), "{:?}", cells[0].error);
        // Every successful cell has positive throughput.
        for c in &cells {
            if let Some(t) = c.tput() {
                assert!(t > 0.0, "{}", c.method);
            }
        }
    }
}
