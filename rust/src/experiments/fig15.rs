//! Fig. 15 — overall performance: throughput, latency and power for the
//! four Table I workloads across Synergy and the seven baselines.
//! Paper shape: Synergy always best (avg 23.0× TPUT, −73.9% latency,
//! −15.8% power vs baselines); IndModel OORs on Workloads 1–2; on
//! Workloads 3–4 Synergy beats the runner-up (IndE2E) by 1.8× / 2.2×.

use crate::baselines::Cost;
use crate::experiments::common::evaluate_roster;
use crate::orchestrator::Objective;
use crate::util::cli::Args;
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::workload::{all_workloads, fleet4};

pub fn run(args: &Args) -> String {
    let fleet = fleet4();
    let mut out = String::new();
    let mut tput_gains = Vec::new();
    let mut lat_reductions = Vec::new();
    let mut pow_reductions = Vec::new();
    for w in all_workloads() {
        let cells = evaluate_roster(&w.pipelines, &fleet, Objective::TputMax, Cost::Latency, args);
        let mut t = Table::new(["method", "TPUT (inf/s)", "latency (s)", "power (J/s)"]);
        for c in &cells {
            t.row([
                c.method.to_string(),
                c.fmt_tput(),
                c.fmt_latency(),
                c.fmt_power(),
            ]);
        }
        out.push_str(&format!("\n--- {} ---\n{}", w.name, t.render()));
        let synergy = &cells[0];
        for c in &cells[1..] {
            if let (Some(st), Some(bt)) = (synergy.tput(), c.tput()) {
                tput_gains.push(st / bt);
            }
            if let (Some(sl), Some(bl)) = (synergy.latency(), c.latency()) {
                lat_reductions.push(1.0 - sl / bl);
            }
            if let (Some(sp), Some(bp)) = (synergy.power(), c.power()) {
                pow_reductions.push(1.0 - sp / bp);
            }
        }
    }
    out.push_str(&format!(
        "\nsummary vs baselines (geomean gains): TPUT {:.1}× (paper 23.0×), \
         latency −{:.1}% (paper −73.9%), power {:+.1}% (paper −15.8%)\n",
        geomean(&tput_gains),
        100.0 * crate::util::stats::mean(&lat_reductions),
        -100.0 * crate::util::stats::mean(&pow_reductions),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::workload;

    #[test]
    fn synergy_wins_every_workload() {
        let args = Args::parse(["--runs".to_string(), "12".to_string()], &["runs"]);
        let fleet = fleet4();
        for wid in 1..=4 {
            let w = workload(wid).expect("Table I workload");
            let cells =
                evaluate_roster(&w.pipelines, &fleet, Objective::TputMax, Cost::Latency, &args);
            let synergy = cells[0].tput().expect("Synergy must not OOR");
            for c in &cells[1..] {
                if let Some(t) = c.tput() {
                    assert!(
                        synergy >= t * 0.95,
                        "{}: Synergy {synergy:.2} vs {} {t:.2}",
                        w.name,
                        c.method
                    );
                }
            }
        }
    }

    #[test]
    fn indmodel_oors_under_contention() {
        // Workload 2's three mid-size models collide when placed
        // independently (the paper's IndModel failure).
        let args = Args::parse(["--runs".to_string(), "8".to_string()], &["runs"]);
        let w = workload(2).unwrap();
        let cells = evaluate_roster(&w.pipelines, &fleet4(), Objective::TputMax, Cost::Latency, &args);
        let ind = cells.iter().find(|c| c.method == "IndModel").unwrap();
        assert!(ind.result.is_none(), "IndModel should OOR on W2");
    }
}
