//! Fig. 4 — Synergy (accelerator collaboration) vs smartphone offloading on
//! Workloads 1–2: total throughput and average power. Paper: 57.7× and
//! 28.8× throughput in favor of Synergy, with less or comparable power.

use crate::baselines::PhoneOffload;
use crate::experiments::common::{evaluate, sim_cfg_from};
use crate::orchestrator::Synergy;
use crate::util::cli::Args;
use crate::util::table::{fmt_ratio, Table};
use crate::workload::{fleet4, fleet4_with_phone, workload};

pub fn run(args: &Args) -> String {
    let mut t = Table::new([
        "workload",
        "Synergy TPUT",
        "Offload TPUT",
        "ratio",
        "paper",
        "Synergy W",
        "Offload W",
    ]);
    let paper_ratio = [57.7, 28.8];
    for (i, wid) in [1usize, 2].iter().enumerate() {
        let w = workload(*wid).expect("Table I workload");
        let synergy = evaluate(&Synergy::planner(), "Synergy", &w.pipelines, &fleet4(), args);
        let offload = evaluate(
            &PhoneOffload,
            "PhoneOffload",
            &w.pipelines,
            &fleet4_with_phone(),
            args,
        );
        let (st, ot) = (synergy.tput().unwrap_or(0.0), offload.tput().unwrap_or(0.0));
        t.row([
            w.name.clone(),
            format!("{st:.2}"),
            format!("{ot:.3}"),
            fmt_ratio(st / ot.max(1e-9)),
            fmt_ratio(paper_ratio[i]),
            format!("{:.2}", synergy.power().unwrap_or(0.0)),
            format!("{:.2}", offload.power().unwrap_or(0.0)),
        ]);
    }
    let _ = sim_cfg_from(args, crate::scheduler::Policy::atp());
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synergy_beats_offloading_by_an_order_of_magnitude() {
        let args = Args::default();
        let w = workload(1).unwrap();
        let synergy = evaluate(&Synergy::planner(), "Synergy", &w.pipelines, &fleet4(), &args);
        let offload = evaluate(
            &PhoneOffload,
            "PhoneOffload",
            &w.pipelines,
            &fleet4_with_phone(),
            &args,
        );
        let ratio = synergy.tput().unwrap() / offload.tput().unwrap();
        assert!(ratio > 5.0, "ratio {ratio}");
        // Offloading's continuous raw-data streaming must not be cheaper.
        assert!(offload.power().unwrap() > 0.9 * synergy.power().unwrap());
    }
}
