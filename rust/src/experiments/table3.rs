//! Table III — different objectives on Workloads 1–2: Synergy planning for
//! TPUT-max (default), Latency-min, and Power-min. Each objective must win
//! its own metric; TPUT-max should be the balanced choice (paper: 22.1×
//! the throughput of Power-min at only 1.2× the power on W1).

use crate::experiments::common::evaluate;
use crate::orchestrator::{Objective, Synergy};
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet4, workload};

pub fn cells(args: &Args, wid: usize) -> Vec<(Objective, crate::experiments::common::Cell)> {
    let w = workload(wid).expect("Table I workload");
    let f = fleet4();
    [Objective::TputMax, Objective::LatencyMin, Objective::PowerMin]
        .into_iter()
        .map(|obj| {
            let planner = Synergy::with_objective(obj);
            (obj, evaluate(&planner, obj.name(), &w.pipelines, &f, args))
        })
        .collect()
}

pub fn run(args: &Args) -> String {
    let mut out = String::new();
    for wid in [1usize, 2] {
        let mut t = Table::new(["objective", "TPUT (inf/s)", "latency (s)", "power (J/s)"]);
        for (obj, cell) in cells(args, wid) {
            t.row([
                obj.name().to_string(),
                cell.fmt_tput(),
                cell.fmt_latency(),
                cell.fmt_power(),
            ]);
        }
        out.push_str(&format!("\n--- Workload {wid} ---\n{}", t.render()));
    }
    out.push_str(
        "\npaper W1: TPUT-max 4.20/0.86s/1.47W; Latency-min 3.15/0.86/1.42; \
         Power-min 0.19/27.17/1.22\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_objective_wins_its_metric() {
        let args = Args::parse(["--runs".to_string(), "12".to_string()], &["runs"]);
        let rows = cells(&args, 1);
        let get = |o: Objective| {
            rows.iter()
                .find(|(obj, _)| *obj == o)
                .map(|(_, c)| c.result.clone().unwrap())
                .unwrap()
        };
        let tput = get(Objective::TputMax);
        let lat = get(Objective::LatencyMin);
        let pow = get(Objective::PowerMin);
        assert!(tput.throughput >= lat.throughput * 0.95);
        assert!(tput.throughput >= pow.throughput * 0.95);
        assert!(lat.avg_latency <= tput.avg_latency * 1.1);
        assert!(pow.power_w <= tput.power_w * 1.05);
        assert!(pow.power_w <= lat.power_w * 1.05);
    }
}
