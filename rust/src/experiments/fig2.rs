//! Fig. 2 — tiny AI accelerator vs conventional MCUs: inference latency
//! (KWS) and energy (FaceID) on the MAX78000 vs MAX32650 (Cortex-M4) and
//! STM32F7 (Cortex-M7). Paper: KWS 2.0 / 350 / 123 ms; FaceID 0.40 / 42.1 /
//! 464 mJ (STM32F7's energy is worst despite being faster than the M4 —
//! its core draws far more). We reproduce the *ordering and magnitudes*;
//! absolute numbers differ because our fitted models match Table I's sizes,
//! not the authors' MAC counts.

use crate::device::DeviceKind;
use crate::estimator::clock;
use crate::model::zoo::{model_by_name, ModelName};
use crate::util::cli::Args;
use crate::util::table::Table;

struct Platform {
    name: &'static str,
    kind: DeviceKind,
    paper_kws_ms: f64,
    paper_faceid_mj: f64,
}

pub fn run(_args: &Args) -> String {
    let platforms = [
        Platform { name: "MAX78000", kind: DeviceKind::Max78000, paper_kws_ms: 2.0, paper_faceid_mj: 0.40 },
        Platform { name: "MAX32650", kind: DeviceKind::McuMax32650, paper_kws_ms: 350.0, paper_faceid_mj: 42.1 },
        Platform { name: "STM32F7", kind: DeviceKind::McuStm32F7, paper_kws_ms: 123.0, paper_faceid_mj: 464.0 },
    ];
    let kws = model_by_name(ModelName::KWS);
    let faceid = model_by_name(ModelName::FaceID);

    let mut t = Table::new([
        "platform",
        "KWS lat (ms)",
        "paper (ms)",
        "FaceID energy (mJ)",
        "paper (mJ)",
    ]);
    let mut rows = Vec::new();
    for p in &platforms {
        let spec = p.kind.spec();
        let (kws_s, faceid_s, active_w) = match &spec.accel {
            Some(a) => (
                clock::infer_latency_accel(kws, kws.full(), a.parallel_procs, a.clock_hz),
                clock::infer_latency_accel(faceid, faceid.full(), a.parallel_procs, a.clock_hz),
                spec.power.accel_active_w,
            ),
            None => (
                clock::infer_latency_sequential(
                    kws, kws.full(), spec.cpu_clock_hz, spec.cycles_per_mac,
                ),
                clock::infer_latency_sequential(
                    faceid, faceid.full(), spec.cpu_clock_hz, spec.cycles_per_mac,
                ),
                spec.power.cpu_active_w,
            ),
        };
        let energy_mj = faceid_s * active_w * 1e3;
        rows.push((p.name, kws_s * 1e3, energy_mj));
        t.row([
            p.name.to_string(),
            format!("{:.1}", kws_s * 1e3),
            format!("{:.1}", p.paper_kws_ms),
            format!("{:.2}", energy_mj),
            format!("{:.1}", p.paper_faceid_mj),
        ]);
    }

    let mut out = t.render();
    let accel = &rows[0];
    let m4 = &rows[1];
    out.push_str(&format!(
        "\nshape check: accel is {:.0}× faster than the M4 (paper: {:.0}×) and {:.0}× \
         more energy-efficient (paper: {:.0}×)\n",
        m4.1 / accel.1,
        350.0 / 2.0,
        m4.2 / accel.2,
        42.1 / 0.40,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_paper() {
        let report = run(&Args::default());
        assert!(report.contains("MAX78000"));
        // Pull out our measured columns to assert the orderings the figure
        // communicates: accel ≪ both MCUs in latency and energy.
        let lines: Vec<&str> = report.lines().collect();
        let row = |name: &str| -> Vec<f64> {
            lines
                .iter()
                .find(|l| l.starts_with(name))
                .unwrap()
                .split_whitespace()
                .skip(1)
                .filter_map(|x| x.parse().ok())
                .collect()
        };
        let accel = row("MAX78000");
        let m4 = row("MAX32650");
        let m7 = row("STM32F7");
        assert!(accel[0] < m4[0] / 10.0, "latency {accel:?} vs {m4:?}");
        assert!(accel[0] < m7[0] / 10.0);
        assert!(m7[0] < m4[0], "M7 is faster than M4");
        assert!(accel[2] < m4[2] / 10.0, "energy");
        assert!(m7[2] > m4[2], "M7 burns more energy than M4 (paper shape)");
    }
}
