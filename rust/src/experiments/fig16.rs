//! Fig. 16 — runtime environment changes.
//!
//! (a) Number of devices 2–5 with four pipelines (ConvNet5, KWS, SimpleNet,
//!     ResSimpleNet): Synergy's throughput grows with devices and
//!     saturates around 4; most baselines stay flat.
//! (b) Number of pipelines 1–6 (UNet, ConvNet5, SimpleNet, KWS,
//!     ResSimpleNet, WideNet) on four devices: *average* per-pipeline
//!     throughput declines under contention; Synergy stays on top
//!     (paper: 1.35 avg at six pipelines, 19.4× the runner-up).

use crate::baselines::Cost;
use crate::experiments::common::evaluate_roster;
use crate::model::zoo::ModelName;
use crate::orchestrator::Objective;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet_n, pipelines_with_mapping, EndpointMapping};

const FIG16A_MODELS: [ModelName; 4] = [
    ModelName::ConvNet5,
    ModelName::KWS,
    ModelName::SimpleNet,
    ModelName::ResSimpleNet,
];

const FIG16B_MODELS: [ModelName; 6] = [
    ModelName::UNet,
    ModelName::ConvNet5,
    ModelName::SimpleNet,
    ModelName::KWS,
    ModelName::ResSimpleNet,
    ModelName::WideNet,
];

pub fn run_a(args: &Args) -> String {
    let mut t = Table::new(["method", "2 dev", "3 dev", "4 dev", "5 dev"]);
    let mut rows: Vec<Vec<String>> = vec![];
    for ndev in 2..=5 {
        let fleet = fleet_n(ndev);
        let pipelines =
            pipelines_with_mapping(&FIG16A_MODELS, EndpointMapping::Distributed, ndev);
        let cells = evaluate_roster(&pipelines, &fleet, Objective::TputMax, Cost::Latency, args);
        for (i, c) in cells.iter().enumerate() {
            if rows.len() <= i {
                rows.push(vec![c.method.to_string()]);
            }
            rows[i].push(c.fmt_tput());
        }
    }
    for r in rows {
        t.row(r);
    }
    let mut out = t.render();
    out.push_str("\npaper shape: Synergy grows with devices and saturates at 4; baselines mostly flat\n");
    out
}

pub fn run_b(args: &Args) -> String {
    let mut t = Table::new(["method", "1", "2", "3", "4", "5", "6 pipelines (avg TPUT)"]);
    let mut rows: Vec<Vec<String>> = vec![];
    for n in 1..=6 {
        let fleet = fleet_n(4);
        let pipelines = pipelines_with_mapping(&FIG16B_MODELS[..n], EndpointMapping::Distributed, 4);
        let cells = evaluate_roster(&pipelines, &fleet, Objective::TputMax, Cost::Latency, args);
        for (i, c) in cells.iter().enumerate() {
            if rows.len() <= i {
                rows.push(vec![c.method.to_string()]);
            }
            // Average throughput across pipelines (§VI-C1).
            rows[i].push(match c.tput() {
                Some(tp) => format!("{:.2}", tp / n as f64),
                None => "OOR".to_string(),
            });
        }
    }
    for r in rows {
        t.row(r);
    }
    let mut out = t.render();
    out.push_str("\npaper: average TPUT declines with pipeline count; Synergy 1.35 at 6 (19.4× runner-up)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::evaluate;
    use crate::orchestrator::Synergy;

    #[test]
    fn more_devices_do_not_hurt_synergy() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let mut tputs = Vec::new();
        for ndev in 2..=5 {
            let fleet = fleet_n(ndev);
            let pipelines =
                pipelines_with_mapping(&FIG16A_MODELS, EndpointMapping::Distributed, ndev);
            let cell = evaluate(&Synergy::planner(), "Synergy", &pipelines, &fleet, &args);
            tputs.push(cell.tput().expect("Synergy OOR"));
        }
        for w in tputs.windows(2) {
            assert!(w[1] >= w[0] * 0.8, "device scaling regressed: {tputs:?}");
        }
    }

    #[test]
    fn average_tput_declines_with_pipelines() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let fleet = fleet_n(4);
        let one = pipelines_with_mapping(&FIG16B_MODELS[..1], EndpointMapping::Distributed, 4);
        let six = pipelines_with_mapping(&FIG16B_MODELS[..6], EndpointMapping::Distributed, 4);
        let t1 = evaluate(&Synergy::planner(), "Synergy", &one, &fleet, &args)
            .tput()
            .unwrap();
        let t6 = evaluate(&Synergy::planner(), "Synergy", &six, &fleet, &args)
            .tput()
            .unwrap()
            / 6.0;
        assert!(t6 < t1, "contention must reduce average TPUT: {t6} vs {t1}");
    }
}
