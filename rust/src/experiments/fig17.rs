//! Fig. 17 — composing heterogeneous accelerators: three pipelines
//! (ConvNet5, UNet, EfficientNetV2) on four MAX78000s vs three MAX78000s +
//! one MAX78002. Paper: Synergy 0.93 → 3.33 TPUT with the 78002;
//! PriMinDev collapses to 0.06 by stacking everything on the big device;
//! IndE2E OORs on the homogeneous fleet but recovers with the 78002.

use crate::baselines::Cost;
use crate::experiments::common::evaluate_roster;
use crate::model::zoo::ModelName;
use crate::orchestrator::Objective;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet4, fleet4_hetero, pipelines_with_mapping, EndpointMapping};

const MODELS: [ModelName; 3] = [
    ModelName::ConvNet5,
    ModelName::UNet,
    ModelName::EfficientNetV2,
];

pub fn run(args: &Args) -> String {
    let pipelines = pipelines_with_mapping(&MODELS, EndpointMapping::Distributed, 4);
    let mut t = Table::new(["method", "4×78000", "3×78000 + 78002"]);
    let homo = evaluate_roster(&pipelines, &fleet4(), Objective::TputMax, Cost::Latency, args);
    let hetero =
        evaluate_roster(&pipelines, &fleet4_hetero(), Objective::TputMax, Cost::Latency, args);
    for (a, b) in homo.iter().zip(&hetero) {
        t.row([a.method.to_string(), a.fmt_tput(), b.fmt_tput()]);
    }
    let mut out = t.render();
    out.push_str(
        "\npaper: Synergy 0.93 → 3.33; PriMinDev 0.06 with the 78002 (stacks everything \
         on it); IndE2E OOR on 4×78000 but second best with the 78002\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upgrade_helps_synergy() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let pipelines = pipelines_with_mapping(&MODELS, EndpointMapping::Distributed, 4);
        let homo =
            evaluate_roster(&pipelines, &fleet4(), Objective::TputMax, Cost::Latency, &args);
        let hetero =
            evaluate_roster(&pipelines, &fleet4_hetero(), Objective::TputMax, Cost::Latency, &args);
        let s_homo = homo[0].tput().expect("Synergy OOR homo");
        let s_hetero = hetero[0].tput().expect("Synergy OOR hetero");
        assert!(
            s_hetero >= s_homo,
            "78002 should not hurt: {s_homo} → {s_hetero}"
        );
        // Synergy must remain the best method on the hetero fleet.
        for c in &hetero[1..] {
            if let Some(t) = c.tput() {
                assert!(s_hetero >= t * 0.95, "{}: {t}", c.method);
            }
        }
    }
}
