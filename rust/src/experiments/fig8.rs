//! Fig. 8 — layer-wise latency decomposition for UNet on the MAX78000:
//! inference vs memory (load/unload) vs communication per split boundary,
//! alongside output sizes. The paper's totals: inference 1.5 ms, memory
//! 10.6 ms (7×), communication 6 869.1 ms (4 579×); per-boundary comm spans
//! a 36× range. These ratios are what drive data-intensity prioritization.

use crate::device::DeviceKind;
use crate::estimator::clock;
use crate::model::zoo::{model_by_name, ModelName};
use crate::util::cli::Args;
use crate::util::table::Table;

pub fn run(_args: &Args) -> String {
    let m = model_by_name(ModelName::UNet);
    let spec = DeviceKind::Max78000.spec();
    let accel = spec.accel.as_ref().unwrap();
    let radio = &spec.radio;

    let mut t = Table::new(["layer", "out bytes", "infer (ms)", "mem (ms)", "comm (ms)"]);
    let (mut inf_tot, mut mem_tot, mut comm_tot) = (0.0, 0.0, 0.0);
    let (mut comm_min, mut comm_max) = (f64::INFINITY, 0.0f64);
    for l in 0..m.num_layers() {
        let infer =
            clock::infer_latency_accel(m, crate::model::SplitRange::new(l, l + 1), accel.parallel_procs, accel.clock_hz);
        let out_bytes = m.out_bytes(l);
        // Memory: unloading this layer's output + loading it on the peer.
        let mem = 2.0 * (accel.bus_overhead_s + out_bytes as f64 / accel.bus_bytes_per_s);
        let comm = radio.tx_time(out_bytes);
        inf_tot += infer;
        mem_tot += mem;
        comm_tot += comm;
        comm_min = comm_min.min(comm);
        comm_max = comm_max.max(comm);
        t.row([
            format!("{l}"),
            format!("{out_bytes}"),
            format!("{:.3}", infer * 1e3),
            format!("{:.3}", mem * 1e3),
            format!("{:.1}", comm * 1e3),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\ntotals: inference {:.1} ms, memory {:.1} ms ({:.1}× inf; paper 7×), \
         comm {:.0} ms ({:.0}× inf; paper 4579×)\n\
         per-boundary comm spread: {:.1}× (paper 36×)\n",
        inf_tot * 1e3,
        mem_tot * 1e3,
        mem_tot / inf_tot,
        comm_tot * 1e3,
        comm_tot / inf_tot,
        comm_max / comm_min,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_dominates_memory_dominates_inference() {
        let report = run(&Args::default());
        let totals = report
            .lines()
            .find(|l| l.starts_with("totals:"))
            .unwrap()
            .to_string();
        // Extract the two ratio figures.
        let ratios: Vec<f64> = totals
            .split('(')
            .skip(1)
            .filter_map(|s| s.split('×').next()?.trim().parse().ok())
            .collect();
        assert!(ratios[0] > 2.0, "memory ≫ inference: {ratios:?}");
        assert!(ratios[1] > 500.0, "comm ≫ inference: {ratios:?}");
    }
}
