//! Fig. 11 — why clock-cycle modeling: across every layer of the eight
//! Table I models, trainable-parameter counts correlate weakly with
//! measured inference latency on the accelerator (a), while clock-cycle
//! counts correlate strongly (b) — and the cycle-based latency estimate
//! lands within 1% of measurement.

use crate::device::{Device, DeviceKind, Fleet};
use crate::estimator::clock;
use crate::model::zoo::{model_by_name, ModelName};
use crate::model::SplitRange;
use crate::pipeline::PipelineId;
use crate::plan::task::{PlanTask, TaskKind};
use crate::scheduler::GroundTruth;
use crate::util::cli::Args;
use crate::util::stats::pearson;
use crate::util::table::Table;

pub fn run(args: &Args) -> String {
    let fleet = Fleet::new(vec![Device::new(0, "dut", DeviceKind::Max78000, vec![], vec![])]);
    let gt = GroundTruth::with_seed(args.opt_parse("seed", 7u64));
    let accel = DeviceKind::Max78000.spec().accel.unwrap();

    let mut params = Vec::new();
    let mut cycles = Vec::new();
    let mut measured = Vec::new();
    let mut max_model_gap: f64 = 0.0;
    for (mi, name) in ModelName::TABLE1.iter().enumerate() {
        let m = model_by_name(*name);
        let mut model_meas = 0.0;
        let mut model_est = 0.0;
        for l in 0..m.num_layers() {
            let layer = &m.layers[l];
            let input = m.in_shape(l);
            let range = SplitRange::new(l, l + 1);
            let task = PlanTask {
                pipeline: PipelineId(mi),
                seq: l,
                device: crate::device::DeviceId(0),
                kind: TaskKind::Infer { range },
            };
            let meas = gt.duration(&fleet, &task, m, None, 0);
            let est = clock::infer_latency_accel(m, range, accel.parallel_procs, accel.clock_hz);
            params.push((layer.weight_bytes(input) + layer.bias_bytes(input)) as f64);
            cycles.push(clock::layer_cycles_accel(layer, input, accel.parallel_procs) as f64);
            measured.push(meas);
            model_meas += meas;
            model_est += est;
        }
        // Model-level estimate gap: per-layer setup overheads amortize, as
        // in the paper's whole-inference measurements.
        max_model_gap = max_model_gap.max((model_meas - model_est).abs() / model_meas);
    }

    let r_params = pearson(&params, &measured);
    let r_cycles = pearson(&cycles, &measured);
    let mut t = Table::new(["predictor", "Pearson r vs measured latency", "paper"]);
    t.row([
        "trainable parameters".to_string(),
        format!("{r_params:.3}"),
        "weak".into(),
    ]);
    t.row([
        "clock cycles (Eq. 4–5)".to_string(),
        format!("{r_cycles:.3}"),
        "strong".into(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "\nlayers: {}; max per-model |estimate − measured| / measured = {:.2}% (paper: <1%)\n",
        params.len(),
        max_model_gap * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_correlate_far_better_than_params() {
        let report = run(&Args::default());
        let grab = |tag: &str| -> f64 {
            report
                .lines()
                .find(|l| l.starts_with(tag))
                .unwrap()
                .split_whitespace()
                .filter_map(|x| x.parse::<f64>().ok())
                .next()
                .unwrap()
        };
        let r_params = grab("trainable");
        let r_cycles = grab("clock");
        assert!(r_cycles > 0.99, "cycles r = {r_cycles}");
        assert!(r_params < 0.8, "params r = {r_params} should be weak");
        assert!(r_cycles - r_params > 0.2);
    }

    #[test]
    fn estimate_gap_below_two_percent() {
        let report = run(&Args::default());
        let line = report
            .lines()
            .find(|l| l.contains("per-model |estimate"))
            .unwrap();
        let pct: f64 = line
            .split('=')
            .nth(1)
            .unwrap()
            .trim()
            .split('%')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(pct < 2.0, "gap {pct}%");
    }
}
