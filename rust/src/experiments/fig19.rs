//! Fig. 19 — minimizing power as the objective across methods on
//! Workloads 1–2: every planner selects plans prioritizing minimal power
//! (the partitioning baselines switch to an energy cost; the structural
//! heuristics already minimize radio bytes, the dominant consumer).
//! Paper: Synergy executes both workloads at the lowest power, no OOR.

use crate::baselines::Cost;
use crate::experiments::common::evaluate_roster;
use crate::orchestrator::Objective;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet4, workload};

pub fn run(args: &Args) -> String {
    let mut out = String::new();
    for wid in [1usize, 2] {
        let w = workload(wid).expect("Table I workload");
        let cells =
            evaluate_roster(&w.pipelines, &fleet4(), Objective::PowerMin, Cost::Energy, args);
        let mut t = Table::new(["method", "power (J/s)", "TPUT (inf/s)"]);
        for c in &cells {
            t.row([c.method.to_string(), c.fmt_power(), c.fmt_tput()]);
        }
        out.push_str(&format!("\n--- {} (Power-min) ---\n{}", w.name, t.render()));
    }
    out.push_str("\npaper: Synergy lowest power on both workloads, without OOR\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synergy_power_is_minimal_among_successes() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let w = workload(1).unwrap();
        let cells =
            evaluate_roster(&w.pipelines, &fleet4(), Objective::PowerMin, Cost::Energy, &args);
        let synergy = cells[0].power().expect("Synergy must not OOR");
        for c in &cells[1..] {
            if let Some(p) = c.power() {
                assert!(
                    synergy <= p * 1.02,
                    "{}: {p:.3} W beats Synergy {synergy:.3} W",
                    c.method
                );
            }
        }
    }
}
