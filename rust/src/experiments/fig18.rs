//! Fig. 18 — effect of source/target mappings on Synergy's planning:
//! Any (free endpoint choice), Distributed (spread endpoints, the
//! Workload 1 default) and Overlapped (one device is both source and
//! target for every pipeline).
//!
//! The paper reports Overlapped < Distributed < Any, *because* in its setup
//! the overlapped device cannot host the models, so every pipeline's data
//! funnels through that one radio. Our fitted zoo reproduces Table I's
//! sizes but is slightly more colocatable (ConvNet5 + UNet + part of
//! ResSimpleNet squeeze into 442 KB / 32 layers), so the three-pipeline
//! Overlapped case partially escapes the bottleneck. We therefore report
//! both the paper's exact triple AND a memory-pressured variant (adding
//! WideNet, pushing past one device's capacity) where the communication
//! funnel — and the paper's ordering — emerges. See EXPERIMENTS.md.

use crate::experiments::common::evaluate;
use crate::model::zoo::ModelName;
use crate::orchestrator::Synergy;
use crate::util::cli::Args;
use crate::util::table::Table;
use crate::workload::{fleet4, pipelines_with_mapping, EndpointMapping};

const W1_MODELS: [ModelName; 3] = [
    ModelName::ConvNet5,
    ModelName::ResSimpleNet,
    ModelName::UNet,
];

const PRESSURED_MODELS: [ModelName; 4] = [
    ModelName::ConvNet5,
    ModelName::ResSimpleNet,
    ModelName::UNet,
    ModelName::WideNet,
];

pub fn tput(models: &[ModelName], mapping: EndpointMapping, args: &Args) -> Option<f64> {
    let fleet = fleet4();
    let pipelines = pipelines_with_mapping(models, mapping, 4);
    evaluate(&Synergy::planner(), "Synergy", &pipelines, &fleet, args).tput()
}

pub fn run(args: &Args) -> String {
    let mut out = String::new();
    for (label, models) in [
        ("Workload 1 triple", &W1_MODELS[..]),
        ("memory-pressured (+WideNet)", &PRESSURED_MODELS[..]),
    ] {
        let mut t = Table::new(["mapping", "TPUT (inf/s)"]);
        for (name, mapping) in [
            ("Any", EndpointMapping::Any),
            ("Distributed", EndpointMapping::Distributed),
            ("Overlapped", EndpointMapping::Overlapped),
        ] {
            let v = tput(models, mapping, args);
            t.row([
                name.to_string(),
                crate::util::table::fmt_or_oor(v, ""),
            ]);
        }
        out.push_str(&format!("\n--- {label} ---\n{}", t.render()));
    }
    out.push_str(
        "\npaper: Overlapped lowest (communication funnel through the shared endpoint \
         device), Any highest; the funnel requires the models to exceed one device's \
         capacity, which the pressured variant enforces\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn any_is_best_mapping() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let any = tput(&W1_MODELS, EndpointMapping::Any, &args).unwrap();
        let dist = tput(&W1_MODELS, EndpointMapping::Distributed, &args).unwrap();
        assert!(any >= dist * 0.95, "Any {any} vs Distributed {dist}");
    }

    #[test]
    fn pressured_overlapped_hits_the_communication_funnel() {
        let args = Args::parse(["--runs".to_string(), "10".to_string()], &["runs"]);
        let dist = tput(&PRESSURED_MODELS, EndpointMapping::Distributed, &args).unwrap();
        let over = tput(&PRESSURED_MODELS, EndpointMapping::Overlapped, &args).unwrap();
        assert!(
            dist >= over,
            "under memory pressure the overlapped endpoint funnels traffic: \
             Distributed {dist} vs Overlapped {over}"
        );
    }
}
