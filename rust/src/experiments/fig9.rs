//! Fig. 9 — pipeline prioritization strategies vs the complete search
//! (Oracle): relative estimated throughput over all C(8,3) = 56 pipeline
//! combinations on two MAX78000s, plus the search-space reduction factor.
//! Paper: Synergy (descending data intensity) lands within 3.9% of Oracle
//! and the progressive accumulation cuts the space by 5 576×.
//!
//! `--full` sweeps all 56 combinations (minutes); the default samples 12.

use crate::estimator::{estimate_plan, LatencyModel};
use crate::model::zoo::{model_by_name, ModelName};
use crate::orchestrator::oracle::oracle_search;
use crate::orchestrator::{Objective, Priority, ProgressivePlanner};
use crate::pipeline::{PipelineSpec, SourceReq, TargetReq};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{fmt_ratio, Table};
use crate::workload::fleet_n;

fn combos(sample: Option<usize>, seed: u64) -> Vec<[ModelName; 3]> {
    let models = ModelName::TABLE1;
    let mut all = Vec::new();
    for i in 0..models.len() {
        for j in i + 1..models.len() {
            for k in j + 1..models.len() {
                all.push([models[i], models[j], models[k]]);
            }
        }
    }
    if let Some(n) = sample {
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut all);
        all.truncate(n);
    }
    all
}

fn pipes(combo: &[ModelName; 3]) -> Vec<PipelineSpec> {
    combo
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            PipelineSpec::new(i, m.as_str(), SourceReq::Any, model_by_name(m).clone(), TargetReq::Any)
        })
        .collect()
}

pub fn run(args: &Args) -> String {
    let full = args.flag("full");
    let sample = if full { None } else { Some(args.opt_parse("combos", 12usize)) };
    let combos = combos(sample, args.opt_parse("seed", 7u64));
    let fleet = fleet_n(2);
    let lm = LatencyModel::new(&fleet);
    let cfg = crate::plan::EnumerateCfg::default();

    // relative-to-oracle estimated throughput per strategy.
    let mut rel: Vec<Vec<f64>> = vec![Vec::new(); Priority::ALL.len()];
    let mut reductions: Vec<f64> = Vec::new();
    let mut skipped = 0;
    for combo in &combos {
        let ps = pipes(combo);
        let oracle = oracle_search(&ps, &fleet, Objective::TputMax, cfg);
        let oracle_tput = match &oracle.plan {
            Some(plan) => estimate_plan(plan, &ps, &fleet, &lm).throughput,
            None => {
                skipped += 1;
                continue; // combo OOR even for Oracle on 2 devices
            }
        };
        for (s, prio) in Priority::ALL.iter().enumerate() {
            let planner = ProgressivePlanner::new(*prio, Objective::TputMax);
            match planner.select(&ps, &fleet) {
                Ok(plan) => {
                    let tput = estimate_plan(&plan, &ps, &fleet, &lm).throughput;
                    rel[s].push(tput / oracle_tput);
                    if *prio == Priority::DataIntensityDesc {
                        reductions
                            .push(oracle.space_size as f64 / planner.candidates_scored.get() as f64);
                    }
                }
                Err(_) => rel[s].push(0.0),
            }
        }
    }

    let mut t = Table::new(["strategy", "relative TPUT vs Oracle", "paper"]);
    t.row(["Oracle".to_string(), "1.000".to_string(), "1.000".into()]);
    for (s, prio) in Priority::ALL.iter().enumerate() {
        let paper = match prio {
            Priority::DataIntensityDesc => "0.961 (−3.9%)",
            _ => "lower",
        };
        t.row([
            prio.name().to_string(),
            format!("{:.3}", mean(&rel[s])),
            paper.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\ncombos evaluated: {} (skipped {skipped} OOR); search-space reduction \
         (cross product / candidates scored): {} (paper: 5576×)\n",
        combos.len() - skipped,
        fmt_ratio(mean(&reductions)),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synergy_priority_is_close_to_oracle_on_small_sample() {
        // Use a small deterministic sample to keep test time bounded.
        let combos = combos(Some(3), 42);
        let fleet = fleet_n(2);
        let lm = LatencyModel::new(&fleet);
        let cfg = crate::plan::EnumerateCfg::default();
        for combo in &combos {
            let ps = pipes(combo);
            let oracle = oracle_search(&ps, &fleet, Objective::TputMax, cfg);
            let Some(oplan) = &oracle.plan else { continue };
            let otput = estimate_plan(oplan, &ps, &fleet, &lm).throughput;
            let planner =
                ProgressivePlanner::new(Priority::DataIntensityDesc, Objective::TputMax);
            let plan = planner.select(&ps, &fleet).unwrap();
            let tput = estimate_plan(&plan, &ps, &fleet, &lm).throughput;
            assert!(tput / otput > 0.7, "{combo:?}: {tput} vs oracle {otput}");
            assert!(tput / otput <= 1.0 + 1e-9);
        }
    }
}
