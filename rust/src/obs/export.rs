//! Machine-readable JSON forms of the crate's reports, for the `--json`
//! CLI flags and external dashboards. Built on [`crate::util::json`], so
//! output keys are sorted and byte-stable across reruns.
//!
//! Wall-clock annex figures keep their `_wall_` names here (consumers
//! may want the overhead numbers); determinism comparisons should use
//! the trace/metrics paths, which scrub the annex explicitly.

use super::blame::BlameReport;
use super::diff::{MetricsDiff, RecordingDiff};
use crate::analysis::CapacityReport;
use crate::api::SessionReport;
use crate::population::{Dist, PopulationReport};
use crate::util::json::{obj, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn count(v: usize) -> Json {
    Json::Num(v as f64)
}

fn dist_json(d: &Dist) -> Json {
    obj([
        ("min", num(d.min)),
        ("p50", num(d.p50)),
        ("p95", num(d.p95)),
        ("p99", num(d.p99)),
        ("max", num(d.max)),
        ("mean", num(d.mean)),
    ])
}

/// `SessionReport` as JSON: whole-session aggregates, interval series,
/// switch timeline, and QoS spans (the raw task trace stays out — that
/// is what the Chrome exporter is for).
pub fn session_report_json(r: &SessionReport) -> Json {
    let intervals: Vec<Json> = r
        .intervals
        .iter()
        .map(|iv| {
            obj([
                ("start", num(iv.start)),
                ("end", num(iv.end)),
                ("completions", count(iv.completions)),
                ("throughput_hz", num(iv.throughput)),
                ("avg_latency_s", num(iv.avg_latency_s)),
                ("power_w", num(iv.power_w)),
                (
                    "battery_j",
                    Json::Obj(
                        iv.battery_j
                            .iter()
                            .map(|&(d, j)| (format!("d{}", d.0), num(j)))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let switches: Vec<Json> = r
        .switches
        .iter()
        .map(|s| {
            obj([
                ("t", num(s.t)),
                ("cause", Json::Str(s.cause.clone())),
                ("apps", count(s.apps)),
                ("incremental", Json::Bool(s.incremental)),
                ("reused_apps", count(s.reused_apps)),
                ("enumerated_apps", count(s.enumerated_apps)),
                ("est_throughput_hz", num(s.est_throughput)),
                ("replan_wall_s", num(s.replan_wall_s)),
                ("rebind_wall_s", num(s.rebind_wall_s)),
            ])
        })
        .collect();
    let qos: Vec<Json> = r
        .qos_spans
        .iter()
        .map(|q| {
            obj([
                ("app", count(q.app.0)),
                ("name", Json::Str(q.name.clone())),
                ("violation", Json::Str(q.violation.to_string())),
                ("start", num(q.start)),
                ("end", num(q.end)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("duration_s", num(r.duration)),
        ("completions", count(r.completions)),
        ("throughput_hz", num(r.throughput)),
        ("energy_j", num(r.energy_j)),
        ("power_w", num(r.power_w)),
        ("intervals", Json::Arr(intervals)),
        ("switches", Json::Arr(switches)),
        ("qos_spans", Json::Arr(qos)),
    ];
    if let Some(s) = &r.served {
        fields.push((
            "served",
            obj([
                ("executor", Json::Str(s.executor.into())),
                ("admitted_rounds", count(s.admitted_rounds)),
                ("completed_rounds", count(s.completed_rounds)),
                ("rebinds", count(s.rebinds)),
                ("workers", count(s.workers)),
            ]),
        ));
    }
    obj(fields)
}

/// `PopulationReport` as JSON: cohort distributions, cache counters, the
/// fingerprint (hex, the bit-identity witness), and per-user rows.
pub fn population_report_json(r: &PopulationReport) -> Json {
    let outcomes: Vec<Json> = r
        .outcomes
        .iter()
        .map(|u| {
            obj([
                ("seed", num(u.seed as f64)),
                ("fleet", Json::Str(u.fleet_name.into())),
                ("journey", Json::Str(u.journey.into())),
                ("completions", count(u.completions)),
                ("energy_j", num(u.energy_j)),
                ("switches", count(u.switches)),
                ("qos_violation_s", num(u.qos_violation_s)),
                ("replan_wall_s", num(u.replan_wall_s)),
                ("digest", Json::Str(format!("{:016x}", u.digest))),
            ])
        })
        .collect();
    let mut fields = vec![
        ("users", count(r.users)),
        ("workers", count(r.workers)),
        ("completions", dist_json(&r.completions)),
        ("energy_j", dist_json(&r.energy_j)),
        ("switches", dist_json(&r.switches)),
        ("qos_violation_s", dist_json(&r.qos_violation_s)),
        ("replan_wall_s", dist_json(&r.replan_wall_s)),
        ("replan_wall_total_s", num(r.replan_wall_total_s)),
        ("fingerprint", Json::Str(format!("{:016x}", r.fingerprint))),
        ("outcomes", Json::Arr(outcomes)),
        ("metrics", r.metrics.to_json()),
    ];
    if let Some(c) = &r.cache {
        fields.push((
            "cache",
            obj([
                ("lookups", num(c.lookups as f64)),
                ("raw_hits", num(c.hits as f64)),
                ("unique_signatures", count(c.unique_signatures)),
                ("unique_plans", count(c.unique_plans)),
                ("hit_rate", num(c.hit_rate())),
            ]),
        ));
    }
    if let Some(seed) = r.traced_seed {
        fields.push(("traced_seed", num(seed as f64)));
    }
    if let Some(b) = &r.blame {
        fields.push(("blame", blame_report_json(b)));
    }
    obj(fields)
}

/// `BlameReport` as JSON — the `synergy blame --json` payload.
pub fn blame_report_json(r: &BlameReport) -> Json {
    let pipelines: Vec<Json> = r
        .pipelines
        .iter()
        .map(|p| {
            obj([
                ("pipeline", count(p.pipeline)),
                ("rounds", count(p.rounds)),
                ("compute_ns", num(p.compute_ns as f64)),
                ("radio_ns", num(p.radio_ns as f64)),
                ("queue_ns", num(p.queue_ns as f64)),
                ("pacing_ns", num(p.pacing_ns as f64)),
                ("latency_ns", num(p.latency_ns as f64)),
                ("mean_latency_s", num(p.mean_latency_s())),
                ("dominant", Json::Str(p.dominant().to_string())),
            ])
        })
        .collect();
    let units: Vec<Json> = r
        .units
        .iter()
        .map(|u| {
            obj([
                ("device", count(u.device.0)),
                ("unit", Json::Str(format!("{:?}", u.unit))),
                ("busy_ns", num(u.busy_ns as f64)),
                ("queue_caused_ns", num(u.queue_caused_ns as f64)),
                ("normalized_busy_s", num(u.normalized_busy_s)),
            ])
        })
        .collect();
    let bottleneck = match r.measured_bottleneck {
        Some((d, u)) => obj([("device", count(d.0)), ("unit", Json::Str(format!("{u:?}")))]),
        None => Json::Null,
    };
    obj([
        ("rounds", count(r.rounds)),
        ("incomplete_rounds", count(r.incomplete_rounds)),
        ("measured_bottleneck", bottleneck),
        ("pipelines", Json::Arr(pipelines)),
        ("units", Json::Arr(units)),
    ])
}

/// `RecordingDiff` as JSON — the `synergy trace-diff --json` payload.
pub fn trace_diff_json(d: &RecordingDiff) -> Json {
    let entries: Vec<Json> = d
        .entries
        .iter()
        .map(|e| {
            obj([
                ("process", Json::Str(e.process.clone())),
                ("thread", Json::Str(e.thread.clone())),
                ("name", Json::Str(e.name.clone())),
                ("kind", Json::Str(e.kind.into())),
                ("count_a", count(e.count_a)),
                ("count_b", count(e.count_b)),
                ("total_a", num(e.total_a)),
                ("total_b", num(e.total_b)),
                ("delta", num(e.delta())),
            ])
        })
        .collect();
    let pipelines: Vec<Json> = d
        .pipelines
        .iter()
        .map(|p| {
            obj([
                ("pipeline", count(p.pipeline)),
                ("rounds_a", count(p.rounds_a)),
                ("rounds_b", count(p.rounds_b)),
                ("mean_latency_a_s", num(p.mean_latency_a_s)),
                ("mean_latency_b_s", num(p.mean_latency_b_s)),
                ("delta_latency_s", num(p.delta_latency_s())),
                ("delta_compute_s", num(p.delta_compute_s)),
                ("delta_radio_s", num(p.delta_radio_s)),
                ("delta_queue_s", num(p.delta_queue_s)),
                ("delta_pacing_s", num(p.delta_pacing_s)),
                (
                    "moved",
                    match p.moved {
                        Some(c) => Json::Str(c.to_string()),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    obj([
        ("identical", Json::Bool(d.is_empty())),
        ("entries", Json::Arr(entries)),
        ("pipelines", Json::Arr(pipelines)),
    ])
}

/// `MetricsDiff` as JSON.
pub fn metrics_diff_json(d: &MetricsDiff) -> Json {
    let entries: Vec<Json> = d
        .entries
        .iter()
        .map(|e| {
            obj([
                ("name", Json::Str(e.name.clone())),
                ("kind", Json::Str(e.kind.into())),
                ("a", num(e.a)),
                ("b", num(e.b)),
                ("delta", num(e.delta())),
            ])
        })
        .collect();
    obj([("identical", Json::Bool(d.is_empty())), ("entries", Json::Arr(entries))])
}

/// `CapacityReport` as JSON — the `synergy explain --json` payload.
pub fn capacity_report_json(r: &CapacityReport) -> Json {
    let units: Vec<Json> = r
        .units
        .iter()
        .map(|u| {
            obj([
                ("device", count(u.device.0)),
                ("unit", Json::Str(format!("{:?}", u.unit))),
                ("busy_s", num(u.busy_s)),
                ("utilization", num(u.utilization)),
                ("demand_utilization", num(u.demand_utilization)),
            ])
        })
        .collect();
    let pipelines: Vec<Json> = r
        .pipelines
        .iter()
        .map(|p| {
            obj([
                ("pipeline", count(p.pipeline.0)),
                ("chain_latency_s", num(p.chain_latency_s)),
                ("own_bottleneck_s", num(p.own_bottleneck_s)),
                ("own_bottleneck_device", count(p.own_bottleneck_device.0)),
                ("own_bottleneck_unit", Json::Str(format!("{:?}", p.own_bottleneck_unit))),
                ("isolated_rate_hz", num(p.isolated_rate_hz)),
                ("shared_rate_hz", num(p.shared_rate_hz)),
                ("interference_s", num(p.interference_s)),
                ("demand_hz", num(p.demand_hz)),
                ("headroom_hz", num(p.headroom_hz)),
            ])
        })
        .collect();
    let bottleneck = match r.bottleneck {
        Some((d, u, busy)) => obj([
            ("device", count(d.0)),
            ("unit", Json::Str(format!("{u:?}"))),
            ("busy_s", num(busy)),
        ]),
        None => Json::Null,
    };
    obj([
        ("units", Json::Arr(units)),
        ("bottleneck", bottleneck),
        ("round_period_s", num(r.round_period_s)),
        ("critical_path_s", num(r.critical_path_s)),
        ("throughput_hz", num(r.throughput_hz)),
        ("throughput_sequential_hz", num(r.throughput_sequential_hz)),
        ("pipelines", Json::Arr(pipelines)),
        ("schedulable", Json::Bool(r.check().is_ok())),
    ])
}
