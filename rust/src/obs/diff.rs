//! Trace differencing: structurally compare two flight recordings (or
//! two metrics snapshots) and rank what moved.
//!
//! Recordings diff at two levels. The *event* level aggregates both
//! recordings by (process, thread, label, event kind) — span count and
//! total duration, counter count and last value, instant count — and
//! reports every key whose aggregate differs, ranked by delta magnitude.
//! The *pipeline* level reconstructs task spans from both sides
//! ([`super::critical::tasks_from_recording`]), builds a
//! [`BlameReport`](super::blame::BlameReport) for each, and reports
//! per-pipeline round/latency deltas together with the blame category
//! that moved most — the "where did the regression go" answer.
//!
//! Diffing is pure structural comparison of deterministic artifacts: a
//! recording diffed against itself (or against a rerun, on either
//! engine, at any worker count) is empty, which `tests/blame_diff.rs`
//! pins and `synergy trace-diff` turns into an exit code.

use std::collections::BTreeMap;

use super::blame::{BlameCategory, BlameReport, PipelineBlame};
use super::critical::{ns, tasks_from_recording};
use super::registry::MetricsSnapshot;
use super::sink::{EventKind, FlightRecording};

/// One differing (process, thread, label, kind) aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct DiffEntry {
    pub process: String,
    pub thread: String,
    pub name: String,
    /// `"span"`, `"instant"`, or `"counter"`.
    pub kind: &'static str,
    /// Event counts on each side.
    pub count_a: usize,
    pub count_b: usize,
    /// Aggregate value on each side: total span seconds, a counter's
    /// last value, 0 for instants (instants diff by count alone).
    pub total_a: f64,
    pub total_b: f64,
}

impl DiffEntry {
    /// Signed aggregate movement (`b − a`).
    pub fn delta(&self) -> f64 {
        self.total_b - self.total_a
    }

    /// Ranking key: aggregate movement, falling back to count movement
    /// for instants (whose aggregate is always 0).
    fn magnitude(&self) -> f64 {
        let v = self.delta().abs();
        if v > 0.0 {
            v
        } else {
            (self.count_b as f64 - self.count_a as f64).abs()
        }
    }
}

/// One pipeline whose rounds, latency, or blame mix moved. All deltas
/// are per-round means in seconds, `b − a`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PipelineDelta {
    pub pipeline: usize,
    pub rounds_a: usize,
    pub rounds_b: usize,
    pub mean_latency_a_s: f64,
    pub mean_latency_b_s: f64,
    pub delta_compute_s: f64,
    pub delta_radio_s: f64,
    pub delta_queue_s: f64,
    pub delta_pacing_s: f64,
    /// The blame category whose per-round mean moved most — `None` when
    /// only round counts differ.
    pub moved: Option<BlameCategory>,
}

impl PipelineDelta {
    /// Per-round mean latency movement in seconds (`b − a`).
    pub fn delta_latency_s(&self) -> f64 {
        self.mean_latency_b_s - self.mean_latency_a_s
    }
}

/// Ranked structural difference of two recordings.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RecordingDiff {
    /// Differing event aggregates, ranked by delta magnitude (ties by
    /// key). Empty iff both recordings aggregate identically.
    pub entries: Vec<DiffEntry>,
    /// Pipelines whose measured story moved, ordered by pipeline id.
    pub pipelines: Vec<PipelineDelta>,
}

impl RecordingDiff {
    /// `true` when nothing differs — the identity-diff contract.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.pipelines.is_empty()
    }
}

#[derive(Clone, Copy, Default)]
struct Agg {
    count: usize,
    total: f64,
}

fn kind_tag(k: &EventKind) -> (u8, &'static str) {
    match k {
        EventKind::Span { .. } => (0, "span"),
        EventKind::Instant => (1, "instant"),
        EventKind::Counter { .. } => (2, "counter"),
    }
}

fn aggregate(rec: &FlightRecording) -> BTreeMap<(String, String, String, u8), Agg> {
    let mut out: BTreeMap<(String, String, String, u8), Agg> = BTreeMap::new();
    for ev in &rec.events {
        let track = rec.track_of(ev);
        let (rank, _) = kind_tag(&ev.kind);
        let a = out
            .entry((track.process.clone(), track.thread.clone(), ev.name.clone(), rank))
            .or_default();
        a.count += 1;
        match ev.kind {
            // Integer-ns duration totals: bit-stable regardless of the
            // (deterministic) accumulation order.
            EventKind::Span { dur } => a.total += ns(dur) as f64 / 1e9,
            EventKind::Instant => {}
            EventKind::Counter { value } => a.total = value,
        }
    }
    out
}

fn kind_name(rank: u8) -> &'static str {
    match rank {
        0 => "span",
        1 => "instant",
        _ => "counter",
    }
}

fn mean_category_s(p: Option<&PipelineBlame>, c: BlameCategory) -> f64 {
    match p {
        Some(p) if p.rounds > 0 => p.category_ns(c) as f64 / 1e9 / p.rounds as f64,
        _ => 0.0,
    }
}

fn pipeline_deltas(a: &BlameReport, b: &BlameReport) -> Vec<PipelineDelta> {
    let index = |r: &BlameReport| -> BTreeMap<usize, PipelineBlame> {
        r.pipelines.iter().map(|p| (p.pipeline, *p)).collect()
    };
    let (ia, ib) = (index(a), index(b));
    let mut ids: Vec<usize> = ia.keys().chain(ib.keys()).copied().collect();
    ids.sort_unstable();
    ids.dedup();

    let mut out = Vec::new();
    for id in ids {
        let (pa, pb) = (ia.get(&id), ib.get(&id));
        let rounds = |p: Option<&PipelineBlame>| p.map_or(0, |p| p.rounds);
        let mean_latency = |p: Option<&PipelineBlame>| p.map_or(0.0, |p| p.mean_latency_s());
        let mut delta = PipelineDelta {
            pipeline: id,
            rounds_a: rounds(pa),
            rounds_b: rounds(pb),
            mean_latency_a_s: mean_latency(pa),
            mean_latency_b_s: mean_latency(pb),
            delta_compute_s: 0.0,
            delta_radio_s: 0.0,
            delta_queue_s: 0.0,
            delta_pacing_s: 0.0,
            moved: None,
        };
        let mut best = 0.0_f64;
        for c in BlameCategory::ALL {
            let d = mean_category_s(pb, c) - mean_category_s(pa, c);
            match c {
                BlameCategory::Compute => delta.delta_compute_s = d,
                BlameCategory::Radio => delta.delta_radio_s = d,
                BlameCategory::Queue => delta.delta_queue_s = d,
                BlameCategory::Pacing => delta.delta_pacing_s = d,
            }
            if d.abs() > best {
                best = d.abs();
                delta.moved = Some(c);
            }
        }
        let differs = delta.rounds_a != delta.rounds_b
            || delta.mean_latency_a_s != delta.mean_latency_b_s
            || delta.moved.is_some();
        if differs {
            out.push(delta);
        }
    }
    out
}

/// Structurally diff two recordings: event aggregates plus per-pipeline
/// blame movement. Task-span reconstruction failures (a recording with
/// foreign span labels) degrade to an event-level-only diff rather than
/// erroring — the event level already covers every difference.
pub fn diff_recordings(a: &FlightRecording, b: &FlightRecording) -> RecordingDiff {
    let (agg_a, agg_b) = (aggregate(a), aggregate(b));

    let mut keys: Vec<&(String, String, String, u8)> = agg_a.keys().chain(agg_b.keys()).collect();
    keys.sort();
    keys.dedup();

    let mut entries = Vec::new();
    for key in keys {
        let empty = Agg::default();
        let va = agg_a.get(key).unwrap_or(&empty);
        let vb = agg_b.get(key).unwrap_or(&empty);
        if va.count != vb.count || va.total != vb.total {
            entries.push(DiffEntry {
                process: key.0.clone(),
                thread: key.1.clone(),
                name: key.2.clone(),
                kind: kind_name(key.3),
                count_a: va.count,
                count_b: vb.count,
                total_a: va.total,
                total_b: vb.total,
            });
        }
    }
    entries.sort_by(|x, y| {
        let kx = (&x.process, &x.thread, &x.name, x.kind);
        let ky = (&y.process, &y.thread, &y.name, y.kind);
        y.magnitude().total_cmp(&x.magnitude()).then_with(|| kx.cmp(&ky))
    });

    let blame_a = tasks_from_recording(a).map(|t| BlameReport::from_spans(&t)).unwrap_or_default();
    let blame_b = tasks_from_recording(b).map(|t| BlameReport::from_spans(&t)).unwrap_or_default();

    RecordingDiff { entries, pipelines: pipeline_deltas(&blame_a, &blame_b) }
}

/// One differing metric.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricDelta {
    pub name: String,
    /// `"counter"`, `"gauge"`, or `"histogram"`.
    pub kind: &'static str,
    /// Representative value on each side (histograms use their sum).
    pub a: f64,
    pub b: f64,
}

impl MetricDelta {
    /// Signed movement (`b − a`).
    pub fn delta(&self) -> f64 {
        self.b - self.a
    }
}

/// Ranked structural difference of two metrics snapshots.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MetricsDiff {
    /// Differing metrics, ranked by |delta| (ties by name).
    pub entries: Vec<MetricDelta>,
}

impl MetricsDiff {
    /// `true` when the snapshots are identical.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Compare two metrics snapshots name-by-name. Missing names count as
/// absent (0 for counters/histogram sums; gauges compare against 0.0).
pub fn diff_metrics(a: &MetricsSnapshot, b: &MetricsSnapshot) -> MetricsDiff {
    let mut entries = Vec::new();

    let mut counter_names: Vec<&String> = a.counters.keys().chain(b.counters.keys()).collect();
    counter_names.sort();
    counter_names.dedup();
    for name in counter_names {
        let (va, vb) = (a.counters.get(name).copied(), b.counters.get(name).copied());
        if va != vb {
            entries.push(MetricDelta {
                name: name.clone(),
                kind: "counter",
                a: va.unwrap_or(0) as f64,
                b: vb.unwrap_or(0) as f64,
            });
        }
    }

    let mut gauge_names: Vec<&String> = a.gauges.keys().chain(b.gauges.keys()).collect();
    gauge_names.sort();
    gauge_names.dedup();
    for name in gauge_names {
        let (va, vb) = (a.gauges.get(name).copied(), b.gauges.get(name).copied());
        if va != vb {
            entries.push(MetricDelta {
                name: name.clone(),
                kind: "gauge",
                a: va.unwrap_or(0.0),
                b: vb.unwrap_or(0.0),
            });
        }
    }

    let mut hist_names: Vec<&String> = a.hists.keys().chain(b.hists.keys()).collect();
    hist_names.sort();
    hist_names.dedup();
    for name in hist_names {
        let (ha, hb) = (a.hists.get(name), b.hists.get(name));
        if ha != hb {
            entries.push(MetricDelta {
                name: name.clone(),
                kind: "histogram",
                a: ha.map_or(0.0, |h| h.sum),
                b: hb.map_or(0.0, |h| h.sum),
            });
        }
    }

    entries.sort_by(|x, y| {
        y.delta()
            .abs()
            .total_cmp(&x.delta().abs())
            .then_with(|| x.name.cmp(&y.name))
    });
    MetricsDiff { entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceId;
    use crate::model::SplitRange;
    use crate::obs::emit::record_task_spans;
    use crate::obs::registry::MetricsRegistry;
    use crate::obs::sink::TraceSink;
    use crate::plan::TaskKind;
    use crate::scheduler::{TaskSpan, Trace};

    fn round(pipeline: usize, run: usize, shift: f64, infer_s: f64) -> Vec<TaskSpan> {
        let mk = |seq: usize, kind: TaskKind, start: f64, end: f64| TaskSpan {
            pipeline,
            seq,
            run,
            device: DeviceId(0),
            unit: kind.unit(),
            kind,
            start: start + shift,
            end: end + shift,
        };
        vec![
            mk(0, TaskKind::Sense { bytes: 1 }, 0.0, 0.1),
            mk(1, TaskKind::Infer { range: SplitRange::new(0, 1) }, 0.1, 0.1 + infer_s),
            mk(2, TaskKind::Interact { bytes: 1 }, 0.1 + infer_s, 0.2 + infer_s),
        ]
    }

    fn recording(infer_s: f64) -> FlightRecording {
        let mut spans = round(0, 0, 0.0, infer_s);
        spans.extend(round(0, 1, 1.0, infer_s));
        let mut rec = FlightRecording::new();
        record_task_spans(&Trace { spans }, &mut rec);
        rec
    }

    #[test]
    fn self_diff_is_empty() {
        let rec = recording(0.5);
        let d = diff_recordings(&rec, &rec);
        assert!(d.is_empty(), "{d:?}");
        // A rerun with identical content but different emission order
        // also diffs empty.
        let mut reordered = FlightRecording::new();
        for ev in rec.events.iter().rev() {
            let track = rec.track_of(ev);
            let t = reordered.track(&track.process, &track.thread);
            if let EventKind::Span { dur } = ev.kind {
                reordered.span(t, &ev.name, ev.t, ev.t + dur);
            }
        }
        assert!(diff_recordings(&rec, &reordered).is_empty());
    }

    #[test]
    fn slower_infer_ranks_first_and_blames_compute() {
        let fast = recording(0.5);
        let slow = recording(0.9);
        let d = diff_recordings(&fast, &slow);
        assert!(!d.is_empty());
        // The biggest event-level mover is the infer span aggregate.
        assert!(d.entries[0].name.contains("infer"), "{:?}", d.entries[0]);
        assert!(d.entries[0].delta() > 0.0);
        // The pipeline story names compute as the moved category.
        assert_eq!(d.pipelines.len(), 1);
        let p = d.pipelines[0];
        assert_eq!(p.moved, Some(BlameCategory::Compute));
        assert!((p.delta_compute_s - 0.4).abs() < 1e-9);
        assert!(p.delta_latency_s() > 0.0);
    }

    #[test]
    fn missing_track_shows_as_count_delta() {
        let a = recording(0.5);
        let mut b = recording(0.5);
        let extra = b.track("session", "switches");
        b.instant(extra, "plan-switch: device-joined", 0.5);
        let d = diff_recordings(&a, &b);
        assert_eq!(d.entries.len(), 1);
        assert_eq!(d.entries[0].kind, "instant");
        assert_eq!((d.entries[0].count_a, d.entries[0].count_b), (0, 1));
        assert!(d.pipelines.is_empty());
    }

    #[test]
    fn metrics_diff_ranks_by_magnitude() {
        let ra = MetricsRegistry::new();
        ra.counter("session.completions").add(10);
        ra.set_gauge("session.energy_j", 2.0);
        ra.observe("round.latency", 0.5);
        let rb = MetricsRegistry::new();
        rb.counter("session.completions").add(12);
        rb.set_gauge("session.energy_j", 8.0);
        rb.observe("round.latency", 0.5);

        let d = diff_metrics(&ra.snapshot(), &rb.snapshot());
        assert_eq!(d.entries.len(), 2);
        assert_eq!(d.entries[0].name, "session.energy_j");
        assert_eq!(d.entries[0].delta(), 6.0);
        assert_eq!(d.entries[1].name, "session.completions");

        let same = diff_metrics(&ra.snapshot(), &ra.snapshot());
        assert!(same.is_empty());
    }
}
