//! Chrome trace-event JSON export (the format Perfetto and
//! `chrome://tracing` load) plus a serde-free structural validator.
//!
//! Mapping: each distinct track *process* becomes a Perfetto process
//! (pid), each track a thread within it (tid), both announced with `"M"`
//! metadata events. Spans serialize as complete `"X"` events, markers as
//! thread-scoped `"i"` instants, counter samples as `"C"` events with
//! `args.value`. Timestamps convert from simulated seconds to integer
//! microseconds.
//!
//! Export is canonical: events are sorted by (track, kind, time, name,
//! payload) before serialization, and the JSON builder emits sorted
//! object keys. Two recordings of the same timeline therefore serialize
//! to bit-identical bytes even when their emission interleavings differ
//! — the property the determinism tests compare.

use std::collections::BTreeMap;

use super::sink::{EventKind, FlightRecording, TraceEvent, TraceSink};
use crate::util::json::{obj, Json};

/// Convert simulated seconds to the integer microseconds Chrome traces
/// use. Rounding keeps the serialized numbers exponent-free.
fn us(t: f64) -> Json {
    Json::Num((t * 1e6).round())
}

fn kind_rank(k: &EventKind) -> u8 {
    match k {
        EventKind::Span { .. } => 0,
        EventKind::Instant => 1,
        EventKind::Counter { .. } => 2,
    }
}

fn payload(k: &EventKind) -> f64 {
    match *k {
        EventKind::Span { dur } => dur,
        EventKind::Instant => 0.0,
        EventKind::Counter { value } => value,
    }
}

/// Serialize `rec` as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`), canonically ordered. Load the result in
/// [Perfetto](https://ui.perfetto.dev) or `chrome://tracing`.
pub fn to_chrome_json(rec: &FlightRecording) -> String {
    // pid per distinct process name (first-appearance order), tid per
    // track within its process. Perfetto treats 0 as "unset", so both
    // are 1-based.
    let mut processes: Vec<&str> = Vec::new();
    let mut pid_of = Vec::with_capacity(rec.tracks.len());
    let mut tid_of = Vec::with_capacity(rec.tracks.len());
    for tr in &rec.tracks {
        let pid = match processes.iter().position(|p| *p == tr.process) {
            Some(i) => i,
            None => {
                processes.push(&tr.process);
                processes.len() - 1
            }
        };
        pid_of.push(pid + 1);
        let tid = rec.tracks[..tid_of.len()]
            .iter()
            .filter(|t| t.process == tr.process)
            .count();
        tid_of.push(tid + 1);
    }

    let mut events: Vec<Json> = Vec::with_capacity(rec.events.len() + rec.tracks.len() + 1);
    for (i, p) in processes.iter().enumerate() {
        events.push(obj([
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("process_name".into())),
            ("pid", Json::Num((i + 1) as f64)),
            ("args", obj([("name", Json::Str((*p).into()))])),
        ]));
    }
    for (i, tr) in rec.tracks.iter().enumerate() {
        events.push(obj([
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(pid_of[i] as f64)),
            ("tid", Json::Num(tid_of[i] as f64)),
            ("args", obj([("name", Json::Str(tr.thread.clone()))])),
        ]));
    }

    // Canonical event order: (track, kind, time, name, payload), with
    // floats under total_cmp — the sort that makes export byte-stable.
    let mut ordered: Vec<&TraceEvent> = rec.events.iter().collect();
    ordered.sort_by(|a, b| {
        (a.track.0, kind_rank(&a.kind))
            .cmp(&(b.track.0, kind_rank(&b.kind)))
            .then(a.t.total_cmp(&b.t))
            .then_with(|| a.name.cmp(&b.name))
            .then(payload(&a.kind).total_cmp(&payload(&b.kind)))
    });
    for ev in ordered {
        let pid = Json::Num(pid_of[ev.track.0] as f64);
        let tid = Json::Num(tid_of[ev.track.0] as f64);
        let name = Json::Str(ev.name.clone());
        events.push(match ev.kind {
            EventKind::Span { dur } => obj([
                ("ph", Json::Str("X".into())),
                ("name", name),
                ("pid", pid),
                ("tid", tid),
                ("ts", us(ev.t)),
                ("dur", us(dur)),
            ]),
            EventKind::Instant => obj([
                ("ph", Json::Str("i".into())),
                ("s", Json::Str("t".into())),
                ("name", name),
                ("pid", pid),
                ("tid", tid),
                ("ts", us(ev.t)),
            ]),
            EventKind::Counter { value } => obj([
                ("ph", Json::Str("C".into())),
                ("name", name),
                ("pid", pid),
                ("tid", tid),
                ("ts", us(ev.t)),
                ("args", obj([("value", Json::Num(value))])),
            ]),
        });
    }

    obj([("traceEvents", Json::Arr(events))]).to_string_compact()
}

/// Structurally validate `text` as a Chrome trace-event document: a
/// top-level `traceEvents` array whose members carry the fields each
/// phase requires. Returns the number of events checked. Serde-free —
/// this is what `xtask -- validate-trace` runs in CI against the
/// `synergy trace` smoke output.
pub fn validate_chrome_trace(text: &str) -> Result<usize, String> {
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing top-level \"traceEvents\" array".to_string())?;
    for (i, ev) in events.iter().enumerate() {
        let fail = |what: &str| Err(format!("traceEvents[{i}]: {what}"));
        if ev.as_obj().is_none() {
            return fail("not an object");
        }
        let Some(ph) = ev.get("ph").and_then(Json::as_str) else {
            return fail("missing \"ph\"");
        };
        if ev.get("name").and_then(Json::as_str).is_none() {
            return fail("missing \"name\"");
        }
        if ev.get("pid").and_then(Json::as_f64).is_none() {
            return fail("missing numeric \"pid\"");
        }
        let has_tid = ev.get("tid").and_then(Json::as_f64).is_some();
        let has_ts = ev.get("ts").and_then(Json::as_f64).is_some();
        match ph {
            "M" => {} // metadata: pid suffices (thread_name also has tid)
            "X" => {
                if !has_tid || !has_ts {
                    return fail("\"X\" event needs numeric tid and ts");
                }
                match ev.get("dur").and_then(Json::as_f64) {
                    Some(d) if d >= 0.0 => {}
                    _ => return fail("\"X\" event needs non-negative \"dur\""),
                }
            }
            "i" => {
                if !has_tid || !has_ts {
                    return fail("\"i\" event needs numeric tid and ts");
                }
                if ev.get("s").and_then(Json::as_str).is_none() {
                    return fail("\"i\" event needs a scope \"s\"");
                }
            }
            "C" => {
                if !has_ts {
                    return fail("\"C\" event needs numeric ts");
                }
                if ev.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64).is_none() {
                    return fail("\"C\" event needs args.value");
                }
            }
            other => return Err(format!("traceEvents[{i}]: unknown phase {other:?}")),
        }
    }
    Ok(events.len())
}

/// Parse a Chrome trace-event document back into a [`FlightRecording`]
/// — the inverse of [`to_chrome_json`], used by `synergy trace-diff` to
/// load recordings from disk. Track names come from the `"M"` metadata
/// events (unnamed pids/tids fall back to `pid<N>`/`tid<N>`), and
/// timestamps convert from integer microseconds back to seconds, so a
/// re-export of the imported recording is byte-identical.
pub fn recording_from_chrome_json(text: &str) -> Result<FlightRecording, String> {
    validate_chrome_trace(text)?;
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing top-level \"traceEvents\" array".to_string())?;

    let mut process_names: BTreeMap<i64, String> = BTreeMap::new();
    let mut thread_names: BTreeMap<(i64, i64), String> = BTreeMap::new();
    let id = |ev: &Json, field: &str| -> i64 {
        ev.get(field).and_then(Json::as_f64).unwrap_or(0.0) as i64
    };
    for ev in events {
        if ev.get("ph").and_then(Json::as_str) != Some("M") {
            continue;
        }
        let arg = ev.get("args").and_then(|a| a.get("name")).and_then(Json::as_str);
        let Some(arg) = arg else {
            continue; // Foreign metadata kinds are ignorable.
        };
        match ev.get("name").and_then(Json::as_str) {
            Some("process_name") => {
                process_names.insert(id(ev, "pid"), arg.to_string());
            }
            Some("thread_name") => {
                thread_names.insert((id(ev, "pid"), id(ev, "tid")), arg.to_string());
            }
            _ => {}
        }
    }

    let mut rec = FlightRecording::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).unwrap_or("");
        if ph == "M" {
            continue;
        }
        let (pid, tid) = (id(ev, "pid"), id(ev, "tid"));
        let process = process_names
            .get(&pid)
            .cloned()
            .unwrap_or_else(|| format!("pid{pid}"));
        let thread = thread_names
            .get(&(pid, tid))
            .cloned()
            .unwrap_or_else(|| format!("tid{tid}"));
        let track = rec.track(&process, &thread);
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
        let t = ev.get("ts").and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
        match ph {
            "X" => {
                let dur = ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
                rec.span(track, name, t, t + dur);
            }
            "i" => rec.instant(track, name, t),
            "C" => {
                let value = ev
                    .get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0);
                rec.counter(track, name, t, value);
            }
            // validate_chrome_trace already rejected unknown phases.
            other => return Err(format!("unknown phase {other:?}")),
        }
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::TraceSink;

    fn sample() -> FlightRecording {
        let mut r = FlightRecording::new();
        let cpu = r.track("d0", "Cpu");
        let acc = r.track("d0", "Accel");
        let sw = r.track("session", "switches");
        r.span(cpu, "p0 sense", 0.0, 0.5);
        r.span(acc, "p0 infer", 0.5, 1.25);
        r.instant(sw, "plan-switch: device-joined", 2.0);
        r.counter(sw, "power_w", 0.0, 0.125);
        r
    }

    #[test]
    fn export_validates_and_is_canonical_across_emission_order() {
        let a = sample();
        let json = to_chrome_json(&a);
        assert_eq!(validate_chrome_trace(&json), Ok(4 + 2 + 3)); // events + procs + threads

        // Same timeline, different emission interleaving → same bytes.
        let mut b = FlightRecording::new();
        let cpu = b.track("d0", "Cpu");
        let acc = b.track("d0", "Accel");
        let sw = b.track("session", "switches");
        b.counter(sw, "power_w", 0.0, 0.125);
        b.instant(sw, "plan-switch: device-joined", 2.0);
        b.span(acc, "p0 infer", 0.5, 1.25);
        b.span(cpu, "p0 sense", 0.0, 0.5);
        assert_eq!(json, to_chrome_json(&b));
    }

    #[test]
    fn timestamps_are_integer_microseconds() {
        let json = to_chrome_json(&sample());
        assert!(json.contains("\"ts\":500000"), "{json}");
        assert!(json.contains("\"dur\":750000"), "{json}");
        assert!(json.contains("\"ts\":2000000"), "{json}");
    }

    #[test]
    fn chrome_json_roundtrips_through_import() {
        let rec = sample();
        let json = to_chrome_json(&rec);
        let back = recording_from_chrome_json(&json).unwrap();
        // Track names and integer-µs timestamps survive, so the
        // re-export is byte-identical — the trace-diff loading contract.
        assert_eq!(to_chrome_json(&back), json);
        assert_eq!(back.len(), rec.len());
        assert!(recording_from_chrome_json("{}").is_err());
        assert!(recording_from_chrome_json("not json").is_err());
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("[]").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\": [{\"ph\": \"X\"}]}").is_err());
        let neg_dur = "{\"traceEvents\": [{\"ph\":\"X\",\"name\":\"x\",\"pid\":1,\
                        \"tid\":1,\"ts\":0,\"dur\":-1}]}";
        assert!(validate_chrome_trace(neg_dur).is_err());
        assert_eq!(validate_chrome_trace("{\"traceEvents\": []}"), Ok(0));
    }
}
