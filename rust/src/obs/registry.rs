//! The metrics registry: named counters, gauges, and histograms with
//! deterministic snapshots.
//!
//! Counters are atomics so concurrent emitters (population workers, the
//! shared plan cache) can bump them without a lock; everything else sits
//! behind a mutex. Snapshots iterate `BTreeMap`s, so serialization order
//! is fixed regardless of registration order.
//!
//! Names under the `annex.` prefix are *wall-clock annex* figures —
//! useful for overhead accounting but scheduling-dependent (raw cache
//! hits, replan wall seconds). [`MetricsSnapshot::scrub_annex`] drops
//! them, and everything that remains is bit-identical across reruns and
//! worker counts. Determinism tests scrub before comparing.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::util::json::{obj, Json};
use crate::util::stats;

/// Name prefix for scheduling-dependent figures (wall-clock readings,
/// raw racy counts). Scrubbed before determinism comparisons.
pub const ANNEX_PREFIX: &str = "annex.";

/// A monotonically increasing atomic counter. Handed out as
/// `Arc<Counter>` so hot paths bump it without touching the registry
/// lock (the shared plan cache's raw hit count lives in one of these).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Vec<f64>>,
}

/// Registry of named metrics. Cheap to create per session or per cohort;
/// there is deliberately no global instance — a process-wide registry
/// would entangle parallel population runs and break per-user
/// determinism.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    /// Fresh, empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // Metric state stays valid across a panicking holder; recover
        // rather than poisoning every later snapshot.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Register (or fetch) the counter called `name`. The returned arc
    /// can be bumped from any thread without re-entering the registry.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.lock();
        if let Some(c) = g.counters.get(name) {
            return Arc::clone(c);
        }
        let c = Arc::new(Counter::default());
        g.counters.insert(name.to_string(), Arc::clone(&c));
        c
    }

    /// Set the gauge `name` to `value` (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.lock().gauges.insert(name.to_string(), value);
    }

    /// Append one observation to the histogram `name`.
    pub fn observe(&self, name: &str, value: f64) {
        self.lock().hists.entry(name.to_string()).or_default().push(value);
    }

    /// Deterministic point-in-time snapshot (sorted names, summarized
    /// histograms).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.lock();
        MetricsSnapshot {
            counters: g.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
            gauges: g.gauges.clone(),
            hists: g.hists.iter().map(|(k, v)| (k.clone(), HistSummary::of(v))).collect(),
        }
    }
}

/// Five-number summary of a histogram at snapshot time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistSummary {
    /// Number of observations.
    pub count: usize,
    /// Sum of observations.
    pub sum: f64,
    /// Smallest observation (0 when empty).
    pub min: f64,
    /// Largest observation (0 when empty).
    pub max: f64,
    /// Median (linear interpolation).
    pub p50: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
}

impl HistSummary {
    /// Summarize `xs` (all-zero summary for empty input).
    pub fn of(xs: &[f64]) -> HistSummary {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        HistSummary {
            count: xs.len(),
            sum: xs.iter().sum(),
            min: if xs.is_empty() { 0.0 } else { min },
            max: if xs.is_empty() { 0.0 } else { max },
            p50: stats::percentile(xs, 50.0),
            p95: stats::percentile(xs, 95.0),
        }
    }

    fn to_json(self) -> Json {
        obj([
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
        ])
    }
}

/// Frozen copy of a registry: sorted name → value maps, safe to compare,
/// diff, and serialize. Produced by [`MetricsRegistry::snapshot`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram summaries by name.
    pub hists: BTreeMap<String, HistSummary>,
}

impl MetricsSnapshot {
    /// Counter value by name, if registered.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    /// Gauge value by name, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Drop every metric under the [`ANNEX_PREFIX`] — the wall-clock /
    /// scheduling-dependent figures. What remains is deterministic.
    pub fn scrub_annex(&mut self) {
        self.counters.retain(|k, _| !k.starts_with(ANNEX_PREFIX));
        self.gauges.retain(|k, _| !k.starts_with(ANNEX_PREFIX));
        self.hists.retain(|k, _| !k.starts_with(ANNEX_PREFIX));
    }

    /// Add `other`'s counters into `self` (missing names are created).
    /// Counters only: cohort aggregation re-observes raw values for
    /// gauges and histograms instead of merging summaries.
    pub fn absorb_counters(&mut self, other: &MetricsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
    }

    /// Flat JSON form: `{"counters": {...}, "gauges": {...},
    /// "histograms": {name: {count, sum, min, max, p50, p95}}}`.
    pub fn to_json(&self) -> Json {
        obj([
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect()),
            ),
            (
                "histograms",
                Json::Obj(self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_shared_and_snapshot_deterministically() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("planner.bound_cutoffs");
        let b = reg.counter("planner.bound_cutoffs");
        a.inc();
        b.add(2);
        assert_eq!(reg.snapshot().counter("planner.bound_cutoffs"), Some(3));

        reg.set_gauge("session.energy_j", 1.5);
        reg.observe("user.completions", 10.0);
        reg.observe("user.completions", 20.0);
        let s = reg.snapshot();
        assert_eq!(s.gauge("session.energy_j"), Some(1.5));
        let h = s.hists["user.completions"];
        assert_eq!(h.count, 2);
        assert_eq!(h.sum, 30.0);
        assert_eq!(h.min, 10.0);
        assert_eq!(h.max, 20.0);
        assert_eq!(h.p50, 15.0);
    }

    #[test]
    fn scrub_annex_drops_only_prefixed_names() {
        let reg = MetricsRegistry::new();
        reg.counter("plan_cache.lookups").add(5);
        reg.counter("annex.plan_cache.raw_hits").add(3);
        reg.set_gauge("annex.session.replan_wall_s", 0.01);
        reg.set_gauge("session.energy_j", 2.0);
        let mut s = reg.snapshot();
        s.scrub_annex();
        assert_eq!(s.counter("plan_cache.lookups"), Some(5));
        assert_eq!(s.counter("annex.plan_cache.raw_hits"), None);
        assert_eq!(s.gauge("annex.session.replan_wall_s"), None);
        assert_eq!(s.gauge("session.energy_j"), Some(2.0));
    }

    #[test]
    fn snapshot_json_is_stable_across_registration_order() {
        let a = MetricsRegistry::new();
        a.counter("b").inc();
        a.counter("a").add(2);
        let b = MetricsRegistry::new();
        b.counter("a").add(2);
        b.counter("b").inc();
        assert_eq!(
            a.snapshot().to_json().to_string_compact(),
            b.snapshot().to_json().to_string_compact()
        );
    }

    #[test]
    fn empty_histogram_summarizes_to_zeros() {
        let h = HistSummary::of(&[]);
        assert_eq!(h.count, 0);
        assert_eq!((h.min, h.max, h.p50, h.p95), (0.0, 0.0, 0.0, 0.0));
    }
}
