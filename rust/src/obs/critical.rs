//! Critical-path extraction: turn a task trace (or a [`FlightRecording`]
//! of one) into per-round latency attributions.
//!
//! A pipeline round is its seq-ordered task chain; everything between
//! the first task's start and the Interact task's end is the round's
//! end-to-end latency. Every nanosecond of it lands in exactly one of
//! four buckets:
//!
//! - **compute** — a non-radio task was running (Sense, Load, Infer,
//!   Unload, Interact),
//! - **radio** — a Tx/Rx task was running,
//! - **queue** — the next task's (device, unit) lane was busy with some
//!   *other* span during the gap before it started,
//! - **pacing** — the residual: admission pacing, dependency slack, and
//!   any idle air between tasks that no lane contention explains.
//!
//! Attribution works in integer nanoseconds ([`ns`]) and telescopes —
//! task durations plus inter-task gaps sum to `end − start` exactly —
//! so the conservation invariant `attributed_ns() == latency_ns()` holds
//! bit-exactly on both engines, which `tests/blame_diff.rs` pins.
//!
//! Extraction is post-hoc: it reads a finished trace, never instruments
//! a running engine.

use std::collections::BTreeMap;

use super::sink::{EventKind, FlightRecording};
use crate::device::DeviceId;
use crate::model::SplitRange;
use crate::plan::{TaskKind, UnitKind};
use crate::scheduler::TaskSpan;

/// Simulated seconds to integer nanoseconds, the unit all attribution
/// arithmetic runs in. Rounding (not truncation) keeps values that are
/// exact in microseconds — e.g. Chrome-export roundtrips — exact here.
pub fn ns(t: f64) -> i64 {
    (t * 1e9).round() as i64
}

/// One complete round's latency attribution. The four category fields
/// partition the round's latency exactly:
/// `compute_ns + radio_ns + queue_ns + pacing_ns == end_ns - start_ns`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundBlame {
    /// Pipeline the round belongs to.
    pub pipeline: usize,
    /// Round number within the pipeline.
    pub run: usize,
    /// First task's start, in integer nanoseconds.
    pub start_ns: i64,
    /// Interact task's end, in integer nanoseconds.
    pub end_ns: i64,
    /// Time a non-radio task of this round was executing.
    pub compute_ns: i64,
    /// Time a Tx/Rx task of this round was executing.
    pub radio_ns: i64,
    /// Gap time the next task's lane was occupied by another span.
    pub queue_ns: i64,
    /// Residual gap time (admission pacing, dependency slack).
    pub pacing_ns: i64,
}

impl RoundBlame {
    /// End-to-end round latency in nanoseconds.
    pub fn latency_ns(&self) -> i64 {
        self.end_ns - self.start_ns
    }

    /// Sum of the four attribution buckets — equals [`Self::latency_ns`]
    /// by construction (the conservation invariant).
    pub fn attributed_ns(&self) -> i64 {
        self.compute_ns + self.radio_ns + self.queue_ns + self.pacing_ns
    }
}

/// Queue-wait charged to one (device, unit) lane: how long complete
/// rounds spent waiting for this unit while it ran other work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneQueue {
    pub device: DeviceId,
    pub unit: UnitKind,
    /// Total queue-wait nanoseconds behind this lane.
    pub queue_ns: i64,
}

/// Busy time one pipeline's complete rounds spent on one lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneBusy {
    pub device: DeviceId,
    pub unit: UnitKind,
    pub pipeline: usize,
    /// Total task-execution nanoseconds on this lane.
    pub busy_ns: i64,
}

/// The extraction result: per-round attributions plus the per-lane
/// aggregates blame reports build on. Lists are sorted by their natural
/// keys, so equal traces extract to equal values.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct CriticalPath {
    /// One entry per complete round, ordered by (pipeline, run).
    pub rounds: Vec<RoundBlame>,
    /// Rounds skipped because their task chain was truncated (trace
    /// window, horizon cut) or never reached its Interact task.
    pub incomplete_rounds: usize,
    /// Queue-wait per lane, over complete rounds.
    pub queue_by_lane: Vec<LaneQueue>,
    /// Busy time per (lane, pipeline), over complete rounds.
    pub busy_by_lane: Vec<LaneBusy>,
}

/// Total lane occupancy within `[a, b)` given the lane's spans sorted by
/// start. Unit exclusivity keeps lane spans non-overlapping, so summing
/// per-span overlaps never double-counts.
fn occupied_within(spans: &[(i64, i64)], a: i64, b: i64) -> i64 {
    // Only the last span starting before `a` can straddle it.
    let mut i = spans.partition_point(|&(s, _)| s < a).saturating_sub(1);
    let mut total = 0;
    while i < spans.len() {
        let (s, e) = spans[i];
        if s >= b {
            break;
        }
        total += (e.min(b) - s.max(a)).max(0);
        i += 1;
    }
    total
}

/// Walk `spans` and attribute every complete round's latency. Rounds are
/// grouped by (pipeline, run) and ordered by seq; a round is complete
/// when its seqs are contiguous from 0 and end in an Interact task.
pub fn extract_critical(spans: &[TaskSpan]) -> CriticalPath {
    // Lane occupancy index: queue classification asks "was this unit
    // busy during the gap before task i?".
    let mut lanes: BTreeMap<(DeviceId, UnitKind), Vec<(i64, i64)>> = BTreeMap::new();
    for s in spans {
        lanes.entry((s.device, s.unit)).or_default().push((ns(s.start), ns(s.end)));
    }
    for v in lanes.values_mut() {
        v.sort_unstable();
    }

    let mut rounds_by_key: BTreeMap<(usize, usize), Vec<&TaskSpan>> = BTreeMap::new();
    for s in spans {
        rounds_by_key.entry((s.pipeline, s.run)).or_default().push(s);
    }

    let mut out = CriticalPath::default();
    let mut queue_by_lane: BTreeMap<(DeviceId, UnitKind), i64> = BTreeMap::new();
    let mut busy_by_lane: BTreeMap<(DeviceId, UnitKind, usize), i64> = BTreeMap::new();
    for ((pipeline, run), mut tasks) in rounds_by_key {
        tasks.sort_by_key(|s| s.seq);
        let contiguous = tasks.iter().enumerate().all(|(i, s)| s.seq == i);
        let terminal = matches!(tasks.last().map(|s| s.kind), Some(TaskKind::Interact { .. }));
        if !contiguous || !terminal {
            out.incomplete_rounds += 1;
            continue;
        }

        let start_ns = ns(tasks[0].start);
        let mut blame = RoundBlame {
            pipeline,
            run,
            start_ns,
            end_ns: ns(tasks[tasks.len() - 1].end),
            compute_ns: 0,
            radio_ns: 0,
            queue_ns: 0,
            pacing_ns: 0,
        };
        let mut prev_end = start_ns;
        for t in &tasks {
            let (s, e) = (ns(t.start), ns(t.end));
            let dur = e - s;
            match t.kind {
                TaskKind::Tx { .. } | TaskKind::Rx { .. } => blame.radio_ns += dur,
                _ => blame.compute_ns += dur,
            }
            *busy_by_lane.entry((t.device, t.unit, pipeline)).or_insert(0) += dur;

            let gap = s - prev_end;
            if gap > 0 {
                let occupied = lanes
                    .get(&(t.device, t.unit))
                    .map_or(0, |v| occupied_within(v, prev_end, s))
                    .min(gap);
                blame.queue_ns += occupied;
                blame.pacing_ns += gap - occupied;
                if occupied > 0 {
                    *queue_by_lane.entry((t.device, t.unit)).or_insert(0) += occupied;
                }
            } else {
                // A causality violation would surface as negative pacing
                // instead of silently breaking conservation.
                blame.pacing_ns += gap;
            }
            prev_end = e;
        }
        out.rounds.push(blame);
    }

    out.queue_by_lane = queue_by_lane
        .into_iter()
        .map(|((device, unit), queue_ns)| LaneQueue { device, unit, queue_ns })
        .collect();
    out.busy_by_lane = busy_by_lane
        .into_iter()
        .map(|((device, unit, pipeline), busy_ns)| LaneBusy { device, unit, pipeline, busy_ns })
        .collect();
    out
}

fn parse_device(process: &str) -> Option<DeviceId> {
    process.strip_prefix('d')?.parse().ok().map(DeviceId)
}

fn parse_unit(thread: &str) -> Option<UnitKind> {
    match thread {
        "Sensor" => Some(UnitKind::Sensor),
        "Cpu" => Some(UnitKind::Cpu),
        "Accel" => Some(UnitKind::Accel),
        "Radio" => Some(UnitKind::Radio),
        _ => None,
    }
}

/// Payload sizes are not in the recording, so reconstructed kinds carry
/// zero bytes — attribution only looks at the kind's category.
fn kind_from_label(label: &str) -> Option<TaskKind> {
    Some(match label {
        "sense" => TaskKind::Sense { bytes: 0 },
        "load" => TaskKind::Load { bytes: 0 },
        "infer" => TaskKind::Infer { range: SplitRange::new(0, 1) },
        "unload" => TaskKind::Unload { bytes: 0 },
        "tx" => TaskKind::Tx { bytes: 0, to: DeviceId(0) },
        "rx" => TaskKind::Rx { bytes: 0, from: DeviceId(0) },
        "interact" => TaskKind::Interact { bytes: 0 },
        _ => return None,
    })
}

/// `p<pipeline> <task> r<run> s<seq>`, the label
/// [`record_task_spans`](super::emit::record_task_spans) writes.
fn parse_task_name(name: &str) -> Option<(usize, TaskKind, usize, usize)> {
    let mut it = name.split(' ');
    let pipeline = it.next()?.strip_prefix('p')?.parse().ok()?;
    let kind = kind_from_label(it.next()?)?;
    let run = it.next()?.strip_prefix('r')?.parse().ok()?;
    let seq = it.next()?.strip_prefix('s')?.parse().ok()?;
    if it.next().is_some() {
        return None;
    }
    Some((pipeline, kind, run, seq))
}

/// Reconstruct the task spans a recording holds: spans on `d<N>` /
/// unit-named tracks whose labels parse as task identities. Busy-lane
/// spans (bare unit labels on the same tracks), counters, instants, and
/// session tracks are skipped; a `p`-prefixed label that fails to parse
/// is an error — it means the emit format drifted.
pub fn tasks_from_recording(rec: &FlightRecording) -> Result<Vec<TaskSpan>, String> {
    let mut out = Vec::new();
    for ev in &rec.events {
        let EventKind::Span { dur } = ev.kind else {
            continue;
        };
        let track = rec.track_of(ev);
        let Some(device) = parse_device(&track.process) else {
            continue;
        };
        let Some(unit) = parse_unit(&track.thread) else {
            continue;
        };
        if !ev.name.starts_with('p') {
            continue;
        }
        let (pipeline, kind, run, seq) = parse_task_name(&ev.name)
            .ok_or_else(|| format!("malformed task-span label {:?}", ev.name))?;
        out.push(TaskSpan {
            pipeline,
            seq,
            run,
            device,
            unit,
            kind,
            start: ev.t,
            end: ev.t + dur,
        });
    }
    out.sort_by(|a, b| {
        a.start
            .total_cmp(&b.start)
            .then_with(|| (a.pipeline, a.run, a.seq).cmp(&(b.pipeline, b.run, b.seq)))
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::emit::record_task_spans;
    use crate::obs::sink::TraceSink;
    use crate::scheduler::Trace;

    fn task(
        pipeline: usize,
        run: usize,
        seq: usize,
        kind: TaskKind,
        device: usize,
        start: f64,
        end: f64,
    ) -> TaskSpan {
        TaskSpan {
            pipeline,
            seq,
            run,
            device: DeviceId(device),
            unit: kind.unit(),
            kind,
            start,
            end,
        }
    }

    /// Two pipelines contending for d0's Accel: p1's infer waits behind
    /// p0's, and the wait classifies as queue, not pacing.
    fn contended_spans() -> Vec<TaskSpan> {
        vec![
            task(0, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1),
            task(0, 0, 1, TaskKind::Infer { range: SplitRange::new(0, 1) }, 0, 0.1, 0.6),
            task(0, 0, 2, TaskKind::Interact { bytes: 1 }, 0, 0.6, 0.7),
            task(1, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1),
            // Waits 0.5 s for the Accel (queue), then 0.1 s of nothing
            // (pacing), runs 0.7–1.2.
            task(1, 0, 1, TaskKind::Infer { range: SplitRange::new(0, 1) }, 0, 0.7, 1.2),
            task(1, 0, 2, TaskKind::Interact { bytes: 1 }, 0, 1.2, 1.3),
        ]
    }

    #[test]
    fn attribution_conserves_latency_bit_exactly() {
        let cp = extract_critical(&contended_spans());
        assert_eq!(cp.incomplete_rounds, 0);
        assert_eq!(cp.rounds.len(), 2);
        for r in &cp.rounds {
            assert_eq!(r.attributed_ns(), r.latency_ns(), "{r:?}");
        }
    }

    #[test]
    fn queue_wait_is_separated_from_pacing() {
        let cp = extract_critical(&contended_spans());
        let p1 = cp.rounds[1];
        assert_eq!(p1.pipeline, 1);
        // 0.1 sense + 0.5 infer + 0.1 interact compute; gap 0.1–0.7 is
        // 0.5 queued behind p0's infer + 0.1 idle.
        assert_eq!(p1.compute_ns, 700_000_000);
        assert_eq!(p1.queue_ns, 500_000_000);
        assert_eq!(p1.pacing_ns, 100_000_000);
        assert_eq!(p1.radio_ns, 0);

        let accel_queue: i64 = cp
            .queue_by_lane
            .iter()
            .filter(|l| l.unit == UnitKind::Accel)
            .map(|l| l.queue_ns)
            .sum();
        assert_eq!(accel_queue, 500_000_000);
    }

    #[test]
    fn radio_tasks_bucket_separately() {
        let spans = vec![
            task(0, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1),
            task(0, 0, 1, TaskKind::Tx { bytes: 1, to: DeviceId(1) }, 0, 0.1, 0.3),
            task(0, 0, 2, TaskKind::Rx { bytes: 1, from: DeviceId(0) }, 1, 0.3, 0.5),
            task(0, 0, 3, TaskKind::Infer { range: SplitRange::new(0, 1) }, 1, 0.5, 0.7),
            task(0, 0, 4, TaskKind::Interact { bytes: 1 }, 1, 0.7, 0.8),
        ];
        let cp = extract_critical(&spans);
        assert_eq!(cp.rounds.len(), 1);
        let r = cp.rounds[0];
        assert_eq!(r.radio_ns, 400_000_000);
        assert_eq!(r.compute_ns, 400_000_000);
        assert_eq!(r.attributed_ns(), r.latency_ns());
    }

    #[test]
    fn truncated_rounds_count_as_incomplete() {
        let mut spans = contended_spans();
        spans.remove(0); // p0 loses its seq-0 sense task.
        let cp = extract_critical(&spans);
        assert_eq!(cp.incomplete_rounds, 1);
        assert_eq!(cp.rounds.len(), 1);
        assert_eq!(cp.rounds[0].pipeline, 1);

        // A round without its Interact terminal is incomplete too.
        let open = vec![task(0, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1)];
        let cp = extract_critical(&open);
        assert_eq!(cp.incomplete_rounds, 1);
        assert!(cp.rounds.is_empty());
    }

    #[test]
    fn recording_roundtrip_preserves_task_identity() {
        let spans = contended_spans();
        let mut rec = FlightRecording::new();
        record_task_spans(&Trace { spans: spans.clone() }, &mut rec);
        // Busy-lane noise on the same tracks must not confuse the parser.
        let lane = rec.track("d0", "Accel");
        rec.span(lane, "Accel", 0.1, 1.2);

        let got = tasks_from_recording(&rec).unwrap();
        assert_eq!(got.len(), spans.len());
        let mut want = spans;
        want.sort_by(|a, b| {
            a.start
                .total_cmp(&b.start)
                .then_with(|| (a.pipeline, a.run, a.seq).cmp(&(b.pipeline, b.run, b.seq)))
        });
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.pipeline, g.run, g.seq), (w.pipeline, w.run, w.seq));
            assert_eq!((g.device, g.unit), (w.device, w.unit));
            assert_eq!(g.start.to_bits(), w.start.to_bits());
            assert_eq!(g.end.to_bits(), w.end.to_bits());
            assert_eq!(g.kind.unit(), w.kind.unit());
        }

        let malformed = {
            let mut r = FlightRecording::new();
            let t = r.track("d0", "Cpu");
            r.span(t, "p0 sense", 0.0, 0.1); // pre-PR-10 label: no r/s.
            r
        };
        assert!(tasks_from_recording(&malformed).is_err());
    }

    #[test]
    fn ns_rounds_rather_than_truncates() {
        assert_eq!(ns(0.1), 100_000_000);
        assert_eq!(ns(0.3), 300_000_000);
        assert_eq!(ns(1e-9), 1);
    }
}
