//! Observability: the flight recorder (§VII's temporal claims, made
//! visible) and the analysis layer that turns recordings into
//! explanations.
//!
//! The pieces, all dependency-free:
//!
//! - [`sink`] — the [`TraceSink`] span/instant/counter API stamped in
//!   **simulated** time, with a zero-cost [`NullSink`] and the in-memory
//!   [`FlightRecording`]. Emission is post-hoc over deterministic
//!   engine artifacts ([`emit`]), never live from worker threads, so a
//!   recording is bit-identical across reruns and worker counts.
//! - [`registry`] — [`MetricsRegistry`]: named atomic counters, gauges,
//!   and histograms with deterministic [`MetricsSnapshot`]s. Wall-clock
//!   and scheduling-dependent figures live under the `annex.` prefix
//!   and are dropped by [`MetricsSnapshot::scrub_annex`] before
//!   determinism comparisons.
//! - [`perfetto`] / [`export`] — exporters: canonical Chrome
//!   trace-event JSON (loads in [Perfetto](https://ui.perfetto.dev)),
//!   the inverse importer for `trace-diff`, a serde-free structural
//!   validator for CI, and flat JSON forms of the session / population /
//!   capacity / blame reports for `--json` CLI output.
//! - [`critical`] / [`blame`] / [`diff`] — post-hoc analysis over
//!   recordings: critical-path extraction with bit-exact latency
//!   attribution, [`BlameReport`]s whose measured bottleneck
//!   cross-checks the static capacity analysis, and structural trace /
//!   metrics differencing with ranked deltas.
//!
//! Surfaces: `synergy trace --scenario cascade8 --out trace.json`,
//! `synergy blame --scenario <name>`, `synergy trace-diff A.json
//! B.json`,
//! [`Session::finish_traced`](crate::api::Session::finish_traced), and
//! [`PopulationCfg::trace_user`](crate::population::PopulationCfg).
//!
//! The xtask linter bans `std::time` in this module: every timestamp a
//! sink sees is simulated or injected by the caller.

pub mod blame;
pub mod critical;
pub mod diff;
pub mod emit;
pub mod export;
pub mod perfetto;
pub mod registry;
pub mod sink;

pub use blame::{BlameCategory, BlameReport, PipelineBlame, UnitBlame};
pub use critical::{extract_critical, tasks_from_recording, CriticalPath, RoundBlame};
pub use diff::{diff_metrics, diff_recordings, MetricsDiff, RecordingDiff};
pub use emit::{record_session, session_metrics};
pub use perfetto::{recording_from_chrome_json, to_chrome_json, validate_chrome_trace};
pub use registry::{Counter, HistSummary, MetricsRegistry, MetricsSnapshot, ANNEX_PREFIX};
pub use sink::{EventKind, FlightRecording, NullSink, TraceEvent, TraceSink, Track, TrackId};
