//! Blame reports: aggregate per-round critical-path attributions
//! ([`super::critical`]) into per-pipeline and per-(device, unit)
//! stories, and name the *measured* bottleneck.
//!
//! The measured bottleneck uses the same normalization and tie rule as
//! the static [`analyze_capacity`](crate::analysis::analyze_capacity)
//! analysis: each lane's busy time is normalized per round of each
//! pipeline that used it (`Σ_p busy_{p,lane} / rounds_p`), the busiest
//! lane wins, and ties keep the lowest (device, unit) key. That makes
//! [`BlameReport::agrees_with`] a meaningful cross-check — the static
//! prediction and the measured trace must name the same unit, which
//! `tests/blame_diff.rs` gates for every canned workload × fleet.

use std::collections::BTreeMap;
use std::fmt;

use super::critical::{extract_critical, tasks_from_recording};
use super::sink::FlightRecording;
use crate::analysis::CapacityReport;
use crate::device::DeviceId;
use crate::plan::UnitKind;
use crate::scheduler::TaskSpan;

/// Where a slice of round latency went. Declaration order is the
/// tie-break order for [`PipelineBlame::dominant`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BlameCategory {
    /// Non-radio task execution (Sense, Load, Infer, Unload, Interact).
    Compute,
    /// Tx/Rx task execution.
    Radio,
    /// Waiting for a (device, unit) lane that was busy with other work.
    Queue,
    /// Residual idle time: admission pacing, dependency slack.
    Pacing,
}

impl BlameCategory {
    /// All categories, in declaration (tie-break) order.
    pub const ALL: [BlameCategory; 4] = [
        BlameCategory::Compute,
        BlameCategory::Radio,
        BlameCategory::Queue,
        BlameCategory::Pacing,
    ];
}

impl fmt::Display for BlameCategory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BlameCategory::Compute => "compute",
            BlameCategory::Radio => "radio",
            BlameCategory::Queue => "queue",
            BlameCategory::Pacing => "pacing",
        })
    }
}

/// One pipeline's latency attribution, summed over its complete rounds.
/// The category totals partition `latency_ns` exactly, inheriting the
/// per-round conservation invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineBlame {
    pub pipeline: usize,
    /// Complete rounds aggregated here.
    pub rounds: usize,
    pub compute_ns: i64,
    pub radio_ns: i64,
    pub queue_ns: i64,
    pub pacing_ns: i64,
    /// Total end-to-end latency over the aggregated rounds.
    pub latency_ns: i64,
}

impl PipelineBlame {
    /// Total nanoseconds attributed to `c`.
    pub fn category_ns(&self, c: BlameCategory) -> i64 {
        match c {
            BlameCategory::Compute => self.compute_ns,
            BlameCategory::Radio => self.radio_ns,
            BlameCategory::Queue => self.queue_ns,
            BlameCategory::Pacing => self.pacing_ns,
        }
    }

    /// The category holding the most latency; ties keep the first in
    /// [`BlameCategory::ALL`] order.
    pub fn dominant(&self) -> BlameCategory {
        let mut best = BlameCategory::Compute;
        for c in BlameCategory::ALL {
            if self.category_ns(c) > self.category_ns(best) {
                best = c;
            }
        }
        best
    }

    /// Mean end-to-end round latency in seconds (0 when no rounds).
    pub fn mean_latency_s(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.latency_ns as f64 / 1e9 / self.rounds as f64
        }
    }
}

/// One (device, unit) lane's measured load story.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnitBlame {
    pub device: DeviceId,
    pub unit: UnitKind,
    /// Task-execution time on this lane, over complete rounds.
    pub busy_ns: i64,
    /// How long rounds waited *for* this lane while it ran other work.
    pub queue_caused_ns: i64,
    /// Busy seconds normalized per round of each pipeline that used the
    /// lane — the measured analogue of static per-round unit busy, and
    /// the bottleneck ranking key.
    pub normalized_busy_s: f64,
}

/// The aggregated blame story of one trace. All lists are sorted by
/// their natural keys; building the report twice from equal traces
/// yields equal reports (`tests/blame_diff.rs` pins this across
/// engines, reruns, and worker counts).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct BlameReport {
    /// Per-pipeline attributions, ordered by pipeline id.
    pub pipelines: Vec<PipelineBlame>,
    /// Per-lane load, ordered by (device, unit).
    pub units: Vec<UnitBlame>,
    /// Complete rounds aggregated across all pipelines.
    pub rounds: usize,
    /// Rounds skipped as truncated/unfinished.
    pub incomplete_rounds: usize,
    /// The measured bottleneck lane (highest normalized busy; ties keep
    /// the lowest key — the same rule the static analysis uses). `None`
    /// when the trace holds no complete round.
    pub measured_bottleneck: Option<(DeviceId, UnitKind)>,
}

impl BlameReport {
    /// Aggregate a task trace (either engine's) into a blame report.
    pub fn from_spans(spans: &[TaskSpan]) -> BlameReport {
        let cp = extract_critical(spans);

        let mut pipelines: BTreeMap<usize, PipelineBlame> = BTreeMap::new();
        for r in &cp.rounds {
            let p = pipelines.entry(r.pipeline).or_insert(PipelineBlame {
                pipeline: r.pipeline,
                rounds: 0,
                compute_ns: 0,
                radio_ns: 0,
                queue_ns: 0,
                pacing_ns: 0,
                latency_ns: 0,
            });
            p.rounds += 1;
            p.compute_ns += r.compute_ns;
            p.radio_ns += r.radio_ns;
            p.queue_ns += r.queue_ns;
            p.pacing_ns += r.pacing_ns;
            p.latency_ns += r.latency_ns();
        }
        let rounds_of: BTreeMap<usize, usize> =
            pipelines.values().map(|p| (p.pipeline, p.rounds)).collect();

        let mut units: BTreeMap<(DeviceId, UnitKind), UnitBlame> = BTreeMap::new();
        for b in &cp.busy_by_lane {
            let u = units.entry((b.device, b.unit)).or_insert(UnitBlame {
                device: b.device,
                unit: b.unit,
                busy_ns: 0,
                queue_caused_ns: 0,
                normalized_busy_s: 0.0,
            });
            u.busy_ns += b.busy_ns;
            if let Some(&n) = rounds_of.get(&b.pipeline) {
                if n > 0 {
                    u.normalized_busy_s += b.busy_ns as f64 / 1e9 / n as f64;
                }
            }
        }
        for q in &cp.queue_by_lane {
            if let Some(u) = units.get_mut(&(q.device, q.unit)) {
                u.queue_caused_ns += q.queue_ns;
            }
        }

        // Strict `>` keeps the first (lowest) lane key on ties — the
        // fold analyze_capacity uses for its static bottleneck.
        let mut bottleneck: Option<((DeviceId, UnitKind), f64)> = None;
        for (&key, u) in &units {
            bottleneck = match bottleneck {
                Some((_, best)) if best >= u.normalized_busy_s => bottleneck,
                _ => Some((key, u.normalized_busy_s)),
            };
        }

        BlameReport {
            rounds: cp.rounds.len(),
            incomplete_rounds: cp.incomplete_rounds,
            pipelines: pipelines.into_values().collect(),
            units: units.into_values().collect(),
            measured_bottleneck: bottleneck.map(|(key, _)| key),
        }
    }

    /// Aggregate a flight recording's task spans — errors if the
    /// recording's task-span labels do not parse.
    pub fn from_recording(rec: &FlightRecording) -> Result<BlameReport, String> {
        Ok(BlameReport::from_spans(&tasks_from_recording(rec)?))
    }

    /// `true` when the measured bottleneck names the same (device, unit)
    /// as the static capacity analysis — the check that makes the
    /// planner's predictions and the engines' traces argue.
    pub fn agrees_with(&self, cap: &CapacityReport) -> bool {
        self.measured_bottleneck == cap.bottleneck_unit()
    }

    /// Conservation check over every pipeline: attributed category
    /// totals must equal total latency, bit-exactly. `Err` names the
    /// first offending pipeline.
    pub fn check_conservation(&self) -> Result<(), String> {
        for p in &self.pipelines {
            let attributed = p.compute_ns + p.radio_ns + p.queue_ns + p.pacing_ns;
            if attributed != p.latency_ns {
                return Err(format!(
                    "pipeline {}: attributed {} ns != latency {} ns",
                    p.pipeline, attributed, p.latency_ns
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SplitRange;
    use crate::plan::TaskKind;

    fn task(
        pipeline: usize,
        run: usize,
        seq: usize,
        kind: TaskKind,
        device: usize,
        start: f64,
        end: f64,
    ) -> TaskSpan {
        TaskSpan {
            pipeline,
            seq,
            run,
            device: DeviceId(device),
            unit: kind.unit(),
            kind,
            start,
            end,
        }
    }

    fn contended() -> Vec<TaskSpan> {
        vec![
            task(0, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1),
            task(0, 0, 1, TaskKind::Infer { range: SplitRange::new(0, 1) }, 0, 0.1, 0.6),
            task(0, 0, 2, TaskKind::Interact { bytes: 1 }, 0, 0.6, 0.7),
            task(1, 0, 0, TaskKind::Sense { bytes: 1 }, 0, 0.0, 0.1),
            task(1, 0, 1, TaskKind::Infer { range: SplitRange::new(0, 1) }, 0, 0.7, 1.2),
            task(1, 0, 2, TaskKind::Interact { bytes: 1 }, 0, 1.2, 1.3),
        ]
    }

    #[test]
    fn report_aggregates_and_conserves() {
        let r = BlameReport::from_spans(&contended());
        assert_eq!(r.rounds, 2);
        assert_eq!(r.incomplete_rounds, 0);
        assert_eq!(r.pipelines.len(), 2);
        r.check_conservation().unwrap();
        assert_eq!(r.pipelines[0].dominant(), BlameCategory::Compute);
        assert_eq!(r.pipelines[1].dominant(), BlameCategory::Compute);
        assert_eq!(r.pipelines[1].queue_ns, 500_000_000);
    }

    #[test]
    fn measured_bottleneck_is_the_contended_accel() {
        let r = BlameReport::from_spans(&contended());
        // Accel runs 1.0 s of infer across two 1-round pipelines; the
        // Cpu and Sensor lanes carry far less.
        assert_eq!(r.measured_bottleneck, Some((DeviceId(0), UnitKind::Accel)));
        let accel = r
            .units
            .iter()
            .find(|u| u.unit == UnitKind::Accel)
            .expect("accel lane present");
        assert_eq!(accel.busy_ns, 1_000_000_000);
        assert_eq!(accel.queue_caused_ns, 500_000_000);
        assert!((accel.normalized_busy_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_has_no_bottleneck() {
        let r = BlameReport::from_spans(&[]);
        assert_eq!(r.rounds, 0);
        assert_eq!(r.measured_bottleneck, None);
        assert!(r.pipelines.is_empty());
        r.check_conservation().unwrap();
    }

    #[test]
    fn dominant_prefers_declaration_order_on_ties() {
        let p = PipelineBlame {
            pipeline: 0,
            rounds: 1,
            compute_ns: 5,
            radio_ns: 5,
            queue_ns: 5,
            pacing_ns: 5,
            latency_ns: 20,
        };
        assert_eq!(p.dominant(), BlameCategory::Compute);
    }
}
