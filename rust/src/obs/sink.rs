//! The flight-recorder sink: spans, instants, and counter samples on
//! named tracks, stamped in **simulated** time.
//!
//! Two implementations: [`NullSink`] (recording off — every method is a
//! no-op and [`TraceSink::enabled`] returns `false`, so emission sites
//! skip even their `format!` calls) and [`FlightRecording`] (an in-memory
//! event buffer that the Chrome/Perfetto exporter serializes).
//!
//! Timestamps are seconds of simulated time, the same clock the DES and
//! the serve-engine timeline run on. Wall-clock readings never enter a
//! recording — the xtask linter bans `std::time` in this module outright
//! — which is what makes traces bit-identical across reruns and worker
//! counts.

/// Handle to a (process, thread) track inside a sink. `TrackId(0)` is
/// what [`NullSink`] hands out; a recording sink returns a stable index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrackId(pub(crate) usize);

/// A named timeline: `process` groups tracks (a device, or the session
/// itself), `thread` is the lane within it (a compute unit, "switches").
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Track {
    /// Coarse grouping — becomes the Perfetto process name.
    pub process: String,
    /// Lane within the group — becomes the Perfetto thread name.
    pub thread: String,
}

/// What happened at [`TraceEvent::t`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A duration: the event's `t` is the start, `dur` the length (s).
    Span { dur: f64 },
    /// A point marker (plan switch, battery depletion, epoch retire).
    Instant,
    /// A sampled value on a counter track (power_w, battery_j, inflight).
    Counter { value: f64 },
}

/// One recorded event on one track, stamped in simulated seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Index into [`FlightRecording::tracks`].
    pub track: TrackId,
    /// Event label (span name, marker text, or counter series name).
    pub name: String,
    /// Simulated time in seconds (span start for [`EventKind::Span`]).
    pub t: f64,
    /// Span / instant / counter payload.
    pub kind: EventKind,
}

/// Where emission sites write. All timestamps are simulated seconds;
/// implementations must not consult any clock of their own.
///
/// Emission helpers check [`TraceSink::enabled`] before building names,
/// so the disabled path performs no allocation at all (the zero-cost
/// contract `tests/obs_zero_alloc.rs` pins).
pub trait TraceSink {
    /// `false` for the no-op sink: callers skip formatting entirely.
    fn enabled(&self) -> bool;
    /// Intern a (process, thread) track and return its handle.
    fn track(&mut self, process: &str, thread: &str) -> TrackId;
    /// Record a duration `[start, end]` on `track`.
    fn span(&mut self, track: TrackId, name: &str, start: f64, end: f64);
    /// Record a point marker at `t` on `track`.
    fn instant(&mut self, track: TrackId, name: &str, t: f64);
    /// Record a counter sample `value` at `t` on `track`.
    fn counter(&mut self, track: TrackId, name: &str, t: f64, value: f64);
}

/// Recording disabled: every method is a no-op and `enabled()` is
/// `false`. The zero-alloc bench and test gate this path.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn track(&mut self, _process: &str, _thread: &str) -> TrackId {
        TrackId(0)
    }
    fn span(&mut self, _track: TrackId, _name: &str, _start: f64, _end: f64) {}
    fn instant(&mut self, _track: TrackId, _name: &str, _t: f64) {}
    fn counter(&mut self, _track: TrackId, _name: &str, _t: f64, _value: f64) {}
}

/// In-memory recording: interned tracks plus the event stream, in
/// emission order. The Chrome exporter canonicalizes ordering, so two
/// recordings of the same timeline serialize identically even if their
/// emission interleavings differ.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FlightRecording {
    /// Interned tracks; [`TraceEvent::track`] indexes into this.
    pub tracks: Vec<Track>,
    /// Events in emission order.
    pub events: Vec<TraceEvent>,
}

impl FlightRecording {
    /// Fresh, empty recording.
    pub fn new() -> FlightRecording {
        FlightRecording::default()
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// `true` when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The track an event of *this* recording was emitted on. Panics if
    /// handed an event from a different recording (the handle is an
    /// index).
    pub fn track_of(&self, event: &TraceEvent) -> &Track {
        &self.tracks[event.track.0]
    }
}

impl TraceSink for FlightRecording {
    fn enabled(&self) -> bool {
        true
    }

    fn track(&mut self, process: &str, thread: &str) -> TrackId {
        // Linear intern: track counts are tens (devices × units), and a
        // scan avoids allocating a lookup key on repeat registration.
        if let Some(i) = self
            .tracks
            .iter()
            .position(|tr| tr.process == process && tr.thread == thread)
        {
            return TrackId(i);
        }
        self.tracks.push(Track { process: process.to_string(), thread: thread.to_string() });
        TrackId(self.tracks.len() - 1)
    }

    fn span(&mut self, track: TrackId, name: &str, start: f64, end: f64) {
        self.events.push(TraceEvent {
            track,
            name: name.to_string(),
            t: start,
            kind: EventKind::Span { dur: (end - start).max(0.0) },
        });
    }

    fn instant(&mut self, track: TrackId, name: &str, t: f64) {
        self.events.push(TraceEvent { track, name: name.to_string(), t, kind: EventKind::Instant });
    }

    fn counter(&mut self, track: TrackId, name: &str, t: f64, value: f64) {
        self.events.push(TraceEvent {
            track,
            name: name.to_string(),
            t,
            kind: EventKind::Counter { value },
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled_and_inert() {
        let mut s = NullSink;
        assert!(!s.enabled());
        let t = s.track("d0", "Cpu");
        assert_eq!(t, TrackId(0));
        s.span(t, "x", 0.0, 1.0);
        s.instant(t, "x", 0.5);
        s.counter(t, "x", 0.5, 1.0);
    }

    #[test]
    fn recording_interns_tracks_and_keeps_emission_order() {
        let mut r = FlightRecording::new();
        let a = r.track("d0", "Cpu");
        let b = r.track("d0", "Accel");
        let a2 = r.track("d0", "Cpu");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(r.tracks.len(), 2);

        r.span(a, "infer", 1.0, 2.5);
        r.instant(b, "switch", 2.0);
        r.counter(a, "power_w", 0.0, 0.25);
        assert_eq!(r.len(), 3);
        assert_eq!(r.events[0].kind, EventKind::Span { dur: 1.5 });
        assert_eq!(r.events[1].kind, EventKind::Instant);
        assert_eq!(r.events[2].kind, EventKind::Counter { value: 0.25 });
    }

    #[test]
    fn negative_spans_clamp_to_zero_duration() {
        let mut r = FlightRecording::new();
        let t = r.track("d0", "Cpu");
        r.span(t, "x", 2.0, 1.0);
        assert_eq!(r.events[0].kind, EventKind::Span { dur: 0.0 });
    }
}
