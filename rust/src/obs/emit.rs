//! Emission sites: walk the deterministic artifacts a finished session
//! leaves behind (report intervals, switch timeline, QoS spans, the DES
//! task trace, the serve engine's busy spans) and write them into a
//! [`TraceSink`] / [`MetricsRegistry`].
//!
//! Everything here is *post-hoc*: the engines never call a sink from
//! their hot paths or worker threads. The serve engine's timeline is
//! reconstructed from its `ServeOutcome` (busy spans, rebinds), which
//! the session layer already folds deterministically — so a trace of a
//! served session is bit-identical across worker counts for free.
//!
//! Every function early-returns when the sink is disabled, before any
//! name formatting — the zero-allocation contract the `obs_benches`
//! budget and `tests/obs_zero_alloc.rs` enforce.

use std::collections::BTreeMap;

use super::registry::MetricsRegistry;
use super::sink::{TraceSink, TrackId};
use crate::api::SessionReport;
use crate::device::DeviceId;
use crate::plan::TaskKind;
use crate::power::{BusyKind, BusySpan};
use crate::scheduler::Trace;

/// Short lane label for a scheduler task.
fn task_label(kind: &TaskKind) -> &'static str {
    match kind {
        TaskKind::Sense { .. } => "sense",
        TaskKind::Load { .. } => "load",
        TaskKind::Infer { .. } => "infer",
        TaskKind::Unload { .. } => "unload",
        TaskKind::Tx { .. } => "tx",
        TaskKind::Rx { .. } => "rx",
        TaskKind::Interact { .. } => "interact",
    }
}

/// Unit lane a task occupies in the trace, mirrored from the DES's
/// unit-queue taxonomy.
fn busy_label(kind: BusyKind) -> &'static str {
    match kind {
        BusyKind::Sensor => "Sensor",
        BusyKind::Cpu => "Cpu",
        BusyKind::Accel => "Accel",
        BusyKind::RadioTx => "Radio.tx",
        BusyKind::RadioRx => "Radio.rx",
    }
}

fn device_process(d: DeviceId) -> String {
    format!("d{}", d.0)
}

/// Record a finished session into `sink`: switch/depletion instants, QoS
/// spans, power and battery counter tracks, per-(device, unit) task
/// spans from the DES trace, and — for served sessions — the workers'
/// busy spans replayed from the engine outcome (`serve_busy`).
pub fn record_session(report: &SessionReport, serve_busy: &[BusySpan], sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    record_switches(report, sink);
    record_qos(report, sink);
    record_power(report, sink);
    if let Some(trace) = &report.trace {
        record_task_spans(trace, sink);
        record_inflight(trace, sink);
    }
    record_serve_busy(serve_busy, sink);
}

/// Plan switches and battery depletions as thread-scoped instants on the
/// session's `switches` track. Cause labels are the deterministic
/// [`PlanSwitch::cause`](crate::api::PlanSwitch) strings — the wall-clock
/// annex fields never enter the trace.
pub fn record_switches(report: &SessionReport, sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    let track = sink.track("session", "switches");
    for sw in &report.switches {
        sink.instant(track, &format!("plan-switch: {} ({} apps)", sw.cause, sw.apps), sw.t);
    }
}

/// QoS-violation spans on the session's `qos` track.
pub fn record_qos(report: &SessionReport, sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    let track = sink.track("session", "qos");
    for q in &report.qos_spans {
        sink.span(track, &format!("qos {} {}: {}", q.app, q.name, q.violation), q.start, q.end);
    }
}

/// Power draw (session-wide, stepped per interval) and per-device
/// battery state-of-charge counter tracks.
pub fn record_power(report: &SessionReport, sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    let power = sink.track("session", "power");
    for iv in &report.intervals {
        sink.counter(power, "power_w", iv.start, iv.power_w);
    }
    if let Some(last) = report.intervals.last() {
        sink.counter(power, "power_w", last.end, last.power_w);
    }

    let mut battery_tracks: BTreeMap<DeviceId, TrackId> = BTreeMap::new();
    for iv in &report.intervals {
        for &(d, j) in &iv.battery_j {
            let track = *battery_tracks
                .entry(d)
                .or_insert_with(|| sink.track(&device_process(d), "battery"));
            sink.counter(track, "battery_j", iv.end, j);
        }
    }
}

/// Every task span on its (device, unit) lane, labelled
/// `p<pipeline> <task> r<run> s<seq>` — the §IV-F per-unit occupancy
/// picture. The label carries the full task identity so
/// [`crate::obs::critical::tasks_from_recording`] can reconstruct
/// rounds from an exported recording.
pub fn record_task_spans(trace: &Trace, sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    for span in &trace.spans {
        let track = sink.track(&device_process(span.device), &format!("{:?}", span.unit));
        sink.span(
            track,
            &format!(
                "p{} {} r{} s{}",
                span.pipeline,
                task_label(&span.kind),
                span.run,
                span.seq
            ),
            span.start,
            span.end,
        );
    }
}

/// Rounds-in-flight counter derived from the DES trace: +1 at each
/// (pipeline, run)'s first task start, −1 at its last task end — the
/// queue-depth picture for the simulated engine.
pub fn record_inflight(trace: &Trace, sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    let mut rounds: BTreeMap<(usize, usize), (f64, f64)> = BTreeMap::new();
    for span in &trace.spans {
        let e = rounds.entry((span.pipeline, span.run)).or_insert((span.start, span.end));
        e.0 = e.0.min(span.start);
        e.1 = e.1.max(span.end);
    }
    let mut deltas: Vec<(f64, i64)> = Vec::with_capacity(rounds.len() * 2);
    for &(start, end) in rounds.values() {
        deltas.push((start, 1));
        deltas.push((end, -1));
    }
    // Ends before starts at equal times, so depth dips are not overstated.
    deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let track = sink.track("session", "inflight");
    let mut depth = 0i64;
    let mut i = 0;
    while i < deltas.len() {
        let t = deltas[i].0;
        while i < deltas.len() && deltas[i].0 == t {
            depth += deltas[i].1;
            i += 1;
        }
        sink.counter(track, "inflight", t, depth as f64);
    }
}

/// The serve engine's per-(device, unit) busy spans — reconstructed from
/// the deterministic `ServeOutcome`, never sampled live from workers.
pub fn record_serve_busy(busy: &[BusySpan], sink: &mut impl TraceSink) {
    if !sink.enabled() {
        return;
    }
    let mut tracks: BTreeMap<(DeviceId, BusyKind), TrackId> = BTreeMap::new();
    for span in busy {
        let track = *tracks
            .entry((span.device, span.kind))
            .or_insert_with(|| sink.track(&device_process(span.device), busy_label(span.kind)));
        sink.span(track, busy_label(span.kind), span.end - span.dur, span.end);
    }
}

/// Fold a finished report's aggregates into `reg`: session counters and
/// gauges, plus the wall-clock annex (replan/rebind wall seconds) under
/// the scrub-able `annex.` prefix.
pub fn session_metrics(report: &SessionReport, reg: &MetricsRegistry) {
    reg.counter("session.completions").add(report.completions as u64);
    reg.counter("session.switches").add(report.switches.len() as u64);
    reg.counter("session.qos_spans").add(report.qos_spans.len() as u64);
    reg.counter("session.intervals").add(report.intervals.len() as u64);
    reg.set_gauge("session.duration_s", report.duration);
    reg.set_gauge("session.energy_j", report.energy_j);
    reg.set_gauge("session.power_w", report.power_w);
    reg.set_gauge("session.throughput_hz", report.throughput);
    let replan_wall: f64 = report.switches.iter().map(|s| s.replan_wall_s).sum();
    let rebind_wall: f64 = report.switches.iter().map(|s| s.rebind_wall_s).sum();
    reg.set_gauge("annex.session.replan_wall_s", replan_wall);
    reg.set_gauge("annex.session.rebind_wall_s", rebind_wall);
    if let Some(s) = &report.served {
        reg.counter("serve.admitted_rounds").add(s.admitted_rounds as u64);
        reg.counter("serve.completed_rounds").add(s.completed_rounds as u64);
        reg.counter("serve.rebinds").add(s.rebinds as u64);
        reg.set_gauge("serve.workers", s.workers as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::{EventKind, FlightRecording, NullSink};
    use crate::plan::UnitKind;
    use crate::scheduler::TaskSpan;

    fn toy_trace() -> Trace {
        let span = |pipeline: usize, run: usize, start: f64, end: f64| TaskSpan {
            pipeline,
            seq: 0,
            run,
            device: DeviceId(0),
            unit: UnitKind::Cpu,
            kind: TaskKind::Sense { bytes: 1 },
            start,
            end,
        };
        Trace { spans: vec![span(0, 0, 0.0, 1.0), span(1, 0, 0.5, 2.0), span(0, 1, 1.0, 3.0)] }
    }

    #[test]
    fn inflight_counter_tracks_round_overlap() {
        let mut rec = FlightRecording::new();
        record_inflight(&toy_trace(), &mut rec);
        let depths: Vec<(f64, f64)> = rec
            .events
            .iter()
            .map(|e| match e.kind {
                EventKind::Counter { value } => (e.t, value),
                _ => panic!("unexpected event"),
            })
            .collect();
        // t=0: p0r0 starts; t=0.5: p1r0 starts; t=1: p0r0 ends AND p0r1
        // starts (net 0); t=2: p1r0 ends; t=3: p0r1 ends.
        assert_eq!(
            depths,
            vec![(0.0, 1.0), (0.5, 2.0), (1.0, 2.0), (2.0, 1.0), (3.0, 0.0)]
        );
    }

    #[test]
    fn serve_busy_spans_land_on_unit_lanes() {
        let mut rec = FlightRecording::new();
        let busy = [
            BusySpan { device: DeviceId(1), kind: BusyKind::Accel, dur: 0.5, end: 1.0 },
            BusySpan { device: DeviceId(0), kind: BusyKind::RadioTx, dur: 0.1, end: 0.2 },
        ];
        record_serve_busy(&busy, &mut rec);
        assert_eq!(rec.tracks.len(), 2);
        assert!(rec.tracks.iter().any(|t| t.process == "d1" && t.thread == "Accel"));
        assert!(rec.tracks.iter().any(|t| t.process == "d0" && t.thread == "Radio.tx"));
        assert_eq!(rec.events[0].kind, EventKind::Span { dur: 0.5 });
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let mut sink = NullSink;
        record_task_spans(&toy_trace(), &mut sink);
        record_inflight(&toy_trace(), &mut sink);
        // Nothing to assert on the sink itself (it holds no state); the
        // calls simply must not panic and must take the early-out path.
    }
}
