//! Holistic collaboration plans (§IV-C): one execution plan per concurrent
//! pipeline, plus the joint *runnable* check — the total weight memory,
//! bias memory and layer count of every chunk assigned to each accelerator
//! must stay within that accelerator's capacity. Checking this jointly
//! (rather than per pipeline) is exactly what IndModel lacks and what makes
//! it hit OOR in Workloads 1–2.

use std::collections::BTreeMap;

use crate::device::{AccelMemory, DeviceId, Fleet, OorError};
use crate::pipeline::PipelineSpec;

use super::exec_plan::ExecutionPlan;

/// Joint-OOR failure: which device ran out of which resource.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
#[error("OOR on {device}: {kind}")]
pub struct RunnableError {
    pub device: DeviceId,
    pub kind: OorError,
}

/// A holistic collaboration plan over all concurrent pipelines.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CollabPlan {
    /// One execution plan per pipeline, index-aligned with the pipeline
    /// list the orchestrator was given.
    pub plans: Vec<ExecutionPlan>,
}

impl CollabPlan {
    pub fn new(plans: Vec<ExecutionPlan>) -> CollabPlan {
        CollabPlan { plans }
    }

    /// Per-device memory usage of the whole plan.
    pub fn memory_usage(
        &self,
        pipelines: &[PipelineSpec],
    ) -> BTreeMap<DeviceId, AccelMemory> {
        let mut usage: BTreeMap<DeviceId, AccelMemory> = BTreeMap::new();
        for plan in &self.plans {
            let model = &pipelines
                .iter()
                .find(|p| p.id == plan.pipeline)
                .expect("plan for unknown pipeline")
                .model;
            for a in &plan.chunks {
                let m = usage.entry(a.device).or_default();
                m.weight_bytes += model.weight_bytes(a.range);
                m.bias_bytes += model.bias_bytes(a.range);
                m.layers += a.range.len();
            }
        }
        usage
    }

    /// §IV-C's runnable check over the joint memory usage.
    pub fn check_runnable(
        &self,
        pipelines: &[PipelineSpec],
        fleet: &Fleet,
    ) -> Result<(), RunnableError> {
        for (dev, used) in self.memory_usage(pipelines) {
            let spec = fleet
                .get(dev)
                .spec
                .accel
                .as_ref()
                .expect("chunk assigned to non-accelerator device");
            AccelMemory::default()
                .check(spec, used.weight_bytes, used.bias_bytes, used.layers)
                .map_err(|kind| RunnableError { device: dev, kind })?;
        }
        Ok(())
    }
}

/// Incremental joint-memory tracker for progressive plan accumulation
/// (§IV-D): holds the usage of already-selected execution plans so each
/// candidate for the next pipeline is checked in O(its own chunks).
#[derive(Clone, Debug, Default)]
pub struct MemoryLedger {
    usage: BTreeMap<DeviceId, AccelMemory>,
}

impl MemoryLedger {
    /// Would `plan` fit on top of the current ledger?
    ///
    /// Allocation-free (this runs once per enumerated candidate — the
    /// planner's hot loop): chunks are grouped per device by scanning the
    /// short chunk list instead of building a map.
    pub fn fits(&self, plan: &ExecutionPlan, model: &crate::model::ModelGraph, fleet: &Fleet) -> bool {
        for (i, a) in plan.chunks.iter().enumerate() {
            // Group at the first chunk per device (a plan may place two
            // non-adjacent chunks on the same device).
            if plan.chunks[..i].iter().any(|b| b.device == a.device) {
                continue;
            }
            let spec = match &fleet.get(a.device).spec.accel {
                Some(s) => s,
                None => return false,
            };
            let (mut w, mut b, mut l) = (0u64, 0u64, 0usize);
            for c in plan.chunks[i..].iter().filter(|c| c.device == a.device) {
                w += model.weight_bytes(c.range);
                b += model.bias_bytes(c.range);
                l += c.range.len();
            }
            let ok = self
                .usage
                .get(&a.device)
                .copied()
                .unwrap_or_default()
                .check(spec, w, b, l)
                .is_ok();
            if !ok {
                return false;
            }
        }
        true
    }

    /// Commit a selected plan's usage.
    pub fn commit(&mut self, plan: &ExecutionPlan, model: &crate::model::ModelGraph) {
        for a in &plan.chunks {
            let m = self.usage.entry(a.device).or_default();
            m.weight_bytes += model.weight_bytes(a.range);
            m.bias_bytes += model.bias_bytes(a.range);
            m.layers += a.range.len();
        }
    }

    pub fn usage(&self) -> &BTreeMap<DeviceId, AccelMemory> {
        &self.usage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::{ModelGraph, SplitRange};
    use crate::pipeline::{PipelineId, SourceReq, TargetReq};
    use crate::plan::exec_plan::Assignment;

    /// ~239 KB model: two fit on a MAX78002 but not on a MAX78000 (442 KB).
    fn chunky_model(name: &str) -> ModelGraph {
        ModelGraph::new(
            name,
            Shape::new(16, 16, 64),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 260, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 40, residual: false, has_bias: true },
            ],
        )
    }

    fn fleet2() -> Fleet {
        Fleet::new(vec![
            Device::new(0, "a", DeviceKind::Max78000, vec![], vec![]),
            Device::new(1, "b", DeviceKind::Max78000, vec![], vec![]),
        ])
    }

    fn mono(pid: usize, dev: usize, model: &ModelGraph) -> ExecutionPlan {
        ExecutionPlan {
            pipeline: PipelineId(pid),
            source_dev: DeviceId(dev),
            target_dev: DeviceId(dev),
            chunks: vec![Assignment {
                device: DeviceId(dev),
                range: model.full(),
            }],
        }
    }

    fn pipelines() -> Vec<PipelineSpec> {
        vec![
            PipelineSpec::new(0, "p0", SourceReq::Any, chunky_model("m0"), TargetReq::Any),
            PipelineSpec::new(1, "p1", SourceReq::Any, chunky_model("m1"), TargetReq::Any),
        ]
    }

    #[test]
    fn joint_check_catches_what_individual_checks_miss() {
        let ps = pipelines();
        let f = fleet2();
        // Each model alone fits d0; both together exceed 442 KB — the
        // IndModel failure mode (§III-A example, Fig. 5a).
        let both_on_d0 = CollabPlan::new(vec![
            mono(0, 0, &ps[0].model),
            mono(1, 0, &ps[1].model),
        ]);
        let err = both_on_d0.check_runnable(&ps, &f).unwrap_err();
        assert_eq!(err.device, DeviceId(0));
        assert_eq!(err.kind, OorError::WeightMem);

        let spread = CollabPlan::new(vec![
            mono(0, 0, &ps[0].model),
            mono(1, 1, &ps[1].model),
        ]);
        assert!(spread.check_runnable(&ps, &f).is_ok());
    }

    #[test]
    fn memory_usage_aggregates_per_device() {
        let ps = pipelines();
        let plan = CollabPlan::new(vec![
            mono(0, 0, &ps[0].model),
            mono(1, 0, &ps[1].model),
        ]);
        let usage = plan.memory_usage(&ps);
        let m0 = &ps[0].model;
        assert_eq!(
            usage[&DeviceId(0)].weight_bytes,
            2 * m0.weight_bytes(m0.full())
        );
        assert_eq!(usage[&DeviceId(0)].layers, 4);
    }

    #[test]
    fn ledger_fits_then_commits() {
        let ps = pipelines();
        let f = fleet2();
        let mut ledger = MemoryLedger::default();
        let p0 = mono(0, 0, &ps[0].model);
        assert!(ledger.fits(&p0, &ps[0].model, &f));
        ledger.commit(&p0, &ps[0].model);
        // Second identical-size model no longer fits on d0…
        let p1 = mono(1, 0, &ps[1].model);
        assert!(!ledger.fits(&p1, &ps[1].model, &f));
        // …but fits on d1.
        let p1b = mono(1, 1, &ps[1].model);
        assert!(ledger.fits(&p1b, &ps[1].model, &f));
    }

    #[test]
    fn ledger_groups_same_device_chunks() {
        // One plan with two chunks on the same device must count both
        // against that device (non-adjacent reuse).
        let m = chunky_model("m");
        let f = fleet2();
        let plan = ExecutionPlan {
            pipeline: PipelineId(0),
            source_dev: DeviceId(0),
            target_dev: DeviceId(0),
            chunks: vec![
                Assignment { device: DeviceId(0), range: SplitRange::new(0, 1) },
                Assignment { device: DeviceId(1), range: SplitRange::new(1, 2) },
            ],
        };
        let ledger = MemoryLedger::default();
        assert!(ledger.fits(&plan, &m, &f));
    }
}
