//! Execution plans: one pipeline's task→device mapping, expanded into the
//! concrete task sequence of §IV-C. The paper's example for
//! (camera on glasses, EfficientNet, haptic on ring) with a split at 19:
//!
//! glasses: [camera → load → EfficientNet^{0:19} → unload → Tx to ring]
//! ring:    [Rx from glasses → load → EfficientNet^{19:29} → unload → haptic]

use crate::device::DeviceId;
use crate::model::{ModelGraph, SplitRange};
use crate::pipeline::{PipelineId, PipelineSpec};

use super::task::{PlanTask, TaskKind};

/// One model chunk assigned to one accelerator-bearing device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub device: DeviceId,
    pub range: SplitRange,
}

/// A pipeline's execution plan: source/target device choice plus the
/// ordered chunk assignments (ranges partition `0..L`; consecutive chunks
/// live on distinct devices).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecutionPlan {
    pub pipeline: PipelineId,
    pub source_dev: DeviceId,
    pub target_dev: DeviceId,
    pub chunks: Vec<Assignment>,
}

impl ExecutionPlan {
    /// Validate the structural invariants (used by tests and debug builds).
    pub fn validate(&self, model: &ModelGraph) -> Result<(), String> {
        if self.chunks.is_empty() {
            return Err("no chunks".into());
        }
        let mut expect = 0;
        for (i, a) in self.chunks.iter().enumerate() {
            if a.range.start != expect {
                return Err(format!("chunk {i} starts at {} ≠ {expect}", a.range.start));
            }
            expect = a.range.end;
            if i > 0 && self.chunks[i - 1].device == a.device {
                return Err(format!("consecutive chunks {i} share a device"));
            }
        }
        if expect != model.num_layers() {
            return Err(format!(
                "chunks end at {expect} ≠ {} layers",
                model.num_layers()
            ));
        }
        Ok(())
    }

    /// Number of distinct devices that hold model chunks.
    pub fn num_infer_devices(&self) -> usize {
        let mut devs: Vec<DeviceId> = self.chunks.iter().map(|a| a.device).collect();
        devs.sort();
        devs.dedup();
        devs.len()
    }

    /// Total bytes this plan sends over the radio per run (sensing hop +
    /// inter-chunk hops + result hop). The quantity PriMinDev/PriMaxDev
    /// minimize and a proxy for communication energy.
    pub fn radio_bytes(&self, model: &ModelGraph) -> u64 {
        let mut total = 0;
        if self.source_dev != self.chunks[0].device {
            total += model.in_bytes();
        }
        for w in self.chunks.windows(2) {
            total += model.boundary_bytes(w[0].range.end - 1);
        }
        if self.chunks.last().unwrap().device != self.target_dev {
            total += model.output().bytes();
        }
        total
    }

    /// Expand into the concrete dependency-ordered task sequence.
    ///
    /// Dependencies are linear: task `i+1` consumes task `i`'s output. A Tx
    /// and its matching Rx are adjacent (`Tx` then `Rx`); the scheduler
    /// models the radio occupancy of both ends.
    pub fn tasks(&self, model: &ModelGraph) -> Vec<PlanTask> {
        let mut out = Vec::new();
        self.for_each_task(model, |t| out.push(t));
        out
    }

    /// Visit the task sequence without allocating — the estimator's hot
    /// path (candidate scoring runs this tens of thousands of times per
    /// orchestration; see EXPERIMENTS.md §Perf).
    pub fn for_each_task(&self, model: &ModelGraph, mut f: impl FnMut(PlanTask)) {
        let mut seq = 0;
        let mut push = |device: DeviceId, kind: TaskKind, f: &mut dyn FnMut(PlanTask)| {
            f(PlanTask {
                pipeline: self.pipeline,
                seq,
                device,
                kind,
            });
            seq += 1;
        };

        // (i) sensing on the source device.
        push(self.source_dev, TaskKind::Sense { bytes: model.in_bytes() }, &mut f);

        // Hop to the first chunk's device if needed.
        let first_dev = self.chunks[0].device;
        if self.source_dev != first_dev {
            push(
                self.source_dev,
                TaskKind::Tx { bytes: model.in_bytes(), to: first_dev },
                &mut f,
            );
            push(
                first_dev,
                TaskKind::Rx { bytes: model.in_bytes(), from: self.source_dev },
                &mut f,
            );
        }

        // Chunks: load → infer → unload, with radio hops between devices.
        for (i, a) in self.chunks.iter().enumerate() {
            let in_bytes = if a.range.start == 0 {
                model.in_bytes()
            } else {
                model.boundary_bytes(a.range.start - 1)
            };
            let out_bytes = model.boundary_bytes(a.range.end - 1);
            push(a.device, TaskKind::Load { bytes: in_bytes }, &mut f);
            push(a.device, TaskKind::Infer { range: a.range }, &mut f);
            push(a.device, TaskKind::Unload { bytes: out_bytes }, &mut f);
            if let Some(next) = self.chunks.get(i + 1) {
                push(
                    a.device,
                    TaskKind::Tx { bytes: out_bytes, to: next.device },
                    &mut f,
                );
                push(
                    next.device,
                    TaskKind::Rx { bytes: out_bytes, from: a.device },
                    &mut f,
                );
            }
        }

        // Hop to the target device if needed, then interact.
        let last = self.chunks.last().unwrap();
        let result_bytes = model.output().bytes();
        if last.device != self.target_dev {
            push(
                last.device,
                TaskKind::Tx { bytes: result_bytes, to: self.target_dev },
                &mut f,
            );
            push(
                self.target_dev,
                TaskKind::Rx { bytes: result_bytes, from: last.device },
                &mut f,
            );
        }
        push(self.target_dev, TaskKind::Interact { bytes: result_bytes }, &mut f);
    }

    /// Build the single-device plan (no splitting) — the IndModel/MinDev
    /// degenerate case and a convenient test fixture.
    pub fn monolithic(
        pipeline: &PipelineSpec,
        source: DeviceId,
        infer: DeviceId,
        target: DeviceId,
    ) -> ExecutionPlan {
        ExecutionPlan {
            pipeline: pipeline.id,
            source_dev: source,
            target_dev: target,
            chunks: vec![Assignment {
                device: infer,
                range: pipeline.model.full(),
            }],
        }
    }
}

impl std::fmt::Display for ExecutionPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {} →", self.pipeline, self.source_dev)?;
        for a in &self.chunks {
            write!(f, " [{} on {}]", a.range, a.device)?;
        }
        write!(f, " → {}", self.target_dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::plan::task::UnitKind;

    fn model3() -> ModelGraph {
        ModelGraph::new(
            "m3",
            Shape::new(8, 8, 2),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 4, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 2, cout: 8, residual: false, has_bias: true },
                Layer { kind: LayerKind::Linear, pool: 1, cout: 10, residual: false, has_bias: true },
            ],
        )
    }

    fn split_plan() -> ExecutionPlan {
        ExecutionPlan {
            pipeline: PipelineId(0),
            source_dev: DeviceId(0),
            target_dev: DeviceId(2),
            chunks: vec![
                Assignment { device: DeviceId(1), range: SplitRange::new(0, 2) },
                Assignment { device: DeviceId(2), range: SplitRange::new(2, 3) },
            ],
        }
    }

    #[test]
    fn validate_accepts_partition() {
        assert_eq!(split_plan().validate(&model3()), Ok(()));
    }

    #[test]
    fn validate_rejects_gap_and_shared_device() {
        let m = model3();
        let mut p = split_plan();
        p.chunks[1].range = SplitRange::new(1, 3);
        assert!(p.validate(&m).is_err());
        let mut q = split_plan();
        q.chunks[1].device = DeviceId(1);
        assert!(q.validate(&m).is_err());
    }

    #[test]
    fn task_expansion_structure() {
        let m = model3();
        let tasks = split_plan().tasks(&m);
        // sense, tx, rx, (load, infer, unload) ×2 with tx/rx between,
        // interact on target (already on d2, no final hop).
        let kinds: Vec<UnitKind> = tasks.iter().map(|t| t.unit()).collect();
        assert_eq!(tasks.len(), 1 + 2 + 3 + 2 + 3 + 1);
        assert_eq!(kinds[0], UnitKind::Sensor);
        assert!(matches!(tasks[1].kind, TaskKind::Tx { to, .. } if to == DeviceId(1)));
        assert!(matches!(tasks[2].kind, TaskKind::Rx { from, .. } if from == DeviceId(0)));
        // seq is strictly increasing 0..n.
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.seq, i);
        }
        // Last task is interaction on the target.
        let last = tasks.last().unwrap();
        assert!(matches!(last.kind, TaskKind::Interact { .. }));
        assert_eq!(last.device, DeviceId(2));
    }

    #[test]
    fn intermediate_bytes_are_boundary_sizes() {
        let m = model3();
        let tasks = split_plan().tasks(&m);
        // The inter-chunk Tx carries layer 1's output (4×4×8 = 128 B).
        let tx = tasks
            .iter()
            .find(|t| matches!(t.kind, TaskKind::Tx { to, .. } if to == DeviceId(2)))
            .unwrap();
        assert_eq!(tx.kind.bytes(), 128);
    }

    #[test]
    fn radio_bytes_counts_all_hops() {
        let m = model3();
        let p = split_plan();
        // source→chunk0 hop (input 128 B) + chunk boundary (128 B); result
        // stays on target device (no final hop).
        assert_eq!(p.radio_bytes(&m), m.in_bytes() + 128);
    }

    #[test]
    fn monolithic_same_device_has_no_radio() {
        let m = model3();
        let spec = PipelineSpec::new(
            0, "t",
            crate::pipeline::SourceReq::Device(DeviceId(0)),
            m.clone(),
            crate::pipeline::TargetReq::Device(DeviceId(0)),
        );
        let p = ExecutionPlan::monolithic(&spec, DeviceId(0), DeviceId(0), DeviceId(0));
        assert_eq!(p.radio_bytes(&m), 0);
        let tasks = p.tasks(&m);
        assert!(tasks.iter().all(|t| t.device == DeviceId(0)));
        assert!(!tasks.iter().any(|t| matches!(t.kind, TaskKind::Tx { .. })));
    }
}
