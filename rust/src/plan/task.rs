//! The seven runtime task types of §IV-C, plus the computation-unit
//! taxonomy used by the adaptive task parallelization scheduler (§IV-F):
//! each task runs on exactly one unit kind of one device, which is what
//! makes per-unit queues meaningful.

use crate::device::DeviceId;
use crate::model::SplitRange;
use crate::pipeline::PipelineId;

/// The seven task types: (i) sensing, (ii) data loading, (iii) (partial)
/// model inference, (iv) data unloading, (v) Tx, (vi) Rx, (vii) interaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Capture sensor data of `bytes` on the source device.
    Sense { bytes: u64 },
    /// Move an activation of `bytes` from SRAM into accelerator data memory.
    Load { bytes: u64 },
    /// Run layers `range` of the pipeline's model.
    Infer { range: SplitRange },
    /// Move the result of `bytes` out of accelerator data memory.
    Unload { bytes: u64 },
    /// Transmit `bytes` to device `to`.
    Tx { bytes: u64, to: DeviceId },
    /// Receive `bytes` from device `from`.
    Rx { bytes: u64, from: DeviceId },
    /// Deliver the final result (`bytes`) through the device's interface.
    Interact { bytes: u64 },
}

/// The computation units a device exposes (§IV-F: "processors, AI
/// accelerator, and communication module").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum UnitKind {
    /// Sensor frontend (operates concurrently with the core).
    Sensor,
    /// General-purpose core: memory ops, interaction glue, MCU inference.
    Cpu,
    /// CNN accelerator.
    Accel,
    /// Radio (ESP8266 bridge) — half-duplex: Tx and Rx share it.
    Radio,
}

impl TaskKind {
    /// Which computation unit executes this task.
    pub fn unit(&self) -> UnitKind {
        match self {
            TaskKind::Sense { .. } => UnitKind::Sensor,
            TaskKind::Load { .. } | TaskKind::Unload { .. } | TaskKind::Interact { .. } => {
                UnitKind::Cpu
            }
            TaskKind::Infer { .. } => UnitKind::Accel,
            TaskKind::Tx { .. } | TaskKind::Rx { .. } => UnitKind::Radio,
        }
    }

    /// Payload size the task moves/produces, for diagnostics.
    pub fn bytes(&self) -> u64 {
        match *self {
            TaskKind::Sense { bytes }
            | TaskKind::Load { bytes }
            | TaskKind::Unload { bytes }
            | TaskKind::Tx { bytes, .. }
            | TaskKind::Rx { bytes, .. }
            | TaskKind::Interact { bytes } => bytes,
            TaskKind::Infer { .. } => 0,
        }
    }
}

/// A task bound to a device within a pipeline's expanded plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanTask {
    pub pipeline: PipelineId,
    /// Position within the pipeline's task sequence (dependency order).
    pub seq: usize,
    pub device: DeviceId,
    pub kind: TaskKind,
}

impl PlanTask {
    pub fn unit(&self) -> UnitKind {
        self.kind.unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_unit_mapping() {
        assert_eq!(TaskKind::Sense { bytes: 1 }.unit(), UnitKind::Sensor);
        assert_eq!(TaskKind::Load { bytes: 1 }.unit(), UnitKind::Cpu);
        assert_eq!(
            TaskKind::Infer { range: SplitRange::new(0, 1) }.unit(),
            UnitKind::Accel
        );
        assert_eq!(TaskKind::Unload { bytes: 1 }.unit(), UnitKind::Cpu);
        assert_eq!(
            TaskKind::Tx { bytes: 1, to: DeviceId(0) }.unit(),
            UnitKind::Radio
        );
        assert_eq!(
            TaskKind::Rx { bytes: 1, from: DeviceId(0) }.unit(),
            UnitKind::Radio
        );
        assert_eq!(TaskKind::Interact { bytes: 1 }.unit(), UnitKind::Cpu);
    }

    #[test]
    fn bytes_accessor() {
        assert_eq!(TaskKind::Tx { bytes: 42, to: DeviceId(1) }.bytes(), 42);
        assert_eq!(TaskKind::Infer { range: SplitRange::new(0, 2) }.bytes(), 0);
    }
}
