//! §IV-C — execution plans and holistic collaboration plans.
//!
//! An *execution plan* maps one pipeline's logical tasks to physical devices
//! (including the model-splitting decision). A *holistic collaboration plan*
//! integrates one execution plan per concurrent pipeline, which gives the
//! system visibility over resource competition; it is *runnable* iff every
//! accelerator's weight memory, bias memory and layer-count capacities hold
//! all chunks assigned to it.

pub mod task;
pub mod exec_plan;
pub mod enumerate;
pub mod collab;
pub mod signature;

pub use collab::{CollabPlan, RunnableError};
pub use enumerate::{
    enumerate_plans, enumerate_plans_with, enumerate_skeletons, enumerate_skeletons_all,
    enumerate_skeletons_for, enumerate_splits_with, paper_plan_count, skeleton_space,
    EnumerateCfg, PlannerCfg, SearchMode, Skeleton, BOUNDED_EXACT_THRESHOLD, DEFAULT_BEAM_WIDTH,
};
pub use exec_plan::{Assignment, ExecutionPlan};
pub use signature::{digest_debug, rebind_pipelines, FnvWriter};
pub use task::{PlanTask, TaskKind, UnitKind};
