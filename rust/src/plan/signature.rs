//! Canonical plan signatures and plan rebinding — the plan-layer half of
//! the cross-user plan cache ([`crate::api::GlobalPlanCache`]).
//!
//! Two users whose planning problems are *shape-equal* — same planner
//! configuration, same per-device specs and capabilities, same models,
//! endpoint requirements, and QoS floors in the same registration order —
//! are handed the exact same bounded search, so the selected
//! [`CollabPlan`] can be computed once and shared. This module provides
//! the primitives the cache key and the cache hit are built from:
//!
//! - [`FnvWriter`] / [`digest_debug`] — a streaming FNV-1a 64-bit hash
//!   over a value's `Debug` rendering. Rust's `Debug` for `f64` prints
//!   the shortest round-trip decimal, so equal digests of the config
//!   structs mean bit-equal configurations — without materializing the
//!   (potentially kilobytes-long) `Debug` string of a model graph.
//! - [`rebind_pipelines`] — re-endpoint a cached plan onto another user's
//!   concrete [`PipelineId`]s. Plan selection is purely positional
//!   (priority orders index lists by model properties with index
//!   tie-breaks; device and endpoint references are dense ids), so the
//!   rebind is the *identity* on everything but the id labels: the
//!   rebound plan is bit-equal to what a fresh search would select for
//!   the signature-equal user (pinned by `tests/population.rs`).

use std::fmt::{self, Write};

use crate::pipeline::PipelineId;

use super::CollabPlan;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher behind [`std::fmt::Write`]: format a
/// value straight into the hash state instead of into a `String`.
pub struct FnvWriter {
    hash: u64,
}

impl FnvWriter {
    pub fn new() -> FnvWriter {
        FnvWriter { hash: FNV_OFFSET }
    }

    /// The hash of everything written so far.
    pub fn finish(&self) -> u64 {
        self.hash
    }
}

impl Default for FnvWriter {
    fn default() -> FnvWriter {
        FnvWriter::new()
    }
}

impl Write for FnvWriter {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        for &b in s.as_bytes() {
            self.hash ^= b as u64;
            self.hash = self.hash.wrapping_mul(FNV_PRIME);
        }
        Ok(())
    }
}

/// FNV-1a 64 of a value's `Debug` rendering, streamed (never allocated).
pub fn digest_debug(value: &impl fmt::Debug) -> u64 {
    let mut w = FnvWriter::new();
    // Writing into FnvWriter is infallible; `write!` only propagates the
    // sink's errors.
    let _ = write!(w, "{value:?}");
    w.finish()
}

/// Re-endpoint a cached plan onto a user's concrete pipeline ids,
/// positionally: `plans[i]` gets `ids[i]`. Everything else — device
/// assignments, split ranges, source/target endpoints — is shared
/// structure and carries over untouched (see the module docs for why
/// that is exact, not approximate).
///
/// # Panics
/// If `ids` does not have one id per execution plan — a signature
/// mismatch, which the cache key construction makes impossible.
pub fn rebind_pipelines(plan: &CollabPlan, ids: &[PipelineId]) -> CollabPlan {
    assert_eq!(
        plan.plans.len(),
        ids.len(),
        "rebind needs one pipeline id per execution plan"
    );
    let mut out = plan.clone();
    for (ep, &id) in out.plans.iter_mut().zip(ids) {
        ep.pipeline = id;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceId;
    use crate::model::SplitRange;
    use crate::plan::exec_plan::{Assignment, ExecutionPlan};

    #[test]
    fn fnv1a_matches_the_reference_vectors() {
        assert_eq!(digest_debug(&format_args!("")), 0xcbf2_9ce4_8422_2325);
        let mut w = FnvWriter::new();
        w.write_str("a").unwrap();
        assert_eq!(w.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut w = FnvWriter::new();
        w.write_str("foobar").unwrap();
        assert_eq!(w.finish(), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn digest_separates_values_and_streams_like_a_string() {
        // Streaming in two writes equals one concatenated write.
        let mut a = FnvWriter::new();
        a.write_str("foo").unwrap();
        a.write_str("bar").unwrap();
        let mut b = FnvWriter::new();
        b.write_str("foobar").unwrap();
        assert_eq!(a.finish(), b.finish());
        assert_ne!(digest_debug(&1.0f64), digest_debug(&1.5f64));
    }

    fn plan_for(ids: &[usize]) -> CollabPlan {
        CollabPlan::new(
            ids.iter()
                .map(|&i| ExecutionPlan {
                    pipeline: PipelineId(i),
                    source_dev: DeviceId(0),
                    target_dev: DeviceId(1),
                    chunks: vec![Assignment {
                        device: DeviceId(i % 2),
                        range: SplitRange::new(0, 1),
                    }],
                })
                .collect(),
        )
    }

    #[test]
    fn rebind_relabels_pipelines_and_nothing_else() {
        let cached = plan_for(&[0, 1]);
        let rebound = rebind_pipelines(&cached, &[PipelineId(7), PipelineId(9)]);
        assert_eq!(rebound.plans[0].pipeline, PipelineId(7));
        assert_eq!(rebound.plans[1].pipeline, PipelineId(9));
        // Identity rebind is bit-equal; the relabel touches only the id.
        assert_eq!(rebind_pipelines(&cached, &[PipelineId(0), PipelineId(1)]), cached);
        assert_eq!(rebound.plans[0].chunks, cached.plans[0].chunks);
        assert_eq!(rebound.plans[1].source_dev, cached.plans[1].source_dev);
    }

    #[test]
    #[should_panic(expected = "one pipeline id per execution plan")]
    fn rebind_rejects_mismatched_arity() {
        rebind_pipelines(&plan_for(&[0, 1]), &[PipelineId(0)]);
    }
}
