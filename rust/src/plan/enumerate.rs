//! Execution-plan enumeration (§IV-C/§IV-D).
//!
//! For one pipeline the space is
//!
//! `N_p = Σ_{d=1..D} P(D,d) · C(L-1, d-1) · |src| · |tgt|`
//!
//! — device *orders* (d-permutations of the accelerator fleet), times the
//! `d-1` split boundaries chosen among `L-1`, times the source/target
//! mappings (`D²` when requirements leave them free). Enumeration filters
//! per-chunk single-device fits eagerly (a chunk larger than its device's
//! whole accelerator can never be part of a runnable holistic plan).

use std::collections::BTreeMap;

use crate::device::{AccelMemory, DeviceId, Fleet};
use crate::estimator::{comm, LatencyModel};
use crate::model::{ModelGraph, SplitRange};
use crate::pipeline::{PipelineId, PipelineSpec};

use super::exec_plan::{Assignment, ExecutionPlan};
use super::task::{PlanTask, TaskKind};

/// Enumeration limits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnumerateCfg {
    /// Maximum number of chunks a model may be split into (defaults to the
    /// whole accelerator fleet, as MaxDev requires).
    pub max_split_devices: usize,
}

impl Default for EnumerateCfg {
    fn default() -> Self {
        EnumerateCfg {
            max_split_devices: usize::MAX,
        }
    }
}

/// Default beam width of [`SearchMode::Bounded`].
pub const DEFAULT_BEAM_WIDTH: usize = 8;

/// Bounded search falls back to complete enumeration whenever a pipeline's
/// skeleton space ([`skeleton_space`]) is at most this many skeletons —
/// paper-scale fleets (D ≤ 4, Table I models) all fall below it, so bounded
/// selections there keep exhaustive quality exactly; the beam only takes
/// over where exhaustive search stops being tractable.
pub const BOUNDED_EXACT_THRESHOLD: u64 = 100_000;

/// How the planner searches a pipeline's split-skeleton space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SearchMode {
    /// Enumerate the complete space — exact, but factorial in
    /// devices × layers (`skeleton_space`), so tractable on paper-scale
    /// fleets only.
    #[default]
    Exhaustive,
    /// Beam search over split skeletons plus branch-and-bound candidate
    /// pruning during selection: partial skeletons are ranked by an
    /// admissible cost (cheapest chunk placement + best-case radio hops +
    /// a suffix completion heuristic), `beam_width` states survive per
    /// depth, and selection stops scoring a pipeline's (bound-sorted)
    /// candidates once even an optimistic estimate cannot beat the current
    /// best. Falls back to complete enumeration below
    /// [`BOUNDED_EXACT_THRESHOLD`].
    Bounded {
        /// States kept per beam depth; also bounds the boundary sets kept
        /// per chunk count and the device-rotation diversity per set.
        beam_width: usize,
    },
}

/// Planner-level search configuration, threaded from
/// [`crate::orchestrator::ProgressivePlanner`] through the incremental
/// replan cache in [`crate::api`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct PlannerCfg {
    /// Structural enumeration limits (shared by both search modes).
    pub enumerate: EnumerateCfg,
    /// Exhaustive or bounded skeleton search.
    pub search: SearchMode,
}

impl PlannerCfg {
    /// Bounded search with the given beam width and default limits.
    pub fn bounded(beam_width: usize) -> PlannerCfg {
        PlannerCfg {
            enumerate: EnumerateCfg::default(),
            search: SearchMode::Bounded { beam_width },
        }
    }
}

/// A split skeleton plus its pruning metadata: the chunk→device assignment
/// (without the endpoint choice) and an endpoint-independent lower bound on
/// the chain latency its tasks add (load + infer + unload per chunk, plus
/// the Tx+Rx of every inter-chunk hop). Any full plan built from the
/// skeleton has a chain at least this long, which makes the bound valid for
/// optimistic-score pruning (see `Objective::score_upper_bound`). The
/// incremental replan cache stores these so replans reuse both the
/// enumeration and the pruning work.
///
/// Exhaustive-mode lists carry `chain_bound = 0.0` — a trivially admissible
/// bound that selection never reads (pruning is bounded-mode only), so the
/// default replan path skips the per-skeleton bound computation entirely.
#[derive(Clone, Debug)]
pub struct Skeleton {
    pub chunks: Vec<Assignment>,
    pub chain_bound: f64,
}

/// The single-device chunk-fit rule shared by every enumeration path
/// (exhaustive streaming, bounded beam, rotation assignment): a chunk may
/// only go to an accelerator-bearing device whose weight/bias/layer
/// capacities hold it alone. Joint cross-pipeline fit is the ledger's job.
fn chunk_fits_device(
    fleet: &Fleet,
    model: &ModelGraph,
    dev: DeviceId,
    start: usize,
    end: usize,
) -> bool {
    let spec = match &fleet.get(dev).spec.accel {
        Some(s) => s,
        None => return false,
    };
    let r = SplitRange::new(start, end);
    AccelMemory::default()
        .check(
            spec,
            model.weight_bytes(r),
            model.bias_bytes(r),
            end - start,
        )
        .is_ok()
}

/// Closed-form plan count from the paper (uses `D²` source/target options),
/// for the Fig. 9 search-space comparison: D=3 with the 9-layer KWS gives
/// 1 971, the 14-layer SimpleNet 4 941, the 19-layer UNet 9 261.
pub fn paper_plan_count(num_devices: usize, num_layers: usize) -> u64 {
    let d_max = num_devices.min(num_layers);
    let mut total: u64 = 0;
    for d in 1..=d_max {
        total += permutations(num_devices, d) * combinations(num_layers - 1, d - 1);
    }
    total * (num_devices * num_devices) as u64
}

fn permutations(n: usize, k: usize) -> u64 {
    ((n - k + 1)..=n).map(|x| x as u64).product()
}

fn combinations(n: usize, k: usize) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u64 = 1;
    let mut den: u64 = 1;
    for i in 0..k {
        num *= (n - i) as u64;
        den *= (i + 1) as u64;
    }
    num / den
}

/// Enumerate all execution plans for `pipeline` over `fleet`.
///
/// Convenience wrapper over [`enumerate_plans_with`] that materializes the
/// whole space; the planner's hot path uses the callback form to avoid
/// allocating tens of thousands of plans (see EXPERIMENTS.md §Perf).
pub fn enumerate_plans(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
) -> Vec<ExecutionPlan> {
    let mut plans = Vec::new();
    enumerate_plans_with(pipeline, fleet, cfg, |p| plans.push(p.clone()));
    plans
}

/// Visit every execution plan for `pipeline` over `fleet` without
/// materializing the space: the callback receives a reusable plan whose
/// chunk vector is rewritten in place between calls.
///
/// Chunks may only go to accelerator-bearing devices; each chunk must fit
/// its device's accelerator *alone* (cross-pipeline fit is the holistic
/// check in [`super::collab`]). Consecutive chunks are on distinct devices
/// by construction (a d-permutation has no repeats).
pub fn enumerate_plans_with(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
    mut visit: impl FnMut(&ExecutionPlan),
) {
    let sources = pipeline.source_candidates(fleet);
    let targets = pipeline.target_candidates(fleet);
    if sources.is_empty() || targets.is_empty() {
        return;
    }
    // Reusable plan buffer handed to the callback.
    let mut scratch = ExecutionPlan {
        pipeline: pipeline.id,
        source_dev: sources[0],
        target_dev: targets[0],
        chunks: Vec::new(),
    };
    enumerate_splits_with(pipeline, fleet, cfg, |chunks| {
        scratch.chunks.clear();
        scratch.chunks.extend_from_slice(chunks);
        for &s in &sources {
            for &t in &targets {
                scratch.source_dev = s;
                scratch.target_dev = t;
                visit(&scratch);
            }
        }
    });
}

/// Visit every *split skeleton* — the ordered chunk→device assignment
/// without the source/target endpoint choice — for `pipeline` over `fleet`.
///
/// This is the expensive, endpoint-independent part of plan enumeration
/// (device permutations × split boundaries, with eager per-chunk fit
/// filtering). The incremental re-orchestration cache in [`crate::api`]
/// materializes these skeletons per app and reuses them across fleet and
/// app-set changes; [`enumerate_plans_with`] composes them with the
/// endpoint cross product to recover the full plan space.
pub fn enumerate_splits_with(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
    mut visit: impl FnMut(&[Assignment]),
) {
    let accel_devs = fleet.accel_ids();
    let model = &pipeline.model;
    let num_layers = model.num_layers();
    let d_max = accel_devs
        .len()
        .min(num_layers)
        .min(cfg.max_split_devices);

    // Per-chunk fit is O(1) via the model's prefix sums; the rule is the
    // one shared with the bounded search (`chunk_fits_device`).
    let chunk_fits =
        |dev: DeviceId, start: usize, end: usize| chunk_fits_device(fleet, model, dev, start, end);

    // Reusable chunk buffer handed to the callback.
    let mut chunks: Vec<Assignment> = Vec::with_capacity(d_max);
    // Iterate d = number of chunk devices.
    for d in 1..=d_max {
        let mut perm: Vec<DeviceId> = Vec::with_capacity(d);
        let mut used = vec![false; accel_devs.len()];
        permute(
            &accel_devs,
            d,
            &mut perm,
            &mut used,
            &mut |order: &[DeviceId]| {
                // Choose d-1 boundaries among 1..num_layers.
                let mut bounds: Vec<usize> = Vec::with_capacity(d - 1);
                choose_boundaries(num_layers, d - 1, 1, &mut bounds, &mut |bs: &[usize]| {
                    // Build chunk ranges, checking per-chunk fit as we go.
                    chunks.clear();
                    let mut prev = 0;
                    for (i, &dev) in order.iter().enumerate() {
                        let end = if i + 1 == d { num_layers } else { bs[i] };
                        if !chunk_fits(dev, prev, end) {
                            return;
                        }
                        chunks.push(Assignment {
                            device: dev,
                            range: crate::model::SplitRange::new(prev, end),
                        });
                        prev = end;
                    }
                    visit(&chunks);
                });
            },
        );
    }
}

/// Closed-form size of the split-skeleton space (the endpoint-independent
/// part of [`paper_plan_count`]): `Σ_{d=1..D} P(D,d) · C(L-1, d-1)`,
/// saturating at `u64::MAX` — at 8–16 devices the true count overflows
/// quickly, which is exactly the scaling problem bounded search solves.
pub fn skeleton_space(
    num_accel_devices: usize,
    num_layers: usize,
    max_split_devices: usize,
) -> u64 {
    let d_max = num_accel_devices.min(num_layers).min(max_split_devices);
    let mut total: u128 = 0;
    for d in 1..=d_max {
        let perm: u128 = ((num_accel_devices - d + 1)..=num_accel_devices)
            .map(|x| x as u128)
            .product();
        let comb = combinations_u128(num_layers - 1, d - 1);
        total = total.saturating_add(perm.saturating_mul(comb));
        if total >= u64::MAX as u128 {
            return u64::MAX;
        }
    }
    total as u64
}

fn combinations_u128(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut out: u128 = 1;
    for i in 0..k {
        out = out.saturating_mul((n - i) as u128) / (i + 1) as u128;
    }
    out
}

/// Endpoint-independent chunk-cost model shared by skeleton bounds and the
/// bounded beam.
///
/// Every latency comes from the same [`LatencyModel`] the selection scorer
/// (`EstimateAccum::peek_fast`) uses — chunk tasks are costed by literally
/// calling `task_latency` on the Load/Infer/Unload/Tx/Rx tasks a plan built
/// from the skeleton would contain. That makes `chain_bound ≤ chain` hold
/// by construction (a full plan only ever *adds* sense/endpoint tasks), so
/// the branch-and-bound prune cannot drift out of sync with the estimator,
/// and the estimator's per-platform inference memo is reused as-is.
struct ChunkCost<'a> {
    fleet: &'a Fleet,
    model: &'a ModelGraph,
    lm: LatencyModel<'a>,
    /// Accelerator-bearing device ids.
    devs: Vec<DeviceId>,
    /// One representative device per distinct platform spec.
    slots: Vec<DeviceId>,
    /// `h(k)` = Σ_{l ≥ k} of layer `l`'s cheapest inference latency across
    /// slots: an admissible completion heuristic for partial skeletons.
    suffix_min_infer: Vec<f64>,
    /// Component-wise lower bound over every accel device pair: the
    /// cheapest pair overhead and the fastest pair bandwidth (the two
    /// need not come from the same pair — bounding them independently
    /// keeps the hop estimate a true lower bound on any actual
    /// `link_time`, including on heterogeneous-radio fleets). `None` when
    /// the fleet has fewer than two accel devices.
    link_lb: Option<(f64, f64)>,
}

impl<'a> ChunkCost<'a> {
    fn new(model: &'a ModelGraph, fleet: &'a Fleet) -> ChunkCost<'a> {
        let lm = LatencyModel::new(fleet);
        let devs = fleet.accel_ids();
        let mut slots: Vec<DeviceId> = Vec::new();
        for &d in &devs {
            let spec = &fleet.get(d).spec;
            if !slots.iter().any(|&s| fleet.get(s).spec == *spec) {
                slots.push(d);
            }
        }
        let infer = |dev: DeviceId, r: SplitRange| {
            lm.task_latency(&infer_task(dev, r), model, None)
        };
        let l = model.num_layers();
        let mut suffix_min_infer = vec![0.0; l + 1];
        for layer in (0..l).rev() {
            let r = SplitRange::new(layer, layer + 1);
            let best = slots
                .iter()
                .map(|&s| infer(s, r))
                .fold(f64::INFINITY, f64::min);
            suffix_min_infer[layer] =
                suffix_min_infer[layer + 1] + if best.is_finite() { best } else { 0.0 };
        }
        let mut link_lb = None;
        for (i, &a) in devs.iter().enumerate() {
            for &b in devs.iter().skip(i + 1) {
                let (ra, rb) = (&fleet.get(a).spec.radio, &fleet.get(b).spec.radio);
                let overhead = ra.overhead_s.max(rb.overhead_s);
                let bandwidth = ra.bytes_per_s.min(rb.bytes_per_s);
                link_lb = Some(match link_lb {
                    None => (overhead, bandwidth),
                    Some((o, bw)) => (overhead.min(o), bandwidth.max(bw)),
                });
            }
        }
        ChunkCost {
            fleet,
            model,
            lm,
            devs,
            slots,
            suffix_min_infer,
            link_lb,
        }
    }

    /// Activation bytes entering a chunk that starts at layer `start`.
    fn in_bytes(&self, start: usize) -> u64 {
        if start == 0 {
            self.model.in_bytes()
        } else {
            self.model.boundary_bytes(start - 1)
        }
    }

    fn chunk_fits(&self, dev: DeviceId, start: usize, end: usize) -> bool {
        chunk_fits_device(self.fleet, self.model, dev, start, end)
    }

    /// Load + infer + unload latency of `start..end` on `dev` — the exact
    /// per-task values `peek_fast` will compute for this chunk.
    fn chunk_cost(&self, dev: DeviceId, start: usize, end: usize) -> f64 {
        let task = |kind: TaskKind| PlanTask {
            pipeline: PipelineId(0),
            seq: 0,
            device: dev,
            kind,
        };
        self.lm.task_latency(
            &task(TaskKind::Load { bytes: self.in_bytes(start) }),
            self.model,
            None,
        ) + self.lm.task_latency(
            &task(TaskKind::Infer { range: SplitRange::new(start, end) }),
            self.model,
            None,
        ) + self.lm.task_latency(
            &task(TaskKind::Unload { bytes: self.model.boundary_bytes(end - 1) }),
            self.model,
            None,
        )
    }

    /// Cheapest chunk cost across platforms that fit `start..end`, if any —
    /// `None` reproduces the exhaustive path's eager fit filtering.
    fn min_chunk_cost(&self, start: usize, end: usize) -> Option<f64> {
        let mut best: Option<f64> = None;
        for &rep in &self.slots {
            if !self.chunk_fits(rep, start, end) {
                continue;
            }
            let c = self.chunk_cost(rep, start, end);
            best = Some(best.map_or(c, |b: f64| b.min(c)));
        }
        best
    }

    /// Best-case Tx+Rx chain contribution of one inter-chunk radio hop
    /// (a lower bound on `2 × link_time` for every device pair).
    fn min_link2(&self, bytes: u64) -> f64 {
        match self.link_lb {
            Some((overhead, bandwidth)) => 2.0 * (overhead + bytes as f64 / bandwidth),
            None => 0.0,
        }
    }

    /// Exact chain bound of a fully assigned skeleton (its chunk tasks plus
    /// the actual inter-chunk hops; endpoint tasks only ever add to this).
    fn skeleton_bound(&self, chunks: &[Assignment]) -> f64 {
        let mut total = 0.0;
        for (i, a) in chunks.iter().enumerate() {
            total += self.chunk_cost(a.device, a.range.start, a.range.end);
            if i > 0 {
                let bytes = self.in_bytes(a.range.start);
                total += 2.0
                    * comm::tx_latency(
                        self.fleet.get(chunks[i - 1].device),
                        self.fleet.get(a.device),
                        bytes,
                    );
            }
        }
        total
    }
}

fn infer_task(dev: DeviceId, r: SplitRange) -> PlanTask {
    PlanTask {
        pipeline: PipelineId(0),
        seq: 0,
        device: dev,
        kind: TaskKind::Infer { range: r },
    }
}

/// Beam search over split skeletons — the [`SearchMode::Bounded`] engine.
///
/// Stage 1 beams over split *boundaries* (device-agnostic): a partial state
/// covering layers `0..k` with its chunks costed at their cheapest feasible
/// platform is ranked by `g + h(k)` where `h` is the admissible
/// remaining-inference heuristic; `beam` states survive per depth and the
/// `beam` best completed boundary sets are kept per chunk count (diversity
/// across split arities matters more than depth within one).
///
/// Stage 2 assigns devices per boundary set: devices ranked fastest-first,
/// first-fit with `min(beam, D)` strided rotation offsets so the candidate
/// list covers diverse device subsets — selection then scores candidates
/// in context (joint memory + accumulated load) and picks placements that
/// avoid busy devices.
fn bounded_skeletons(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
    beam_width: usize,
) -> Vec<Skeleton> {
    let beam = beam_width.max(1);
    let model = &pipeline.model;
    let num_layers = model.num_layers();
    let costs = ChunkCost::new(model, fleet);
    let d_max = costs.devs.len().min(num_layers).min(cfg.max_split_devices);
    if d_max == 0 {
        return Vec::new();
    }

    #[derive(Clone)]
    struct BState {
        /// Chunk end boundaries chosen so far (last one = layers covered).
        ends: Vec<usize>,
        /// Admissible cost of the chunks so far.
        g: f64,
    }
    let mut frontier: Vec<BState> = vec![BState { ends: Vec::new(), g: 0.0 }];
    let mut complete: Vec<Vec<BState>> = vec![Vec::new(); d_max + 1];
    for depth in 0..d_max {
        let mut next: Vec<BState> = Vec::new();
        for state in &frontier {
            let start = state.ends.last().copied().unwrap_or(0);
            let hop = if start == 0 {
                0.0
            } else {
                costs.min_link2(costs.in_bytes(start))
            };
            for end in (start + 1)..=num_layers {
                // Intermediate chunks only exist while depth remains.
                if end != num_layers && depth + 1 >= d_max {
                    continue;
                }
                let Some(c) = costs.min_chunk_cost(start, end) else {
                    continue;
                };
                let mut ends = state.ends.clone();
                ends.push(end);
                let st = BState { ends, g: state.g + hop + c };
                if end == num_layers {
                    complete[depth + 1].push(st);
                } else {
                    next.push(st);
                }
            }
        }
        next.sort_by(|a, b| {
            let fa = a.g + costs.suffix_min_infer[*a.ends.last().unwrap()];
            let fb = b.g + costs.suffix_min_infer[*b.ends.last().unwrap()];
            fa.total_cmp(&fb).then_with(|| a.ends.cmp(&b.ends))
        });
        next.truncate(beam);
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }

    // Stage 2: device assignment with strided rotations.
    let mut ranked: Vec<DeviceId> = costs.devs.clone();
    let speed = |d: DeviceId| {
        fleet
            .get(d)
            .spec
            .accel
            .as_ref()
            .map(|a| a.clock_hz * a.parallel_procs as f64)
            .unwrap_or(0.0)
    };
    ranked.sort_by(|&a, &b| speed(b).total_cmp(&speed(a)).then(a.0.cmp(&b.0)));
    let rotations = ranked.len().min(beam);
    let mut skeletons: Vec<Skeleton> = Vec::new();
    for per_d in &mut complete {
        per_d.sort_by(|a, b| a.g.total_cmp(&b.g).then_with(|| a.ends.cmp(&b.ends)));
        per_d.truncate(beam);
        for st in per_d.iter() {
            let mut seen: Vec<Vec<DeviceId>> = Vec::new();
            for j in 0..rotations {
                let offset = j * ranked.len() / rotations;
                let order: Vec<DeviceId> = ranked[offset..]
                    .iter()
                    .chain(ranked[..offset].iter())
                    .copied()
                    .collect();
                let mut chunks: Vec<Assignment> = Vec::with_capacity(st.ends.len());
                let mut used = vec![false; fleet.len()];
                let mut prev = 0;
                let mut ok = true;
                for &end in &st.ends {
                    match order
                        .iter()
                        .find(|&&d| !used[d.0] && costs.chunk_fits(d, prev, end))
                    {
                        Some(&d) => {
                            used[d.0] = true;
                            chunks.push(Assignment {
                                device: d,
                                range: SplitRange::new(prev, end),
                            });
                            prev = end;
                        }
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                let picked: Vec<DeviceId> = chunks.iter().map(|a| a.device).collect();
                if seen.contains(&picked) {
                    continue;
                }
                seen.push(picked);
                let chain_bound = costs.skeleton_bound(&chunks);
                skeletons.push(Skeleton { chunks, chain_bound });
            }
        }
    }
    sort_skeletons_by_bound(&mut skeletons);
    skeletons
}

/// Ascending chain-bound order with a deterministic, allocation-free
/// structural tie-break (these lists reach 100k entries in bounded-exact
/// mode, so the comparator must not allocate).
fn sort_skeletons_by_bound(skeletons: &mut [Skeleton]) {
    let key = |s: &Skeleton| {
        s.chunks
            .iter()
            .map(|a| (a.device.0, a.range.start, a.range.end))
    };
    skeletons.sort_by(|a, b| {
        a.chain_bound
            .total_cmp(&b.chain_bound)
            .then_with(|| key(a).cmp(key(b)))
    });
}

/// Enumerate one pipeline's skeleton candidates under `cfg`.
///
/// Exhaustive mode materializes [`enumerate_splits_with`]'s space in
/// enumeration order (the incremental cache's suffix-shrink filtering and
/// the cached-vs-streaming parity rely on that order). Bounded mode
/// returns a candidate list sorted by ascending [`Skeleton::chain_bound`]
/// — complete below [`BOUNDED_EXACT_THRESHOLD`], beam-pruned above it.
pub fn enumerate_skeletons(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: PlannerCfg,
) -> Vec<Skeleton> {
    // Bounds are only consulted by bounded-mode pruning; the exhaustive
    // path skips computing them (chain_bound = 0.0 is still a valid lower
    // bound) so the default replan cache fill stays as cheap as before.
    let exhaustive = |with_bounds: bool| {
        let costs = with_bounds.then(|| ChunkCost::new(&pipeline.model, fleet));
        let mut out = Vec::new();
        enumerate_splits_with(pipeline, fleet, cfg.enumerate, |chunks| {
            let chain_bound = costs.as_ref().map_or(0.0, |c| c.skeleton_bound(chunks));
            out.push(Skeleton {
                chunks: chunks.to_vec(),
                chain_bound,
            });
        });
        if with_bounds {
            sort_skeletons_by_bound(&mut out);
        }
        out
    };
    match cfg.search {
        SearchMode::Exhaustive => exhaustive(false),
        SearchMode::Bounded { beam_width } => {
            let space = skeleton_space(
                fleet.accel_ids().len(),
                pipeline.model.num_layers(),
                cfg.enumerate.max_split_devices,
            );
            if space <= BOUNDED_EXACT_THRESHOLD {
                exhaustive(true)
            } else {
                bounded_skeletons(pipeline, fleet, cfg.enumerate, beam_width)
            }
        }
    }
}

/// Enumerate skeletons for many pipelines in parallel — one thread per
/// pipeline. Enumeration dominates orchestration cost at fleet scale and
/// pipelines are independent, so this scales the replan path across cores
/// with no behavioral change (results are keyed, order-independent).
pub fn enumerate_skeletons_for(
    specs: &[&PipelineSpec],
    fleet: &Fleet,
    cfg: PlannerCfg,
) -> Vec<(PipelineId, Vec<Skeleton>)> {
    if specs.len() <= 1 {
        return specs
            .iter()
            .map(|s| (s.id, enumerate_skeletons(s, fleet, cfg)))
            .collect();
    }
    // Concurrency is capped at the core count: each enumeration can
    // materialize up to BOUNDED_EXACT_THRESHOLD skeletons, so an
    // unbounded spawn over a large app set would oversubscribe cores and
    // spike memory in lockstep.
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let mut out = Vec::with_capacity(specs.len());
    for batch in specs.chunks(max_threads) {
        out.extend(std::thread::scope(|scope| {
            let handles: Vec<_> = batch
                .iter()
                .map(|&spec| scope.spawn(move || (spec.id, enumerate_skeletons(spec, fleet, cfg))))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("skeleton enumeration thread panicked"))
                .collect::<Vec<_>>()
        }));
    }
    out
}

/// Map form of [`enumerate_skeletons_for`] over a pipeline slice (the
/// progressive planner's bounded-search entry).
pub fn enumerate_skeletons_all(
    specs: &[PipelineSpec],
    fleet: &Fleet,
    cfg: PlannerCfg,
) -> BTreeMap<PipelineId, Vec<Skeleton>> {
    let refs: Vec<&PipelineSpec> = specs.iter().collect();
    enumerate_skeletons_for(&refs, fleet, cfg).into_iter().collect()
}

/// Recursively build d-permutations of `devs`.
fn permute(
    devs: &[DeviceId],
    d: usize,
    cur: &mut Vec<DeviceId>,
    used: &mut [bool],
    f: &mut impl FnMut(&[DeviceId]),
) {
    if cur.len() == d {
        f(cur);
        return;
    }
    for i in 0..devs.len() {
        if used[i] {
            continue;
        }
        used[i] = true;
        cur.push(devs[i]);
        permute(devs, d, cur, used, f);
        cur.pop();
        used[i] = false;
    }
}

/// Recursively choose `k` ascending boundaries in `[from, num_layers)`.
fn choose_boundaries(
    num_layers: usize,
    k: usize,
    from: usize,
    cur: &mut Vec<usize>,
    f: &mut impl FnMut(&[usize]),
) {
    if cur.len() == k {
        f(cur);
        return;
    }
    let remaining = k - cur.len();
    for b in from..=(num_layers - remaining) {
        cur.push(b);
        choose_boundaries(num_layers, k, b + 1, cur, f);
        cur.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};

    fn small_model(layers: usize) -> ModelGraph {
        ModelGraph::new(
            format!("m{layers}"),
            Shape::new(8, 8, 2),
            (0..layers)
                .map(|_| Layer {
                    kind: LayerKind::Conv2d { k: 3 },
                    pool: 1,
                    cout: 4,
                    residual: false, has_bias: true,
                })
                .collect(),
        )
    }

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn any_pipeline(layers: usize) -> PipelineSpec {
        PipelineSpec::new(0, "t", SourceReq::Any, small_model(layers), TargetReq::Any)
    }

    #[test]
    fn paper_counts_reproduce_section_iv_d() {
        // §IV-D: three MAX78000s with the 9/14/19-layer models.
        assert_eq!(paper_plan_count(3, 9), 1_971);
        assert_eq!(paper_plan_count(3, 14), 4_941);
        assert_eq!(paper_plan_count(3, 19), 9_261);
    }

    #[test]
    fn enumeration_matches_closed_form_when_nothing_filtered() {
        // Tiny chunks always fit MAX78000 memory, so the enumerated count
        // must equal the paper's formula exactly.
        for (d, l) in [(2, 4), (3, 5), (2, 9)] {
            let p = any_pipeline(l);
            let plans = enumerate_plans(&p, &fleet(d), EnumerateCfg::default());
            assert_eq!(
                plans.len() as u64,
                paper_plan_count(d, l),
                "D={d} L={l}"
            );
        }
    }

    #[test]
    fn all_enumerated_plans_are_valid() {
        let p = any_pipeline(5);
        let f = fleet(3);
        for plan in enumerate_plans(&p, &f, EnumerateCfg::default()) {
            plan.validate(&p.model).unwrap();
        }
    }

    #[test]
    fn designated_source_target_reduces_space() {
        let mut p = any_pipeline(5);
        p.source = SourceReq::Device(DeviceId(0));
        p.target = TargetReq::Device(DeviceId(1));
        let f = fleet(3);
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert_eq!(plans.len() as u64, paper_plan_count(3, 5) / 9);
        assert!(plans
            .iter()
            .all(|pl| pl.source_dev == DeviceId(0) && pl.target_dev == DeviceId(1)));
    }

    #[test]
    fn max_split_devices_caps_chunks() {
        let p = any_pipeline(6);
        let f = fleet(3);
        let plans = enumerate_plans(
            &p,
            &f,
            EnumerateCfg { max_split_devices: 1 },
        );
        assert!(plans.iter().all(|pl| pl.chunks.len() == 1));
        // D · 1 · D² plans.
        assert_eq!(plans.len(), 3 * 9);
    }

    #[test]
    fn oversized_chunks_are_filtered() {
        // A model that cannot fit on one MAX78000 forces splitting: single
        // 500 KB conv layer per chunk won't fit, so only multi-chunk plans
        // survive... construct a 2-layer model with each layer ~300 KB.
        let m = ModelGraph::new(
            "big",
            Shape::new(16, 16, 64),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 520, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 64, residual: false, has_bias: true },
            ],
        );
        // layer0: 9·64·520 = 299 520 B; layer1: 9·520·64 = 299 520 B.
        // Together 599 040 B > 442 KB, individually fine.
        let p = PipelineSpec::new(0, "big", SourceReq::Any, m, TargetReq::Any);
        let f = fleet(2);
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert!(!plans.is_empty());
        assert!(plans.iter().all(|pl| pl.chunks.len() == 2), "must all split");
    }

    #[test]
    fn skeletons_times_endpoints_equals_plans() {
        // The skeleton space composed with the D² endpoint cross product
        // must reproduce the full enumeration exactly (order included).
        let p = any_pipeline(5);
        let f = fleet(3);
        let mut skeletons: Vec<Vec<Assignment>> = Vec::new();
        enumerate_splits_with(&p, &f, EnumerateCfg::default(), |c| skeletons.push(c.to_vec()));
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert_eq!(plans.len(), skeletons.len() * 9);
        for (i, plan) in plans.iter().enumerate() {
            assert_eq!(plan.chunks, skeletons[i / 9], "plan {i}");
        }
    }

    #[test]
    fn no_accel_devices_means_no_plans() {
        let f = Fleet::new(vec![Device::new(
            0,
            "mcu",
            DeviceKind::McuMax32650,
            vec![],
            vec![],
        )]);
        let p = any_pipeline(3);
        assert!(enumerate_plans(&p, &f, EnumerateCfg::default()).is_empty());
        assert!(enumerate_skeletons(&p, &f, PlannerCfg::bounded(4)).is_empty());
    }

    #[test]
    fn skeleton_space_matches_enumeration_when_nothing_filtered() {
        for (d, l) in [(2, 4), (3, 5)] {
            let p = any_pipeline(l);
            let mut n = 0u64;
            enumerate_splits_with(&p, &fleet(d), EnumerateCfg::default(), |_| n += 1);
            assert_eq!(n, skeleton_space(d, l, usize::MAX), "D={d} L={l}");
        }
    }

    #[test]
    fn skeleton_space_saturates_at_fleet_scale() {
        // 16 devices × a 28-layer model overflows u64 — the bounded mode's
        // raison d'être.
        assert_eq!(skeleton_space(16, 28, usize::MAX), u64::MAX);
        // 8 devices × 9 layers is finite but already in the millions.
        let s = skeleton_space(8, 9, usize::MAX);
        assert!(s > 1_000_000 && s < u64::MAX, "{s}");
        // Capping the split arity shrinks the space.
        assert!(skeleton_space(8, 9, 2) < s);
    }

    #[test]
    fn exhaustive_skeletons_preserve_enumeration_order() {
        let p = any_pipeline(5);
        let f = fleet(3);
        let skels = enumerate_skeletons(&p, &f, PlannerCfg::default());
        let mut raw: Vec<Vec<Assignment>> = Vec::new();
        enumerate_splits_with(&p, &f, EnumerateCfg::default(), |c| raw.push(c.to_vec()));
        assert_eq!(skels.len(), raw.len());
        for (s, r) in skels.iter().zip(&raw) {
            assert_eq!(&s.chunks, r, "order must match the streaming enumeration");
            // Exhaustive entries skip the bound (selection never reads it).
            assert_eq!(s.chain_bound, 0.0);
        }
    }

    #[test]
    fn bounded_below_threshold_is_complete_and_sorted() {
        let p = any_pipeline(5);
        let f = fleet(3);
        assert!(skeleton_space(3, 5, usize::MAX) <= BOUNDED_EXACT_THRESHOLD);
        let b = enumerate_skeletons(&p, &f, PlannerCfg::bounded(4));
        let e = enumerate_skeletons(&p, &f, PlannerCfg::default());
        assert_eq!(b.len(), e.len(), "below threshold bounded must be complete");
        assert!(b.windows(2).all(|w| w[0].chain_bound <= w[1].chain_bound));
        assert!(
            b.iter().all(|s| s.chain_bound > 0.0 && s.chain_bound.is_finite()),
            "bounded-mode entries carry real bounds"
        );
    }

    #[test]
    fn beam_prunes_large_spaces_but_keeps_valid_diverse_candidates() {
        // 8 devices × a 9-layer model is past the exact threshold.
        let p = any_pipeline(9);
        let f = fleet(8);
        let space = skeleton_space(8, 9, usize::MAX);
        assert!(space > BOUNDED_EXACT_THRESHOLD);
        let skels = enumerate_skeletons(&p, &f, PlannerCfg::bounded(DEFAULT_BEAM_WIDTH));
        assert!(!skels.is_empty());
        assert!(
            (skels.len() as u64) < space / 1000,
            "beam must prune: {} of {space}",
            skels.len()
        );
        for s in &skels {
            let mut prev = 0;
            for (i, a) in s.chunks.iter().enumerate() {
                assert_eq!(a.range.start, prev, "chunks must partition 0..L");
                prev = a.range.end;
                if i > 0 {
                    assert_ne!(s.chunks[i - 1].device, a.device);
                }
            }
            assert_eq!(prev, 9);
            assert!(s.chain_bound.is_finite());
        }
        // Rotation diversity: single-chunk candidates cover every device,
        // so context-aware selection can route around busy accelerators.
        let monos: std::collections::BTreeSet<DeviceId> = skels
            .iter()
            .filter(|s| s.chunks.len() == 1)
            .map(|s| s.chunks[0].device)
            .collect();
        assert_eq!(monos.len(), 8, "monolithic candidates must cover the fleet");
        assert!(skels.windows(2).all(|w| w[0].chain_bound <= w[1].chain_bound));
    }

    #[test]
    fn parallel_enumeration_matches_sequential() {
        let f = fleet(3);
        let ps: Vec<PipelineSpec> = (0..3)
            .map(|i| {
                PipelineSpec::new(
                    i,
                    format!("p{i}"),
                    SourceReq::Any,
                    small_model(4 + i),
                    TargetReq::Any,
                )
            })
            .collect();
        for cfg in [PlannerCfg::default(), PlannerCfg::bounded(4)] {
            let all = enumerate_skeletons_all(&ps, &f, cfg);
            assert_eq!(all.len(), 3);
            for p in &ps {
                let solo = enumerate_skeletons(p, &f, cfg);
                let par = &all[&p.id];
                assert_eq!(par.len(), solo.len(), "{cfg:?}");
                for (a, b) in par.iter().zip(&solo) {
                    assert_eq!(a.chunks, b.chunks);
                    assert_eq!(a.chain_bound.to_bits(), b.chain_bound.to_bits());
                }
            }
        }
    }
}
