//! Execution-plan enumeration (§IV-C/§IV-D).
//!
//! For one pipeline the space is
//!
//! `N_p = Σ_{d=1..D} P(D,d) · C(L-1, d-1) · |src| · |tgt|`
//!
//! — device *orders* (d-permutations of the accelerator fleet), times the
//! `d-1` split boundaries chosen among `L-1`, times the source/target
//! mappings (`D²` when requirements leave them free). Enumeration filters
//! per-chunk single-device fits eagerly (a chunk larger than its device's
//! whole accelerator can never be part of a runnable holistic plan).

use crate::device::{AccelMemory, DeviceId, Fleet};
use crate::pipeline::PipelineSpec;

use super::exec_plan::{Assignment, ExecutionPlan};

/// Enumeration limits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnumerateCfg {
    /// Maximum number of chunks a model may be split into (defaults to the
    /// whole accelerator fleet, as MaxDev requires).
    pub max_split_devices: usize,
}

impl Default for EnumerateCfg {
    fn default() -> Self {
        EnumerateCfg {
            max_split_devices: usize::MAX,
        }
    }
}

/// Closed-form plan count from the paper (uses `D²` source/target options),
/// for the Fig. 9 search-space comparison: D=3 with the 9-layer KWS gives
/// 1 971, the 14-layer SimpleNet 4 941, the 19-layer UNet 9 261.
pub fn paper_plan_count(num_devices: usize, num_layers: usize) -> u64 {
    let d_max = num_devices.min(num_layers);
    let mut total: u64 = 0;
    for d in 1..=d_max {
        total += permutations(num_devices, d) * combinations(num_layers - 1, d - 1);
    }
    total * (num_devices * num_devices) as u64
}

fn permutations(n: usize, k: usize) -> u64 {
    ((n - k + 1)..=n).map(|x| x as u64).product()
}

fn combinations(n: usize, k: usize) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u64 = 1;
    let mut den: u64 = 1;
    for i in 0..k {
        num *= (n - i) as u64;
        den *= (i + 1) as u64;
    }
    num / den
}

/// Enumerate all execution plans for `pipeline` over `fleet`.
///
/// Convenience wrapper over [`enumerate_plans_with`] that materializes the
/// whole space; the planner's hot path uses the callback form to avoid
/// allocating tens of thousands of plans (see EXPERIMENTS.md §Perf).
pub fn enumerate_plans(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
) -> Vec<ExecutionPlan> {
    let mut plans = Vec::new();
    enumerate_plans_with(pipeline, fleet, cfg, |p| plans.push(p.clone()));
    plans
}

/// Visit every execution plan for `pipeline` over `fleet` without
/// materializing the space: the callback receives a reusable plan whose
/// chunk vector is rewritten in place between calls.
///
/// Chunks may only go to accelerator-bearing devices; each chunk must fit
/// its device's accelerator *alone* (cross-pipeline fit is the holistic
/// check in [`super::collab`]). Consecutive chunks are on distinct devices
/// by construction (a d-permutation has no repeats).
pub fn enumerate_plans_with(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
    mut visit: impl FnMut(&ExecutionPlan),
) {
    let sources = pipeline.source_candidates(fleet);
    let targets = pipeline.target_candidates(fleet);
    if sources.is_empty() || targets.is_empty() {
        return;
    }
    // Reusable plan buffer handed to the callback.
    let mut scratch = ExecutionPlan {
        pipeline: pipeline.id,
        source_dev: sources[0],
        target_dev: targets[0],
        chunks: Vec::new(),
    };
    enumerate_splits_with(pipeline, fleet, cfg, |chunks| {
        scratch.chunks.clear();
        scratch.chunks.extend_from_slice(chunks);
        for &s in &sources {
            for &t in &targets {
                scratch.source_dev = s;
                scratch.target_dev = t;
                visit(&scratch);
            }
        }
    });
}

/// Visit every *split skeleton* — the ordered chunk→device assignment
/// without the source/target endpoint choice — for `pipeline` over `fleet`.
///
/// This is the expensive, endpoint-independent part of plan enumeration
/// (device permutations × split boundaries, with eager per-chunk fit
/// filtering). The incremental re-orchestration cache in [`crate::api`]
/// materializes these skeletons per app and reuses them across fleet and
/// app-set changes; [`enumerate_plans_with`] composes them with the
/// endpoint cross product to recover the full plan space.
pub fn enumerate_splits_with(
    pipeline: &PipelineSpec,
    fleet: &Fleet,
    cfg: EnumerateCfg,
    mut visit: impl FnMut(&[Assignment]),
) {
    let accel_devs = fleet.accel_ids();
    let model = &pipeline.model;
    let num_layers = model.num_layers();
    let d_max = accel_devs
        .len()
        .min(num_layers)
        .min(cfg.max_split_devices);

    // Chunk-fit memo: chunk_fits[dev][start][end] would be L² per device;
    // compute lazily through a closure over prefix sums instead.
    let prefix_w: Vec<u64> = {
        let mut acc = vec![0u64];
        for l in 0..num_layers {
            let last = *acc.last().unwrap();
            acc.push(last + model.layers[l].weight_bytes(model.in_shape(l)));
        }
        acc
    };
    let prefix_b: Vec<u64> = {
        let mut acc = vec![0u64];
        for l in 0..num_layers {
            let last = *acc.last().unwrap();
            acc.push(last + model.layers[l].bias_bytes(model.in_shape(l)));
        }
        acc
    };
    let chunk_fits = |dev: DeviceId, start: usize, end: usize| -> bool {
        let spec = match &fleet.get(dev).spec.accel {
            Some(s) => s,
            None => return false,
        };
        AccelMemory::default()
            .check(
                spec,
                prefix_w[end] - prefix_w[start],
                prefix_b[end] - prefix_b[start],
                end - start,
            )
            .is_ok()
    };

    // Reusable chunk buffer handed to the callback.
    let mut chunks: Vec<Assignment> = Vec::with_capacity(d_max);
    // Iterate d = number of chunk devices.
    for d in 1..=d_max {
        let mut perm: Vec<DeviceId> = Vec::with_capacity(d);
        let mut used = vec![false; accel_devs.len()];
        permute(
            &accel_devs,
            d,
            &mut perm,
            &mut used,
            &mut |order: &[DeviceId]| {
                // Choose d-1 boundaries among 1..num_layers.
                let mut bounds: Vec<usize> = Vec::with_capacity(d - 1);
                choose_boundaries(num_layers, d - 1, 1, &mut bounds, &mut |bs: &[usize]| {
                    // Build chunk ranges, checking per-chunk fit as we go.
                    chunks.clear();
                    let mut prev = 0;
                    for (i, &dev) in order.iter().enumerate() {
                        let end = if i + 1 == d { num_layers } else { bs[i] };
                        if !chunk_fits(dev, prev, end) {
                            return;
                        }
                        chunks.push(Assignment {
                            device: dev,
                            range: crate::model::SplitRange::new(prev, end),
                        });
                        prev = end;
                    }
                    visit(&chunks);
                });
            },
        );
    }
}

/// Recursively build d-permutations of `devs`.
fn permute(
    devs: &[DeviceId],
    d: usize,
    cur: &mut Vec<DeviceId>,
    used: &mut [bool],
    f: &mut impl FnMut(&[DeviceId]),
) {
    if cur.len() == d {
        f(cur);
        return;
    }
    for i in 0..devs.len() {
        if used[i] {
            continue;
        }
        used[i] = true;
        cur.push(devs[i]);
        permute(devs, d, cur, used, f);
        cur.pop();
        used[i] = false;
    }
}

/// Recursively choose `k` ascending boundaries in `[from, num_layers)`.
fn choose_boundaries(
    num_layers: usize,
    k: usize,
    from: usize,
    cur: &mut Vec<usize>,
    f: &mut impl FnMut(&[usize]),
) {
    if cur.len() == k {
        f(cur);
        return;
    }
    let remaining = k - cur.len();
    for b in from..=(num_layers - remaining) {
        cur.push(b);
        choose_boundaries(num_layers, k, b + 1, cur, f);
        cur.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};

    fn small_model(layers: usize) -> ModelGraph {
        ModelGraph::new(
            format!("m{layers}"),
            Shape::new(8, 8, 2),
            (0..layers)
                .map(|_| Layer {
                    kind: LayerKind::Conv2d { k: 3 },
                    pool: 1,
                    cout: 4,
                    residual: false, has_bias: true,
                })
                .collect(),
        )
    }

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn any_pipeline(layers: usize) -> PipelineSpec {
        PipelineSpec::new(0, "t", SourceReq::Any, small_model(layers), TargetReq::Any)
    }

    #[test]
    fn paper_counts_reproduce_section_iv_d() {
        // §IV-D: three MAX78000s with the 9/14/19-layer models.
        assert_eq!(paper_plan_count(3, 9), 1_971);
        assert_eq!(paper_plan_count(3, 14), 4_941);
        assert_eq!(paper_plan_count(3, 19), 9_261);
    }

    #[test]
    fn enumeration_matches_closed_form_when_nothing_filtered() {
        // Tiny chunks always fit MAX78000 memory, so the enumerated count
        // must equal the paper's formula exactly.
        for (d, l) in [(2, 4), (3, 5), (2, 9)] {
            let p = any_pipeline(l);
            let plans = enumerate_plans(&p, &fleet(d), EnumerateCfg::default());
            assert_eq!(
                plans.len() as u64,
                paper_plan_count(d, l),
                "D={d} L={l}"
            );
        }
    }

    #[test]
    fn all_enumerated_plans_are_valid() {
        let p = any_pipeline(5);
        let f = fleet(3);
        for plan in enumerate_plans(&p, &f, EnumerateCfg::default()) {
            plan.validate(&p.model).unwrap();
        }
    }

    #[test]
    fn designated_source_target_reduces_space() {
        let mut p = any_pipeline(5);
        p.source = SourceReq::Device(DeviceId(0));
        p.target = TargetReq::Device(DeviceId(1));
        let f = fleet(3);
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert_eq!(plans.len() as u64, paper_plan_count(3, 5) / 9);
        assert!(plans
            .iter()
            .all(|pl| pl.source_dev == DeviceId(0) && pl.target_dev == DeviceId(1)));
    }

    #[test]
    fn max_split_devices_caps_chunks() {
        let p = any_pipeline(6);
        let f = fleet(3);
        let plans = enumerate_plans(
            &p,
            &f,
            EnumerateCfg { max_split_devices: 1 },
        );
        assert!(plans.iter().all(|pl| pl.chunks.len() == 1));
        // D · 1 · D² plans.
        assert_eq!(plans.len(), 3 * 9);
    }

    #[test]
    fn oversized_chunks_are_filtered() {
        // A model that cannot fit on one MAX78000 forces splitting: single
        // 500 KB conv layer per chunk won't fit, so only multi-chunk plans
        // survive... construct a 2-layer model with each layer ~300 KB.
        let m = ModelGraph::new(
            "big",
            Shape::new(16, 16, 64),
            vec![
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 520, residual: false, has_bias: true },
                Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 64, residual: false, has_bias: true },
            ],
        );
        // layer0: 9·64·520 = 299 520 B; layer1: 9·520·64 = 299 520 B.
        // Together 599 040 B > 442 KB, individually fine.
        let p = PipelineSpec::new(0, "big", SourceReq::Any, m, TargetReq::Any);
        let f = fleet(2);
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert!(!plans.is_empty());
        assert!(plans.iter().all(|pl| pl.chunks.len() == 2), "must all split");
    }

    #[test]
    fn skeletons_times_endpoints_equals_plans() {
        // The skeleton space composed with the D² endpoint cross product
        // must reproduce the full enumeration exactly (order included).
        let p = any_pipeline(5);
        let f = fleet(3);
        let mut skeletons: Vec<Vec<Assignment>> = Vec::new();
        enumerate_splits_with(&p, &f, EnumerateCfg::default(), |c| skeletons.push(c.to_vec()));
        let plans = enumerate_plans(&p, &f, EnumerateCfg::default());
        assert_eq!(plans.len(), skeletons.len() * 9);
        for (i, plan) in plans.iter().enumerate() {
            assert_eq!(plan.chunks, skeletons[i / 9], "plan {i}");
        }
    }

    #[test]
    fn no_accel_devices_means_no_plans() {
        let f = Fleet::new(vec![Device::new(
            0,
            "mcu",
            DeviceKind::McuMax32650,
            vec![],
            vec![],
        )]);
        let p = any_pipeline(3);
        assert!(enumerate_plans(&p, &f, EnumerateCfg::default()).is_empty());
    }
}
