//! §IV-F — adaptive task parallelization on a discrete-event simulator.
//!
//! The DES plays two roles (DESIGN.md §4):
//!
//! 1. **Hardware ground truth.** [`groundtruth`] produces *actual* task
//!    durations — the closed-form physics of the device models plus fixed
//!    per-task overheads and seeded jitter. All reported experiment metrics
//!    (throughput, latency, power) are measured on this substrate, not
//!    read off the planner's estimates.
//! 2. **The ATP scheduler.** [`engine`] executes a deployed holistic
//!    collaboration plan over per-computation-unit FIFO queues exactly as
//!    §IV-F describes: each unit has a queue and a dedicated scheduler;
//!    inter-pipeline parallelization overlaps tasks of different pipelines,
//!    inter-run parallelization overlaps consecutive runs of one pipeline.
//!
//! The engine is interruptible and resumable ([`SimEngine`]): live
//! sessions ([`crate::api::Session`]) drive it in segments with
//! `run_until` horizons and swap plans mid-timeline without restarting
//! the clock; [`simulate`] is the one-shot batch wrapper.

pub mod epoch;
pub mod groundtruth;
pub mod engine;
pub mod policy;
pub mod trace;

pub use engine::{simulate, RoundRecord, SimConfig, SimEngine, SimReport};
pub use epoch::EpochLedger;
pub use groundtruth::GroundTruth;
pub use policy::Policy;
pub use trace::{TaskSpan, Trace};
