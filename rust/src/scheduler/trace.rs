//! Execution traces: per-task spans recorded by the simulator, used for
//! causality assertions in tests, utilization reports, and the Fig. 8-style
//! latency decompositions.

use crate::device::DeviceId;
use crate::plan::task::{TaskKind, UnitKind};

/// One executed task instance.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpan {
    /// The pipeline's id (stable across plan switches in a live session;
    /// equal to the plan index for the Table I workloads).
    pub pipeline: usize,
    /// Task sequence position within the pipeline.
    pub seq: usize,
    /// Run (continuous-inference iteration) index — global per pipeline,
    /// continuing across plan switches.
    pub run: usize,
    pub device: DeviceId,
    pub unit: UnitKind,
    pub kind: TaskKind,
    pub start: f64,
    pub end: f64,
}

impl TaskSpan {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// A full simulation trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<TaskSpan>,
}

impl Trace {
    /// Check that no two spans overlap on the same (device, unit) — the
    /// fundamental exclusivity invariant of per-unit queues.
    pub fn check_unit_exclusivity(&self) -> Result<(), String> {
        let mut by_unit: std::collections::BTreeMap<(DeviceId, UnitKind), Vec<(f64, f64)>> =
            std::collections::BTreeMap::new();
        for s in &self.spans {
            by_unit
                .entry((s.device, s.unit))
                .or_default()
                .push((s.start, s.end));
        }
        for ((dev, unit), mut spans) in by_unit {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                if w[1].0 < w[0].1 - 1e-12 {
                    return Err(format!(
                        "overlap on {dev:?}/{unit:?}: [{:.6},{:.6}] then [{:.6},{:.6}]",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        Ok(())
    }

    /// Check intra-pipeline causality: within (pipeline, run), task seq i+1
    /// starts no earlier than task seq i ends.
    pub fn check_causality(&self) -> Result<(), String> {
        let mut by_chain: std::collections::BTreeMap<(usize, usize), Vec<(usize, f64, f64)>> =
            std::collections::BTreeMap::new();
        for s in &self.spans {
            by_chain
                .entry((s.pipeline, s.run))
                .or_default()
                .push((s.seq, s.start, s.end));
        }
        for ((p, r), mut chain) in by_chain {
            chain.sort_by_key(|c| c.0);
            for w in chain.windows(2) {
                if w[1].1 < w[0].2 - 1e-12 {
                    return Err(format!(
                        "causality violated p{p} run{r}: seq {} starts {:.6} before seq {} ends {:.6}",
                        w[1].0, w[1].1, w[0].0, w[0].2
                    ));
                }
            }
        }
        Ok(())
    }

    /// Busy time per (device, unit).
    pub fn unit_busy(&self) -> std::collections::BTreeMap<(DeviceId, UnitKind), f64> {
        let mut m = std::collections::BTreeMap::new();
        for s in &self.spans {
            *m.entry((s.device, s.unit)).or_insert(0.0) += s.duration();
        }
        m
    }

    /// Makespan of the trace.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SplitRange;

    fn span(pipeline: usize, seq: usize, run: usize, start: f64, end: f64) -> TaskSpan {
        TaskSpan {
            pipeline,
            seq,
            run,
            device: DeviceId(0),
            unit: UnitKind::Accel,
            kind: TaskKind::Infer { range: SplitRange::new(0, 1) },
            start,
            end,
        }
    }

    #[test]
    fn exclusivity_detects_overlap() {
        let good = Trace { spans: vec![span(0, 0, 0, 0.0, 1.0), span(1, 0, 0, 1.0, 2.0)] };
        assert!(good.check_unit_exclusivity().is_ok());
        let bad = Trace { spans: vec![span(0, 0, 0, 0.0, 1.0), span(1, 0, 0, 0.5, 2.0)] };
        assert!(bad.check_unit_exclusivity().is_err());
    }

    #[test]
    fn causality_detects_reordering() {
        let good = Trace { spans: vec![span(0, 0, 0, 0.0, 1.0), span(0, 1, 0, 1.0, 2.0)] };
        assert!(good.check_causality().is_ok());
        let bad = Trace { spans: vec![span(0, 0, 0, 0.0, 1.0), span(0, 1, 0, 0.9, 2.0)] };
        assert!(bad.check_causality().is_err());
    }

    #[test]
    fn busy_and_makespan() {
        let t = Trace { spans: vec![span(0, 0, 0, 0.0, 1.0), span(0, 1, 1, 2.0, 3.5)] };
        assert_eq!(t.makespan(), 3.5);
        let busy = t.unit_busy();
        assert!((busy[&(DeviceId(0), UnitKind::Accel)] - 2.5).abs() < 1e-12);
    }
}
