//! Ground-truth task durations — the simulated hardware.
//!
//! Durations are the device models' physics (cycle counts over clocks, bus
//! rates, link rates, sensor profiles) plus the imperfections real hardware
//! adds and the planner's closed forms ignore: fixed per-task setup
//! overheads and run-to-run jitter. Jitter is derived deterministically
//! from `(seed, pipeline, seq, run)`, so simulations are reproducible and
//! independent of event ordering.
//!
//! Fig. 11's claim — clock-cycle estimates land within 1% of measurement —
//! holds against exactly this substrate: overheads/jitter are sub-percent
//! for inference tasks, as they are on the real accelerator.

use crate::device::{Fleet, SensorKind};
use crate::estimator::{clock, comm, sensing};
use crate::model::ModelGraph;
use crate::plan::task::{PlanTask, TaskKind, UnitKind};
use crate::util::rng::Rng;

/// Ground-truth duration source for one fleet.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    pub seed: u64,
    /// Relative std-dev of multiplicative jitter (0.003 = 0.3%).
    pub jitter_rel: f64,
    /// Fixed per-inference-task setup (accelerator kickoff), seconds.
    pub infer_overhead_s: f64,
    /// Fixed per-memory-op setup beyond the bus constants, seconds.
    pub memop_overhead_s: f64,
}

impl Default for GroundTruth {
    fn default() -> Self {
        GroundTruth {
            seed: 0x5EED,
            jitter_rel: 0.003,
            infer_overhead_s: 1e-6,
            memop_overhead_s: 5e-6,
        }
    }
}

impl GroundTruth {
    pub fn with_seed(seed: u64) -> GroundTruth {
        GroundTruth {
            seed,
            ..Default::default()
        }
    }

    /// Deterministic multiplicative jitter for a task instance.
    fn jitter(&self, pipeline: usize, seq: usize, run: usize) -> f64 {
        let key = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((pipeline as u64) << 40)
            .wrapping_add((seq as u64) << 20)
            .wrapping_add(run as u64);
        let mut rng = Rng::new(key);
        1.0 + self.jitter_rel * rng.next_gaussian()
    }

    /// Ideal (noise-free) duration of a task: the device physics.
    pub fn ideal(
        &self,
        fleet: &Fleet,
        task: &PlanTask,
        model: &ModelGraph,
        sensor: Option<SensorKind>,
    ) -> f64 {
        let dev = fleet.get(task.device);
        match task.kind {
            TaskKind::Sense { bytes } => sensor
                .map(sensing::sense_latency)
                .unwrap_or_else(|| sensing::sense_latency_bytes(bytes)),
            TaskKind::Load { bytes } | TaskKind::Unload { bytes } => match &dev.spec.accel {
                Some(a) => {
                    self.memop_overhead_s + a.bus_overhead_s + bytes as f64 / a.bus_bytes_per_s
                }
                None => bytes as f64 / dev.spec.cpu_clock_hz,
            },
            TaskKind::Infer { range } => {
                let base = match &dev.spec.accel {
                    Some(a) => {
                        clock::infer_latency_accel(model, range, a.parallel_procs, a.clock_hz)
                    }
                    None => clock::infer_latency_sequential(
                        model,
                        range,
                        dev.spec.cpu_clock_hz,
                        dev.spec.cycles_per_mac,
                    ),
                };
                base + self.infer_overhead_s * range.len() as f64
            }
            TaskKind::Tx { bytes, to } => comm::tx_latency(dev, fleet.get(to), bytes),
            TaskKind::Rx { bytes, from } => comm::tx_latency(fleet.get(from), dev, bytes),
            TaskKind::Interact { .. } => sensing::INTERACT_LATENCY_S,
        }
    }

    /// Measured duration of a task instance in run `run`.
    pub fn duration(
        &self,
        fleet: &Fleet,
        task: &PlanTask,
        model: &ModelGraph,
        sensor: Option<SensorKind>,
        run: usize,
    ) -> f64 {
        let ideal = self.ideal(fleet, task, model, sensor);
        (ideal * self.jitter(task.pipeline.0, task.seq, run)).max(1e-9)
    }

    /// The effective computation unit a task occupies on its device: on a
    /// device without a CNN accelerator, inference runs on the core.
    pub fn unit_of(fleet: &Fleet, task: &PlanTask) -> UnitKind {
        let unit = task.unit();
        if unit == UnitKind::Accel && !fleet.get(task.device).has_accel() {
            UnitKind::Cpu
        } else {
            unit
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceId, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::SplitRange;
    use crate::pipeline::PipelineId;

    fn fleet() -> Fleet {
        Fleet::new(vec![
            Device::new(0, "a", DeviceKind::Max78000, vec![], vec![]),
            Device::new(1, "mcu", DeviceKind::McuMax32650, vec![], vec![]),
        ])
    }

    fn model() -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(16, 16, 3),
            vec![Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true }],
        )
    }

    fn infer_task(dev: usize) -> PlanTask {
        PlanTask {
            pipeline: PipelineId(0),
            seq: 1,
            device: DeviceId(dev),
            kind: TaskKind::Infer { range: SplitRange::new(0, 1) },
        }
    }

    #[test]
    fn duration_is_deterministic_per_instance() {
        let gt = GroundTruth::default();
        let f = fleet();
        let m = model();
        let a = gt.duration(&f, &infer_task(0), &m, None, 3);
        let b = gt.duration(&f, &infer_task(0), &m, None, 3);
        assert_eq!(a, b);
        // Different run → different jitter.
        let c = gt.duration(&f, &infer_task(0), &m, None, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn inference_estimate_gap_below_one_percent() {
        // The Fig. 11 property: on production-size layers, ground truth vs
        // clock-cycle estimate stays within ~1% (overhead + jitter).
        let gt = GroundTruth::default();
        let f = fleet();
        let m = crate::model::zoo::model_by_name(crate::model::ModelName::KWS);
        let task = PlanTask {
            pipeline: PipelineId(0),
            seq: 1,
            device: DeviceId(0),
            kind: TaskKind::Infer { range: m.full() },
        };
        let est = clock::infer_latency_accel(m, m.full(), 64, 50e6);
        for run in 0..50 {
            let meas = gt.duration(&f, &task, m, None, run);
            let gap = (meas - est).abs() / est;
            assert!(gap < 0.015, "run {run}: gap {gap}");
        }
    }

    #[test]
    fn mcu_inference_runs_on_cpu_unit() {
        let f = fleet();
        assert_eq!(GroundTruth::unit_of(&f, &infer_task(0)), UnitKind::Accel);
        assert_eq!(GroundTruth::unit_of(&f, &infer_task(1)), UnitKind::Cpu);
    }

    #[test]
    fn mcu_inference_is_much_slower() {
        let gt = GroundTruth::default();
        let f = fleet();
        let m = model();
        let accel = gt.ideal(&f, &infer_task(0), &m, None);
        let mcu = gt.ideal(&f, &infer_task(1), &m, None);
        assert!(mcu > 10.0 * accel, "accel {accel} mcu {mcu}");
    }
}
