//! Cross-epoch round-index continuity, shared by the execution engines.
//!
//! Both engines that can swap plans mid-run — the discrete-event
//! [`super::SimEngine`] and the streaming [`crate::serving::ServeEngine`] —
//! deploy plans as *epochs*: the old epoch retires with a graceful
//! in-flight drain while the new one starts. Global per-pipeline round
//! indices must keep counting across that switch (the ground-truth jitter
//! stream, trace keys, and session time series are all keyed by them), and
//! a round that *started* under the retiring epoch may still complete and
//! record its index during the drain, so the next epoch must base itself
//! past every started round — completed-round tracking alone would let a
//! draining round collide with the new epoch's round 0.
//!
//! [`EpochLedger`] is that bookkeeping: a per-pipeline high-water mark of
//! started rounds, advanced by whichever engine starts them.

use std::collections::BTreeMap;

use crate::pipeline::PipelineId;

/// Per-pipeline global round-index ledger (see the module docs).
#[derive(Clone, Debug, Default)]
pub struct EpochLedger {
    next_round: BTreeMap<PipelineId, usize>,
}

impl EpochLedger {
    pub fn new() -> EpochLedger {
        EpochLedger::default()
    }

    /// The global index the pipeline's next epoch must start rounds at.
    pub fn base_round(&self, pipeline: PipelineId) -> usize {
        self.next_round.get(&pipeline).copied().unwrap_or(0)
    }

    /// Record that global round `round` of `pipeline` started (or
    /// completed): the next epoch's base moves past it.
    pub fn note_round(&mut self, pipeline: PipelineId, round: usize) {
        let next = self.next_round.entry(pipeline).or_insert(0);
        *next = (*next).max(round + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_advances_past_noted_rounds_and_never_regresses() {
        let mut ledger = EpochLedger::new();
        let p = PipelineId(3);
        assert_eq!(ledger.base_round(p), 0);
        ledger.note_round(p, 0);
        ledger.note_round(p, 4);
        assert_eq!(ledger.base_round(p), 5);
        // Late completions from a draining epoch must not move it back.
        ledger.note_round(p, 2);
        assert_eq!(ledger.base_round(p), 5);
        // Other pipelines are independent.
        assert_eq!(ledger.base_round(PipelineId(0)), 0);
    }
}
