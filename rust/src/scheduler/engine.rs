//! The discrete-event engine: executes a holistic collaboration plan over
//! per-computation-unit FIFO queues (§IV-F) against the ground-truth
//! hardware model, for a configurable number of continuous-inference runs.
//!
//! Each (device, unit) owns a queue and a dedicated scheduler: a task is
//! enqueued the moment its dependencies complete ("ready"), and the unit
//! executes its queue in arrival order — later-arriving tasks wait, exactly
//! as the paper specifies. Policies differ only in the dependency edges
//! they add across pipelines and runs (see [`super::policy`]).

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::device::{DeviceId, Fleet};
use crate::pipeline::PipelineSpec;
use crate::plan::task::{PlanTask, TaskKind, UnitKind};
use crate::plan::CollabPlan;

use super::groundtruth::GroundTruth;
use super::policy::Policy;
use super::trace::{TaskSpan, Trace};

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Continuous-inference runs per pipeline.
    pub runs: usize,
    /// Rounds excluded from throughput/latency measurement (pipeline fill).
    pub warmup: usize,
    pub policy: Policy,
    /// Record a full task trace (tests, Fig. 8 decompositions).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            runs: 24,
            warmup: 4,
            policy: Policy::atp(),
            record_trace: false,
        }
    }
}

/// Measured results of one simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total simulated time until the last task completed.
    pub makespan: f64,
    /// Model executions per second over the measured window (§VI-A3).
    pub throughput: f64,
    /// Mean end-to-end pipeline latency (sense start → interact end).
    pub avg_latency: f64,
    /// Mean power draw over the horizon, watts (≡ J/s as the paper reports).
    pub power_w: f64,
    /// Total energy over the horizon, joules.
    pub energy_j: f64,
    /// Completed pipeline runs.
    pub completions: usize,
    /// Busy seconds per (device, unit).
    pub unit_busy: BTreeMap<(DeviceId, UnitKind), f64>,
    /// Full trace when requested.
    pub trace: Option<Trace>,
}

/// Min-heap event: (time, kind, task id). `Done` sorts before `Ready` at
/// equal times so a freed unit can immediately take the arriving task.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Event {
    time: f64,
    kind: EventKind,
    id: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    Done,
    Ready,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed for a min-heap via BinaryHeap<Event>.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.kind.cmp(&self.kind))
            .then_with(|| other.id.cmp(&self.id))
    }
}

struct TaskTable {
    /// Expanded task list per pipeline (one run's worth).
    per_pipeline: Vec<Vec<PlanTask>>,
    /// Prefix offsets of pipelines within one run's id block.
    offset: Vec<usize>,
    /// Total tasks in one run across pipelines.
    per_run: usize,
    runs: usize,
}

impl TaskTable {
    fn id(&self, p: usize, s: usize, r: usize) -> usize {
        r * self.per_run + self.offset[p] + s
    }

    fn decode(&self, id: usize) -> (usize, usize, usize) {
        let r = id / self.per_run;
        let rem = id % self.per_run;
        // Binary search the pipeline whose offset block contains rem.
        let p = match self.offset.binary_search(&rem) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (p, rem - self.offset[p], r)
    }

    fn num_tasks(&self, p: usize) -> usize {
        self.per_pipeline[p].len()
    }

    fn total(&self) -> usize {
        self.per_run * self.runs
    }
}

/// Run the simulation.
pub fn simulate(
    plan: &CollabPlan,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    gt: &GroundTruth,
    cfg: SimConfig,
) -> SimReport {
    assert!(cfg.runs > cfg.warmup, "need runs > warmup");
    let n = plan.plans.len();
    assert!(n > 0, "empty plan");

    // Expand tasks and resolve pipeline specs in plan order.
    let specs: Vec<&PipelineSpec> = plan
        .plans
        .iter()
        .map(|ep| {
            pipelines
                .iter()
                .find(|p| p.id == ep.pipeline)
                .expect("plan for unknown pipeline")
        })
        .collect();
    let per_pipeline: Vec<Vec<PlanTask>> = plan
        .plans
        .iter()
        .zip(&specs)
        .map(|(ep, spec)| ep.tasks(&spec.model))
        .collect();
    let mut offset = Vec::with_capacity(n);
    let mut acc = 0;
    for tl in &per_pipeline {
        offset.push(acc);
        acc += tl.len();
    }
    let table = TaskTable {
        per_pipeline,
        offset,
        per_run: acc,
        runs: cfg.runs,
    };

    // Initial pending-dependency counts per task instance.
    let mut pending: Vec<u32> = vec![0; table.total()];
    for r in 0..cfg.runs {
        for p in 0..n {
            let last = table.num_tasks(p) - 1;
            for s in 0..=last {
                let mut deps = 0u32;
                if s > 0 {
                    deps += 1; // predecessor in chain
                }
                if s == 0 {
                    deps += match cfg.policy {
                        Policy::Sequential => {
                            // Global chain: previous pipeline this round, or
                            // last pipeline of the previous round.
                            if p > 0 || r > 0 {
                                1
                            } else {
                                0
                            }
                        }
                        Policy::InterPipeline => {
                            // Round barrier: all pipelines of round r-1.
                            if r > 0 {
                                n as u32
                            } else {
                                0
                            }
                        }
                        Policy::Atp { max_inflight } => {
                            let mut d = 0;
                            if r > 0 {
                                d += 1; // sensor ordering: (p,0,r-1)
                            }
                            if r >= max_inflight {
                                d += 1; // bounded in-flight: (p,last,r-k)
                            }
                            d
                        }
                    };
                }
                pending[table.id(p, s, r)] = deps;
            }
        }
    }

    // Unit states.
    #[derive(Default)]
    struct Unit {
        busy: bool,
        queue: VecDeque<usize>,
    }
    let mut units: BTreeMap<(DeviceId, UnitKind), Unit> = BTreeMap::new();

    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    // Seed: all zero-dependency tasks ready at t=0.
    for (id, &p) in pending.iter().enumerate() {
        if p == 0 {
            heap.push(Event { time: 0.0, kind: EventKind::Ready, id });
        }
    }

    let mut start_time: Vec<f64> = vec![f64::NAN; table.total()];
    let mut end_time: Vec<f64> = vec![f64::NAN; table.total()];
    let mut spans: Vec<TaskSpan> = Vec::new();
    let mut unit_busy: BTreeMap<(DeviceId, UnitKind), f64> = BTreeMap::new();
    // Per-device active-seconds by power category.
    let mut busy_by_dev: Vec<crate::device::power::BusyTimes> =
        vec![Default::default(); fleet.len()];
    let mut completed = 0usize;

    let task_of = |id: usize| -> (&PlanTask, usize, usize, usize) {
        let (p, s, r) = table.decode(id);
        (&table.per_pipeline[p][s], p, s, r)
    };

    // Start a task on its (idle) unit at time `t`.
    macro_rules! start_task {
        ($id:expr, $t:expr, $heap:expr) => {{
            let (task, p, _s, r) = task_of($id);
            let sensor = crate::estimator::LatencyModel::source_sensor(specs[p]);
            let dur = gt.duration(fleet, task, &specs[p].model, sensor, r);
            start_time[$id] = $t;
            $heap.push(Event { time: $t + dur, kind: EventKind::Done, id: $id });
        }};
    }

    while let Some(ev) = heap.pop() {
        let (task, p, s, r) = task_of(ev.id);
        let unit_kind = GroundTruth::unit_of(fleet, task);
        let key = (task.device, unit_kind);
        match ev.kind {
            EventKind::Ready => {
                let unit = units.entry(key).or_default();
                unit.queue.push_back(ev.id);
                if !unit.busy {
                    unit.busy = true;
                    let next = unit.queue.pop_front().unwrap();
                    start_task!(next, ev.time, heap);
                }
            }
            EventKind::Done => {
                end_time[ev.id] = ev.time;
                let dur = ev.time - start_time[ev.id];
                *unit_busy.entry(key).or_insert(0.0) += dur;
                {
                    let b = &mut busy_by_dev[task.device.0];
                    match task.kind {
                        TaskKind::Sense { .. } => b.sensor_s += dur,
                        TaskKind::Load { .. }
                        | TaskKind::Unload { .. }
                        | TaskKind::Interact { .. } => b.cpu_s += dur,
                        TaskKind::Infer { .. } => {
                            if unit_kind == UnitKind::Accel {
                                b.accel_s += dur;
                            } else {
                                b.cpu_s += dur;
                            }
                        }
                        TaskKind::Tx { .. } => b.radio_tx_s += dur,
                        TaskKind::Rx { .. } => b.radio_rx_s += dur,
                    }
                }
                if cfg.record_trace {
                    spans.push(TaskSpan {
                        pipeline: p,
                        seq: s,
                        run: r,
                        device: task.device,
                        unit: unit_kind,
                        kind: task.kind,
                        start: start_time[ev.id],
                        end: ev.time,
                    });
                }

                // Successor bookkeeping.
                let mut notify = |id: usize, heap: &mut BinaryHeap<Event>| {
                    pending[id] -= 1;
                    if pending[id] == 0 {
                        heap.push(Event { time: ev.time, kind: EventKind::Ready, id });
                    }
                };
                let last = table.num_tasks(p) - 1;
                if s < last {
                    notify(table.id(p, s + 1, r), &mut heap);
                }
                if s == last {
                    completed += 1;
                    match cfg.policy {
                        Policy::Sequential => {
                            if p + 1 < n {
                                notify(table.id(p + 1, 0, r), &mut heap);
                            } else if r + 1 < cfg.runs {
                                notify(table.id(0, 0, r + 1), &mut heap);
                            }
                        }
                        Policy::InterPipeline => {
                            if r + 1 < cfg.runs {
                                for q in 0..n {
                                    notify(table.id(q, 0, r + 1), &mut heap);
                                }
                            }
                        }
                        Policy::Atp { max_inflight } => {
                            if r + max_inflight < cfg.runs {
                                notify(table.id(p, 0, r + max_inflight), &mut heap);
                            }
                        }
                    }
                }
                if s == 0 {
                    if let Policy::Atp { .. } = cfg.policy {
                        if r + 1 < cfg.runs {
                            notify(table.id(p, 0, r + 1), &mut heap);
                        }
                    }
                }

                // Unit takes its next queued task.
                let unit = units.get_mut(&key).unwrap();
                if let Some(next) = unit.queue.pop_front() {
                    start_task!(next, ev.time, heap);
                } else {
                    unit.busy = false;
                }
            }
        }
    }

    // All tasks must have completed — checked in every build profile. This
    // was a `debug_assert!`, so a release build with a cyclic or missing
    // dependency (e.g. a policy wired with a zero in-flight window)
    // silently returned NaN-poisoned makespan/throughput/latency figures
    // instead of failing. Fail loudly with a diagnostic instead.
    let expected = n * cfg.runs;
    if completed != expected {
        let unfinished = end_time.iter().filter(|t| !t.is_finite()).count();
        let never_ready = pending.iter().filter(|&&d| d > 0).count();
        panic!(
            "DES deadlock: {completed}/{expected} pipeline runs completed \
             ({unfinished} of {} tasks never finished, {never_ready} still \
             have unmet dependencies) — cyclic or missing dependency under \
             policy {:?}",
            table.total(),
            cfg.policy,
        );
    }

    let makespan = end_time.iter().copied().fold(0.0, f64::max);

    // Round completion times: round r done when all pipelines' run r done.
    let round_done: Vec<f64> = (0..cfg.runs)
        .map(|r| {
            (0..n)
                .map(|p| end_time[table.id(p, table.num_tasks(p) - 1, r)])
                .fold(0.0, f64::max)
        })
        .collect();
    let t0 = if cfg.warmup == 0 {
        0.0
    } else {
        round_done[cfg.warmup - 1]
    };
    let measured_rounds = cfg.runs - cfg.warmup;
    let throughput = (n * measured_rounds) as f64 / (round_done[cfg.runs - 1] - t0).max(1e-12);

    // Mean end-to-end latency over measured runs.
    let mut lat_sum = 0.0;
    let mut lat_cnt = 0usize;
    for r in cfg.warmup..cfg.runs {
        for p in 0..n {
            let sense_start = start_time[table.id(p, 0, r)];
            let done = end_time[table.id(p, table.num_tasks(p) - 1, r)];
            lat_sum += done - sense_start;
            lat_cnt += 1;
        }
    }
    let avg_latency = lat_sum / lat_cnt as f64;

    // Energy over the whole horizon.
    let mut energy_j = 0.0;
    for (i, dev) in fleet.devices.iter().enumerate() {
        energy_j += busy_by_dev[i].energy_j(&dev.spec.power, makespan);
    }
    let power_w = energy_j / makespan.max(1e-12);

    SimReport {
        makespan,
        throughput,
        avg_latency,
        power_w,
        energy_j,
        completions: completed,
        unit_busy,
        trace: if cfg.record_trace {
            Some(Trace { spans })
        } else {
            None
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::plan::exec_plan::{Assignment, ExecutionPlan};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn model(layers: usize) -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(16, 16, 3),
            (0..layers)
                .map(|_| Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true })
                .collect(),
        )
    }

    fn pipes(n: usize) -> Vec<PipelineSpec> {
        (0..n)
            .map(|i| {
                PipelineSpec::new(i, format!("p{i}"), SourceReq::Any, model(2), TargetReq::Any)
            })
            .collect()
    }

    fn plan_spread(ps: &[PipelineSpec], ndev: usize) -> CollabPlan {
        CollabPlan::new(
            ps.iter()
                .enumerate()
                .map(|(i, p)| {
                    let d = DeviceId(i % ndev);
                    ExecutionPlan::monolithic(p, d, d, d)
                })
                .collect(),
        )
    }

    fn cfg(policy: Policy) -> SimConfig {
        SimConfig { runs: 12, warmup: 2, policy, record_trace: true }
    }

    #[test]
    fn all_tasks_complete_and_trace_is_sound() {
        let f = fleet(2);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        for policy in [Policy::Sequential, Policy::InterPipeline, Policy::atp()] {
            let rep = simulate(&plan, &ps, &f, &gt, cfg(policy));
            assert_eq!(rep.completions, 3 * 12, "{policy:?}");
            let trace = rep.trace.unwrap();
            trace.check_unit_exclusivity().unwrap();
            trace.check_causality().unwrap();
            assert!(rep.makespan > 0.0);
            assert!(rep.throughput > 0.0);
        }
    }

    #[test]
    fn parallel_policies_dominate_sequential() {
        let f = fleet(3);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 3);
        let gt = GroundTruth::default();
        let seq = simulate(&plan, &ps, &f, &gt, cfg(Policy::Sequential));
        let ipl = simulate(&plan, &ps, &f, &gt, cfg(Policy::InterPipeline));
        let atp = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        // Independent pipelines on separate devices: inter-pipeline overlap
        // is a ~3× win; ATP at least matches it.
        assert!(
            ipl.throughput > 2.0 * seq.throughput,
            "seq {} ipl {}",
            seq.throughput,
            ipl.throughput
        );
        assert!(atp.throughput >= ipl.throughput * 0.95);
        // Sequential's per-run latency is no better (same chain).
        assert!(ipl.avg_latency <= seq.avg_latency * 1.05);
    }

    #[test]
    fn inter_run_overlap_helps_split_pipelines() {
        // One pipeline split across two devices: inter-run parallelization
        // keeps both accelerators busy; the barrier policies cannot.
        let f = fleet(2);
        let m = model(4);
        let ps = vec![PipelineSpec::new(0, "p", SourceReq::Any, m.clone(), TargetReq::Any)];
        let plan = CollabPlan::new(vec![ExecutionPlan {
            pipeline: ps[0].id,
            source_dev: DeviceId(0),
            target_dev: DeviceId(1),
            chunks: vec![
                Assignment { device: DeviceId(0), range: crate::model::SplitRange::new(0, 2) },
                Assignment { device: DeviceId(1), range: crate::model::SplitRange::new(2, 4) },
            ],
        }]);
        let gt = GroundTruth::default();
        let ipl = simulate(&plan, &ps, &f, &gt, cfg(Policy::InterPipeline));
        let atp = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        assert!(
            atp.throughput > 1.2 * ipl.throughput,
            "ipl {} atp {}",
            ipl.throughput,
            atp.throughput
        );
    }

    #[test]
    fn sequential_round_latency_matches_chain_sum() {
        // With one pipeline on one device, throughput ≈ 1 / chain latency
        // regardless of policy.
        let f = fleet(1);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let gt = GroundTruth::default();
        let rep = simulate(&plan, &ps, &f, &gt, cfg(Policy::Sequential));
        let expect = 1.0 / rep.avg_latency;
        let err = (rep.throughput - expect).abs() / expect;
        assert!(err < 0.05, "tput {} vs 1/lat {}", rep.throughput, expect);
    }

    #[test]
    fn energy_exceeds_base_and_scales_with_makespan() {
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let rep = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        let base_power: f64 = f.devices.iter().map(|d| d.spec.power.base_w).sum();
        assert!(rep.power_w > base_power);
        assert!(rep.energy_j > base_power * rep.makespan * 0.99);
    }

    #[test]
    fn deterministic_across_calls() {
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let a = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        let b = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.throughput, b.throughput);
        assert_eq!(a.energy_j, b.energy_j);
    }

    #[test]
    #[should_panic(expected = "DES deadlock")]
    fn deadlock_panics_in_every_profile_instead_of_returning_nans() {
        // A zero in-flight window wires each run's first task to wait on
        // its own run's last task — a dependency cycle, so nothing ever
        // becomes ready. Regression: this check was a `debug_assert!`, so
        // release builds returned NaN-poisoned makespan/throughput instead
        // of failing; it must now panic with a diagnostic in all profiles.
        let f = fleet(1);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        simulate(
            &plan,
            &ps,
            &f,
            &GroundTruth::default(),
            SimConfig {
                runs: 4,
                warmup: 1,
                policy: Policy::Atp { max_inflight: 0 },
                record_trace: false,
            },
        );
    }
}
