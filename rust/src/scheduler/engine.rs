//! The discrete-event engine: executes holistic collaboration plans over
//! per-computation-unit FIFO queues (§IV-F) against the ground-truth
//! hardware model.
//!
//! Each (device, unit) owns a queue and a dedicated scheduler: a task is
//! enqueued the moment its dependencies complete ("ready"), and the unit
//! executes its queue in arrival order — later-arriving tasks wait, exactly
//! as the paper specifies. Policies differ only in the dependency edges
//! they add across pipelines and runs (see [`super::policy`]).
//!
//! Since the live-session redesign the engine is *interruptible and
//! resumable*: [`SimEngine`] owns the clock, the event heap, the unit
//! queues, and the energy accounting, and advances in segments via
//! [`SimEngine::run_until`]. A deployed plan is an *epoch*; swapping plans
//! mid-run ([`SimEngine::set_plan`]) retires the current epoch — queued
//! but unstarted tasks are discarded, in-flight tasks drain gracefully on
//! their units — and seeds the new plan's rounds at the current simulated
//! time, so the clock never restarts across replans. Rounds are spawned
//! lazily as their dependencies resolve, which is what lets an epoch run
//! against a time horizon instead of a fixed round count.
//!
//! The one-shot [`simulate`] entry point is a thin wrapper: one epoch,
//! a fixed round budget, run to completion. Its event ordering, round
//! accounting, and energy integration are bit-identical to the pre-session
//! batch engine.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::analysis::{AnalysisError, SameTimePolicy};
use crate::device::{DeviceId, Fleet};
use crate::pipeline::{PipelineId, PipelineSpec};
use crate::plan::task::{PlanTask, UnitKind};
use crate::plan::CollabPlan;
use crate::power::{busy_kind, Accountant};

use super::epoch::EpochLedger;
use super::groundtruth::GroundTruth;
use super::policy::Policy;
use super::trace::{TaskSpan, Trace};

/// Simulation parameters for the one-shot [`simulate`] wrapper.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Continuous-inference runs per pipeline.
    pub runs: usize,
    /// Rounds excluded from throughput/latency measurement (pipeline fill).
    pub warmup: usize,
    pub policy: Policy,
    /// Record a full task trace (tests, Fig. 8 decompositions).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            runs: 24,
            warmup: 4,
            policy: Policy::atp(),
            record_trace: false,
        }
    }
}

/// Measured results of one simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total simulated time until the last task completed.
    pub makespan: f64,
    /// Model executions per second over the measured window (§VI-A3).
    pub throughput: f64,
    /// Mean end-to-end pipeline latency (sense start → interact end).
    pub avg_latency: f64,
    /// Mean power draw over the horizon, watts (≡ J/s as the paper reports).
    pub power_w: f64,
    /// Total energy over the horizon, joules.
    pub energy_j: f64,
    /// Completed pipeline runs.
    pub completions: usize,
    /// Busy seconds per (device, unit).
    pub unit_busy: BTreeMap<(DeviceId, UnitKind), f64>,
    /// Full trace when requested.
    pub trace: Option<Trace>,
}

/// One completed pipeline round (sense start → interact end), the unit of
/// the session time series.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundRecord {
    pub pipeline: PipelineId,
    /// Global round index for this pipeline — continuous across plan
    /// switches, so the jitter stream and trace keys never restart.
    pub run: usize,
    /// Start of the round's sensing task.
    pub start: f64,
    /// Completion of the round's interaction task.
    pub end: f64,
}

/// Min-heap event: (time, kind, tie, epoch, task id). `Done` sorts before
/// `Ready` at equal times so a freed unit can immediately take the
/// arriving task. `tie` is the [`SameTimePolicy`] rank — all zeros under
/// the deterministic policy, so with a single epoch the ordering is
/// identical to the pre-session batch engine's (time, kind, id); a seeded
/// policy permutes only the order among *simultaneously-ready* events.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Event {
    time: f64,
    kind: EventKind,
    tie: u64,
    epoch: usize,
    id: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    Done,
    Ready,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed for a min-heap via BinaryHeap<Event>.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.kind.cmp(&self.kind))
            .then_with(|| other.tie.cmp(&self.tie))
            .then_with(|| other.epoch.cmp(&self.epoch))
            .then_with(|| other.id.cmp(&self.id))
    }
}

/// Static dependency count of task (p, s, r) under `policy`, with `n`
/// pipelines in the plan — the same edge structure the batch engine wired
/// up front, now computed per lazily spawned round.
fn static_deps(policy: Policy, n: usize, p: usize, s: usize, r: usize) -> u32 {
    let mut deps = if s > 0 { 1u32 } else { 0 };
    if s == 0 {
        deps += match policy {
            Policy::Sequential => {
                // Global chain: previous pipeline this round, or the last
                // pipeline of the previous round.
                if p > 0 || r > 0 {
                    1
                } else {
                    0
                }
            }
            Policy::InterPipeline => {
                // Round barrier: all pipelines of round r-1.
                if r > 0 {
                    n as u32
                } else {
                    0
                }
            }
            Policy::Atp { max_inflight } => {
                let mut d = 0;
                if r > 0 {
                    d += 1; // sensor ordering: (p,0,r-1)
                }
                if r >= max_inflight {
                    d += 1; // bounded in-flight: (p,last,r-k)
                }
                d
            }
        };
    }
    deps
}

/// One deployed plan's task graph within the engine — rounds spawn lazily
/// as dependencies resolve, bounded by `max_rounds` when set.
struct Epoch {
    /// Pipeline specs resolved in plan order.
    specs: Vec<PipelineSpec>,
    /// Expanded task list per pipeline (one round's worth).
    per_pipeline: Vec<Vec<PlanTask>>,
    /// Prefix offsets of pipelines within one round's id block.
    offset: Vec<usize>,
    /// Total tasks in one round across pipelines.
    per_run: usize,
    /// Global round index of this epoch's local round 0, per pipeline.
    base_round: Vec<usize>,
    /// Highest local round with any *started* task, per pipeline. A
    /// started round may still complete (and record its global index)
    /// while the epoch drains, so the next epoch must start past it.
    max_started_round: Vec<Option<usize>>,
    /// Pending-dependency counts, indexed by task id; grows by rounds.
    pending: Vec<u32>,
    /// Task start times, index-aligned with `pending`.
    start_time: Vec<f64>,
    /// Rounds whose task entries have been allocated.
    spawned_rounds: usize,
    /// Round budget (`None` = run against a time horizon).
    max_rounds: Option<usize>,
    /// Tasks completed in this epoch.
    tasks_done: usize,
    /// Pipeline rounds completed in this epoch.
    rounds_done: usize,
    /// A retired epoch drains in-flight tasks but spawns nothing new.
    retired: bool,
}

impl Epoch {
    fn id(&self, p: usize, s: usize, r: usize) -> usize {
        r * self.per_run + self.offset[p] + s
    }

    fn decode(&self, id: usize) -> (usize, usize, usize) {
        let r = id / self.per_run;
        let rem = id % self.per_run;
        // Binary search the pipeline whose offset block contains rem.
        let p = match self.offset.binary_search(&rem) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (p, rem - self.offset[p], r)
    }

    fn num_pipelines(&self) -> usize {
        self.per_pipeline.len()
    }

    fn num_tasks(&self, p: usize) -> usize {
        self.per_pipeline[p].len()
    }

    /// Allocate pending/start entries for rounds up to and including `r`.
    fn ensure_rounds(&mut self, r: usize, policy: Policy) {
        let n = self.num_pipelines();
        while self.spawned_rounds <= r {
            let rr = self.spawned_rounds;
            for p in 0..n {
                for s in 0..self.num_tasks(p) {
                    self.pending.push(static_deps(policy, n, p, s, rr));
                    self.start_time.push(f64::NAN);
                }
            }
            self.spawned_rounds += 1;
        }
    }
}

#[derive(Default)]
struct Unit {
    busy: bool,
    /// Ready tasks awaiting the unit, as (epoch, task id).
    queue: VecDeque<(usize, usize)>,
}

/// The interruptible, resumable discrete-event engine (see the module
/// docs). Owned by [`crate::api::Session`] for live scenarios; the batch
/// [`simulate`] wrapper drives one bounded epoch to completion.
pub struct SimEngine {
    fleet: Fleet,
    gt: GroundTruth,
    policy: Policy,
    record_trace: bool,
    now: f64,
    /// Latest task completion seen (the makespan so far).
    max_end: f64,
    heap: BinaryHeap<Event>,
    units: BTreeMap<(DeviceId, UnitKind), Unit>,
    /// Energy integration (shared subsystem with the serving engine).
    power: Accountant,
    unit_busy: BTreeMap<(DeviceId, UnitKind), f64>,
    epochs: Vec<Epoch>,
    /// Resolved unit kind per started task, keyed by (epoch, id). A task
    /// must complete on the unit it started on even if the fleet changed
    /// while it was in flight.
    in_flight: BTreeMap<(usize, usize), UnitKind>,
    /// Global round-index continuity across epochs (shared bookkeeping
    /// with the streaming serving engine).
    ledger: EpochLedger,
    records: VecDeque<RoundRecord>,
    spans: VecDeque<TaskSpan>,
    /// Rounds completed over the engine's lifetime — keeps counting when
    /// `record_cap` evicts old records.
    completions_total: usize,
    /// Ring window over retained records (long-session memory bound);
    /// `None` retains everything.
    record_cap: Option<usize>,
    /// Ring window over retained trace spans; `None` retains everything.
    span_cap: Option<usize>,
    /// How simultaneously-ready events are ordered (race exploration).
    same_time: SameTimePolicy,
}

impl SimEngine {
    pub fn new(fleet: Fleet, gt: GroundTruth, policy: Policy, record_trace: bool) -> SimEngine {
        let power = Accountant::new(&fleet);
        SimEngine {
            fleet,
            gt,
            policy,
            record_trace,
            now: 0.0,
            max_end: 0.0,
            heap: BinaryHeap::new(),
            units: BTreeMap::new(),
            power,
            unit_busy: BTreeMap::new(),
            epochs: Vec::new(),
            in_flight: BTreeMap::new(),
            ledger: EpochLedger::new(),
            records: VecDeque::new(),
            spans: VecDeque::new(),
            completions_total: 0,
            record_cap: None,
            span_cap: None,
            same_time: SameTimePolicy::default(),
        }
    }

    /// Set the same-time tie-breaking policy (see
    /// [`crate::analysis::SameTimePolicy`]). The default deterministic
    /// policy reproduces the historical `(epoch, id)` tie order
    /// bit-for-bit; a seeded policy permutes only the order among events
    /// that are ready at the same instant, which any correct schedule must
    /// tolerate.
    pub fn set_same_time(&mut self, policy: SameTimePolicy) {
        self.same_time = policy;
    }

    /// Cap retained [`Self::records`] and trace spans to the most recent
    /// `cap` entries each ([`Self::completions`] keeps counting evicted
    /// rounds). `None` (the default) retains everything.
    pub fn set_record_cap(&mut self, cap: Option<usize>) {
        self.record_cap = cap;
        self.span_cap = cap;
    }

    /// Cap retained trace spans only, leaving [`Self::records`] unbounded
    /// — for drivers (live sessions) that drain records incrementally via
    /// [`Self::take_records`] and aggregate them streamingly.
    pub fn set_span_cap(&mut self, cap: Option<usize>) {
        self.span_cap = cap;
    }

    /// The current simulated time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Latest task completion seen so far.
    pub fn makespan(&self) -> f64 {
        self.max_end
    }

    /// Completed pipeline rounds across all epochs (including any evicted
    /// by [`Self::set_record_cap`]).
    pub fn completions(&self) -> usize {
        self.completions_total
    }

    /// Retained completed rounds, in completion order (all of them unless
    /// a record cap is set).
    pub fn records(&self) -> &VecDeque<RoundRecord> {
        &self.records
    }

    /// Drain the retained completed rounds, leaving the engine's buffer
    /// empty — the streaming-aggregation hook for live sessions
    /// ([`Self::completions`] keeps counting).
    pub fn take_records(&mut self) -> VecDeque<RoundRecord> {
        std::mem::take(&mut self.records)
    }

    /// Busy seconds per (device, unit), cumulative.
    pub fn unit_busy(&self) -> &BTreeMap<(DeviceId, UnitKind), f64> {
        &self.unit_busy
    }

    /// Total energy in joules if the horizon ended at `horizon` seconds.
    pub fn energy_total_j(&self, horizon: f64) -> f64 {
        self.power.energy_total_j(horizon)
    }

    /// One device's energy in joules up to `horizon` (battery ramps).
    pub fn device_energy_j(&self, device: DeviceId, horizon: f64) -> f64 {
        self.power.device_energy_j(device, horizon)
    }

    /// Whether the device is currently on the body (its energy slot is
    /// accruing base power).
    pub fn device_present(&self, device: DeviceId) -> bool {
        self.power.present(device)
    }

    /// Whether the device was on the body at some point and has since
    /// left (distinct from a device the fleet has never contained).
    pub fn device_departed(&self, device: DeviceId) -> bool {
        self.power.departed(device)
    }

    /// The fleet the engine is currently executing against.
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// The recorded trace so far (when constructed with `record_trace`).
    pub fn into_trace(self) -> Option<Trace> {
        if self.record_trace {
            Some(Trace {
                spans: self.spans.into_iter().collect(),
            })
        } else {
            None
        }
    }

    /// Replace the fleet at the current time. Presence intervals close for
    /// departed devices (they stop accruing base power; in-flight tasks
    /// still drain and their active energy still counts) and open for new
    /// or platform-swapped ones. Callers swap the plan right after — the
    /// retiring plan may reference departed devices.
    pub fn set_fleet(&mut self, fleet: Fleet) {
        self.power.apply_fleet(&self.fleet, &fleet, self.now);
        self.fleet = fleet;
    }

    /// Retire the current epoch: queued-but-unstarted tasks are dropped,
    /// in-flight tasks drain gracefully, no new rounds spawn.
    pub fn clear_plan(&mut self) {
        let Some(retiring) = self.epochs.len().checked_sub(1) else {
            return;
        };
        if self.epochs[retiring].retired {
            return;
        }
        self.epochs[retiring].retired = true;
        // Round-index continuity: every round that *started* may still
        // complete during the drain and record its global index, so the
        // next epoch's base must land past it (completed-round tracking
        // alone would let a draining round collide with the new epoch's
        // round 0).
        let ep = &self.epochs[retiring];
        for (p, started) in ep.max_started_round.iter().enumerate() {
            if let Some(r) = *started {
                self.ledger.note_round(ep.specs[p].id, ep.base_round[p] + r);
            }
        }
        for unit in self.units.values_mut() {
            unit.queue.retain(|&(e, _)| e != retiring);
        }
    }

    /// Deploy a plan at the current time as a new epoch (retiring any
    /// current one). With `max_rounds = Some(m)` the epoch executes
    /// exactly `m` rounds per pipeline (batch mode); with `None` rounds
    /// spawn indefinitely and execution is bounded by [`Self::run_until`]
    /// horizons.
    ///
    /// Fails with [`AnalysisError::UnknownPipeline`] when the plan
    /// references a pipeline absent from `pipelines` — the current epoch
    /// is still retired in that case (the engine never half-deploys).
    pub fn set_plan(
        &mut self,
        plan: &CollabPlan,
        pipelines: &[PipelineSpec],
        max_rounds: Option<usize>,
    ) -> Result<(), AnalysisError> {
        self.clear_plan();
        if plan.plans.is_empty() {
            return Ok(());
        }
        let specs: Vec<PipelineSpec> = plan
            .plans
            .iter()
            .map(|ep| {
                pipelines
                    .iter()
                    .find(|p| p.id == ep.pipeline)
                    .cloned()
                    .ok_or(AnalysisError::UnknownPipeline { pipeline: ep.pipeline })
            })
            .collect::<Result<_, _>>()?;
        let per_pipeline: Vec<Vec<PlanTask>> = plan
            .plans
            .iter()
            .zip(&specs)
            .map(|(ep, spec)| ep.tasks(&spec.model))
            .collect();
        let mut offset = Vec::with_capacity(per_pipeline.len());
        let mut acc = 0;
        for tl in &per_pipeline {
            offset.push(acc);
            acc += tl.len();
        }
        let base_round: Vec<usize> = specs.iter().map(|s| self.ledger.base_round(s.id)).collect();
        let n = specs.len();
        let mut epoch = Epoch {
            specs,
            per_pipeline,
            offset,
            per_run: acc,
            base_round,
            max_started_round: vec![None; n],
            pending: Vec::new(),
            start_time: Vec::new(),
            spawned_rounds: 0,
            max_rounds,
            tasks_done: 0,
            rounds_done: 0,
            retired: false,
        };
        epoch.ensure_rounds(0, self.policy);
        let e = self.epochs.len();
        // Seed: all zero-dependency tasks of round 0 ready now.
        for (id, &deps) in epoch.pending.iter().enumerate() {
            if deps == 0 {
                self.heap.push(Event {
                    time: self.now,
                    kind: EventKind::Ready,
                    tie: self.same_time.tie(e, id),
                    epoch: e,
                    id,
                });
            }
        }
        self.epochs.push(epoch);
        Ok(())
    }

    /// Start task (epoch e, id) on unit `key` at time `t`.
    fn start_task(&mut self, e: usize, id: usize, key: (DeviceId, UnitKind), t: f64) {
        let ep = &mut self.epochs[e];
        let (p, s, r) = ep.decode(id);
        let task = ep.per_pipeline[p][s];
        let sensor = crate::estimator::LatencyModel::source_sensor(&ep.specs[p]);
        let global_run = ep.base_round[p] + r;
        let dur = self
            .gt
            .duration(&self.fleet, &task, &ep.specs[p].model, sensor, global_run);
        ep.start_time[id] = t;
        ep.max_started_round[p] = Some(ep.max_started_round[p].map_or(r, |m| m.max(r)));
        self.in_flight.insert((e, id), key.1);
        self.heap.push(Event {
            time: t + dur,
            kind: EventKind::Done,
            tie: self.same_time.tie(e, id),
            epoch: e,
            id,
        });
    }

    /// Decrement the pending count of (p, s, r) in the current epoch,
    /// readying it at time `t` when it hits zero.
    fn notify(&mut self, e: usize, p: usize, s: usize, r: usize, t: f64) {
        let policy = self.policy;
        let ep = &mut self.epochs[e];
        ep.ensure_rounds(r, policy);
        let id = ep.id(p, s, r);
        ep.pending[id] -= 1;
        if ep.pending[id] == 0 {
            self.heap.push(Event {
                time: t,
                kind: EventKind::Ready,
                tie: self.same_time.tie(e, id),
                epoch: e,
                id,
            });
        }
    }

    /// Advance the simulation to `horizon`, processing every event at or
    /// before it. Pass `f64::INFINITY` to drain a bounded epoch to
    /// completion.
    ///
    /// Panics with a `DES deadlock` diagnostic when the event heap empties
    /// while the live epoch still has unmet work — a cyclic or missing
    /// dependency would otherwise silently freeze the timeline.
    pub fn run_until(&mut self, horizon: f64) {
        while let Some(&ev) = self.heap.peek() {
            if ev.time > horizon {
                break;
            }
            self.heap.pop();
            self.now = self.now.max(ev.time);
            match ev.kind {
                EventKind::Ready => self.on_ready(ev),
                EventKind::Done => self.on_done(ev),
            }
        }
        if horizon.is_finite() {
            self.now = self.now.max(horizon);
        }
        self.check_stall();
    }

    fn on_ready(&mut self, ev: Event) {
        if self.epochs[ev.epoch].retired {
            // A same-timestamp replan retired this epoch before its seeded
            // tasks ran; they never start.
            return;
        }
        let (p, s, _r) = self.epochs[ev.epoch].decode(ev.id);
        let task = self.epochs[ev.epoch].per_pipeline[p][s];
        let key = (task.device, GroundTruth::unit_of(&self.fleet, &task));
        let next = {
            let unit = self.units.entry(key).or_default();
            unit.queue.push_back((ev.epoch, ev.id));
            if !unit.busy {
                unit.busy = true;
                unit.queue.pop_front()
            } else {
                None
            }
        };
        if let Some((e, id)) = next {
            self.start_task(e, id, key, ev.time);
        }
    }

    fn on_done(&mut self, ev: Event) {
        let unit_kind = self
            .in_flight
            .remove(&(ev.epoch, ev.id))
            .expect("Done for a task that never started");
        let (p, s, r) = self.epochs[ev.epoch].decode(ev.id);
        let task = self.epochs[ev.epoch].per_pipeline[p][s];
        let key = (task.device, unit_kind);
        let start = self.epochs[ev.epoch].start_time[ev.id];
        let dur = ev.time - start;
        self.max_end = self.max_end.max(ev.time);
        *self.unit_busy.entry(key).or_insert(0.0) += dur;
        self.power
            .record(task.device, busy_kind(task.kind, unit_kind), dur);
        let global_run = self.epochs[ev.epoch].base_round[p] + r;
        if self.record_trace {
            self.spans.push_back(TaskSpan {
                pipeline: self.epochs[ev.epoch].specs[p].id.0,
                seq: s,
                run: global_run,
                device: task.device,
                unit: unit_kind,
                kind: task.kind,
                start,
                end: ev.time,
            });
            if let Some(cap) = self.span_cap {
                while self.spans.len() > cap {
                    self.spans.pop_front();
                }
            }
        }

        let ep = &mut self.epochs[ev.epoch];
        ep.tasks_done += 1;
        let last = ep.num_tasks(p) - 1;
        let n = ep.num_pipelines();
        if s == last {
            ep.rounds_done += 1;
            let round_start = ep.start_time[ep.id(p, 0, r)];
            let pipeline = ep.specs[p].id;
            self.records.push_back(RoundRecord {
                pipeline,
                run: global_run,
                start: round_start,
                end: ev.time,
            });
            self.completions_total += 1;
            if let Some(cap) = self.record_cap {
                while self.records.len() > cap {
                    self.records.pop_front();
                }
            }
            self.ledger.note_round(pipeline, global_run);
        }

        // Successor bookkeeping — retired epochs spawn nothing new.
        if !self.epochs[ev.epoch].retired {
            let max_rounds = self.epochs[ev.epoch].max_rounds;
            let allows = move |rr: usize| match max_rounds {
                Some(m) => rr < m,
                None => true,
            };
            if s < last {
                self.notify(ev.epoch, p, s + 1, r, ev.time);
            }
            if s == last {
                match self.policy {
                    Policy::Sequential => {
                        if p + 1 < n {
                            self.notify(ev.epoch, p + 1, 0, r, ev.time);
                        } else if allows(r + 1) {
                            self.notify(ev.epoch, 0, 0, r + 1, ev.time);
                        }
                    }
                    Policy::InterPipeline => {
                        if allows(r + 1) {
                            for q in 0..n {
                                self.notify(ev.epoch, q, 0, r + 1, ev.time);
                            }
                        }
                    }
                    Policy::Atp { max_inflight } => {
                        if allows(r + max_inflight) {
                            self.notify(ev.epoch, p, 0, r + max_inflight, ev.time);
                        }
                    }
                }
            }
            if s == 0 {
                if let Policy::Atp { .. } = self.policy {
                    if allows(r + 1) {
                        self.notify(ev.epoch, p, 0, r + 1, ev.time);
                    }
                }
            }
        }

        // Unit takes its next queued task (possibly from a newer epoch —
        // that is exactly how a plan switch drains).
        let next = {
            let unit = self.units.get_mut(&key).unwrap();
            match unit.queue.pop_front() {
                Some(entry) => Some(entry),
                None => {
                    unit.busy = false;
                    None
                }
            }
        };
        if let Some((e, id)) = next {
            self.start_task(e, id, key, ev.time);
        }
    }

    /// Detect a permanently stalled live epoch: an empty heap means no
    /// event will ever fire again, so unmet work is a dependency bug, not
    /// a pause.
    fn check_stall(&self) {
        if !self.heap.is_empty() {
            return;
        }
        let Some(ep) = self.epochs.last() else { return };
        if ep.retired {
            return;
        }
        let n = ep.num_pipelines();
        let complete = match ep.max_rounds {
            Some(m) => ep.rounds_done >= n * m,
            // An unbounded epoch always has a next round to run.
            None => false,
        };
        if !complete {
            let expected = ep
                .max_rounds
                .map(|m| (n * m).to_string())
                .unwrap_or_else(|| "unbounded".into());
            let spawned = ep.spawned_rounds * ep.per_run;
            let unfinished = spawned - ep.tasks_done;
            let never_ready = ep.pending.iter().filter(|&&d| d > 0).count();
            panic!(
                "DES deadlock: {}/{} pipeline runs completed ({unfinished} of \
                 {spawned} spawned tasks never finished, {never_ready} still \
                 have unmet dependencies) — cyclic or missing dependency under \
                 policy {:?}",
                ep.rounds_done, expected, self.policy,
            );
        }
    }
}

/// Run one plan for a fixed number of rounds and measure it — the batch
/// entry point, now a thin wrapper over one bounded [`SimEngine`] epoch.
pub fn simulate(
    plan: &CollabPlan,
    pipelines: &[PipelineSpec],
    fleet: &Fleet,
    gt: &GroundTruth,
    cfg: SimConfig,
) -> SimReport {
    assert!(cfg.runs > cfg.warmup, "need runs > warmup");
    let n = plan.plans.len();
    assert!(n > 0, "empty plan");

    let mut engine = SimEngine::new(fleet.clone(), gt.clone(), cfg.policy, cfg.record_trace);
    engine
        .set_plan(plan, pipelines, Some(cfg.runs))
        .expect("plan for unknown pipeline");
    engine.run_until(f64::INFINITY);

    // Round (start, end) matrices in plan order. Every round completed
    // (the engine would have panicked on a deadlock otherwise).
    let mut start_of = vec![vec![f64::NAN; cfg.runs]; n];
    let mut end_of = vec![vec![f64::NAN; cfg.runs]; n];
    for rec in engine.records() {
        let p = plan
            .plans
            .iter()
            .position(|ep| ep.pipeline == rec.pipeline)
            .expect("record for unknown pipeline");
        start_of[p][rec.run] = rec.start;
        end_of[p][rec.run] = rec.end;
    }

    let makespan = engine.makespan();

    // Round completion times: round r done when all pipelines' run r done.
    let round_done: Vec<f64> = (0..cfg.runs)
        .map(|r| (0..n).map(|p| end_of[p][r]).fold(0.0, f64::max))
        .collect();
    let t0 = if cfg.warmup == 0 {
        0.0
    } else {
        round_done[cfg.warmup - 1]
    };
    let measured_rounds = cfg.runs - cfg.warmup;
    let throughput = (n * measured_rounds) as f64 / (round_done[cfg.runs - 1] - t0).max(1e-12);

    // Mean end-to-end latency over measured runs.
    let mut lat_sum = 0.0;
    let mut lat_cnt = 0usize;
    for r in cfg.warmup..cfg.runs {
        for p in 0..n {
            lat_sum += end_of[p][r] - start_of[p][r];
            lat_cnt += 1;
        }
    }
    let avg_latency = lat_sum / lat_cnt as f64;

    // Energy over the whole horizon.
    let energy_j = engine.energy_total_j(makespan);
    let power_w = energy_j / makespan.max(1e-12);
    let completions = engine.completions();
    let unit_busy = engine.unit_busy().clone();
    let trace = engine.into_trace();

    SimReport {
        makespan,
        throughput,
        avg_latency,
        power_w,
        energy_j,
        completions,
        unit_busy,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceKind};
    use crate::model::layer::{Layer, LayerKind, Shape};
    use crate::model::ModelGraph;
    use crate::pipeline::{SourceReq, TargetReq};
    use crate::plan::exec_plan::{Assignment, ExecutionPlan};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(
            (0..n)
                .map(|i| Device::new(i, format!("d{i}"), DeviceKind::Max78000, vec![], vec![]))
                .collect(),
        )
    }

    fn model(layers: usize) -> ModelGraph {
        ModelGraph::new(
            "m",
            Shape::new(16, 16, 3),
            (0..layers)
                .map(|_| Layer { kind: LayerKind::Conv2d { k: 3 }, pool: 1, cout: 8, residual: false, has_bias: true })
                .collect(),
        )
    }

    fn pipes(n: usize) -> Vec<PipelineSpec> {
        (0..n)
            .map(|i| {
                PipelineSpec::new(i, format!("p{i}"), SourceReq::Any, model(2), TargetReq::Any)
            })
            .collect()
    }

    fn plan_spread(ps: &[PipelineSpec], ndev: usize) -> CollabPlan {
        CollabPlan::new(
            ps.iter()
                .enumerate()
                .map(|(i, p)| {
                    let d = DeviceId(i % ndev);
                    ExecutionPlan::monolithic(p, d, d, d)
                })
                .collect(),
        )
    }

    fn cfg(policy: Policy) -> SimConfig {
        SimConfig { runs: 12, warmup: 2, policy, record_trace: true }
    }

    #[test]
    fn all_tasks_complete_and_trace_is_sound() {
        let f = fleet(2);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        for policy in [Policy::Sequential, Policy::InterPipeline, Policy::atp()] {
            let rep = simulate(&plan, &ps, &f, &gt, cfg(policy));
            assert_eq!(rep.completions, 3 * 12, "{policy:?}");
            let trace = rep.trace.unwrap();
            trace.check_unit_exclusivity().unwrap();
            trace.check_causality().unwrap();
            assert!(rep.makespan > 0.0);
            assert!(rep.throughput > 0.0);
        }
    }

    #[test]
    fn parallel_policies_dominate_sequential() {
        let f = fleet(3);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 3);
        let gt = GroundTruth::default();
        let seq = simulate(&plan, &ps, &f, &gt, cfg(Policy::Sequential));
        let ipl = simulate(&plan, &ps, &f, &gt, cfg(Policy::InterPipeline));
        let atp = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        // Independent pipelines on separate devices: inter-pipeline overlap
        // is a ~3× win; ATP at least matches it.
        assert!(
            ipl.throughput > 2.0 * seq.throughput,
            "seq {} ipl {}",
            seq.throughput,
            ipl.throughput
        );
        assert!(atp.throughput >= ipl.throughput * 0.95);
        // Sequential's per-run latency is no better (same chain).
        assert!(ipl.avg_latency <= seq.avg_latency * 1.05);
    }

    #[test]
    fn inter_run_overlap_helps_split_pipelines() {
        // One pipeline split across two devices: inter-run parallelization
        // keeps both accelerators busy; the barrier policies cannot.
        let f = fleet(2);
        let m = model(4);
        let ps = vec![PipelineSpec::new(0, "p", SourceReq::Any, m.clone(), TargetReq::Any)];
        let plan = CollabPlan::new(vec![ExecutionPlan {
            pipeline: ps[0].id,
            source_dev: DeviceId(0),
            target_dev: DeviceId(1),
            chunks: vec![
                Assignment { device: DeviceId(0), range: crate::model::SplitRange::new(0, 2) },
                Assignment { device: DeviceId(1), range: crate::model::SplitRange::new(2, 4) },
            ],
        }]);
        let gt = GroundTruth::default();
        let ipl = simulate(&plan, &ps, &f, &gt, cfg(Policy::InterPipeline));
        let atp = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        assert!(
            atp.throughput > 1.2 * ipl.throughput,
            "ipl {} atp {}",
            ipl.throughput,
            atp.throughput
        );
    }

    #[test]
    fn sequential_round_latency_matches_chain_sum() {
        // With one pipeline on one device, throughput ≈ 1 / chain latency
        // regardless of policy.
        let f = fleet(1);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let gt = GroundTruth::default();
        let rep = simulate(&plan, &ps, &f, &gt, cfg(Policy::Sequential));
        let expect = 1.0 / rep.avg_latency;
        let err = (rep.throughput - expect).abs() / expect;
        assert!(err < 0.05, "tput {} vs 1/lat {}", rep.throughput, expect);
    }

    #[test]
    fn energy_exceeds_base_and_scales_with_makespan() {
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let rep = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        let base_power: f64 = f.devices.iter().map(|d| d.spec.power.base_w).sum();
        assert!(rep.power_w > base_power);
        assert!(rep.energy_j > base_power * rep.makespan * 0.99);
    }

    #[test]
    fn deterministic_across_calls() {
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let a = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        let b = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.throughput, b.throughput);
        assert_eq!(a.energy_j, b.energy_j);
    }

    #[test]
    #[should_panic(expected = "DES deadlock")]
    fn deadlock_panics_in_every_profile_instead_of_returning_nans() {
        // A zero in-flight window wires each run's first task to wait on
        // its own run's last task — a dependency cycle, so nothing ever
        // becomes ready. Regression: this check was a `debug_assert!`, so
        // release builds returned NaN-poisoned makespan/throughput instead
        // of failing; it must now panic with a diagnostic in all profiles.
        let f = fleet(1);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        simulate(
            &plan,
            &ps,
            &f,
            &GroundTruth::default(),
            SimConfig {
                runs: 4,
                warmup: 1,
                policy: Policy::Atp { max_inflight: 0 },
                record_trace: false,
            },
        );
    }

    #[test]
    fn stepped_run_until_matches_batch_execution() {
        // Interrupting and resuming the engine must not change the
        // schedule: run the same bounded epoch in many small horizons and
        // compare every completed round against the one-shot wrapper.
        let f = fleet(2);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let rep = simulate(&plan, &ps, &f, &gt, cfg(Policy::atp()));

        let mut eng = SimEngine::new(f.clone(), gt.clone(), Policy::atp(), false);
        eng.set_plan(&plan, &ps, Some(12)).unwrap();
        let step = rep.makespan / 17.0;
        let mut t = 0.0;
        while t < rep.makespan {
            t += step;
            eng.run_until(t);
        }
        eng.run_until(f64::INFINITY);
        assert_eq!(eng.completions(), rep.completions);
        assert_eq!(eng.makespan(), rep.makespan);
        assert_eq!(eng.energy_total_j(eng.makespan()), rep.energy_j);
    }

    #[test]
    fn plan_switch_drains_in_flight_and_keeps_the_clock() {
        // Two pipelines on two devices; mid-run the plan shrinks to one
        // pipeline. The engine must not restart: the clock stays
        // monotonic, rounds from both epochs appear in the records, the
        // trace stays sound across the switch, and per-pipeline global
        // round indices keep counting.
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let mut eng = SimEngine::new(f.clone(), gt.clone(), Policy::atp(), true);
        eng.set_plan(&plan, &ps, None).unwrap();
        eng.run_until(0.5);
        let pre = eng.completions();
        assert!(pre > 0, "no rounds before the switch");
        let t_switch = eng.now();

        let solo = CollabPlan::new(vec![plan.plans[0].clone()]);
        eng.set_plan(&solo, &ps[..1], None).unwrap();
        eng.run_until(1.0);
        let records: Vec<RoundRecord> = eng.records().iter().copied().collect();
        assert!(eng.completions() > pre, "no rounds after the switch");
        // Only pipeline 0 completes rounds after the switch settles, and
        // its global run index never repeats.
        let p0: Vec<usize> = records
            .iter()
            .filter(|r| r.pipeline == PipelineId(0))
            .map(|r| r.run)
            .collect();
        let mut sorted = p0.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), p0.len(), "global run indices must not repeat");
        // Clock monotonicity: no record ends before a record that
        // completed before the switch started.
        assert!(records.iter().all(|r| r.end <= eng.makespan() + 1e-12));
        assert!(eng.now() >= t_switch);
        let trace = eng.into_trace().unwrap();
        trace.check_unit_exclusivity().unwrap();
        trace.check_causality().unwrap();
    }

    #[test]
    fn record_cap_bounds_retained_records_but_not_the_count() {
        let f = fleet(1);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let gt = GroundTruth::default();
        let mut eng = SimEngine::new(f, gt, Policy::atp(), true);
        eng.set_record_cap(Some(5));
        eng.set_plan(&plan, &ps, Some(20)).unwrap();
        eng.run_until(f64::INFINITY);
        assert_eq!(eng.completions(), 20, "the counter must see every round");
        assert_eq!(eng.records().len(), 5, "the ring must evict old records");
        assert!(eng.records().iter().all(|r| r.run >= 15));
        let trace = eng.into_trace().unwrap();
        assert!(trace.spans.len() <= 5, "spans ride the same window");
    }

    /// Bit-parity pin for the `power::Accountant` extraction: on every
    /// canned Table I workload, `simulate()`'s `energy_j` must equal —
    /// to the last bit — the legacy closed-form
    /// `Σ_d BusyTimes_d.energy_j(power_d, makespan)` with the busy times
    /// re-accumulated from the trace in completion order (the exact
    /// arithmetic the pre-`power/` per-device slots performed).
    #[test]
    fn energy_accounting_matches_closed_form_on_all_canned_workloads() {
        use crate::device::power::BusyTimes;
        use crate::orchestrator::{Planner, Synergy};
        let fleet = crate::workload::fleet4();
        let planner = Synergy::planner();
        for w in crate::workload::all_workloads() {
            let plan = planner.plan(&w.pipelines, &fleet).unwrap();
            let rep = simulate(
                &plan,
                &w.pipelines,
                &fleet,
                &GroundTruth::with_seed(7),
                SimConfig {
                    runs: 12,
                    warmup: 2,
                    policy: planner.exec_policy(),
                    record_trace: true,
                },
            );
            let trace = rep.trace.as_ref().unwrap();
            let mut busy = vec![BusyTimes::default(); fleet.len()];
            for s in &trace.spans {
                let b = &mut busy[s.device.0];
                let dur = s.end - s.start;
                match busy_kind(s.kind, s.unit) {
                    crate::power::BusyKind::Sensor => b.sensor_s += dur,
                    crate::power::BusyKind::Cpu => b.cpu_s += dur,
                    crate::power::BusyKind::Accel => b.accel_s += dur,
                    crate::power::BusyKind::RadioTx => b.radio_tx_s += dur,
                    crate::power::BusyKind::RadioRx => b.radio_rx_s += dur,
                }
            }
            let mut expect = 0.0;
            for (b, d) in busy.iter().zip(&fleet.devices) {
                expect += b.energy_j(&d.spec.power, rep.makespan);
            }
            assert_eq!(
                rep.energy_j.to_bits(),
                expect.to_bits(),
                "{}: {} vs {expect}",
                w.name,
                rep.energy_j
            );
            assert!(rep.energy_j > 0.0);
        }
    }

    #[test]
    fn fleet_shrink_mid_session_stops_base_power_accrual() {
        let f = fleet(2);
        let ps = pipes(1);
        let plan = plan_spread(&ps, 1);
        let gt = GroundTruth::default();
        let mut eng = SimEngine::new(f.clone(), gt.clone(), Policy::atp(), false);
        eng.set_plan(&plan, &ps, None).unwrap();
        eng.run_until(1.0);
        // Device 1 (idle) leaves at t=1; its base energy must freeze.
        let d1_at_leave = eng.device_energy_j(DeviceId(1), 1.0);
        eng.set_fleet(fleet(1));
        eng.set_plan(&plan, &ps, None).unwrap();
        eng.run_until(2.0);
        let d1_later = eng.device_energy_j(DeviceId(1), 2.0);
        assert!(
            (d1_later - d1_at_leave).abs() < 1e-9,
            "departed device kept accruing: {d1_at_leave} -> {d1_later}"
        );
        // Device 0 keeps accruing.
        let d0 = eng.device_energy_j(DeviceId(0), 2.0);
        assert!(d0 > eng.device_energy_j(DeviceId(0), 1.0));
    }

    #[test]
    fn set_plan_for_unknown_pipeline_is_a_typed_error() {
        // Regression: this used to panic via `expect` inside the engine.
        let f = fleet(1);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 1);
        let gt = GroundTruth::default();
        let mut eng = SimEngine::new(f, gt, Policy::atp(), false);
        let err = eng.set_plan(&plan, &ps[..1], None).unwrap_err();
        assert!(matches!(
            err,
            crate::analysis::AnalysisError::UnknownPipeline { pipeline: PipelineId(1) }
        ));
    }

    #[test]
    fn randomized_same_time_keeps_round_conservation_and_trace_soundness() {
        // Permuting same-time tie order must never lose or duplicate
        // rounds, overlap a unit, or break causality — only reorder work
        // among simultaneously-ready tasks.
        let f = fleet(2);
        let ps = pipes(3);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        for seed in 0..8u64 {
            let mut eng = SimEngine::new(f.clone(), gt.clone(), Policy::atp(), true);
            eng.set_same_time(SameTimePolicy::Randomized { seed });
            eng.set_plan(&plan, &ps, Some(12)).unwrap();
            eng.run_until(f64::INFINITY);
            assert_eq!(eng.completions(), 3 * 12, "seed {seed}");
            let trace = eng.into_trace().unwrap();
            trace.check_unit_exclusivity().unwrap();
            trace.check_causality().unwrap();
        }
    }

    #[test]
    fn randomized_same_time_is_deterministic_per_seed() {
        let f = fleet(2);
        let ps = pipes(2);
        let plan = plan_spread(&ps, 2);
        let gt = GroundTruth::default();
        let run = |seed: u64| {
            let mut eng = SimEngine::new(f.clone(), gt.clone(), Policy::atp(), false);
            eng.set_same_time(SameTimePolicy::Randomized { seed });
            eng.set_plan(&plan, &ps, Some(12)).unwrap();
            eng.run_until(f64::INFINITY);
            (eng.makespan().to_bits(), eng.energy_total_j(eng.makespan()).to_bits())
        };
        assert_eq!(run(7), run(7), "same seed must replay bit-identically");
    }
}
