//! Execution policies (Fig. 12).
//!
//! - [`Policy::Sequential`] — the conventional approach (Fig. 12a): the
//!   unified round executes pipelines strictly one after another, rounds
//!   back-to-back. Computation units idle whenever "their" task type is
//!   not the current one.
//! - [`Policy::InterPipeline`] — Fig. 12b: tasks of *different pipelines*
//!   overlap within a round (per-unit queues), with a barrier between
//!   rounds.
//! - [`Policy::Atp`] — Fig. 12c: adds *inter-run* parallelization; run
//!   `r+1` may begin while run `r` is still in flight (bounded by
//!   `max_inflight` — double-buffering by default), so the steady-state
//!   round period approaches the bottleneck unit's busy time.

/// Scheduling policy for executing a holistic collaboration plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Sequential,
    InterPipeline,
    Atp { max_inflight: usize },
}

impl Policy {
    /// The paper's adaptive task parallelization with double-buffered
    /// inter-run overlap.
    pub fn atp() -> Policy {
        Policy::Atp { max_inflight: 2 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Sequential => "sequential",
            Policy::InterPipeline => "inter-pipeline",
            Policy::Atp { .. } => "atp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atp_default_is_double_buffered() {
        assert_eq!(Policy::atp(), Policy::Atp { max_inflight: 2 });
        assert_eq!(Policy::atp().name(), "atp");
    }
}
