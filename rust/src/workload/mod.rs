//! Evaluation workloads (§VI-A, Table I) and device fleets.
//!
//! Four workloads over eight pipelines/models on four MAX78000-class
//! wearables (earbud, glasses, watch, ring): Workloads 1–2 are concurrent
//! multi-app scenarios (three pipelines each); Workloads 3–4 are single
//! large-model apps (EfficientNetV2 / MobileNetV2) that exceed a single
//! accelerator and must be split.

use crate::api::{Qos, RuntimeError, Scenario};
use crate::device::{Device, DeviceId, DeviceKind, Fleet, InteractionKind, SensorKind};
use crate::model::zoo::{model_by_name, ModelName};
use crate::pipeline::{PipelineId, PipelineSpec, SourceReq, TargetReq};

pub mod sample;

pub use sample::{sample_user, FleetMix, SampledUser, SAMPLE_HORIZON};

/// A named set of concurrent pipelines.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub pipelines: Vec<PipelineSpec>,
}

/// How source/target devices map onto the fleet (Fig. 18).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EndpointMapping {
    /// Any device can be source or target (`D²` options per pipeline).
    Any,
    /// Endpoints spread evenly across devices — the Workload 1 default.
    Distributed,
    /// One device serves as both source and target for all pipelines.
    Overlapped,
}

/// The standard four-wearable fleet: earbud, glasses, watch, ring.
pub fn fleet4() -> Fleet {
    fleet_of(&[DeviceKind::Max78000; 4])
}

/// A fleet of `n` MAX78000 wearables (Fig. 16a varies n from 2 to 5).
pub fn fleet_n(n: usize) -> Fleet {
    fleet_of(&vec![DeviceKind::Max78000; n])
}

/// Heterogeneous fleet: the watch upgraded to a MAX78002 (Fig. 17).
pub fn fleet4_hetero() -> Fleet {
    fleet_of(&[
        DeviceKind::Max78000,
        DeviceKind::Max78000,
        DeviceKind::Max78002,
        DeviceKind::Max78000,
    ])
}

/// An eight-wearable fleet (two full earbud/glasses/watch/ring bands) —
/// the smallest fleet on which exhaustive plan enumeration stops being
/// tractable (KWS alone has >3M split skeletons; see
/// [`crate::plan::skeleton_space`]). Pair with
/// [`crate::plan::SearchMode::Bounded`].
pub fn fleet8() -> Fleet {
    fleet_of(&[DeviceKind::Max78000; 8])
}

/// A twelve-device heterogeneous fleet: three on-body bands where every
/// third wearable is upgraded to a MAX78002 — the large-fleet stress
/// scenario for bounded planning over mixed platforms.
pub fn fleet12_hetero() -> Fleet {
    let kinds: Vec<DeviceKind> = (0..12)
        .map(|i| {
            if i % 3 == 2 {
                DeviceKind::Max78002
            } else {
                DeviceKind::Max78000
            }
        })
        .collect();
    fleet_of(&kinds)
}

/// The standard fleet plus a smartphone (the §II-B offloading comparison).
pub fn fleet4_with_phone() -> Fleet {
    let mut kinds = vec![DeviceKind::Max78000; 4];
    kinds.push(DeviceKind::Phone);
    fleet_of(&kinds)
}

/// Build a fleet with on-body roles cycling earbud/glasses/watch/ring.
pub fn fleet_of(kinds: &[DeviceKind]) -> Fleet {
    let roles: [(&str, Vec<SensorKind>, Vec<InteractionKind>); 4] = [
        (
            "earbud",
            vec![SensorKind::Microphone],
            vec![InteractionKind::Audio],
        ),
        (
            "glasses",
            vec![SensorKind::Camera],
            vec![InteractionKind::Display],
        ),
        (
            "watch",
            vec![SensorKind::Imu, SensorKind::Ppg, SensorKind::Microphone],
            vec![InteractionKind::Display, InteractionKind::Haptic],
        ),
        (
            "ring",
            vec![SensorKind::Ppg],
            vec![InteractionKind::Haptic, InteractionKind::Led],
        ),
    ];
    Fleet::new(
        kinds
            .iter()
            .enumerate()
            .map(|(i, &kind)| {
                if kind == DeviceKind::Phone {
                    return Device::new(i, "phone", kind, vec![], vec![]);
                }
                let (role, sensors, acts) = &roles[i % roles.len()];
                let name = if i < roles.len() {
                    role.to_string()
                } else {
                    format!("{role}{}", i / roles.len() + 1)
                };
                Device::new(i, name, kind, sensors.clone(), acts.clone())
            })
            .collect(),
    )
}

/// The sensor kind each Table I pipeline reads.
pub fn sensor_for(model: ModelName) -> SensorKind {
    match model {
        ModelName::KWS => SensorKind::Microphone,
        ModelName::ConvNet5 => SensorKind::Imu,
        _ => SensorKind::Camera,
    }
}

/// Build a pipeline for a Table I model with designated endpoints.
pub fn pipeline(id: usize, model: ModelName, source: usize, target: usize) -> PipelineSpec {
    PipelineSpec::new(
        id,
        model.as_str(),
        SourceReq::Device(DeviceId(source)),
        model_by_name(model).clone(),
        TargetReq::Device(DeviceId(target)),
    )
}

/// Pipelines with a chosen endpoint mapping over `n` devices (Fig. 18).
pub fn pipelines_with_mapping(
    models: &[ModelName],
    mapping: EndpointMapping,
    n_devices: usize,
) -> Vec<PipelineSpec> {
    models
        .iter()
        .enumerate()
        .map(|(i, &m)| match mapping {
            EndpointMapping::Any => PipelineSpec::new(
                i,
                m.as_str(),
                SourceReq::Any,
                model_by_name(m).clone(),
                TargetReq::Any,
            ),
            EndpointMapping::Distributed => pipeline(i, m, i % n_devices, (i + 1) % n_devices),
            EndpointMapping::Overlapped => pipeline(i, m, 0, 0),
        })
        .collect()
}

/// Ids of the Table I workloads.
pub const WORKLOAD_IDS: std::ops::RangeInclusive<usize> = 1..=4;

/// Human-readable list of valid workload ids/names (error messages, CLI).
pub fn workload_names() -> String {
    WORKLOAD_IDS
        .map(|id| format!("{id} (Workload {id})"))
        .collect::<Vec<_>>()
        .join(", ")
}

/// Table I workload definitions (1-based ids, matching the paper).
///
/// An unknown id is a typed [`RuntimeError::UnknownWorkload`] naming the
/// valid workloads — the seed's hard `panic!` took the whole CLI down on a
/// `--workload 9` typo.
pub fn workload(id: usize) -> Result<Workload, RuntimeError> {
    // Endpoint assignments follow §VI-A/Fig. 14: Workload 1's endpoints
    // are the Distributed mapping (per §VI-C3); pipeline 4 (KWS) captures
    // on the earbud (d0) and alerts the ring (d3); pipeline 8
    // (MobileNetV2) captures on the glasses (d1) and alerts the ring (d3).
    match id {
        1 => Ok(Workload {
            name: "Workload 1".into(),
            pipelines: vec![
                pipeline(0, ModelName::ConvNet5, 0, 1),
                pipeline(1, ModelName::ResSimpleNet, 1, 2),
                pipeline(2, ModelName::UNet, 2, 3),
            ],
        }),
        2 => Ok(Workload {
            name: "Workload 2".into(),
            pipelines: vec![
                pipeline(0, ModelName::KWS, 0, 3),
                pipeline(1, ModelName::SimpleNet, 1, 2),
                pipeline(2, ModelName::WideNet, 2, 0),
            ],
        }),
        3 => Ok(Workload {
            name: "Workload 3".into(),
            pipelines: vec![pipeline(0, ModelName::EfficientNetV2, 1, 3)],
        }),
        4 => Ok(Workload {
            name: "Workload 4".into(),
            pipelines: vec![pipeline(0, ModelName::MobileNetV2, 1, 3)],
        }),
        other => Err(RuntimeError::UnknownWorkload {
            id: other,
            valid: workload_names(),
        }),
    }
}

/// All four workloads.
pub fn all_workloads() -> Vec<Workload> {
    WORKLOAD_IDS
        .map(|id| workload(id).expect("Table I workload"))
        .collect()
}

/// The mixed workload: all eight Table I models running concurrently,
/// endpoints distributed across `n_devices` — the large-fleet stress
/// scenario (run it on [`fleet8`] / [`fleet12_hetero`] with bounded
/// search).
pub fn workload_mixed8(n_devices: usize) -> Workload {
    Workload {
        name: "Mixed-8".into(),
        pipelines: pipelines_with_mapping(
            &ModelName::TABLE1,
            EndpointMapping::Distributed,
            n_devices,
        ),
    }
}

/// A named fleet + scenario pair for the live-session API (the
/// `synergy scenario` subcommand, examples, benches).
#[derive(Clone, Debug)]
pub struct CannedScenario {
    pub name: &'static str,
    pub fleet: Fleet,
    pub scenario: Scenario,
}

/// The jog fleet: the standard four wearables with the *watch last*
/// (device ids are dense, so only the highest id can drop off mid-run —
/// and in the jog story it is the watch that dismounts).
pub fn fleet4_jog() -> Fleet {
    Fleet::new(vec![
        Device::new(
            0,
            "earbud",
            DeviceKind::Max78000,
            vec![SensorKind::Microphone],
            vec![InteractionKind::Audio],
        ),
        Device::new(
            1,
            "glasses",
            DeviceKind::Max78000,
            vec![SensorKind::Camera],
            vec![InteractionKind::Display],
        ),
        Device::new(
            2,
            "ring",
            DeviceKind::Max78000,
            vec![SensorKind::Ppg],
            vec![InteractionKind::Haptic, InteractionKind::Led],
        ),
        Device::new(
            3,
            "watch",
            DeviceKind::Max78000,
            vec![SensorKind::Imu, SensorKind::Ppg, SensorKind::Microphone],
            vec![InteractionKind::Display, InteractionKind::Haptic],
        ),
    ])
}

/// The jog scenario: keyword spotting and scene understanding run
/// throughout; a jog-tracker app (IMU on the watch) arrives mid-run, the
/// user docks the watch at t=6 s (the tracker closes just before), and
/// the watch rejoins at t=10 s with the tracker restarting — four
/// incremental replans inside one continuous timeline.
pub fn scenario_jog4() -> CannedScenario {
    let fleet = fleet4_jog();
    let watch = fleet.get(DeviceId(3)).clone();
    let kws = PipelineSpec::new(
        0,
        "keyword-spotting",
        SourceReq::Sensor(SensorKind::Microphone),
        model_by_name(ModelName::KWS).clone(),
        TargetReq::Interaction(InteractionKind::Haptic),
    );
    let scene = PipelineSpec::new(
        1,
        "scene-understanding",
        SourceReq::Sensor(SensorKind::Camera),
        model_by_name(ModelName::UNet).clone(),
        TargetReq::Interaction(InteractionKind::Display),
    );
    let jog_tracker = |id: usize| {
        PipelineSpec::new(
            id,
            "jog-tracker",
            SourceReq::Sensor(SensorKind::Imu),
            model_by_name(ModelName::ConvNet5).clone(),
            TargetReq::Interaction(InteractionKind::Haptic),
        )
    };
    let scenario = Scenario::new()
        .at(0.0)
        .register_with_qos(kws, Qos { min_rate_hz: 2.0, ..Qos::default() })
        .at(0.0)
        .register(scene)
        .at(1.5)
        .register(jog_tracker(2))
        .at(5.5)
        .unregister(PipelineId(2))
        .at(6.0)
        .device_left(3)
        .at(10.0)
        .device_joined(watch)
        .at(10.5)
        .register(jog_tracker(3))
        .until(14.0);
    CannedScenario { name: "jog", fleet, scenario }
}

/// The large-fleet churn scenario: all eight Table I apps arrive
/// staggered on an eight-wearable fleet (endpoints distributed across the
/// first seven devices so the suffix device is free to churn), the
/// highest-id wearable drops off mid-run and later rejoins. Pair with
/// bounded plan search ([`crate::orchestrator::Synergy::planner_bounded`]).
pub fn scenario_churn8() -> CannedScenario {
    let fleet = fleet8();
    let rejoin = fleet.get(DeviceId(7)).clone();
    let mut scenario = Scenario::new();
    for (i, spec) in workload_mixed8(7).pipelines.into_iter().enumerate() {
        scenario = scenario.at(0.25 * i as f64).register(spec);
    }
    let scenario = scenario
        .at(5.0)
        .device_left(7)
        .at(8.0)
        .device_joined(rejoin)
        .until(11.0);
    CannedScenario { name: "churn8", fleet, scenario }
}

/// The bursty contextual-AI scenario: short-lived app bursts arriving and
/// departing in waves on the eight-wearable fleet — two always-on apps,
/// then a three-app burst (a context window opening), a quiet valley, a
/// second two-app burst, and a wind-down. Every id is used once, so
/// replays are deterministic; endpoints stay within d0..d6. Pair with
/// bounded plan search ([`crate::orchestrator::Synergy::planner_bounded`]).
pub fn scenario_bursty8() -> CannedScenario {
    let fleet = fleet8();
    let scenario = Scenario::new()
        // Always-on base load.
        .at(0.0)
        .register(pipeline(0, ModelName::KWS, 0, 3))
        .at(0.5)
        .register(pipeline(1, ModelName::SimpleNet, 1, 2))
        // Burst one: a context window opens, three apps pile on.
        .at(2.0)
        .register(pipeline(2, ModelName::ConvNet5, 4, 5))
        .at(2.25)
        .register(pipeline(3, ModelName::ResSimpleNet, 5, 6))
        .at(2.5)
        .register(pipeline(4, ModelName::WideNet, 2, 0))
        // The burst drains almost as fast as it arrived.
        .at(4.0)
        .unregister(PipelineId(2))
        .at(4.25)
        .unregister(PipelineId(3))
        .at(4.5)
        .unregister(PipelineId(4))
        // Burst two, different mix.
        .at(6.0)
        .register(pipeline(5, ModelName::ConvNet5, 6, 4))
        .at(6.5)
        .register(pipeline(6, ModelName::SimpleNet, 3, 1))
        // Wind-down.
        .at(8.0)
        .unregister(PipelineId(5))
        .at(8.5)
        .unregister(PipelineId(6))
        .until(10.0);
    CannedScenario { name: "bursty8", fleet, scenario }
}

/// The battery-driven departure cascade: four always-on apps whose
/// endpoints live on the first body band (d0–d3), batteries declared on
/// the whole second band (d4–d7) with staggered capacities. Every
/// depletion is an *exact* timeline event (no poll quantization): the
/// suffix wearable drains dry, departs, the replan shifts its load onto
/// the survivors — raising their modeled draw and *accelerating* the next
/// depletion — until the second band is gone and the apps run on d0–d3
/// alone. Runs identically on the simulator and the streaming serve path
/// (`synergy scenario --name cascade8` / `synergy serve --scenario
/// cascade8`); pair with bounded plan search.
pub fn scenario_cascade8() -> CannedScenario {
    let fleet = fleet8();
    let scenario = Scenario::new()
        .at(0.0)
        .register(pipeline(0, ModelName::KWS, 0, 3))
        .at(0.0)
        .register(pipeline(1, ModelName::SimpleNet, 1, 2))
        .at(0.0)
        .register(pipeline(2, ModelName::ConvNet5, 2, 0))
        .at(0.0)
        .register(pipeline(3, ModelName::ResSimpleNet, 3, 1))
        // Staggered capacities: the suffix device always depletes first,
        // and each departure concentrates load on the rest.
        .battery(DeviceId(7), 0.5)
        .battery(DeviceId(6), 0.9)
        .battery(DeviceId(5), 1.4)
        .battery(DeviceId(4), 2.0)
        .until(10.0);
    CannedScenario { name: "cascade8", fleet, scenario }
}

/// Look up a canned scenario by name (see [`canned_scenario_names`]).
pub fn canned_scenario(name: &str) -> Option<CannedScenario> {
    match name {
        "jog" | "jog4" => Some(scenario_jog4()),
        "churn8" => Some(scenario_churn8()),
        "bursty8" => Some(scenario_bursty8()),
        "cascade8" => Some(scenario_cascade8()),
        _ => None,
    }
}

/// Valid canned-scenario names (CLI help and error messages).
pub fn canned_scenario_names() -> &'static str {
    "jog, churn8, bursty8, cascade8"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet4_roles_and_capabilities() {
        let f = fleet4();
        assert_eq!(f.len(), 4);
        assert_eq!(f.get(DeviceId(0)).name, "earbud");
        assert!(f.get(DeviceId(0)).has_sensor(SensorKind::Microphone));
        assert!(f.get(DeviceId(1)).has_sensor(SensorKind::Camera));
        assert!(f.get(DeviceId(3)).has_interaction(InteractionKind::Haptic));
    }

    #[test]
    fn hetero_fleet_upgrades_watch() {
        let f = fleet4_hetero();
        assert_eq!(f.get(DeviceId(2)).spec.kind, DeviceKind::Max78002);
        assert_eq!(f.get(DeviceId(0)).spec.kind, DeviceKind::Max78000);
    }

    #[test]
    fn workloads_match_table1_assignment() {
        let w1 = workload(1).unwrap();
        assert_eq!(w1.pipelines.len(), 3);
        assert_eq!(w1.pipelines[0].name, "ConvNet5");
        let w2 = workload(2).unwrap();
        assert_eq!(w2.pipelines[0].name, "KWS");
        assert_eq!(
            w2.pipelines[0].source,
            SourceReq::Device(DeviceId(0)),
            "KWS captures on the earbud"
        );
        assert_eq!(w2.pipelines[0].target, TargetReq::Device(DeviceId(3)));
        let w4 = workload(4).unwrap();
        assert_eq!(w4.pipelines.len(), 1);
        assert_eq!(w4.pipelines[0].name, "MobileNetV2");
    }

    #[test]
    fn unknown_workload_is_a_typed_error_listing_valid_ids() {
        // Regression: the seed panicked with `no workload 9` instead of
        // returning a typed error the CLI can surface.
        let err = workload(9).unwrap_err();
        assert!(
            matches!(err, RuntimeError::UnknownWorkload { id: 9, .. }),
            "{err:?}"
        );
        let msg = format!("{err}");
        for id in WORKLOAD_IDS {
            assert!(msg.contains(&format!("Workload {id}")), "{msg}");
        }
        assert!(workload(0).is_err());
    }

    #[test]
    fn large_fleets_have_the_advertised_shapes() {
        let f8 = fleet8();
        assert_eq!(f8.len(), 8);
        assert!(f8
            .devices
            .iter()
            .all(|d| d.spec.kind == DeviceKind::Max78000));
        assert_eq!(f8.get(DeviceId(4)).name, "earbud2");
        let f12 = fleet12_hetero();
        assert_eq!(f12.len(), 12);
        let fast = f12
            .devices
            .iter()
            .filter(|d| d.spec.kind == DeviceKind::Max78002)
            .count();
        assert_eq!(fast, 4, "every third wearable is upgraded");
        assert_eq!(f12.accel_ids().len(), 12);
    }

    #[test]
    fn mixed8_covers_all_table1_models_with_valid_endpoints() {
        let w = workload_mixed8(8);
        assert_eq!(w.pipelines.len(), 8);
        for (p, m) in w.pipelines.iter().zip(ModelName::TABLE1) {
            assert_eq!(p.name, m.as_str());
            match (p.source, p.target) {
                (SourceReq::Device(s), TargetReq::Device(t)) => {
                    assert!(s.0 < 8 && t.0 < 8);
                }
                other => panic!("distributed endpoints expected, got {other:?}"),
            }
        }
    }

    #[test]
    fn phone_fleet_has_five_devices() {
        let f = fleet4_with_phone();
        assert_eq!(f.len(), 5);
        assert_eq!(f.get(DeviceId(4)).spec.kind, DeviceKind::Phone);
    }

    #[test]
    fn mapping_variants() {
        let models = [ModelName::ConvNet5, ModelName::ResSimpleNet, ModelName::UNet];
        let over = pipelines_with_mapping(&models, EndpointMapping::Overlapped, 4);
        assert!(over
            .iter()
            .all(|p| p.source == SourceReq::Device(DeviceId(0))
                && p.target == TargetReq::Device(DeviceId(0))));
        let dist = pipelines_with_mapping(&models, EndpointMapping::Distributed, 4);
        let sources: Vec<_> = dist.iter().map(|p| p.source).collect();
        assert_eq!(sources.len(), 3);
        assert_ne!(sources[0], sources[1]);
        let any = pipelines_with_mapping(&models, EndpointMapping::Any, 4);
        assert!(any.iter().all(|p| p.source == SourceReq::Any));
    }

    #[test]
    fn larger_fleets_get_numbered_roles() {
        let f = fleet_n(5);
        assert_eq!(f.get(DeviceId(4)).name, "earbud2");
    }

    #[test]
    fn canned_scenarios_are_well_formed() {
        for name in ["jog", "churn8", "bursty8", "cascade8"] {
            let c = canned_scenario(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(c.scenario.duration() > 0.0, "{name}");
            assert!(!c.scenario.events().is_empty(), "{name}");
            assert!(c.fleet.len() >= 4, "{name}");
        }
        assert!(canned_scenario("nope").is_none());
        // The jog fleet puts the watch last so it can dismount mid-run.
        let jog = scenario_jog4();
        assert_eq!(jog.fleet.get(DeviceId(3)).name, "watch");
        assert!(jog.fleet.get(DeviceId(3)).has_sensor(SensorKind::Imu));
    }

    #[test]
    fn cascade8_arms_the_whole_second_band_with_staggered_capacities() {
        let c = scenario_cascade8();
        assert_eq!(c.fleet.len(), 8);
        let batteries = c.scenario.batteries();
        assert_eq!(batteries.len(), 4);
        // Batteries cover exactly d4..d7, capacities ascending as ids
        // descend — the suffix always dries out first.
        let mut by_dev: Vec<(usize, f64)> =
            batteries.iter().map(|&(d, cap, _)| (d.0, cap)).collect();
        by_dev.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(by_dev.iter().map(|&(d, _)| d).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        assert!(by_dev.windows(2).all(|w| w[0].1 > w[1].1), "{by_dev:?}");
        // Every app endpoint stays on the first band, so all four suffix
        // departures replan cleanly.
        for ev in c.scenario.events() {
            if let crate::api::ScenarioAction::Register { spec, .. } = &ev.action {
                match (spec.source, spec.target) {
                    (SourceReq::Device(s), TargetReq::Device(t)) => {
                        assert!(s.0 < 4 && t.0 < 4, "{spec:?}");
                    }
                    other => panic!("pinned endpoints expected, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn bursty8_bursts_arrive_and_depart_in_waves() {
        use crate::api::ScenarioAction;
        let c = scenario_bursty8();
        assert_eq!(c.fleet.len(), 8);
        let evs = c.scenario.events().to_vec();
        let registers = evs
            .iter()
            .filter(|e| matches!(e.action, ScenarioAction::Register { .. }))
            .count();
        let unregisters = evs
            .iter()
            .filter(|e| matches!(e.action, ScenarioAction::Unregister(_)))
            .count();
        assert_eq!(registers, 7);
        assert_eq!(unregisters, 5, "both bursts fully drain");
        // Ids are single-use, so replays never alias apps.
        let mut ids: Vec<usize> = evs
            .iter()
            .filter_map(|e| match &e.action {
                ScenarioAction::Register { spec, .. } => Some(spec.id.0),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), registers);
    }

    #[test]
    fn every_workload_plans_on_its_paper_fleet() {
        // Each Table I workload must be orchestratable by Synergy on the
        // four-device setup the paper evaluates it with.
        use crate::orchestrator::{Planner, Synergy};
        let f = fleet4();
        for w in all_workloads() {
            let plan = Synergy::planner()
                .plan(&w.pipelines, &f)
                .unwrap_or_else(|e| panic!("{}: {e:?}", w.name));
            plan.check_runnable(&w.pipelines, &f).unwrap();
        }
    }
}
